//! Offline stand-in for the `anyhow` crate.
//!
//! The build sandbox has no crates.io access, so this vendored crate
//! provides the subset of `anyhow` 1.x the repo uses: [`Error`] (with a
//! context chain), [`Result`], the [`Context`] extension trait for
//! `Result` and `Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros. `{e}` prints the outermost message, `{e:#}` the full chain
//! joined by `: `, and `{e:?}` an `anyhow`-style "Caused by" listing.
//! Swapping in the real crate is a one-line Cargo.toml change.

use std::error::Error as StdError;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost message plus the chain of causes
/// (outermost first). Context added via [`Context`] prepends entries.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message (like `anyhow::Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that is what keeps the blanket `From` below coherent (same trick as
// the real anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return ::core::result::Result::Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("middle")
            .unwrap_err()
            .context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root cause");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "root cause");
    }
}
