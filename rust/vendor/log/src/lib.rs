//! Offline stand-in for the `log` crate facade.
//!
//! The build sandbox has no crates.io access, so this vendored crate
//! provides the exact subset of the `log` 0.4 API the repo uses:
//! `Level`, `LevelFilter`, `Log`, `Record`, `Metadata`, `set_logger`,
//! `set_max_level`, and the `error!`/`warn!`/`info!`/`debug!`/`trace!`
//! macros. Swapping in the real crate is a one-line Cargo.toml change;
//! no source edits are required.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single log record (`Error` is most severe).
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Global verbosity ceiling (`Off` disables everything).
#[repr(usize)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a log record (just the level in this subset).
#[derive(Debug, Clone, Copy)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log record: level + preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }
}

/// A log sink. Implementors are installed once via [`set_logger`].
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }
    fn log(&self, _: &Record<'_>) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already installed")
    }
}

impl std::error::Error for SetLoggerError {}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __private_api_log(level: Level, args: fmt::Arguments<'_>) {
    if (level as usize) <= MAX_LEVEL.load(Ordering::Relaxed) {
        let record = Record {
            metadata: Metadata { level },
            args,
        };
        let l = logger();
        if l.enabled(record.metadata()) {
            l.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => ($crate::__private_api_log($crate::Level::Error, format_args!($($arg)+)))
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => ($crate::__private_api_log($crate::Level::Warn, format_args!($($arg)+)))
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => ($crate::__private_api_log($crate::Level::Info, format_args!($($arg)+)))
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => ($crate::__private_api_log($crate::Level::Debug, format_args!($($arg)+)))
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => ($crate::__private_api_log($crate::Level::Trace, format_args!($($arg)+)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_against_filter() {
        assert!((Level::Error as usize) <= (LevelFilter::Info as usize));
        assert!((Level::Debug as usize) > (LevelFilter::Info as usize));
    }

    #[test]
    fn default_logger_is_silent() {
        // No logger installed in this test binary: must not panic.
        __private_api_log(Level::Error, format_args!("dropped"));
    }
}
