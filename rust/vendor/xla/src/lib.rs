//! Offline stub of the `xla` crate (PJRT C-API bindings).
//!
//! The build sandbox has neither crates.io access nor a PJRT shared
//! library, so this vendored crate splits the API the repo uses into
//! two tiers:
//!
//! - **Host tier (fully functional):** [`Literal`] — construction
//!   (`vec1`, `scalar`, `tuple`), `reshape`, `to_vec`,
//!   `get_first_element`, `array_shape`, `to_tuple`. Everything in the
//!   coordinator's host path (flat parameter bus, outer optimizer,
//!   broadcast dedup, sweep store) runs for real against this tier, so
//!   the full test suite exercises genuine data movement.
//! - **Device tier (gated):** `PjRtClient` / compilation / execution
//!   return a descriptive error. Callers already skip gracefully when
//!   artifacts are absent; with real PJRT bindings substituted in
//!   Cargo.toml the same call sites execute lowered HLO unchanged.
//!
//! Like the real bindings, `vec1`/`scalar` copy host data into the
//! literal and `to_vec` copies it back out — so host-path benchmarks
//! measure genuine per-byte transfer costs, not no-ops.
//!
//! **Thread safety:** every type here is `Send + Sync` (plain owned
//! buffers, no interior mutability), matching the real bindings:
//! PJRT clients and loaded executables are thread-safe per client, and
//! literals are immutable once constructed. The coordinator's
//! replica-parallel worker pool relies on this — executables and
//! literals are shared across worker threads as `Arc`s — so the
//! contract is pinned by compile-time assertions below.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB: &str = "xla stub backend (rust/vendor/xla): PJRT execution is unavailable \
offline; point Cargo.toml's `xla` dependency at real PJRT bindings to run lowered artifacts";

/// Element storage for one literal. Public only so [`NativeType`] can
/// name it in its (doc-hidden) plumbing methods.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

impl Buf {
    fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
            Buf::U32(v) => v.len(),
            Buf::Tuple(v) => v.len(),
        }
    }

    fn dtype_name(&self) -> &'static str {
        match self {
            Buf::F32(_) => "f32",
            Buf::I32(_) => "i32",
            Buf::U32(_) => "u32",
            Buf::Tuple(_) => "tuple",
        }
    }
}

/// Native element types a [`Literal`] can carry.
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn into_buf(data: Vec<Self>) -> Buf;
    #[doc(hidden)]
    fn from_buf(buf: &Buf) -> Option<Vec<Self>>;
    #[doc(hidden)]
    fn slice_from(buf: &Buf) -> Option<&[Self]>;
}

macro_rules! native {
    ($t:ty, $variant:ident) => {
        impl NativeType for $t {
            fn into_buf(data: Vec<Self>) -> Buf {
                Buf::$variant(data)
            }
            fn from_buf(buf: &Buf) -> Option<Vec<Self>> {
                match buf {
                    Buf::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
            fn slice_from(buf: &Buf) -> Option<&[Self]> {
                match buf {
                    Buf::$variant(v) => Some(v),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(i32, I32);
native!(u32, U32);

/// A host-side XLA literal: dims + typed element buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    buf: Buf,
}

impl Literal {
    /// Rank-1 literal copying the given host slice (as the real
    /// bindings do).
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            buf: T::into_buf(data.to_vec()),
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal {
            dims: Vec::new(),
            buf: T::into_buf(vec![value]),
        }
    }

    /// A tuple literal (what executables return under `return_tuple`).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal {
            dims: Vec::new(),
            buf: Buf::Tuple(elements),
        }
    }

    pub fn element_count(&self) -> usize {
        self.buf.len()
    }

    /// Same data, new dims; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.buf, Buf::Tuple(_)) {
            return Err(Error("reshape: literal is a tuple".into()));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.buf.len() {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {dims:?}",
                self.buf.len()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            buf: self.buf.clone(),
        })
    }

    /// Copy the elements back out to a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_buf(&self.buf)
            .ok_or_else(|| Error(format!("to_vec: literal is {}", self.buf.dtype_name())))
    }

    /// Borrow the elements without copying — the zero-copy wire
    /// serializer reads bit patterns straight from here instead of
    /// staging through `to_vec`. (Real bindings expose the backing
    /// buffer via `untyped_data`; same two-line shim as `to_slice`.)
    pub fn as_slice<T: NativeType>(&self) -> Result<&[T]> {
        T::slice_from(&self.buf)
            .ok_or_else(|| Error(format!("as_slice: literal is {}", self.buf.dtype_name())))
    }

    /// Copy the elements into a caller-provided slice — the
    /// allocation-free read-back the flat parameter bus uses on the
    /// sync hot path. (Real bindings expose the same read via
    /// `to_vec`; adapting this one call is a two-line shim.)
    pub fn to_slice<T: NativeType>(&self, dst: &mut [T]) -> Result<()> {
        let src = T::slice_from(&self.buf)
            .ok_or_else(|| Error(format!("to_slice: literal is {}", self.buf.dtype_name())))?;
        if src.len() != dst.len() {
            return Err(Error(format!(
                "to_slice: literal has {} elements, destination {}",
                src.len(),
                dst.len()
            )));
        }
        dst.copy_from_slice(src);
        Ok(())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("get_first_element: empty literal".into()))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        if matches!(self.buf, Buf::Tuple(_)) {
            return Err(Error("array_shape: literal is a tuple".into()));
        }
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.buf {
            Buf::Tuple(v) => Ok(v),
            other => Err(Error(format!("to_tuple: literal is {}", other.dtype_name()))),
        }
    }
}

/// Dims of an array (non-tuple) literal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---- device tier (gated: descriptive errors in the stub) --------------

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(STUB.into()))
    }

    pub fn platform_name(&self) -> String {
        "stub-host".into()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB.into()))
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error(STUB.into()))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(STUB.into()))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB.into()))
    }
}

/// Compile-time pin of the thread-safety contract the coordinator's
/// worker pool depends on (real PJRT bindings satisfy the same bounds).
#[allow(dead_code)]
fn _assert_send_sync() {
    fn ok<T: Send + Sync>() {}
    ok::<Literal>();
    ok::<ArrayShape>();
    ok::<PjRtClient>();
    ok::<PjRtLoadedExecutable>();
    ok::<PjRtBuffer>();
    ok::<HloModuleProto>();
    ok::<XlaComputation>();
    ok::<Error>();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_first_element() {
        assert_eq!(Literal::scalar(7u32).get_first_element::<u32>().unwrap(), 7);
        assert_eq!(Literal::scalar(2.5f32).get_first_element::<f32>().unwrap(), 2.5);
    }

    #[test]
    fn reshape_checks_count() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::scalar(1i32), Literal::scalar(2i32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].get_first_element::<i32>().unwrap(), 2);
    }

    #[test]
    fn device_tier_is_gated() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
