//! Coordinator invariants that pin Algorithm 1's semantics, exercised
//! through the full runtime path (real artifacts, real PJRT execution)
//! on tiny token budgets. Skipped gracefully when artifacts are absent.

use std::path::Path;
use std::sync::Arc;

use diloco::config::RepoConfig;
use diloco::coordinator::{run, Algo, RunConfig};
use diloco::runtime::{ModelRuntime, Runtime};

fn setup() -> Option<(RepoConfig, Arc<Runtime>)> {
    let repo = RepoConfig::load(Path::new(env!("CARGO_MANIFEST_DIR"))).ok()?;
    if !repo.model_dir("m0").join("manifest.json").is_file() {
        eprintln!("skipping: artifacts missing (make artifacts)");
        return None;
    }
    Some((repo, Runtime::cpu().ok()?))
}

fn quick(algo: Algo, seed: u64) -> RunConfig {
    RunConfig {
        algo,
        global_batch_seqs: 8,
        sync_every: 5,
        // multiple of the batch (8*64=512 tokens) so step counts are exact
        token_budget: Some(20_480),
        inner_lr: 4e-3,
        outer_lr: 1.0,
        seed,
        eval_tokens: 4096,
        log_every: 1000,
        ..Default::default()
    }
}

#[test]
fn determinism_same_seed_same_loss() {
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let a = run(&mr, &repo.optimizer, &quick(Algo::DiLoCo { replicas: 2 }, 3)).unwrap();
    let b = run(&mr, &repo.optimizer, &quick(Algo::DiLoCo { replicas: 2 }, 3)).unwrap();
    assert_eq!(a.final_eval_loss, b.final_eval_loss);
    assert_eq!(a.final_train_loss, b.final_train_loss);
    let c = run(&mr, &repo.optimizer, &quick(Algo::DiLoCo { replicas: 2 }, 4)).unwrap();
    assert_ne!(a.final_eval_loss, c.final_eval_loss);
}

#[test]
fn replica_parallel_workers_bit_identical_to_sequential() {
    // The worker pool must not change training at all: same config with
    // --workers 1 (sequential oracle) and --workers 4 produces
    // bit-identical losses, curves, and sync counts through the full
    // PJRT path. (The host-tier twin of this test, which runs without
    // artifacts, is tests/worker_pool.rs.)
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let mut cfg = quick(Algo::DiLoCo { replicas: 4 }, 13);
    cfg.eval_every = Some(8);
    cfg.workers = 1;
    let seq = run(&mr, &repo.optimizer, &cfg).unwrap();
    cfg.workers = 4;
    let par = run(&mr, &repo.optimizer, &cfg).unwrap();
    assert_eq!(seq.final_eval_loss, par.final_eval_loss);
    assert_eq!(seq.final_train_loss, par.final_train_loss);
    assert_eq!(seq.loss_curve, par.loss_curve);
    assert_eq!(seq.eval_curve, par.eval_curve);
    assert_eq!(seq.outer_syncs, par.outer_syncs);
}

#[test]
fn diloco_m1_h1_eta1_mu0_equals_data_parallel() {
    // With M=1, H=1, eta=1 and zero outer momentum, the outer step sets
    // global = replica exactly, so DiLoCo degenerates to Data-Parallel
    // (paper section 2.2's comparison, with the momentum term removed).
    let Some((repo, rt)) = setup() else { return };
    let mut policy = repo.optimizer.clone();
    policy.outer_momentum = 0.0;
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let mut dl = quick(Algo::DiLoCo { replicas: 1 }, 7);
    dl.sync_every = 1;
    dl.outer_lr = 1.0;
    let dp = quick(Algo::DataParallel, 7);
    let a = run(&mr, &policy, &dl).unwrap();
    let b = run(&mr, &policy, &dp).unwrap();
    // Not bit-exact: the outer step computes theta - (theta - r) in f32,
    // which can differ from r by an ulp per sync; tolerance covers the
    // accumulated drift over the run.
    assert!(
        (a.final_eval_loss - b.final_eval_loss).abs() < 2e-3,
        "{} vs {}",
        a.final_eval_loss,
        b.final_eval_loss
    );
}

#[test]
fn replica_count_partitions_batch() {
    // Same global batch across M: each setup consumes the same number
    // of tokens and steps (Algorithm 1's accounting).
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let mut metrics = Vec::new();
    for m in [1usize, 2, 4] {
        let cfg = quick(Algo::DiLoCo { replicas: m }, 11);
        metrics.push(run(&mr, &repo.optimizer, &cfg).unwrap());
    }
    for w in metrics.windows(2) {
        assert_eq!(w[0].steps, w[1].steps);
        assert_eq!(w[0].tokens, w[1].tokens);
        assert_eq!(w[0].global_batch_tokens, w[1].global_batch_tokens);
    }
}

#[test]
fn outer_sync_count_follows_cadence() {
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let mut cfg = quick(Algo::DiLoCo { replicas: 2 }, 5);
    cfg.sync_every = 7;
    let m = run(&mr, &repo.optimizer, &cfg).unwrap();
    // floor(T/7) cadence syncs plus a final sync if T % 7 != 0
    let expected = m.steps / 7 + usize::from(m.steps % 7 != 0);
    assert_eq!(m.outer_syncs, expected, "steps={}", m.steps);
}

#[test]
fn overtraining_multiplies_budget() {
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let mut cfg = quick(Algo::DataParallel, 5);
    cfg.overtrain = 2.0;
    let m2 = run(&mr, &repo.optimizer, &cfg).unwrap();
    cfg.overtrain = 1.0;
    let m1 = run(&mr, &repo.optimizer, &cfg).unwrap();
    assert_eq!(m2.steps, 2 * m1.steps);
}

#[test]
fn rejects_indivisible_batch() {
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let mut cfg = quick(Algo::DiLoCo { replicas: 4 }, 5);
    cfg.global_batch_seqs = 6; // not divisible by 4
    assert!(run(&mr, &repo.optimizer, &cfg).is_err());
}

#[test]
fn eval_loss_decreases_with_budget() {
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let mut cfg = quick(Algo::DataParallel, 21);
    cfg.token_budget = Some(8_000);
    let small = run(&mr, &repo.optimizer, &cfg).unwrap();
    cfg.token_budget = Some(120_000);
    let big = run(&mr, &repo.optimizer, &cfg).unwrap();
    assert!(
        big.final_eval_loss < small.final_eval_loss - 0.05,
        "{} vs {}",
        big.final_eval_loss,
        small.final_eval_loss
    );
}
