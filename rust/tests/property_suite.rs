//! Cross-module property tests (seeded kit in util::prop; proptest is
//! unavailable offline). These pin randomized invariants that the unit
//! tests only spot-check.

use diloco::netsim::walltime::{walltime, WalltimeAlgo, WalltimeInput};
use diloco::netsim::{HIGH, LOW, MEDIUM};
use diloco::runtime::decompose_micro;
use diloco::scaling::optimal_batch_log2;
use diloco::train::schedule::LrSchedule;
use diloco::util::json::Json;
use diloco::util::prop::{check, close};
use diloco::util::rng::Rng;

#[test]
fn prop_schedule_bounded_and_peaks_at_warmup() {
    check(
        0x5CED,
        128,
        |rng: &mut Rng| {
            let peak = rng.range_f64(1e-5, 1.0);
            let total = 2 + rng.below(5000) as usize;
            (peak, total)
        },
        |&(peak, total)| {
            let s = LrSchedule::new(peak, total, 0.1, 1000, 0.05);
            let mut max_seen: f64 = 0.0;
            for t in 1..=total {
                let lr = s.lr(t);
                if !(lr > 0.0 && lr <= peak * (1.0 + 1e-12)) {
                    return Err(format!("lr {lr} out of (0, {peak}] at t={t}"));
                }
                max_seen = max_seen.max(lr);
            }
            close(max_seen, peak, 1e-9)?;
            close(s.lr(total), peak * 0.05, 1e-9)
        },
    );
}

#[test]
fn prop_decompose_micro_sums_to_total() {
    check(
        0xDEC0,
        256,
        |rng: &mut Rng| {
            // sizes like the real manifests: {8,1} or {8,4,1} etc.
            let total = rng.below(200) as usize;
            let sizes = match rng.below(3) {
                0 => vec![8usize, 1],
                1 => vec![8usize, 4, 1],
                _ => vec![16usize, 8, 1],
            };
            (total, sizes)
        },
        |(total, sizes)| {
            let plan = decompose_micro(*total, sizes).map_err(|e| e.to_string())?;
            if plan.iter().sum::<usize>() != *total {
                return Err(format!("plan {plan:?} != total {total}"));
            }
            // greedy: plan must be non-increasing
            if plan.windows(2).any(|w| w[1] > w[0]) {
                return Err(format!("plan not sorted: {plan:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth >= 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => {
                // mix of integers, fractions, negatives, exponents
                let v = match rng.below(3) {
                    0 => rng.below(1_000_000) as f64,
                    1 => rng.normal() * 1e-3,
                    _ => -(rng.f64() * 1e12),
                };
                Json::Num(v)
            }
            3 => {
                let chars = ["a", "\"", "\\", "\n", "é", "😀", "\t", "x", "\u{1}"];
                let s: String = (0..rng.below(12))
                    .map(|_| chars[rng.below(chars.len() as u64) as usize])
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr(
                (0..rng.below(5))
                    .map(|_| random_value(rng, depth + 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    check(
        0x15A0,
        256,
        |rng: &mut Rng| random_value(rng, 0),
        |v| {
            for text in [v.to_string_compact(), v.to_string_pretty()] {
                let back = Json::parse(&text).map_err(|e| e.to_string())?;
                if &back != v {
                    return Err(format!("roundtrip mismatch: {v} -> {text} -> {back}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_batch_interpolation_within_grid() {
    check(
        0xBA7C,
        128,
        |rng: &mut Rng| {
            let k = 3 + rng.below(4) as usize;
            let opt = rng.range_f64(9.0, 9.0 + k as f64 - 1.0);
            let pts: Vec<(f64, f64)> = (0..k)
                .map(|i| {
                    let l = 9.0 + i as f64;
                    (2f64.powf(l), (l - opt) * (l - opt) + 2.0)
                })
                .collect();
            (pts, opt)
        },
        |(pts, opt)| {
            let got = optimal_batch_log2(pts).map_err(|e| e.to_string())?;
            close(got, *opt, 1e-6)?;
            let lo = pts.first().unwrap().0.log2();
            let hi = pts.last().unwrap().0.log2();
            if got < lo - 1e-9 || got > hi + 1e-9 {
                return Err(format!("{got} outside [{lo}, {hi}]"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_walltime_diloco_comm_monotone_in_h_and_bandwidth() {
    check(
        0x7A11,
        96,
        |rng: &mut Rng| {
            let params = rng.range_f64(1e7, 1e11);
            let batch = 2f64.powi(14 + rng.below(8) as i32);
            let m = [2usize, 4, 8][rng.below(3) as usize];
            (params, batch, m)
        },
        |&(params, batch, m)| {
            let mk = |h: usize, net| {
                walltime(&WalltimeInput {
                    algo: WalltimeAlgo::DiLoCo {
                        replicas: m,
                        sync_every: h,
                    },
                    params,
                    tokens: 20.0 * params,
                    batch_tokens: batch,
                    cross_dc: net,
                    outer_bits: diloco::netsim::walltime::BITS_PER_PARAM,
                    outer_bits_down: diloco::netsim::walltime::BITS_PER_PARAM,
                    overlap_tau: 0.0,
                    churn: None,
                })
            };
            // comm decreases as H grows
            let mut prev = f64::INFINITY;
            for h in [1usize, 10, 100, 1000] {
                let c = mk(h, LOW).comm_s;
                if c > prev + 1e-9 {
                    return Err(format!("comm not monotone in H at {h}"));
                }
                prev = c;
            }
            // comm decreases with better networks
            let (l, m_, h) = (mk(30, LOW), mk(30, MEDIUM), mk(30, HIGH));
            if !(l.comm_s >= m_.comm_s && m_.comm_s >= h.comm_s) {
                return Err("comm not monotone in bandwidth".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_utilization_bounded_and_monotone() {
    use diloco::netsim::utilization::{SimAlgo, SimModel, ARCHETYPES};
    check(
        0xC0,
        96,
        |rng: &mut Rng| {
            let arch = rng.below(3) as usize;
            let h = [1usize, 10, 50, 100, 300][rng.below(5) as usize];
            (arch, h)
        },
        |&(arch, h)| {
            let m = SimModel::default();
            let a = &ARCHETYPES[arch];
            let mut prev = 0.0;
            for w in diloco::netsim::utilization::bandwidth_grid_gbps() {
                let cu = m.utilization(a, SimAlgo::DiLoCo { sync_every: h }, w);
                if !(0.0..=1.0).contains(&cu) {
                    return Err(format!("CU {cu} out of [0,1]"));
                }
                if cu + 1e-12 < prev {
                    return Err("CU not monotone in bandwidth".into());
                }
                prev = cu;
            }
            Ok(())
        },
    );
}
