//! Streaming DiLoCo invariants (paper section 8 / Appendix A): the
//! fragmented outer sync must degenerate to vanilla DiLoCo at P=1, keep
//! per-sync traffic at 1/P, and flush all fragments by the end of
//! training. Runs through the full PJRT path on tiny budgets.

use std::path::Path;

use diloco::config::RepoConfig;
use diloco::coordinator::{run, Algo, RunConfig};
use diloco::runtime::{ModelRuntime, Runtime};

fn setup() -> Option<(RepoConfig, std::sync::Arc<Runtime>)> {
    let repo = RepoConfig::load(Path::new(env!("CARGO_MANIFEST_DIR"))).ok()?;
    if !repo.model_dir("m0").join("manifest.json").is_file() {
        eprintln!("skipping: artifacts missing (make artifacts)");
        return None;
    }
    Some((repo, Runtime::cpu().ok()?))
}

fn cfg(fragments: usize, h: usize) -> RunConfig {
    RunConfig {
        algo: Algo::DiLoCo { replicas: 2 },
        global_batch_seqs: 8,
        sync_every: h,
        token_budget: Some(20_480),
        inner_lr: 4e-3,
        outer_lr: 0.8,
        seed: 9,
        eval_tokens: 4096,
        log_every: 1000,
        streaming_fragments: fragments,
        ..Default::default()
    }
}

#[test]
fn p2_full_flush_schedule_is_exactly_vanilla() {
    // A genuinely distinct config pair that provably coincides: with H
    // larger than the whole run, both P=1 and P=2 schedules collapse
    // to a single full-flush sync at the final step (due_fragment is
    // None at t = total_steps), so the fragmented run must reproduce
    // vanilla bit for bit — schedule, losses, evals, and wire bytes.
    // (The retired version of this test compared cfg(1, 10) against
    // itself, which could never fail.)
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let vanilla = run(&mr, &repo.optimizer, &cfg(1, 10_000)).unwrap();
    let streamed = run(&mr, &repo.optimizer, &cfg(2, 10_000)).unwrap();
    assert_eq!(vanilla.outer_syncs, 1, "one final full flush");
    assert_eq!(streamed.outer_syncs, 1, "P=2 with H > T is also one full flush");
    assert_eq!(vanilla.final_eval_loss, streamed.final_eval_loss);
    assert_eq!(vanilla.final_train_loss, streamed.final_train_loss);
    assert_eq!(vanilla.loss_curve, streamed.loss_curve);
    assert_eq!(vanilla.eval_curve, streamed.eval_curve);
    assert_eq!(vanilla.wire_up_bytes, streamed.wire_up_bytes);
    assert_eq!(vanilla.wire_down_bytes, streamed.wire_down_bytes);
    // and the metrics faithfully record the differing fragment counts
    assert_eq!(vanilla.fragments, 1);
    assert_eq!(streamed.fragments, 2);
}

#[test]
fn fragments_sync_p_times_more_often() {
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let v = run(&mr, &repo.optimizer, &cfg(1, 10)).unwrap();
    let s = run(&mr, &repo.optimizer, &cfg(5, 10)).unwrap();
    // P=5, H=10 -> a fragment sync every 2 steps: ~5x the sync events,
    // each carrying 1/5 of the parameters (same total traffic).
    assert!(
        s.outer_syncs >= 4 * v.outer_syncs,
        "streamed {} vs vanilla {}",
        s.outer_syncs,
        v.outer_syncs
    );
}

#[test]
fn streaming_trains_comparably() {
    // Streaming amortizes the same communication; its loss should land
    // near vanilla DiLoCo's (paper: "does not reduce total
    // communication", quality preserved).
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    let v = run(&mr, &repo.optimizer, &cfg(1, 10)).unwrap();
    let s = run(&mr, &repo.optimizer, &cfg(2, 10)).unwrap();
    assert!(
        (s.final_eval_loss - v.final_eval_loss).abs() < 0.15,
        "streamed {} vs vanilla {}",
        s.final_eval_loss,
        v.final_eval_loss
    );
    // 20k tokens only moves init loss (ln 512 = 6.24) a few tenths;
    // this is a comparability check, not a convergence check.
    assert!(s.final_eval_loss < 6.15, "did not train: {}", s.final_eval_loss);
}

#[test]
fn rejects_non_dividing_fragments() {
    let Some((repo, rt)) = setup() else { return };
    let mr = ModelRuntime::load(rt, &repo.model_dir("m0")).unwrap();
    assert!(run(&mr, &repo.optimizer, &cfg(3, 10)).is_err());
}
