//! Elastic membership + checkpoint/resume oracles (the ticked
//! coordinator of `coordinator::pool::drive_ctl`):
//!
//! (1) **the churn-free control path is the old drive loop, bit for
//!     bit**: `drive_ctl` with `DriveCtl::fresh` (and with the empty
//!     `FaultPlan` resolved to zero events) must replay `drive`
//!     exactly — losses, evals, sync counts, the global arena, final
//!     replica payloads, and both wire legs.
//! (2) **checkpoint + resume is bit-identical to the uninterrupted
//!     run** for every (up, down) codec pair at τ=0 and τ>0, with the
//!     checkpoint pushed through its JSON serialization both ways —
//!     what `diloco checkpoint` writes is what `diloco resume` reads.
//! (3) **fault schedules replay across a resume**: a crash scheduled
//!     after the checkpoint boundary fires identically in the resumed
//!     run, keyed to the absolute outer-sync index.
//! (4) **survivor trajectories after a mid-segment death are
//!     bit-identical at workers 1 vs 2 vs 4**, diverge from the
//!     churn-free run only after the death, and freeze the dead
//!     replica at its death state.
//! (5) **joiners come alive at an outer boundary** initialized from
//!     the broadcast view, under identity and lossy up-wires alike,
//!     scheduling-independently.
//!
//! Host tier only: no PJRT, no artifacts.

use std::sync::Arc;

use diloco::comm::{codec_for, OuterBits};
use diloco::coordinator::{
    drive, drive_ctl, Checkpoint, DriveCtl, DrivePlan, EventKind, FaultEvent, FaultKind,
    FaultPlan, InnerEngine, OuterSync, ReplicaState,
};
use diloco::data::synthetic::{CorpusSpec, TokenStream};
use diloco::runtime::{FlatLayout, HostTensor};
use diloco::util::json::Json;

// ---- the deterministic host-math engine (same as the pool twins) -----

struct ToyEngine {
    n: usize,
}

impl InnerEngine for ToyEngine {
    fn inner_step(
        &self,
        rep: usize,
        replica: &mut ReplicaState,
        t: usize,
    ) -> anyhow::Result<f64> {
        let toks = replica.shard.next_batch(2, 8);
        let mut loss = 0.0f64;
        for leaf in 0..self.n {
            let lit = &replica.state[leaf];
            let dims = lit.array_shape()?.dims().to_vec();
            let mut v = lit.to_vec::<f32>()?;
            for (i, x) in v.iter_mut().enumerate() {
                *x = 0.5 * *x
                    + 1e-3 * toks[(i + t) % toks.len()] as f32
                    + 1e-2 * (t as f32 + rep as f32 * 0.25).sin();
            }
            loss += v.iter().map(|&f| f as f64).sum::<f64>() / v.len() as f64;
            replica.state[leaf] = Arc::new(xla::Literal::vec1(&v).reshape(&dims)?);
        }
        Ok(loss / self.n as f64)
    }

    fn eval(&self, params: &[Arc<xla::Literal>]) -> anyhow::Result<f64> {
        let mut acc = 0.0f64;
        for (i, p) in params.iter().enumerate() {
            for x in p.to_vec::<f32>()? {
                acc += x as f64 * (i + 1) as f64;
            }
        }
        Ok(acc)
    }
}

fn layout() -> Arc<FlatLayout> {
    Arc::new(FlatLayout::new(vec![
        vec![3, 2],
        vec![4],
        vec![2, 2],
        vec![5],
        vec![1],
    ]))
}

fn init_lits(l: &FlatLayout) -> Vec<Arc<xla::Literal>> {
    (0..l.n_leaves())
        .map(|leaf| {
            let v: Vec<f32> = (0..l.len(leaf))
                .map(|i| ((leaf * 37 + i * 11 + 5) % 23) as f32 * 0.1 - 1.0)
                .collect();
            Arc::new(HostTensor::from_vec(l.shape(leaf), v).to_literal().unwrap())
        })
        .collect()
}

const SEED: u64 = 5;

fn fresh_replicas(l: &FlatLayout, m: usize) -> Vec<ReplicaState> {
    let init = init_lits(l);
    (0..m)
        .map(|r| ReplicaState {
            state: init.clone(),
            shard: TokenStream::new(CorpusSpec::default(), SEED, r as u64),
        })
        .collect()
}

fn fresh_sync(l: &Arc<FlatLayout>, up: OuterBits, down: OuterBits) -> OuterSync {
    let init = init_lits(l);
    let host: Vec<HostTensor> = init
        .iter()
        .map(|lit| HostTensor::from_literal(lit).unwrap())
        .collect();
    OuterSync::new(Arc::clone(l), &host, init, 0.7, 0.9, FRAGMENTS)
        .unwrap()
        .with_codec(codec_for(up), 42)
        .with_down_codec(codec_for(down))
}

const TOTAL: usize = 26;
const INTERVAL: usize = 6; // per-fragment sync interval (H/P)
const FRAGMENTS: usize = 2;
const EVAL_EVERY: usize = 3;
const M: usize = 4;

fn plan(workers: usize, tau: usize) -> DrivePlan {
    DrivePlan {
        total_steps: TOTAL,
        sync_interval: INTERVAL,
        fragments: FRAGMENTS,
        n_params: layout().n_leaves(),
        eval_every: Some(EVAL_EVERY),
        log_every: 1000,
        workers,
        overlap_tau: tau,
    }
}

/// Everything the oracles compare bitwise. Upload counts are deliberately
/// absent: a resumed run rebuilds its literal cache lazily, so it uploads
/// less than the uninterrupted run while computing the exact same bits.
#[derive(PartialEq, Debug)]
struct Trace {
    step_losses: Vec<f64>,
    eval_curve: Vec<(usize, f64)>,
    outer_syncs: usize,
    global_bits: Vec<u32>,
    finals: Vec<Vec<Vec<f32>>>,
    wire_up: u64,
    wire_down: u64,
}

fn finals_of(l: &FlatLayout, replicas: &[ReplicaState]) -> Vec<Vec<Vec<f32>>> {
    replicas
        .iter()
        .map(|r| {
            (0..l.n_leaves())
                .map(|leaf| r.state[leaf].to_vec::<f32>().unwrap())
                .collect()
        })
        .collect()
}

fn trace_of(
    step_losses: Vec<f64>,
    eval_curve: Vec<(usize, f64)>,
    outer_syncs: usize,
    sync: &OuterSync,
    l: &FlatLayout,
    replicas: &[ReplicaState],
) -> Trace {
    Trace {
        step_losses,
        eval_curve,
        outer_syncs,
        global_bits: sync.global().data().iter().map(|x| x.to_bits()).collect(),
        finals: finals_of(l, replicas),
        wire_up: sync.wire_stats().total_up(),
        wire_down: sync.wire_stats().total_down(),
    }
}

/// The uninterrupted run through the plain `drive` entry point.
fn plain_run(up: OuterBits, down: OuterBits, workers: usize, tau: usize) -> Trace {
    let l = layout();
    let engine = ToyEngine { n: l.n_leaves() };
    let mut replicas = fresh_replicas(&l, M);
    let mut sync = fresh_sync(&l, up, down);
    let out = drive(&engine, &mut replicas, Some(&mut sync), &plan(workers, tau)).expect("drive");
    trace_of(out.step_losses, out.eval_curve, out.outer_syncs, &sync, &l, &replicas)
}

/// The uninterrupted run through `drive_ctl` with the given controls.
/// Returns the trace and the final `DriveCtl` (journal, live flags).
fn ctl_run(
    up: OuterBits,
    down: OuterBits,
    workers: usize,
    tau: usize,
    mut ctl: DriveCtl,
) -> (Trace, DriveCtl) {
    let l = layout();
    let engine = ToyEngine { n: l.n_leaves() };
    let mut replicas = fresh_replicas(&l, ctl.live.len());
    let mut sync = fresh_sync(&l, up, down);
    let out = drive_ctl(&engine, &mut replicas, Some(&mut sync), &plan(workers, tau), &mut ctl)
        .expect("drive_ctl");
    (
        trace_of(out.step_losses, out.eval_curve, out.outer_syncs, &sync, &l, &replicas),
        ctl,
    )
}

// ---- (1) the churn-free control path is the old drive loop -----------

#[test]
fn fresh_ctl_and_empty_fault_plan_replay_drive_bit_for_bit() {
    for (up, down) in [
        (OuterBits::Fp32, OuterBits::Fp32),
        (OuterBits::Int4, OuterBits::Bf16),
    ] {
        for tau in [0usize, 3] {
            let oracle = plain_run(up, down, 1, tau);
            assert_eq!(oracle.step_losses.len(), TOTAL, "{up:?}/{down:?} τ={tau}");

            // DriveCtl::fresh is exactly `drive`
            let (fresh, _) = ctl_run(up, down, 1, tau, DriveCtl::fresh(M));
            assert_eq!(
                fresh, oracle,
                "{up:?}/{down:?} τ={tau}: DriveCtl::fresh must replay drive"
            );

            // ... and so is the empty --churn spec, resolved through the
            // real FaultPlan path (acceptance: a churn-free FaultPlan run
            // is bit-identical to today's path)
            let events = FaultPlan::parse("", 17).unwrap().resolve(M, 99);
            assert!(events.is_empty(), "empty spec resolves to zero events");
            let mut ctl = DriveCtl::fresh(M);
            ctl.events = events;
            let (empty_plan, ctl) = ctl_run(up, down, 2, tau, ctl);
            assert_eq!(
                empty_plan, oracle,
                "{up:?}/{down:?} τ={tau}: the empty fault plan must be inert"
            );
            assert_eq!(ctl.journal.count(EventKind::Crash), 0);
            assert_eq!(ctl.journal.count(EventKind::Join), 0);
            assert!(
                ctl.journal.count(EventKind::SyncSend) > 0,
                "sends are journaled even without churn"
            );
        }
    }
}

// ---- (2) checkpoint + resume is bit-identical ------------------------

/// Run to `stop` merged outer syncs, capture a checkpoint, push it
/// through the JSON wire format both ways, rebuild everything from the
/// parsed copy, and finish the run. `events` (the fault schedule) is
/// attached to both legs, exactly as `run_resume` re-resolves the
/// config's `--churn` spec.
fn interrupted_then_resumed(
    up: OuterBits,
    down: OuterBits,
    tau: usize,
    stop: u64,
    events: Vec<FaultEvent>,
) -> (Trace, DriveCtl) {
    let l = layout();
    let engine = ToyEngine { n: l.n_leaves() };

    // leg 1: run until `stop` syncs have merged, then capture
    let mut replicas = fresh_replicas(&l, M);
    let mut sync = fresh_sync(&l, up, down);
    let mut ctl = DriveCtl::fresh(M);
    ctl.events = events.clone();
    ctl.stop_after_sync = Some(stop);
    let out = drive_ctl(&engine, &mut replicas, Some(&mut sync), &plan(1, tau), &mut ctl)
        .expect("interrupted leg");
    let step = ctl.stopped_at.expect("the stop boundary must hit before T");
    assert_eq!(out.step_losses.len(), step, "losses cover exactly the run-so-far");
    assert_eq!(ctl.journal.count(EventKind::Checkpoint), 1);
    let ck = Checkpoint::capture(
        step,
        &replicas,
        &ctl.residuals,
        &ctl.live,
        Some(&sync),
        &out,
        &ctl.journal,
    )
    .expect("capture at the stop boundary");

    // the serialized form is the contract: what `diloco checkpoint`
    // writes is what `diloco resume` reads
    let text = ck.to_json().to_string_compact();
    let ck = Checkpoint::from_json(&Json::parse(&text).unwrap()).expect("checkpoint round-trip");
    assert_eq!(ck.step, step);

    // leg 2: rebuild replicas, bus, and controls from the parsed copy
    let mut replicas: Vec<ReplicaState> = ck
        .replicas
        .iter()
        .enumerate()
        .map(|(r, rck)| {
            let mut shard = TokenStream::new(CorpusSpec::default(), SEED, r as u64);
            shard.skip(rck.consumed);
            ReplicaState {
                state: rck.literals().expect("leaf rebuild"),
                shard,
            }
        })
        .collect();
    let mut bus = fresh_sync(&l, up, down);
    bus.restore_state(ck.sync.as_ref().expect("diloco checkpoint carries sync state"))
        .expect("sync restore");
    let snap_init = Some(bus.broadcast_view().to_vec());
    let mut ctl = DriveCtl {
        events,
        live: ck.live.clone(),
        stop_after_sync: None,
        start_step: ck.step,
        resume: true,
        journal: ck.journal.clone(),
        residuals: ck.replicas.iter().map(|r| r.residual.clone()).collect(),
        snap_init,
        stopped_at: None,
    };
    let resumed = drive_ctl(&engine, &mut replicas, Some(&mut bus), &plan(2, tau), &mut ctl)
        .expect("resumed leg");
    let full = ck.stitch(&resumed);
    (
        trace_of(full.step_losses, full.eval_curve, full.outer_syncs, &bus, &l, &replicas),
        ctl,
    )
}

#[test]
fn checkpoint_resume_is_bit_identical_for_every_codec_pair() {
    // τ=0 stops at the sync boundary itself; τ=3 must wait out the
    // overlap window (the stop is only legal with nothing in flight).
    for up in OuterBits::ALL {
        for down in OuterBits::ALL {
            for tau in [0usize, 3] {
                let oracle = plain_run(up, down, 1, tau);
                let (stitched, ctl) = interrupted_then_resumed(up, down, tau, 2, Vec::new());
                assert_eq!(
                    stitched, oracle,
                    "{up:?}/{down:?} τ={tau}: resume must continue the \
                     interrupted run bit for bit"
                );
                // the journal carries the whole story across the cut
                assert_eq!(ctl.journal.count(EventKind::Checkpoint), 1, "{up:?}/{down:?}");
                assert_eq!(ctl.journal.count(EventKind::Resume), 1, "{up:?}/{down:?}");
                assert_eq!(
                    ctl.journal.count(EventKind::SyncMerge),
                    oracle.outer_syncs,
                    "{up:?}/{down:?} τ={tau}: every merge journaled exactly once \
                     across both legs"
                );
            }
        }
    }
}

// ---- (3) fault schedules replay across a resume ----------------------

#[test]
fn scheduled_crash_after_the_checkpoint_replays_identically_on_resume() {
    // the crash is keyed to absolute sync index 3 — after the stop at
    // 2, so it must fire in the resumed leg exactly where the
    // uninterrupted run fires it
    let events = vec![FaultEvent {
        at_sync: 3,
        replica: 1,
        kind: FaultKind::Crash,
    }];
    for (up, down) in [
        (OuterBits::Fp32, OuterBits::Fp32),
        (OuterBits::Int8, OuterBits::Fp32),
    ] {
        let mut ctl = DriveCtl::fresh(M);
        ctl.events = events.clone();
        let (oracle, octl) = ctl_run(up, down, 1, 0, ctl);
        assert_eq!(octl.journal.count(EventKind::Crash), 1, "{up:?}/{down:?}");
        assert!(!octl.live[1], "{up:?}/{down:?}: replica 1 dead at the end");

        let (stitched, rctl) = interrupted_then_resumed(up, down, 0, 2, events.clone());
        assert_eq!(
            stitched, oracle,
            "{up:?}/{down:?}: the fault schedule must replay across the cut"
        );
        assert_eq!(rctl.journal.count(EventKind::Crash), 1, "fired once, in leg 2");
        assert_eq!(rctl.live, octl.live, "{up:?}/{down:?}");
    }
}

// ---- (4) survivors after a mid-segment death --------------------------

#[test]
fn survivor_trajectories_after_a_death_are_bit_identical_across_workers() {
    // crash keyed to sync 2: replica 1 dies at the top of the (12, 18]
    // segment, so steps 1..=12 match the churn-free run exactly and
    // the mean switches to the 3 survivors from step 13 on
    let events = vec![FaultEvent {
        at_sync: 2,
        replica: 1,
        kind: FaultKind::Crash,
    }];
    for tau in [0usize, 3] {
        let mut ctl = DriveCtl::fresh(M);
        ctl.events = events.clone();
        let (oracle, octl) = ctl_run(OuterBits::Fp32, OuterBits::Fp32, 1, tau, ctl);
        assert_eq!(oracle.step_losses.len(), TOTAL, "τ={tau}: dead fleet still logs T steps");
        assert_eq!(octl.journal.count(EventKind::Crash), 1);

        // acceptance: workers 1 vs 2 vs 4 bit-identical under churn
        for workers in [2usize, 4] {
            let mut ctl = DriveCtl::fresh(M);
            ctl.events = events.clone();
            let (par, _) = ctl_run(OuterBits::Fp32, OuterBits::Fp32, workers, tau, ctl);
            assert_eq!(
                par, oracle,
                "τ={tau} w={workers}: survivor trajectories must be \
                 scheduling-independent"
            );
        }

        // the death changes the trajectory only after it happens
        let clean = plain_run(OuterBits::Fp32, OuterBits::Fp32, 1, tau);
        assert_eq!(
            oracle.step_losses[..12],
            clean.step_losses[..12],
            "τ={tau}: pre-death steps are untouched"
        );
        assert_ne!(
            oracle.step_losses[12..],
            clean.step_losses[12..],
            "τ={tau}: the survivor mean must actually move"
        );

        // the dead replica froze at its death state; every survivor
        // adopted the final full flush
        assert_eq!(oracle.finals[0], oracle.finals[2], "τ={tau}");
        assert_eq!(oracle.finals[0], oracle.finals[3], "τ={tau}");
        assert_ne!(
            oracle.finals[1], oracle.finals[0],
            "τ={tau}: a dead replica never sees the merges it missed"
        );
    }
}

// ---- (5) joiners initialize from the broadcast view -------------------

#[test]
fn joiner_comes_alive_at_an_outer_boundary_from_the_broadcast_view() {
    // universe of 4 with slot 3 dark at start; the join is keyed to
    // sync 0, so it fires at the first boundary after merge 1 lands
    let events = vec![FaultEvent {
        at_sync: 0,
        replica: 3,
        kind: FaultKind::Join,
    }];
    // identity up-wire (the coordinator hands the joiner global
    // literals) and lossy up-wire (the worker's decoded snapshot is
    // the joiner's view) are different code paths — pin both
    for up in [OuterBits::Fp32, OuterBits::Int4] {
        let fresh_ctl = || {
            let mut ctl = DriveCtl::fresh(M);
            ctl.live[3] = false;
            ctl.events = events.clone();
            ctl
        };
        let (oracle, octl) = ctl_run(up, OuterBits::Fp32, 1, 0, fresh_ctl());
        assert_eq!(octl.journal.count(EventKind::Join), 1, "{up:?}");
        assert!(octl.live.iter().all(|&l| l), "{up:?}: everyone live at the end");
        assert_eq!(oracle.step_losses.len(), TOTAL, "{up:?}");

        // the joiner ends on the same flushed global as everyone else
        assert_eq!(oracle.finals[3], oracle.finals[0], "{up:?}: joiner converged");

        // joining must change the reduce (4 contributors instead of 3)
        let mut three = DriveCtl::fresh(M);
        three.live[3] = false;
        let (without, _) = ctl_run(up, OuterBits::Fp32, 1, 0, three);
        assert_ne!(
            oracle.global_bits, without.global_bits,
            "{up:?}: the joiner must actually contribute"
        );

        // scheduling independence with a dark slot + a join in play
        for workers in [2usize, 4] {
            let (par, _) = ctl_run(up, OuterBits::Fp32, workers, 0, fresh_ctl());
            assert_eq!(par, oracle, "{up:?} w={workers}");
        }
    }
}
