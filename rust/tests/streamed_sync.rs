//! Chunk-reassembly property test: every legal interleaving of
//! streamed up-leg chunks must resolve byte- and bit-identical to the
//! one-shot `sync_encoded` oracle fed the exact same payload bytes.
//!
//! "Legal" cuts sit on the BLOCK grid relative to each due range's
//! wire start — the grid `CommLink::encode_replica_streamed` flushes
//! on and the `ContribChunk` reassembly's overlap decode assumes.
//! Within that grid the test draws random cut sets and random
//! cross-replica arrival orders from a seeded LCG, so chunk counts,
//! chunk sizes, and interleavings all vary per trial; the resulting
//! global parameter bits, broadcast payload bytes, and wire accounting
//! must never move. Runs the full codec matrix (fp32 and quantized,
//! both wires), fragment schedules with odd int4 tail ranges, and a
//! randomized mid-stream drop (the churn path's `arrival_drop`).

use std::collections::VecDeque;
use std::sync::Arc;

use diloco::comm::codec::BLOCK;
use diloco::comm::{codec_for, OuterBits, ReplicaComm, WorkerComm};
use diloco::coordinator::OuterSync;
use diloco::runtime::{FlatLayout, HostTensor};
use diloco::transport::frame::WireSlice;

const M: usize = 3;
const SEED: u64 = 23;
const FRAGMENTS: usize = 2;

/// Deterministic LCG (no rand crate offline); high bits only.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 11
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n.max(1) as u64) as usize
    }
}

/// Multi-block leaves with odd tails: cuts land mid-leaf, at leaf
/// seams, and against partial trailing codec blocks.
fn layout() -> Arc<FlatLayout> {
    Arc::new(FlatLayout::new(vec![
        vec![700],
        vec![300, 2],
        vec![513],
        vec![9],
    ]))
}

fn host_fn(layout: &FlatLayout, f: impl Fn(usize) -> f32) -> Vec<HostTensor> {
    (0..layout.n_leaves())
        .map(|l| {
            let r = layout.range(l);
            HostTensor::from_vec(layout.shape(l), r.map(&f).collect())
        })
        .collect()
}

fn lits_of(tensors: &[HostTensor]) -> Vec<Arc<xla::Literal>> {
    tensors
        .iter()
        .map(|t| Arc::new(t.to_literal().unwrap()))
        .collect()
}

fn build(
    l: &Arc<FlatLayout>,
    init: &[HostTensor],
    init_lits: &[Arc<xla::Literal>],
    up: OuterBits,
    down: OuterBits,
) -> OuterSync {
    OuterSync::new(Arc::clone(l), init, init_lits.to_vec(), 0.8, 0.9, FRAGMENTS)
        .unwrap()
        .with_codec(codec_for(up), SEED)
        .with_down_codec(codec_for(down))
        .with_sync_threads(3)
}

/// One replica's one-shot payload from fresh comm state — the byte
/// ground truth both the oracle merge and every chunked feed share.
fn encode_payload(
    sync: &OuterSync,
    init_lits: &[Arc<xla::Literal>],
    state: &[Arc<xla::Literal>],
    r: usize,
    frag: Option<usize>,
    sync_index: u64,
) -> Vec<u8> {
    let link = sync.link();
    let mut wc = WorkerComm::default();
    let mut rc = ReplicaComm::default();
    link.init_snapshot(&mut wc, init_lits).unwrap();
    link.init_replica(&mut rc);
    link.encode_replica(r, state, &mut wc, &mut rc, frag, sync_index)
        .unwrap()
        .as_slice()
        .to_vec()
}

/// Every wire offset a chunk may legally end at (exclusive of the
/// payload end): block seams within each due range, plus range seams.
fn legal_cuts(sync: &OuterSync, up: OuterBits, frag: Option<usize>) -> Vec<usize> {
    let link = sync.link();
    let codec = codec_for(up);
    let mut cuts = Vec::new();
    let mut off = 0usize;
    for r in link.up().ranges(frag) {
        let mut b = BLOCK;
        while b < r.len() {
            cuts.push(off + codec.wire_bytes(b));
            b += BLOCK;
        }
        off += codec.wire_bytes(r.len());
        cuts.push(off);
    }
    cuts.pop(); // the payload end closes the last chunk, it is not a cut
    cuts
}

/// Cut `payload` at a random subset of the legal grid.
fn random_chunks(rng: &mut Lcg, payload: &[u8], grid: &[usize]) -> VecDeque<(usize, Vec<u8>)> {
    let mut bounds = vec![0usize];
    match rng.below(4) {
        // one-shot: the whole payload as a single chunk (the
        // `arrival_absorb` shape for non-streaming workers)
        0 => {}
        // finest legal chunking: every grid point
        1 => bounds.extend_from_slice(grid),
        // random subset
        _ => bounds.extend(grid.iter().copied().filter(|_| rng.below(3) == 0)),
    }
    bounds.push(payload.len());
    bounds
        .windows(2)
        .filter(|w| w[0] < w[1])
        .map(|w| (w[0], payload[w[0]..w[1]].to_vec()))
        .collect()
}

struct SyncResult {
    global_bits: Vec<u32>,
    bcast: Option<Vec<u8>>,
    wire_total: u64,
}

#[test]
fn adversarial_interleavings_match_the_one_shot_oracle() {
    let l = layout();
    let init = host_fn(&l, |i| (i as f32 * 0.01).cos());
    let init_lits = lits_of(&init);
    let pairs = [
        (OuterBits::Int4, OuterBits::Int4),
        (OuterBits::Int8, OuterBits::Fp32),
        (OuterBits::Fp32, OuterBits::Int4),
        (OuterBits::Fp32, OuterBits::Fp32),
    ];
    let mut rng = Lcg(0x5eed_cafe);
    let mut fired_early_total = 0usize;
    for (up, down) in pairs {
        let mut oracle = build(&l, &init, &init_lits, up, down);
        let mut arrival = build(&l, &init, &init_lits, up, down);
        let mut round = 0u64;
        for frag in [None, Some(0), Some(1)] {
            let grid = legal_cuts(&oracle, up, frag);
            assert!(
                grid.len() > 2,
                "{up:?} frag {frag:?}: the layout must yield real cut choices"
            );
            for _ in 0..3 {
                round += 1;
                let states: Vec<_> = (0..M)
                    .map(|r| {
                        let phase = round as f32;
                        lits_of(&host_fn(&l, |i| {
                            ((i + 31 * r) as f32 * 0.03 + phase).sin()
                        }))
                    })
                    .collect();
                let payloads: Vec<Vec<u8>> = states
                    .iter()
                    .enumerate()
                    .map(|(r, st)| encode_payload(&oracle, &init_lits, st, r, frag, round))
                    .collect();

                // the oracle merges the exact same bytes in one shot
                let frames: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
                oracle.sync_encoded(&frames, frag).unwrap();
                let want = SyncResult {
                    global_bits: oracle.global().data().iter().map(|x| x.to_bits()).collect(),
                    bcast: oracle.take_broadcast_bytes().map(|b| b.as_slice().to_vec()),
                    wire_total: oracle.wire_stats().total(),
                };

                // adversarial feed: random cuts, random arrival order
                let rids: Vec<usize> = (0..M).collect();
                let mut ar = arrival.arrival_begin(&rids, frag).unwrap();
                let mut queues: Vec<VecDeque<(usize, Vec<u8>)>> = payloads
                    .iter()
                    .map(|p| random_chunks(&mut rng, p, &grid))
                    .collect();
                while queues.iter().any(|q| !q.is_empty()) {
                    let ready: Vec<usize> =
                        (0..M).filter(|&r| !queues[r].is_empty()).collect();
                    let pick = ready[rng.below(ready.len())];
                    let (off, bytes) = queues[pick].pop_front().unwrap();
                    arrival
                        .arrival_chunk(&mut ar, pick, off, WireSlice::copied_from(&bytes))
                        .unwrap();
                }
                assert!(ar.complete(), "{up:?}/{down:?} frag {frag:?}: all bytes fed");
                let (fired, total) = ar.fired();
                assert_eq!(fired, total, "every reduce shard fired");
                fired_early_total += ar.fired_early();
                let spent = arrival.sync_arrival(ar, &rids, None).unwrap();
                assert!(!spent.is_empty(), "chunk views come back for reclaim");

                let got_bits: Vec<u32> =
                    arrival.global().data().iter().map(|x| x.to_bits()).collect();
                let tag = format!("{up:?}/{down:?} frag {frag:?} round {round}");
                assert_eq!(got_bits, want.global_bits, "{tag}: global bits");
                let got_bcast = arrival.take_broadcast_bytes().map(|b| b.as_slice().to_vec());
                assert_eq!(got_bcast, want.bcast, "{tag}: broadcast payload bytes");
                assert_eq!(
                    arrival.wire_stats().total(),
                    want.wire_total,
                    "{tag}: wire accounting"
                );
            }
        }
    }
    assert!(
        fired_early_total > 0,
        "across all trials some shard must reduce before the last byte lands — \
         otherwise the pipeline never overlapped anything"
    );
}

#[test]
fn randomized_mid_stream_drop_matches_the_survivor_oracle() {
    let l = layout();
    let init = host_fn(&l, |i| (i as f32 * 0.02).sin());
    let init_lits = lits_of(&init);
    let mut rng = Lcg(0xd0_0d1e);
    for trial in 0..4u64 {
        let mut oracle = build(&l, &init, &init_lits, OuterBits::Int4, OuterBits::Int4);
        let mut arrival = build(&l, &init, &init_lits, OuterBits::Int4, OuterBits::Int4);
        let states: Vec<_> = (0..M)
            .map(|r| {
                lits_of(&host_fn(&l, |i| {
                    ((i + 13 * r) as f32 * 0.04 + trial as f32).cos()
                }))
            })
            .collect();
        let payloads: Vec<Vec<u8>> = states
            .iter()
            .enumerate()
            .map(|(r, st)| encode_payload(&oracle, &init_lits, st, r, None, trial))
            .collect();
        let casualty = rng.below(M);
        let survivors: Vec<usize> = (0..M).filter(|&r| r != casualty).collect();

        // the oracle merges only the survivors' bytes
        let frames: Vec<&[u8]> = survivors.iter().map(|&r| payloads[r].as_slice()).collect();
        oracle.sync_encoded(&frames, None).unwrap();
        let _ = oracle.take_broadcast_bytes().unwrap();

        // the arrival starts with everyone, loses the casualty at a
        // random point in its stream, and refires over the survivors
        let rids: Vec<usize> = (0..M).collect();
        let grid = legal_cuts(&arrival, OuterBits::Int4, None);
        let mut ar = arrival.arrival_begin(&rids, None).unwrap();
        let mut queues: Vec<VecDeque<(usize, Vec<u8>)>> = payloads
            .iter()
            .map(|p| random_chunks(&mut rng, p, &grid))
            .collect();
        // how many of the casualty's chunks land before its lane dies
        let mut casualty_left = rng.below(queues[casualty].len() + 1);
        while queues.iter().enumerate().any(|(r, q)| {
            !q.is_empty() && (r != casualty || casualty_left > 0)
        }) {
            let ready: Vec<usize> = (0..M)
                .filter(|&r| !queues[r].is_empty() && (r != casualty || casualty_left > 0))
                .collect();
            let pick = ready[rng.below(ready.len())];
            if pick == casualty {
                casualty_left -= 1;
            }
            let (off, bytes) = queues[pick].pop_front().unwrap();
            arrival
                .arrival_chunk(&mut ar, pick, off, WireSlice::copied_from(&bytes))
                .unwrap();
        }
        arrival.arrival_drop(&mut ar, &[casualty]).unwrap();
        assert_eq!(ar.contributors(), &survivors[..]);
        assert!(ar.complete(), "survivors' bytes are all in");
        arrival.sync_arrival(ar, &survivors, None).unwrap();
        let _ = arrival.take_broadcast_bytes().unwrap();

        let a: Vec<u32> = oracle.global().data().iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = arrival.global().data().iter().map(|x| x.to_bits()).collect();
        assert_eq!(
            a, b,
            "trial {trial}: post-drop refire must equal the survivor-only one-shot"
        );
    }
}

#[test]
fn malformed_chunk_streams_fail_loud() {
    let l = layout();
    let init = host_fn(&l, |i| (i as f32 * 0.015).sin());
    let init_lits = lits_of(&init);
    let mut arrival = build(&l, &init, &init_lits, OuterBits::Int8, OuterBits::Fp32);
    let payload = {
        let state = lits_of(&host_fn(&l, |i| (i as f32 * 0.07).cos()));
        encode_payload(&arrival, &init_lits, &state, 0, None, 0)
    };
    let rids: Vec<usize> = (0..M).collect();
    let mut ar = arrival.arrival_begin(&rids, None).unwrap();

    // a replica outside the contributor set
    assert!(arrival
        .arrival_chunk(&mut ar, 7, 0, WireSlice::copied_from(&payload[..16]))
        .is_err());
    // empty chunks carry no watermark progress and are a protocol bug
    assert!(arrival
        .arrival_chunk(&mut ar, 0, 0, WireSlice::copied_from(&[]))
        .is_err());
    // a gap: first chunk must start at offset 0
    assert!(arrival
        .arrival_chunk(&mut ar, 0, 8, WireSlice::copied_from(&payload[8..24]))
        .is_err());
    // overrun past the expected payload size
    let mut fat = payload.clone();
    fat.extend_from_slice(&[0u8; 32]);
    assert!(arrival
        .arrival_chunk(&mut ar, 0, 0, WireSlice::copied_from(&fat))
        .is_err());
    // a duplicate of an already-accepted prefix is a stale retransmit
    arrival
        .arrival_chunk(&mut ar, 0, 0, WireSlice::copied_from(&payload))
        .unwrap();
    assert!(arrival
        .arrival_chunk(&mut ar, 0, 0, WireSlice::copied_from(&payload))
        .is_err());
    // merging with truncated live contributors fails loud
    assert!(!ar.complete());
    assert!(arrival.sync_arrival(ar, &rids, None).is_err());
}
