//! Replica-parallel determinism: the worker pool must be bit-identical
//! to the sequential oracle (`workers = 1`) for any worker count — same
//! per-step losses, same eval curve, same outer-sync count, same upload
//! counts through the bus, same final global arena, same final replica
//! literals. These tests drive the real `coordinator::pool::drive` loop
//! (segments, barrier, broadcast) and the real `OuterSync` flat-bus
//! path with a deterministic host-math engine, so they run on the host
//! tier in every environment — no PJRT, no artifacts.
//!
//! (The same invariant is asserted through the full PJRT path, when
//! artifacts exist, by `tests/diloco_invariants.rs`.)

use std::sync::Arc;

use diloco::coordinator::{drive, DrivePlan, InnerEngine, OuterSync, ReplicaState};
use diloco::data::synthetic::{CorpusSpec, TokenStream};
use diloco::runtime::{FlatLayout, HostTensor};

/// A deterministic stand-in for the PJRT inner step: the update mixes
/// the replica's private token shard (so shard ownership is exercised)
/// with the step index, entirely in host math. Loss is a pure function
/// of the post-step state, so any scheduling difference would surface.
struct ToyEngine {
    n: usize,
    /// Inject a failure at (replica, step) to test error propagation.
    fail_at: Option<(usize, usize)>,
}

impl InnerEngine for ToyEngine {
    fn inner_step(
        &self,
        rep: usize,
        replica: &mut ReplicaState,
        t: usize,
    ) -> anyhow::Result<f64> {
        if self.fail_at == Some((rep, t)) {
            anyhow::bail!("injected failure at replica {rep}, step {t}");
        }
        let toks = replica.shard.next_batch(2, 8);
        let mut loss = 0.0f64;
        for leaf in 0..self.n {
            let lit = &replica.state[leaf];
            let dims = lit.array_shape()?.dims().to_vec();
            let mut v = lit.to_vec::<f32>()?;
            for (i, x) in v.iter_mut().enumerate() {
                *x = 0.5 * *x
                    + 1e-3 * toks[(i + t) % toks.len()] as f32
                    + 1e-2 * (t as f32 + rep as f32 * 0.25).sin();
            }
            loss += v.iter().map(|&f| f as f64).sum::<f64>() / v.len() as f64;
            replica.state[leaf] = Arc::new(xla::Literal::vec1(&v).reshape(&dims)?);
        }
        Ok(loss / self.n as f64)
    }

    /// Deterministic digest of the parameter literals.
    fn eval(&self, params: &[Arc<xla::Literal>]) -> anyhow::Result<f64> {
        let mut acc = 0.0f64;
        for (i, p) in params.iter().enumerate() {
            for x in p.to_vec::<f32>()? {
                acc += x as f64 * (i + 1) as f64;
            }
        }
        Ok(acc)
    }
}

fn layout() -> Arc<FlatLayout> {
    Arc::new(FlatLayout::new(vec![
        vec![3, 2],
        vec![4],
        vec![2, 2],
        vec![5],
        vec![1],
    ]))
}

fn fresh_replicas(layout: &FlatLayout, m: usize, seed: u64) -> Vec<ReplicaState> {
    // all replicas start from the same "global init", like Algorithm 1
    let init: Vec<Arc<xla::Literal>> = (0..layout.n_leaves())
        .map(|l| {
            let v: Vec<f32> = (0..layout.len(l))
                .map(|i| ((l * 37 + i * 11 + 5) % 23) as f32 * 0.1 - 1.0)
                .collect();
            Arc::new(
                HostTensor::from_vec(layout.shape(l), v)
                    .to_literal()
                    .unwrap(),
            )
        })
        .collect();
    (0..m)
        .map(|r| ReplicaState {
            state: init.clone(),
            shard: TokenStream::new(CorpusSpec::default(), seed, r as u64),
        })
        .collect()
}

fn init_host(layout: &FlatLayout, replicas: &[ReplicaState]) -> Vec<HostTensor> {
    (0..layout.n_leaves())
        .map(|l| HostTensor::from_literal(&replicas[0].state[l]).unwrap())
        .collect()
}

struct RunResult {
    step_losses: Vec<f64>,
    loss_curve: Vec<(usize, f64)>,
    eval_curve: Vec<(usize, f64)>,
    outer_syncs: usize,
    uploads: u64,
    global: Vec<f32>,
    /// Per-replica, per-leaf payloads after the run.
    finals: Vec<Vec<Vec<f32>>>,
    /// Whether each replica's synced leaves point at the shared global
    /// literal after the final full flush.
    shares_global: bool,
}

/// One full DiLoCo schedule (streaming fragments included) through the
/// pool with the given worker count.
fn run_once(m: usize, workers: usize, fragments: usize, seed: u64) -> RunResult {
    let l = layout();
    let engine = ToyEngine {
        n: l.n_leaves(),
        fail_at: None,
    };
    let mut replicas = fresh_replicas(&l, m, seed);
    let host = init_host(&l, &replicas);
    let init_lits: Vec<Arc<xla::Literal>> = replicas[0].state.clone();
    let mut sync = OuterSync::new(Arc::clone(&l), &host, init_lits, 0.7, 0.9, fragments)
        .expect("sync setup");
    let plan = DrivePlan {
        total_steps: 22,
        sync_interval: 3, // H=6, P=2 -> a fragment every 3 steps
        fragments,
        n_params: l.n_leaves(),
        eval_every: Some(7),
        log_every: 5,
        workers,
        overlap_tau: 0,
    };
    let out = drive(&engine, &mut replicas, Some(&mut sync), &plan).expect("drive");
    let finals: Vec<Vec<Vec<f32>>> = replicas
        .iter()
        .map(|r| {
            (0..l.n_leaves())
                .map(|leaf| r.state[leaf].to_vec::<f32>().unwrap())
                .collect()
        })
        .collect();
    let lits = sync.global_literals().expect("global literal cache").to_vec();
    let shares_global = replicas
        .iter()
        .all(|r| (0..l.n_leaves()).all(|leaf| Arc::ptr_eq(&r.state[leaf], &lits[leaf])));
    RunResult {
        step_losses: out.step_losses,
        loss_curve: out.loss_curve,
        eval_curve: out.eval_curve,
        outer_syncs: out.outer_syncs,
        uploads: sync.uploads(),
        global: sync.global().data().to_vec(),
        finals,
        shares_global,
    }
}

#[test]
fn parallel_run_is_bit_identical_to_sequential_oracle() {
    let m = 4;
    let oracle = run_once(m, 1, 2, 42);
    assert_eq!(oracle.step_losses.len(), 22);
    assert!(oracle.outer_syncs > 0);
    assert!(
        oracle.shares_global,
        "final flush must leave every replica sharing the global literals"
    );

    for workers in [2usize, 4, 16 /* clamped to M */] {
        let par = run_once(m, workers, 2, 42);
        // f64 equality is exact: same values in the same order, or bust
        assert_eq!(par.step_losses, oracle.step_losses, "workers={workers}");
        assert_eq!(par.loss_curve, oracle.loss_curve, "workers={workers}");
        assert_eq!(par.eval_curve, oracle.eval_curve, "workers={workers}");
        assert_eq!(par.outer_syncs, oracle.outer_syncs, "workers={workers}");
        assert_eq!(par.uploads, oracle.uploads, "workers={workers}");
        assert_eq!(
            par.global.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            oracle.global.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "workers={workers}: global arena drifted"
        );
        assert_eq!(par.finals, oracle.finals, "workers={workers}");
        assert!(par.shares_global, "workers={workers}");
    }
}

#[test]
fn uneven_partition_and_vanilla_sync_agree() {
    // M=3 over 2 workers (worker 0 owns replicas {0, 2}) with P=1
    let oracle = run_once(3, 1, 1, 7);
    let par = run_once(3, 2, 1, 7);
    assert_eq!(par.step_losses, oracle.step_losses);
    assert_eq!(par.eval_curve, oracle.eval_curve);
    assert_eq!(par.uploads, oracle.uploads);
    assert_eq!(par.finals, oracle.finals);
}

#[test]
fn data_parallel_mode_without_sync_agrees() {
    // sync=None exercises the eval-point boundaries (DP evaluates the
    // replica's live state, so eval steps must be exact barriers).
    let l = layout();
    let run_dp = |workers: usize| {
        let engine = ToyEngine {
            n: l.n_leaves(),
            fail_at: None,
        };
        let mut replicas = fresh_replicas(&l, 2, 9);
        let plan = DrivePlan {
            total_steps: 10,
            sync_interval: usize::MAX,
            fragments: 1,
            n_params: l.n_leaves(),
            eval_every: Some(4),
            log_every: 3,
            workers,
            overlap_tau: 0,
        };
        let out = drive(&engine, &mut replicas, None, &plan).expect("drive");
        let finals: Vec<Vec<f32>> = replicas
            .iter()
            .map(|r| r.state[0].to_vec::<f32>().unwrap())
            .collect();
        (out.step_losses, out.eval_curve, finals)
    };
    assert_eq!(run_dp(1), run_dp(2));
}

#[test]
fn worker_failure_propagates_without_hanging() {
    let l = layout();
    let engine = ToyEngine {
        n: l.n_leaves(),
        fail_at: Some((1, 5)),
    };
    for workers in [1usize, 3] {
        let mut replicas = fresh_replicas(&l, 3, 1);
        let host = init_host(&l, &replicas);
        let init_lits = replicas[0].state.clone();
        let mut sync = OuterSync::new(Arc::clone(&l), &host, init_lits, 0.7, 0.9, 1).unwrap();
        let plan = DrivePlan {
            total_steps: 12,
            sync_interval: 4,
            fragments: 1,
            n_params: l.n_leaves(),
            eval_every: None,
            log_every: 100,
            workers,
            overlap_tau: 0,
        };
        let err = drive(&engine, &mut replicas, Some(&mut sync), &plan)
            .expect_err("injected failure must propagate");
        assert!(
            format!("{err:#}").contains("injected failure"),
            "workers={workers}: {err:#}"
        );
        // either path must hand replica ownership back on failure
        assert_eq!(replicas.len(), 3, "workers={workers}");
    }
}
