//! Integration: the rust runtime loads python-lowered HLO artifacts,
//! executes them on PJRT CPU, and the numerics behave like a language
//! model trainer (init deterministic, loss ~ ln(vocab) at init, loss
//! decreases when training on a repeated batch, micro-batch
//! accumulation consistent with the fused step).

use std::path::Path;
use std::sync::Arc;

use diloco::runtime::{
    f32_scalar, i32_literal, scalar_f32, u32_scalar, HostTensor, ModelRuntime, Runtime,
};

fn model_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/m0")
}

fn have_artifacts() -> bool {
    model_dir().join("manifest.json").is_file()
}

/// None = skip: artifacts not lowered, or no PJRT backend (the
/// vendored `xla` stub gates execution; real bindings run this tier).
fn load_m0() -> Option<(Arc<Runtime>, ModelRuntime)> {
    if !have_artifacts() {
        eprintln!("skipping: artifacts missing (make artifacts)");
        return None;
    }
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT backend (offline xla stub)");
        return None;
    };
    let mr = ModelRuntime::load(rt.clone(), &model_dir()).expect("manifest");
    Some((rt, mr))
}

#[test]
fn manifest_loads_and_validates() {
    let Some((_rt, mr)) = load_m0() else { return };
    assert_eq!(mr.manifest.model.name, "m0");
    assert_eq!(mr.n_leaves(), 10 * mr.manifest.model.layers + 2);
    assert_eq!(mr.manifest.model.vocab, 512);
}

#[test]
fn init_is_deterministic_and_executes() {
    let Some((_rt, mr)) = load_m0() else { return };
    let init = mr.artifact("init").unwrap();
    let seed = u32_scalar(7);
    let a = init.call(&[&seed]).unwrap();
    let b = init.call(&[&seed]).unwrap();
    assert_eq!(a.len(), mr.n_leaves());
    for (x, y) in a.iter().zip(&b) {
        let hx = HostTensor::from_literal(x).unwrap();
        let hy = HostTensor::from_literal(y).unwrap();
        assert_eq!(hx, hy);
    }
    // embed leaf is first, shape [512, d_model]
    let embed = HostTensor::from_literal(&a[0]).unwrap();
    assert_eq!(embed.shape[0], 512);
}

#[test]
fn train_step_reduces_loss_on_repeated_batch() {
    let Some((_rt, mr)) = load_m0() else { return };
    let n = mr.n_leaves();
    let init = mr.artifact("init").unwrap();
    let ts = mr.artifact("train_step").unwrap();
    let params = init.call(&[&u32_scalar(0)]).unwrap();
    let zeros: Vec<xla::Literal> = mr
        .manifest
        .params
        .iter()
        .map(|s| HostTensor::zeros(&s.shape).to_literal().unwrap())
        .collect();

    let mb = mr.manifest.train_step_batch();
    let seq = mr.manifest.model.seq_len;
    // fixed pseudo-random batch
    let tokens: Vec<i32> = (0..mb * seq)
        .map(|i| ((i * 2654435761usize) % 509) as i32)
        .collect();
    let tok_lit = i32_literal(&[mb, seq], &tokens).unwrap();

    let zeros2: Vec<xla::Literal> = zeros
        .iter()
        .map(|z| HostTensor::from_literal(z).unwrap().to_literal().unwrap())
        .collect();
    let mut state: Vec<xla::Literal> = params
        .into_iter()
        .chain(zeros2)
        .chain(zeros)
        .collect();
    assert_eq!(state.len(), 3 * n);

    let mut first = None;
    let mut last = 0.0;
    for step in 0..30 {
        let step_l = f32_scalar(step as f32 + 1.0);
        let lr = f32_scalar(3e-3);
        let wd = f32_scalar(1e-4);
        let mut args: Vec<&xla::Literal> = state.iter().collect();
        args.push(&tok_lit);
        args.push(&step_l);
        args.push(&lr);
        args.push(&wd);
        let out = ts.call(&args).unwrap();
        assert_eq!(out.len(), 3 * n + 2);
        let loss = scalar_f32(&out[3 * n]).unwrap();
        let gnorm = scalar_f32(&out[3 * n + 1]).unwrap();
        assert!(loss.is_finite() && gnorm.is_finite());
        if first.is_none() {
            first = Some(loss);
            // init loss should be near ln(512) = 6.24
            assert!((loss - 6.24).abs() < 1.0, "init loss {loss}");
        }
        last = loss;
        state = out.into_iter().take(3 * n).collect();
    }
    assert!(
        last < first.unwrap() - 0.5,
        "loss did not decrease: {} -> {last}",
        first.unwrap()
    );
}

#[test]
fn grad_accumulation_matches_fused_step() {
    let Some((_rt, mr)) = load_m0() else { return };
    let n = mr.n_leaves();
    let init = mr.artifact("init").unwrap();
    let gs8 = mr.artifact("grad_step_mb8").unwrap();
    let gs1 = mr.artifact("grad_step_mb1").unwrap();
    let acc = mr.artifact("grad_acc").unwrap();
    let params = init.call(&[&u32_scalar(3)]).unwrap();
    let seq = mr.manifest.model.seq_len;

    let tokens: Vec<i32> = (0..8 * seq).map(|i| ((i * 7 + 3) % 512) as i32).collect();
    let t8 = i32_literal(&[8, seq], &tokens).unwrap();

    // full batch grad
    let mut args: Vec<&xla::Literal> = params.iter().collect();
    args.push(&t8);
    let full = gs8.call(&args).unwrap();

    // accumulate 8 single-sequence micro grads with weight 1/8 each
    let mut acc_state: Option<Vec<xla::Literal>> = None;
    for i in 0..8 {
        let t1 = i32_literal(&[1, seq], &tokens[i * seq..(i + 1) * seq]).unwrap();
        let mut a: Vec<&xla::Literal> = params.iter().collect();
        a.push(&t1);
        let g = gs1.call(&a).unwrap();
        let g: Vec<xla::Literal> = g.into_iter().take(n).collect();
        acc_state = Some(match acc_state {
            None => g,
            Some(prev) => {
                let wa = f32_scalar(1.0);
                let wb = f32_scalar(1.0);
                let mut args: Vec<&xla::Literal> =
                    prev.iter().chain(g.iter()).collect();
                args.push(&wa);
                args.push(&wb);
                acc.call(&args).unwrap()
            }
        });
    }
    let summed = acc_state.unwrap();
    for (i, (got, want)) in summed.iter().zip(full.iter().take(n)).enumerate() {
        let g = HostTensor::from_literal(got).unwrap();
        let w = HostTensor::from_literal(want).unwrap();
        for (a, b) in g.data.iter().zip(&w.data) {
            let mean_micro = a / 8.0;
            assert!(
                (mean_micro - b).abs() <= 1e-5 + 2e-4 * b.abs().max(1e-3),
                "leaf {i}: {mean_micro} vs {b}"
            );
        }
    }
}

#[test]
fn eval_step_counts_targets() {
    let Some((_rt, mr)) = load_m0() else { return };
    let init = mr.artifact("init").unwrap();
    let ev = mr.artifact("eval_step").unwrap();
    let params = init.call(&[&u32_scalar(0)]).unwrap();
    let eb = mr.manifest.eval_batch;
    let seq = mr.manifest.model.seq_len;
    let tokens: Vec<i32> = (0..eb * seq).map(|i| (i % 512) as i32).collect();
    let t = i32_literal(&[eb, seq], &tokens).unwrap();
    let mut args: Vec<&xla::Literal> = params.iter().collect();
    args.push(&t);
    let out = ev.call(&args).unwrap();
    let sum_nll = scalar_f32(&out[0]).unwrap();
    let count = scalar_f32(&out[1]).unwrap();
    assert_eq!(count as usize, eb * (seq - 1));
    assert!(sum_nll > 0.0);
}
