//! [P]-mode validation (DESIGN.md section 5): run OUR fitting pipeline
//! on the PAPER's published measurements and require that it recovers
//! the PAPER's fitted coefficients. This checks methodological fidelity
//! end-to-end without needing the authors' compute.

use diloco::report::paperdata as paper;
use diloco::report::tables::{fit_paper_joint_loss, fit_paper_loss_laws};
use diloco::scaling::parametric::{fit_parametric, Obs, ParametricForm};
use diloco::scaling::residuals::log_residual;
use diloco::scaling::PowerLaw;

#[test]
fn our_power_law_fits_recover_table7() {
    // Fitting L(N)~A*N^alpha to Table 4's losses must land on Table 7's
    // coefficients. alpha is tight; A trades off against alpha so we
    // compare predictions rather than A directly.
    for ((algo, fit), (palgo, pa, palpha)) in
        fit_paper_loss_laws().iter().zip(paper::TABLE7)
    {
        assert_eq!(algo, palgo);
        assert!(
            (fit.alpha - palpha).abs() < 0.004,
            "{algo}: alpha {} vs paper {palpha}",
            fit.alpha
        );
        let paper_fit = PowerLaw { a: pa, alpha: palpha };
        for &n in &paper::PAPER_N {
            let rel = (fit.predict(n) - paper_fit.predict(n)).abs() / paper_fit.predict(n);
            assert!(rel < 0.01, "{algo} at N={n}: rel {rel}");
        }
    }
}

#[test]
fn our_joint_fit_recovers_table10_loss_row() {
    let f = fit_paper_joint_loss();
    let (_, a, alpha, beta) = paper::TABLE10[0];
    assert!((f.alpha - alpha).abs() < 0.004, "alpha {} vs {alpha}", f.alpha);
    assert!((f.beta - beta).abs() < 0.004, "beta {} vs {beta}", f.beta);
    // predictions within 1% across the grid
    for &n in &paper::PAPER_N {
        for m in [1.0, 2.0, 4.0, 8.0] {
            let ours = f.predict(n, m);
            let theirs = a * n.powf(alpha) * m.powf(beta);
            assert!((ours - theirs).abs() / theirs < 0.01);
        }
    }
}

#[test]
fn loo_prediction_residuals_match_paper_scale() {
    // Paper Table 11: loss residuals at N=2.4B are ~0.008-0.019.
    // Our reproduction of the protocol should land in that range.
    for (col, m) in [(1usize, 1.0f64), (2, 2.0), (3, 4.0), (4, 8.0)] {
        let ys: Vec<f64> = paper::TABLE4.iter().take(6).map(|r| r[col]).collect();
        let fit = PowerLaw::fit(&paper::PAPER_N[..6], &ys).unwrap();
        let resid = log_residual(paper::TABLE4[6][col], fit.predict(2.4e9));
        assert!(
            resid < 0.03,
            "M={m}: independent LOO residual {resid} too large"
        );
    }
}

#[test]
fn extrapolation_to_4b_10b_matches_table5_within_2pct() {
    // Fig 13's claim: laws fit on 35M-2.4B predict 4B/10B losses within
    // a few percent of the measured values in Table 5.
    let fits = fit_paper_loss_laws();
    // "within a few percentage points" (paper section 6.4); DP at 10B
    // is the worst case at 3.3%.
    let check = |algo: &str, n: f64, measured: f64| {
        let fit = &fits.iter().find(|(a, _)| a == algo).unwrap().1;
        let rel = (fit.predict(n) - measured).abs() / measured;
        assert!(rel < 0.04, "{algo} at {n}: rel {rel}");
    };
    for (algo, l) in paper::TABLE5_4B {
        check(algo, 4e9, l);
    }
    for (algo, l) in paper::TABLE5_10B {
        check(algo, 10e9, l);
    }
}

#[test]
fn table13_parametric_forms_reproduce_ordering() {
    // Reproduce the Table 13 protocol on the paper's own data: fit all
    // four forms on N<=1.3B, evaluate residual on held-out 2.4B. The
    // paper's key qualitative findings: every form lands in the ~1e-3
    // residual regime, and richer forms (rows 2-3) beat the pure power
    // law (row 1).
    let mut train = Vec::new();
    let mut holdout = Vec::new();
    for (i, (row, &nn)) in paper::TABLE4.iter().zip(paper::PAPER_N.iter()).enumerate() {
        for (col, mm) in [(1usize, 1.0f64), (2, 2.0), (3, 4.0), (4, 8.0)] {
            let o = Obs { n: nn, m: mm, loss: row[col] };
            if i == 6 {
                holdout.push(o)
            } else {
                train.push(o)
            }
        }
    }
    let mut residuals = Vec::new();
    for form in ParametricForm::all() {
        let fit = fit_parametric(form, &train, &holdout, 0xCAFE, 96).unwrap();
        residuals.push((form.label(), fit.holdout_residual));
    }
    for (label, r) in &residuals {
        assert!(*r < 0.02, "{label}: residual {r}");
    }
    // richer-than-power-law forms should do at least as well
    let power = residuals[0].1;
    let best_rich = residuals[1..3]
        .iter()
        .map(|r| r.1)
        .fold(f64::INFINITY, f64::min);
    assert!(
        best_rich <= power * 1.5,
        "rich forms {best_rich} should be competitive with power law {power}"
    );
}

#[test]
fn table6_simulator_calibration_quality() {
    // The calibrated simulator must reproduce a healthy fraction of the
    // paper's 90 Table 6 cells exactly (grid-point equality), and the
    // CU=50% column near-perfectly (it pins the traffic model).
    // The paper's Table 6 generator (Douillard et al. 2025's simulator)
    // is unreleased; its CU=50% column is fully determined by the
    // Appendix-A cost model and pins the traffic constants, which is
    // what we require to match. Higher-CU columns depend on internal
    // scheduling details the papers don't specify (see EXPERIMENTS.md
    // "Table 6" for the inferred bounds) — we require only that the
    // calibration beats the trivial zero-match baseline there.
    let (model, matched, total) = diloco::netsim::utilization::calibrate(&paper::TABLE6);
    assert!(total >= 88, "expected ~90 cells, got {total}");
    assert!(
        matched >= 20,
        "calibration matched only {matched}/{total} cells"
    );
    // CU=50% column: the *default* (documented) model must land within
    // one grid step (ratio <= 1.25) of every published cell. (Exact
    // string equality is impossible: the paper's own rounding is
    // inconsistent — e.g. grid point 2.947 prints as "3.0" while
    // 104.82 prints as "104.8".)
    let default_model = diloco::netsim::utilization::SimModel::default();
    let _ = model;
    let mut col0 = 0;
    let mut col0_total = 0;
    for &(arch_name, h, cells) in paper::TABLE6.iter() {
        let arch = diloco::netsim::utilization::ARCHETYPES
            .iter()
            .find(|a| a.name == arch_name)
            .unwrap();
        let algo = if h == 0 {
            diloco::netsim::utilization::SimAlgo::DataParallel
        } else {
            diloco::netsim::utilization::SimAlgo::DiLoCo { sync_every: h }
        };
        if let Some(want) = cells[0] {
            col0_total += 1;
            if let Some(got) = default_model.required_bandwidth_gbps(arch, algo, 0.5) {
                let ratio = (got / want).max(want / got);
                if ratio <= 1.25 {
                    col0 += 1;
                }
            }
        }
    }
    assert!(
        col0 == col0_total,
        "CU=50% column matched {col0}/{col0_total} within one grid step"
    );
}
