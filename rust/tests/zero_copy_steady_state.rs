//! The zero-copy acceptance audit: once warmed up, a socket sync round
//! trip performs **zero wire-buffer allocations and zero payload
//! staging copies on both legs**. Every frame buffer must come from a
//! recycle pool and every payload must serialize straight into (and
//! parse straight out of) its framed buffer.
//!
//! The audit counters ([`frame::metrics`]) are process globals, so this
//! test lives in its own integration-test binary — nothing else
//! allocates wire buffers while the measured window is open.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;

use diloco::transport::frame::{metrics, reclaim_wires, WireBuf, WireSlice};
use diloco::transport::msg::{
    Broadcast, Cmd, PayloadSpec, SegmentChurn, SyncPayload, WorkerReport,
};
use diloco::transport::tcp::{
    accept_workers, connect_with_backoff, worker_handshake, LaneReactor, SessionInfo,
    TcpWorkerLink, CONNECT_ATTEMPTS, ENGINE_TOY,
};
use diloco::transport::WorkerLink;

const WARMUP: usize = 4;
const MEASURED: usize = 8;
const TOTAL: usize = WARMUP + MEASURED;
/// Per-round broadcast payload (streamed in two chunks) and report
/// payload sizes — big enough that a stray staging copy would be a
/// real memcpy, small enough to keep the test instant.
const BCAST: [u8; 256] = [0xB7; 256];
const REPORT_LEN: usize = 192;

#[test]
fn steady_state_socket_sync_allocates_and_copies_nothing() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let info = SessionInfo {
        fingerprint: 0xA11_0C,
        up_bits: 4,
        down_bits: 4,
        engine: ENGINE_TOY,
        live: vec![true],
        config_json: String::from("{}"),
    };

    let worker = thread::spawn(move || {
        let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
        let got = worker_handshake(&mut stream, &[0], 0, 0, 0).unwrap();
        let mut link = TcpWorkerLink::new(stream, &got).unwrap();
        // encode buffers reclaimed from shipped reports, reused forever
        let mut bank: Vec<WireBuf> = Vec::new();
        for round in 0..TOTAL {
            // absorb synthesized Spares, then take the round's Run
            // (its streamed Bcast resolves transparently underneath)
            let cmd = loop {
                match link.recv_cmd().expect("coordinator is alive") {
                    Cmd::Spares(bufs) => bank.extend(bufs),
                    other => break other,
                }
            };
            let Cmd::Run {
                from,
                broadcast: Broadcast::Encoded { bytes, .. },
                ..
            } = cmd
            else {
                panic!("round {round}: expected Run with a streamed broadcast");
            };
            assert_eq!(from, round);
            assert_eq!(bytes.as_slice(), &BCAST, "round {round}: broadcast bytes");
            drop(bytes); // release the frame so the sweep can reclaim it
            // encode the uplink into a recycled buffer (a fresh alloc
            // only while the bank is still priming)
            let mut buf = bank.pop().unwrap_or_default();
            buf.reset();
            buf.extend_payload(&[round as u8; REPORT_LEN]);
            link.send_report(Ok(WorkerReport {
                reps: vec![(
                    0,
                    vec![round as f64],
                    SyncPayload::Encoded(WireSlice::whole(Arc::new(buf))),
                )],
            }))
            .unwrap();
        }
        // drain the last round's Spares, then the Finish
        loop {
            match link.recv_cmd().expect("awaiting Finish") {
                Cmd::Spares(_) => continue,
                Cmd::Finish { .. } => break,
                Cmd::Run { .. } => panic!("expected Finish, got another Run"),
            }
        }
    });

    let lanes = accept_workers(&listener, 1, &info).unwrap();
    let mut reactor = LaneReactor::new(lanes).unwrap();
    // headroom so a heartbeat landing mid-round never finds the pool
    // dry (its buffers are taken and returned inside the read pump)
    reactor.recycle((0..4).map(|_| WireBuf::new()).collect());

    let mut measured: Option<(u64, u64)> = None;
    for round in 0..TOTAL {
        if round == WARMUP {
            measured = Some(metrics::snapshot());
        }
        // downlink: streamed broadcast + the Run that references it
        reactor.bcast_begin(None, round as u64, BCAST.len() as u64).unwrap();
        reactor.bcast_chunk(&BCAST[..128]).unwrap();
        reactor.bcast_chunk(&BCAST[128..]).unwrap();
        reactor
            .send_cmd(&Cmd::Run {
                from: round,
                to: round + 1,
                broadcast: Broadcast::Pending { frag: None },
                payload: PayloadSpec::None,
                churn: SegmentChurn::default(),
            })
            .unwrap();
        // uplink: collect, check, reclaim the frame into the pool
        let reports = reactor.collect_reports().unwrap();
        assert_eq!(reports.len(), 1, "round {round}");
        let mut spent: Vec<WireSlice> = Vec::new();
        for rep in reports {
            for (rid, losses, p) in rep.reps {
                assert_eq!(rid, 0);
                assert_eq!(losses, vec![round as f64]);
                let SyncPayload::Encoded(ws) = p else {
                    panic!("round {round}: expected an encoded payload");
                };
                assert_eq!(ws.as_slice(), &[round as u8; REPORT_LEN]);
                spent.push(ws);
            }
        }
        reactor.recycle(reclaim_wires(spent));
    }

    let (alloc0, copy0) = measured.expect("warmup completed");
    let (alloc1, copy1) = metrics::snapshot();
    assert_eq!(
        (alloc1 - alloc0, copy1 - copy0),
        (0, 0),
        "steady-state rounds {WARMUP}..{TOTAL} must allocate no wire buffers and \
         stage no payload copies on either leg"
    );

    reactor.send_finish(&Broadcast::empty());
    worker.join().unwrap();
}
