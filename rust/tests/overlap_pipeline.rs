//! Overlapped outer sync invariants (the non-blocking fragment
//! pipeline with delayed application — see `coordinator::pool`):
//!
//! (1) **τ=0 is the barrier, bit for bit, at every (up, down) codec
//!     pair**: `drive` with `overlap_tau = 0` is pinned against an
//!     in-test *barrier oracle* — a hand-rolled replay of the retired
//!     segment loop (step, encode, `OuterSync::sync`/`sync_encoded`,
//!     broadcast-adopt, eval at the barrier) that never goes through
//!     the pipeline code. Step losses, eval curve, global arena,
//!     final replica payloads, wire bytes on both legs, and bus
//!     uploads must all agree exactly.
//! (2) **workers 1 vs 2 vs 4 are bit-identical at τ > 0** for every
//!     codec pair — the delayed merge schedule, EF streams, and
//!     encode seeds are scheduling-independent.
//! (3) delayed application changes the *schedule*, never the totals:
//!     τ>0 keeps sync counts and wire bytes, moves losses, and
//!     grounds evals on the merge schedule (an in-flight sync is
//!     invisible to eval).
//! (4) merge-ordering guards fail loud: τ without a sync engine, τ
//!     big enough to put two syncs in flight, and the end-of-training
//!     drain that must leave no fragment unflushed.
//!
//! Host tier only: no PJRT, no artifacts.

use std::sync::Arc;

use diloco::comm::{codec_for, OuterBits, ReplicaComm, WorkerComm};
use diloco::coordinator::{drive, DrivePlan, InnerEngine, OuterSync, ReplicaState};
use diloco::data::synthetic::{CorpusSpec, TokenStream};
use diloco::runtime::{FlatLayout, HostTensor};

// ---- the deterministic host-math engine (same as the pool twins) -----

struct ToyEngine {
    n: usize,
    /// Inject a failure at (replica, step) to test error propagation.
    fail_at: Option<(usize, usize)>,
}

impl InnerEngine for ToyEngine {
    fn inner_step(
        &self,
        rep: usize,
        replica: &mut ReplicaState,
        t: usize,
    ) -> anyhow::Result<f64> {
        if self.fail_at == Some((rep, t)) {
            anyhow::bail!("injected failure at replica {rep}, step {t}");
        }
        let toks = replica.shard.next_batch(2, 8);
        let mut loss = 0.0f64;
        for leaf in 0..self.n {
            let lit = &replica.state[leaf];
            let dims = lit.array_shape()?.dims().to_vec();
            let mut v = lit.to_vec::<f32>()?;
            for (i, x) in v.iter_mut().enumerate() {
                *x = 0.5 * *x
                    + 1e-3 * toks[(i + t) % toks.len()] as f32
                    + 1e-2 * (t as f32 + rep as f32 * 0.25).sin();
            }
            loss += v.iter().map(|&f| f as f64).sum::<f64>() / v.len() as f64;
            replica.state[leaf] = Arc::new(xla::Literal::vec1(&v).reshape(&dims)?);
        }
        Ok(loss / self.n as f64)
    }

    fn eval(&self, params: &[Arc<xla::Literal>]) -> anyhow::Result<f64> {
        let mut acc = 0.0f64;
        for (i, p) in params.iter().enumerate() {
            for x in p.to_vec::<f32>()? {
                acc += x as f64 * (i + 1) as f64;
            }
        }
        Ok(acc)
    }
}

fn layout() -> Arc<FlatLayout> {
    Arc::new(FlatLayout::new(vec![
        vec![3, 2],
        vec![4],
        vec![2, 2],
        vec![5],
        vec![1],
    ]))
}

fn init_lits(l: &FlatLayout) -> Vec<Arc<xla::Literal>> {
    (0..l.n_leaves())
        .map(|leaf| {
            let v: Vec<f32> = (0..l.len(leaf))
                .map(|i| ((leaf * 37 + i * 11 + 5) % 23) as f32 * 0.1 - 1.0)
                .collect();
            Arc::new(HostTensor::from_vec(l.shape(leaf), v).to_literal().unwrap())
        })
        .collect()
}

fn fresh_replicas(l: &FlatLayout, m: usize) -> Vec<ReplicaState> {
    let init = init_lits(l);
    (0..m)
        .map(|r| ReplicaState {
            state: init.clone(),
            shard: TokenStream::new(CorpusSpec::default(), 5, r as u64),
        })
        .collect()
}

fn fresh_sync(l: &Arc<FlatLayout>, up: OuterBits, down: OuterBits, fragments: usize) -> OuterSync {
    let init = init_lits(l);
    let host: Vec<HostTensor> = init
        .iter()
        .map(|lit| HostTensor::from_literal(lit).unwrap())
        .collect();
    OuterSync::new(Arc::clone(l), &host, init, 0.7, 0.9, fragments)
        .unwrap()
        .with_codec(codec_for(up), 42)
        .with_down_codec(codec_for(down))
}

/// Everything both the oracle and the pipeline report.
#[derive(PartialEq, Debug)]
struct RunTrace {
    step_losses: Vec<f64>,
    eval_curve: Vec<(usize, f64)>,
    outer_syncs: usize,
    global_bits: Vec<u32>,
    finals: Vec<Vec<Vec<f32>>>,
    wire_up: u64,
    wire_down: u64,
    uploads: u64,
}

const TOTAL: usize = 26;
const INTERVAL: usize = 6; // per-fragment sync interval (H/P)
const FRAGMENTS: usize = 2;
// Every third step: hits both in-segment steps (3, 9, 15, 21) and
// sync/merge boundaries (6, 12, 18, 24), so both eval paths — and
// their grounding on the merge schedule — are exercised.
const EVAL_EVERY: usize = 3;

fn finals_of(l: &FlatLayout, replicas: &[ReplicaState]) -> Vec<Vec<Vec<f32>>> {
    replicas
        .iter()
        .map(|r| {
            (0..l.n_leaves())
                .map(|leaf| r.state[leaf].to_vec::<f32>().unwrap())
                .collect()
        })
        .collect()
}

/// The schedule through the real pipeline (`coordinator::pool::drive`).
fn pipeline_run(up: OuterBits, down: OuterBits, m: usize, workers: usize, tau: usize) -> RunTrace {
    let l = layout();
    let engine = ToyEngine { n: l.n_leaves(), fail_at: None };
    let mut replicas = fresh_replicas(&l, m);
    let mut sync = fresh_sync(&l, up, down, FRAGMENTS);
    let plan = DrivePlan {
        total_steps: TOTAL,
        sync_interval: INTERVAL,
        fragments: FRAGMENTS,
        n_params: l.n_leaves(),
        eval_every: Some(EVAL_EVERY),
        log_every: 1000,
        workers,
        overlap_tau: tau,
    };
    let out = drive(&engine, &mut replicas, Some(&mut sync), &plan).expect("drive");
    RunTrace {
        step_losses: out.step_losses,
        eval_curve: out.eval_curve,
        outer_syncs: out.outer_syncs,
        global_bits: sync.global().data().iter().map(|x| x.to_bits()).collect(),
        finals: finals_of(&l, &replicas),
        wire_up: sync.wire_stats().total_up(),
        wire_down: sync.wire_stats().total_down(),
        uploads: sync.uploads(),
    }
}

/// The retired barrier semantics, replayed by hand — never touches the
/// pipeline's dispatch/collect/in-flight machinery. Sequential
/// (step-major, replica-minor), sync at every boundary, broadcast
/// adopted on the spot, evals inside a segment read the previous
/// sync's global and boundary evals the fresh one.
fn barrier_oracle(up: OuterBits, down: OuterBits, m: usize) -> RunTrace {
    let l = layout();
    let engine = ToyEngine { n: l.n_leaves(), fail_at: None };
    let mut replicas = fresh_replicas(&l, m);
    let mut sync = fresh_sync(&l, up, down, FRAGMENTS);
    let link = sync.link();
    let active = link.is_active();
    let wire_up = !codec_for(up).is_identity();
    let wire_down = !codec_for(down).is_identity();
    let mut wc = WorkerComm::default();
    let mut rcs: Vec<ReplicaComm> = (0..m).map(|_| ReplicaComm::default()).collect();
    if active {
        link.init_snapshot(&mut wc, &replicas[0].state).unwrap();
        for rc in rcs.iter_mut() {
            link.init_replica(rc);
        }
    }

    let mut step_losses = Vec::new();
    let mut eval_curve = Vec::new();
    let mut syncs = 0u64;
    let mut t0 = 0usize;
    while t0 < TOTAL {
        let t1 = TOTAL.min((t0 / INTERVAL + 1) * INTERVAL);
        // inner steps, step-major / replica-minor, mean in replica order
        for t in t0 + 1..=t1 {
            let mut step_loss = 0.0f64;
            for (r, rep) in replicas.iter_mut().enumerate() {
                step_loss += engine.inner_step(r, rep, t).unwrap() / m as f64;
            }
            step_losses.push(step_loss);
        }
        // in-segment evals: the previous sync's global
        for t in t0 + 1..t1 {
            if t % EVAL_EVERY == 0 && t != TOTAL {
                eval_curve.push((t, engine.eval(sync.global_literals().unwrap()).unwrap()));
            }
        }
        // the outer sync at the barrier
        let frag = if FRAGMENTS > 1 && t1 != TOTAL {
            Some(((t1 / INTERVAL).wrapping_sub(1)) % FRAGMENTS)
        } else {
            None
        };
        if wire_up {
            let payloads: Vec<diloco::transport::frame::WireSlice> = {
                let wc = &mut wc;
                replicas
                    .iter()
                    .zip(rcs.iter_mut())
                    .enumerate()
                    .map(|(r, (rep, rc))| {
                        link.encode_replica(r, &rep.state, wc, rc, frag, syncs).unwrap()
                    })
                    .collect()
            };
            let frames: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
            sync.sync_encoded(&frames, frag).unwrap();
        } else {
            let parts: Vec<&[Arc<xla::Literal>]> =
                replicas.iter().map(|r| &r.state[..]).collect();
            sync.sync(&parts, frag).unwrap();
        }
        syncs += 1;
        // broadcast, adopted on the spot (nothing runs in between)
        let adopt: Vec<(usize, Arc<xla::Literal>)> = if wire_down {
            let bytes = sync.take_broadcast_bytes().expect("lossy down payload");
            link.adopt_encoded(&mut wc, frag, bytes.as_slice()).unwrap()
        } else {
            let leaves: Vec<usize> = sync.synced_leaves(frag).collect();
            let lits = sync.global_literals().unwrap();
            let adopt: Vec<(usize, Arc<xla::Literal>)> = leaves
                .into_iter()
                .map(|leaf| (leaf, Arc::clone(&lits[leaf])))
                .collect();
            if active {
                link.adopt_literals(&mut wc, &adopt).unwrap();
            }
            adopt
        };
        for rep in replicas.iter_mut() {
            for (leaf, lit) in &adopt {
                rep.state[*leaf] = Arc::clone(lit);
            }
        }
        // boundary eval: the fresh post-sync global
        if t1 % EVAL_EVERY == 0 && t1 != TOTAL {
            eval_curve.push((t1, engine.eval(sync.global_literals().unwrap()).unwrap()));
        }
        t0 = t1;
    }
    RunTrace {
        step_losses,
        eval_curve,
        outer_syncs: syncs as usize,
        global_bits: sync.global().data().iter().map(|x| x.to_bits()).collect(),
        finals: finals_of(&l, &replicas),
        wire_up: sync.wire_stats().total_up(),
        wire_down: sync.wire_stats().total_down(),
        uploads: sync.uploads(),
    }
}

// ---- (1) τ=0 == the barrier, for every codec pair --------------------

#[test]
fn tau_zero_is_bit_identical_to_the_barrier_for_every_codec_pair() {
    for up in OuterBits::ALL {
        for down in OuterBits::ALL {
            let oracle = barrier_oracle(up, down, 4);
            assert_eq!(oracle.step_losses.len(), TOTAL, "{up:?}/{down:?}");
            assert!(oracle.outer_syncs > 0 && oracle.wire_up > 0, "{up:?}/{down:?}");
            for workers in [1usize, 2] {
                let pipe = pipeline_run(up, down, 4, workers, 0);
                assert_eq!(
                    pipe, oracle,
                    "{up:?}/{down:?} w={workers}: τ=0 must replay the barrier \
                     schedule bit for bit"
                );
            }
        }
    }
}

// ---- (2) workers bit-identical at τ > 0 ------------------------------

#[test]
fn workers_bit_identical_at_positive_tau_for_every_codec_pair() {
    // τ=1: every merge lands mid-segment; τ=3 (= (H/P)/2): the last
    // send's merge collides with the end of training and exercises the
    // drain (merge-then-flush at T).
    for up in OuterBits::ALL {
        for down in OuterBits::ALL {
            for tau in [1usize, INTERVAL / 2] {
                let oracle = pipeline_run(up, down, 4, 1, tau);
                assert_eq!(oracle.step_losses.len(), TOTAL);
                for workers in [2usize, 4] {
                    let par = pipeline_run(up, down, 4, workers, tau);
                    assert_eq!(
                        par, oracle,
                        "{up:?}/{down:?} τ={tau} w={workers}: overlap must stay \
                         scheduling-independent"
                    );
                }
            }
        }
    }
}

// ---- (3) τ changes the schedule, not the totals ----------------------

#[test]
fn overlap_delays_merges_without_changing_sync_totals() {
    let barrier = pipeline_run(OuterBits::Fp32, OuterBits::Fp32, 4, 1, 0);
    let overlap = pipeline_run(OuterBits::Fp32, OuterBits::Fp32, 4, 1, 3);
    // same sync events, same wire traffic: overlap defers application,
    // it never skips or duplicates communication
    assert_eq!(overlap.outer_syncs, barrier.outer_syncs);
    assert_eq!(overlap.wire_up, barrier.wire_up);
    assert_eq!(overlap.wire_down, barrier.wire_down);
    // but delayed application is a different training trajectory
    assert_ne!(
        overlap.step_losses, barrier.step_losses,
        "τ>0 must actually delay the merge"
    );

    // eval grounding on the merge schedule: the eval at step 6 lands
    // on the send boundary, τ steps before merge(9) — the τ=3 run must
    // still see the INITIAL global (the sync is in flight, no replica
    // has it), while the barrier run already sees sync(6)'s result.
    let l = layout();
    let engine = ToyEngine { n: l.n_leaves(), fail_at: None };
    let at_init = engine.eval(&init_lits(&l)).unwrap();
    assert_eq!(overlap.eval_curve[0], (3, at_init), "pre-sync eval sees init");
    assert_eq!(overlap.eval_curve[1].0, 6);
    assert_eq!(
        overlap.eval_curve[1].1, at_init,
        "an in-flight sync must be invisible to eval"
    );
    assert_eq!(barrier.eval_curve[1].0, 6);
    assert_ne!(
        barrier.eval_curve[1].1, at_init,
        "the barrier applies sync(6) at its own boundary"
    );
}

// ---- (4) drain + guards ---------------------------------------------

#[test]
fn end_of_training_drains_the_in_flight_fragment() {
    // τ=3, sends at 6/12/18/24 and the final flush at 26: merge(24)
    // clamps to 26, so the drain must merge it, then flush — 5 syncs,
    // and every replica ends on the shared final global literals.
    let l = layout();
    let engine = ToyEngine { n: l.n_leaves(), fail_at: None };
    let mut replicas = fresh_replicas(&l, 4);
    let mut sync = fresh_sync(&l, OuterBits::Fp32, OuterBits::Fp32, FRAGMENTS);
    let plan = DrivePlan {
        total_steps: TOTAL,
        sync_interval: INTERVAL,
        fragments: FRAGMENTS,
        n_params: l.n_leaves(),
        eval_every: None,
        log_every: 1000,
        workers: 2,
        overlap_tau: 3,
    };
    let out = drive(&engine, &mut replicas, Some(&mut sync), &plan).expect("drive");
    assert_eq!(out.outer_syncs, 5, "4 fragment sends + the final full flush");
    let lits = sync.global_literals().unwrap().to_vec();
    for (r, rep) in replicas.iter().enumerate() {
        for leaf in 0..l.n_leaves() {
            assert!(
                Arc::ptr_eq(&rep.state[leaf], &lits[leaf]),
                "replica {r} leaf {leaf}: final flush must broadcast to everyone"
            );
        }
    }
}

#[test]
fn merge_ordering_guards_fail_loud() {
    let l = layout();
    let engine = ToyEngine { n: l.n_leaves(), fail_at: None };
    // τ without an outer sync: nothing exists to delay
    let mut replicas = fresh_replicas(&l, 2);
    let plan = DrivePlan {
        total_steps: 10,
        sync_interval: usize::MAX,
        fragments: 1,
        n_params: l.n_leaves(),
        eval_every: None,
        log_every: 1000,
        workers: 1,
        overlap_tau: 1,
    };
    let err = drive(&engine, &mut replicas, None, &plan).expect_err("tau without sync");
    assert!(format!("{err:#}").contains("overlap_tau"), "{err:#}");

    // τ >= the fragment interval: a second sync would launch while the
    // first is still in flight
    for tau in [INTERVAL, INTERVAL + 5] {
        let mut replicas = fresh_replicas(&l, 2);
        let mut sync = fresh_sync(&l, OuterBits::Fp32, OuterBits::Fp32, FRAGMENTS);
        let plan = DrivePlan {
            total_steps: TOTAL,
            sync_interval: INTERVAL,
            fragments: FRAGMENTS,
            n_params: l.n_leaves(),
            eval_every: None,
            log_every: 1000,
            workers: 1,
            overlap_tau: tau,
        };
        let err = drive(&engine, &mut replicas, Some(&mut sync), &plan)
            .expect_err("two syncs in flight must be refused");
        assert!(format!("{err:#}").contains("in flight"), "τ={tau}: {err:#}");
    }

    // an un-taken lossy broadcast refuses the next sync (the OuterSync
    // guard the pipeline relies on: a dropped payload would silently
    // desynchronize every replica from the down-wire view)
    let mut sync = fresh_sync(&l, OuterBits::Fp32, OuterBits::Int4, 1);
    let theta = init_lits(&l);
    sync.sync(&[&theta[..], &theta[..]], None).unwrap();
    assert!(
        sync.sync(&[&theta[..], &theta[..]], None).is_err(),
        "un-taken broadcast payload must refuse the next sync"
    );
}

// ---- (5) worker failure with a sync in flight ------------------------

#[test]
fn worker_failure_with_sync_in_flight_propagates_without_hanging() {
    // τ=3: the failure at step 8 lands after the send at 6 and before
    // its merge at 9 — a sync is in flight when replica 1 dies. The
    // drive must return a clean Err (no hang on the abandoned merge),
    // name the injected failure, and hand every replica state back,
    // at any worker count.
    let l = layout();
    let engine = ToyEngine {
        n: l.n_leaves(),
        fail_at: Some((1, 8)),
    };
    for workers in [1usize, 2, 4] {
        let mut replicas = fresh_replicas(&l, 4);
        let mut sync = fresh_sync(&l, OuterBits::Fp32, OuterBits::Fp32, FRAGMENTS);
        let plan = DrivePlan {
            total_steps: TOTAL,
            sync_interval: INTERVAL,
            fragments: FRAGMENTS,
            n_params: l.n_leaves(),
            eval_every: None,
            log_every: 1000,
            workers,
            overlap_tau: 3,
        };
        let err = drive(&engine, &mut replicas, Some(&mut sync), &plan)
            .expect_err("injected failure must propagate with a sync in flight");
        assert!(
            format!("{err:#}").contains("injected failure"),
            "workers={workers}: {err:#}"
        );
        assert_eq!(
            replicas.len(),
            4,
            "workers={workers}: replica states must be handed back"
        );
    }
}
