//! The loopback twin: a coordinator driving real TCP sockets on
//! 127.0.0.1 must be bit-identical to the single-process in-proc run —
//! same per-step losses, same eval curve, same wire accounting, same
//! final replica payloads — for the identity codecs and for the int4
//! quantized wires, at barrier (τ=0) and overlapped (τ=1) schedules.
//! The in-proc channel transport is the oracle; any divergence means
//! the frame codec, the lane executor, or the worker-side comm rebuild
//! changed training math.
//!
//! Also pins the crash path: a worker that silently drops its socket
//! mid-run must surface as journaled `Crash` events for its replicas
//! while the survivors finish the schedule.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;

use diloco::comm::{CommLink, OuterBits, ReplicaComm, WorkerComm};
use diloco::coordinator::{
    drive_ctl, drive_reactor, worker_session, DriveCtl, DrivePlan, EventKind, InnerEngine,
    OuterSync, OwnedReplica,
};
use diloco::runtime::HostTensor;
use diloco::train::toy::{toy_init, toy_layout, toy_replicas, toy_replicas_for, ToyEngine};
use diloco::transport::msg::Cmd;
use diloco::transport::tcp::{
    accept_workers, connect_with_backoff, worker_handshake, LaneReactor, SessionInfo,
    TcpWorkerLink, CONNECT_ATTEMPTS, ENGINE_TOY,
};
use diloco::transport::WorkerLink;

const M: usize = 4;
const SEED: u64 = 42;
const FRAGMENTS: usize = 2;

fn plan(workers: usize, tau: usize) -> DrivePlan {
    DrivePlan {
        total_steps: 22,
        sync_interval: 3, // H=6, P=2 -> a fragment every 3 steps
        fragments: FRAGMENTS,
        n_params: toy_layout().n_leaves(),
        eval_every: Some(7),
        log_every: 5,
        workers,
        overlap_tau: tau,
    }
}

fn outer_sync(up: OuterBits, down: OuterBits) -> OuterSync {
    use diloco::comm::codec_for;
    let l = toy_layout();
    let init_lits = toy_init(&l, SEED).unwrap();
    let host: Vec<HostTensor> = init_lits
        .iter()
        .map(|lit| HostTensor::from_literal(lit).unwrap())
        .collect();
    OuterSync::new(Arc::clone(&l), &host, init_lits, 0.7, 0.9, FRAGMENTS)
        .unwrap()
        .with_codec(codec_for(up), SEED)
        .with_down_codec(codec_for(down))
}

struct RunResult {
    step_losses: Vec<f64>,
    loss_curve: Vec<(usize, f64)>,
    eval_curve: Vec<(usize, f64)>,
    outer_syncs: usize,
    wire_up: u64,
    wire_down: u64,
    framed: u64,
    global_bits: Vec<u32>,
    final_eval: f64,
    /// Per-replica, per-leaf payload bits after the final flush, in
    /// replica-id order.
    finals: Vec<Vec<Vec<u32>>>,
}

fn leaf_bits(state: &[Arc<xla::Literal>], n_leaves: usize) -> Vec<Vec<u32>> {
    (0..n_leaves)
        .map(|leaf| {
            state[leaf]
                .to_vec::<f32>()
                .unwrap()
                .iter()
                .map(|x| x.to_bits())
                .collect()
        })
        .collect()
}

/// The oracle: the whole universe in this process over mpsc channels
/// (`drive_ctl`'s sequential path — the reference every transport is
/// pinned against).
fn run_inproc(up: OuterBits, down: OuterBits, tau: usize) -> RunResult {
    let l = toy_layout();
    let engine = ToyEngine::new(&l);
    let mut replicas = toy_replicas(&l, 0..M, SEED).unwrap();
    let mut sync = outer_sync(up, down);
    let mut ctl = DriveCtl::fresh(M);
    let out = drive_ctl(&engine, &mut replicas, Some(&mut sync), &plan(1, tau), &mut ctl)
        .expect("in-proc drive");
    let final_eval = engine.eval(sync.global_literals().unwrap()).unwrap();
    RunResult {
        step_losses: out.step_losses,
        loss_curve: out.loss_curve,
        eval_curve: out.eval_curve,
        outer_syncs: out.outer_syncs,
        wire_up: sync.wire_stats().total_up(),
        wire_down: sync.wire_stats().total_down(),
        framed: sync.wire_stats().total_framed(),
        global_bits: sync.global().data().iter().map(|x| x.to_bits()).collect(),
        final_eval,
        finals: replicas
            .iter()
            .map(|r| leaf_bits(&r.state, l.n_leaves()))
            .collect(),
    }
}

/// One worker process, played by a thread: connect, hand-shake, rebuild
/// engine + replicas + comm link from scratch (exactly what
/// `diloco worker` does), serve segments, return final replica states.
fn spawn_worker(
    addr: String,
    claims: Vec<usize>,
    up: OuterBits,
    down: OuterBits,
) -> thread::JoinHandle<Vec<OwnedReplica>> {
    thread::spawn(move || {
        let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
        let info = worker_handshake(&mut stream, &claims, 0, 0, 0).unwrap();
        assert_eq!(info.engine, ENGINE_TOY);
        let l = toy_layout();
        let engine = ToyEngine::new(&l);
        let reps = toy_replicas_for(&l, &claims, SEED).unwrap();
        let mut owned: Vec<OwnedReplica> = claims
            .iter()
            .zip(reps)
            .map(|(&rid, rep)| OwnedReplica {
                rid,
                live: info.live[rid],
                rep,
                rc: ReplicaComm::default(),
            })
            .collect();
        let mut wc = WorkerComm::default();
        let link = CommLink::for_run(&l, up, down, FRAGMENTS, SEED);
        let link = if link.is_active() {
            link.init_snapshot(&mut wc, &owned[0].rep.state).unwrap();
            for o in &mut owned {
                link.init_replica(&mut o.rc);
            }
            Some(link)
        } else {
            None
        };
        let mut wl = TcpWorkerLink::new(stream, &info).unwrap();
        let (owned, _arena, finish) =
            worker_session(&engine, l.n_leaves(), link, wc, owned, &mut wl);
        finish.unwrap();
        owned
    })
}

/// The same schedule over real sockets: two worker threads each owning
/// half the universe, the coordinator on TCP lanes.
fn run_tcp(up: OuterBits, down: OuterBits, tau: usize) -> RunResult {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let info = SessionInfo {
        fingerprint: 0x7717, // nonzero: workers sending 0 adopt it
        up_bits: up.bits() as u8,
        down_bits: down.bits() as u8,
        engine: ENGINE_TOY,
        live: vec![true; M],
        config_json: String::from("{}"),
    };
    let workers = vec![
        spawn_worker(addr.clone(), vec![0, 1], up, down),
        spawn_worker(addr, vec![2, 3], up, down),
    ];
    let lanes = accept_workers(&listener, workers.len(), &info).unwrap();
    let mut reactor = LaneReactor::new(lanes).unwrap();

    let l = toy_layout();
    let engine = ToyEngine::new(&l);
    let mut sync = outer_sync(up, down);
    let mut ctl = DriveCtl::fresh(M);
    let out = drive_reactor(&engine, &mut reactor, Some(&mut sync), &plan(2, tau), &mut ctl)
        .expect("tcp drive");
    let final_eval = engine.eval(sync.global_literals().unwrap()).unwrap();

    let mut owned: Vec<OwnedReplica> = workers
        .into_iter()
        .flat_map(|h| h.join().expect("worker thread"))
        .collect();
    owned.sort_by_key(|o| o.rid);
    RunResult {
        step_losses: out.step_losses,
        loss_curve: out.loss_curve,
        eval_curve: out.eval_curve,
        outer_syncs: out.outer_syncs,
        wire_up: sync.wire_stats().total_up(),
        wire_down: sync.wire_stats().total_down(),
        framed: sync.wire_stats().total_framed(),
        global_bits: sync.global().data().iter().map(|x| x.to_bits()).collect(),
        final_eval,
        finals: owned
            .iter()
            .map(|o| leaf_bits(&o.rep.state, l.n_leaves()))
            .collect(),
    }
}

fn assert_twin(up: OuterBits, down: OuterBits, tau: usize) {
    let oracle = run_inproc(up, down, tau);
    let tcp = run_tcp(up, down, tau);
    let tag = format!("{up:?}/{down:?} tau={tau}");
    assert_eq!(oracle.step_losses.len(), 22, "{tag}");
    assert!(oracle.outer_syncs > 0, "{tag}");
    // f64/bit equality is exact: same values in the same order, or bust
    assert_eq!(tcp.step_losses, oracle.step_losses, "{tag}: step losses");
    assert_eq!(tcp.loss_curve, oracle.loss_curve, "{tag}: loss curve");
    assert_eq!(tcp.eval_curve, oracle.eval_curve, "{tag}: eval curve");
    assert_eq!(tcp.outer_syncs, oracle.outer_syncs, "{tag}: sync count");
    assert_eq!(tcp.wire_up, oracle.wire_up, "{tag}: up-wire bytes");
    assert_eq!(tcp.wire_down, oracle.wire_down, "{tag}: down-wire bytes");
    assert_eq!(tcp.framed, oracle.framed, "{tag}: framed bytes");
    assert_eq!(tcp.global_bits, oracle.global_bits, "{tag}: global arena");
    assert_eq!(
        tcp.final_eval.to_bits(),
        oracle.final_eval.to_bits(),
        "{tag}: final eval"
    );
    assert_eq!(tcp.finals, oracle.finals, "{tag}: final replica payloads");
}

#[test]
fn tcp_twin_identity_codecs_barrier() {
    assert_twin(OuterBits::Fp32, OuterBits::Fp32, 0);
}

#[test]
fn tcp_twin_identity_codecs_overlapped() {
    assert_twin(OuterBits::Fp32, OuterBits::Fp32, 1);
}

#[test]
fn tcp_twin_int4_both_wires_barrier() {
    assert_twin(OuterBits::Int4, OuterBits::Int4, 0);
}

#[test]
fn tcp_twin_int4_both_wires_overlapped() {
    assert_twin(OuterBits::Int4, OuterBits::Int4, 1);
}

/// A worker link that vanishes (socket and all) after serving `left`
/// commands — the test double for `kill -9` on a worker process.
struct DropAfter {
    inner: TcpWorkerLink,
    left: usize,
}

impl WorkerLink for DropAfter {
    fn recv_cmd(&mut self) -> Option<Cmd> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        self.inner.recv_cmd()
    }

    fn send_report(
        &mut self,
        report: anyhow::Result<diloco::transport::msg::WorkerReport>,
    ) -> anyhow::Result<()> {
        self.inner.send_report(report)
    }
}

#[test]
fn dead_tcp_worker_becomes_a_journaled_crash_and_survivors_finish() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let info = SessionInfo {
        fingerprint: 0,
        up_bits: 32,
        down_bits: 32,
        engine: ENGINE_TOY,
        live: vec![true; M],
        config_json: String::from("{}"),
    };

    // Worker A serves the whole run; worker B drops its socket after
    // three segments without a goodbye.
    let survivor = spawn_worker(addr.clone(), vec![0, 1], OuterBits::Fp32, OuterBits::Fp32);
    let casualty = {
        let addr = addr.clone();
        thread::spawn(move || {
            let claims = vec![2usize, 3];
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            let session = worker_handshake(&mut stream, &claims, 0, 0, 0).unwrap();
            let l = toy_layout();
            let engine = ToyEngine::new(&l);
            let reps = toy_replicas_for(&l, &claims, SEED).unwrap();
            let owned: Vec<OwnedReplica> = claims
                .iter()
                .zip(reps)
                .map(|(&rid, rep)| OwnedReplica {
                    rid,
                    live: session.live[rid],
                    rep,
                    rc: ReplicaComm::default(),
                })
                .collect();
            let wl = TcpWorkerLink::new(stream, &session).unwrap();
            let mut wl = DropAfter { inner: wl, left: 3 };
            let (_, _, finish) =
                worker_session(&engine, l.n_leaves(), None, WorkerComm::default(), owned, &mut wl);
            finish.unwrap(); // the casualty itself exits cleanly
        })
    };
    let lanes = accept_workers(&listener, 2, &info).unwrap();
    let mut reactor = LaneReactor::new(lanes).unwrap();

    let l = toy_layout();
    let engine = ToyEngine::new(&l);
    let mut sync = outer_sync(OuterBits::Fp32, OuterBits::Fp32);
    let mut ctl = DriveCtl::fresh(M);
    let out = drive_reactor(&engine, &mut reactor, Some(&mut sync), &plan(2, 0), &mut ctl)
        .expect("survivors must finish the schedule");
    assert_eq!(out.step_losses.len(), 22, "full schedule ran");

    // The dropped lane's replicas crash out of the membership...
    assert_eq!(ctl.live, vec![true, true, false, false]);
    let crashed: Vec<usize> = ctl
        .journal
        .events()
        .iter()
        .filter(|e| e.kind == EventKind::Crash)
        .filter_map(|e| e.replica)
        .collect();
    assert_eq!(crashed, vec![2, 3], "both of the dead worker's replicas journal a crash");
    // ...and the run keeps syncing afterwards (survivors contribute).
    assert!(out.outer_syncs > 3, "survivors kept the outer loop going");

    let survivors = survivor.join().expect("survivor thread");
    assert_eq!(survivors.len(), 2, "survivor hands back both replicas");
    casualty.join().expect("casualty thread");
}
