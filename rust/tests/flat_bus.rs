//! Flat-bus equivalence and broadcast-dedup invariants.
//!
//! (1) The vectorized flat-bus outer step (`OuterSync` over
//! `FlatParams`) is pinned bit-for-bit against the retired per-leaf
//! scalar implementation, which lives on (frozen, one canonical copy)
//! as `coordinator::outer_opt::scalar_ref` and serves here as the
//! oracle — over random replica counts M in 1..8, leaf shapes,
//! momentum, outer LR, fragment counts, and multi-round streaming
//! schedules. This is what
//! guarantees `tests/diloco_invariants.rs` and
//! `tests/streaming_diloco.rs` semantics are unchanged by the perf
//! rework.
//!
//! (2) The deduplicated broadcast uploads each synced leaf exactly
//! once per sync (N, not M×N — counted through the bus), replicas
//! share the uploaded literal by pointer, and the final full flush
//! leaves no fragment stale.
//!
//! These tests run on the host tier of the literal bridge — no PJRT,
//! no artifacts needed.

use std::sync::Arc;

use diloco::coordinator::outer_opt::scalar_ref;
use diloco::coordinator::OuterSync;
use diloco::runtime::{FlatLayout, HostTensor};
use diloco::util::prop;
use diloco::util::rng::Rng;

// ---- helpers ---------------------------------------------------------

fn random_shapes(rng: &mut Rng) -> Vec<Vec<usize>> {
    let leaves = 1 + rng.below(6) as usize;
    (0..leaves)
        .map(|_| {
            if rng.below(2) == 0 {
                vec![1 + rng.below(12) as usize]
            } else {
                vec![1 + rng.below(6) as usize, 1 + rng.below(6) as usize]
            }
        })
        .collect()
}

fn random_leaf_values(rng: &mut Rng, layout: &FlatLayout) -> Vec<Vec<f32>> {
    (0..layout.n_leaves())
        .map(|l| (0..layout.len(l)).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn to_host(layout: &FlatLayout, leaves: &[Vec<f32>]) -> Vec<HostTensor> {
    leaves
        .iter()
        .enumerate()
        .map(|(l, v)| HostTensor::from_vec(layout.shape(l), v.clone()))
        .collect()
}

fn to_lits(layout: &FlatLayout, leaves: &[Vec<f32>]) -> Vec<Arc<xla::Literal>> {
    to_host(layout, leaves)
        .iter()
        .map(|t| Arc::new(t.to_literal().unwrap()))
        .collect()
}

// ---- (1) flat bus == scalar oracle, bit for bit ----------------------

#[test]
fn prop_flat_bus_matches_scalar_oracle() {
    #[derive(Debug)]
    struct Case {
        shapes: Vec<Vec<usize>>,
        m: usize,
        fragments: usize,
        lr: f64,
        mu: f64,
        rounds: Vec<(Option<usize>, Vec<Vec<Vec<f32>>>)>, // (frag, per-replica leaves)
        init: Vec<Vec<f32>>,
    }

    prop::check(
        0xF1A7,
        48,
        |rng: &mut Rng| {
            let shapes = random_shapes(rng);
            let layout = FlatLayout::new(shapes.clone());
            let m = 1 + rng.below(8) as usize;
            let fragments = 1 + rng.below(4) as usize;
            let lr = rng.range_f64(0.1, 1.5);
            let mu = if rng.below(3) == 0 { 0.0 } else { rng.range_f64(0.0, 0.99) };
            let init = random_leaf_values(rng, &layout);
            // a streaming round-robin schedule ending in a full flush,
            // with fresh replica values every round (as after H inner
            // steps)
            let n_rounds = fragments + 1 + rng.below(3) as usize;
            let rounds = (0..n_rounds)
                .map(|k| {
                    let frag = if fragments > 1 && k + 1 != n_rounds {
                        Some(k % fragments)
                    } else {
                        None
                    };
                    let reps = (0..m).map(|_| random_leaf_values(rng, &layout)).collect();
                    (frag, reps)
                })
                .collect();
            Case {
                shapes,
                m,
                fragments,
                lr,
                mu,
                rounds,
                init,
            }
        },
        |case| {
            let layout = Arc::new(FlatLayout::new(case.shapes.clone()));

            // flat side: OuterSync over the literal bridge
            let init_host = to_host(&layout, &case.init);
            let init_lits = to_lits(&layout, &case.init);
            let mut flat = OuterSync::new(
                Arc::clone(&layout),
                &init_host,
                init_lits,
                case.lr,
                case.mu,
                case.fragments,
            )
            .map_err(|e| e.to_string())?;

            // oracle side: the frozen scalar reference on raw vectors
            let mut oracle_global: Vec<Vec<f32>> = case.init.clone();
            let mut oracle = scalar_ref::ScalarOuterOpt::new(case.lr as f32, case.mu as f32);

            for (frag, reps) in &case.rounds {
                let rep_lits: Vec<Vec<Arc<xla::Literal>>> =
                    reps.iter().map(|r| to_lits(&layout, r)).collect();
                let parts: Vec<&[Arc<xla::Literal>]> =
                    rep_lits.iter().map(|v| &v[..]).collect();
                flat.sync(&parts, *frag).map_err(|e| e.to_string())?;

                let p = case.fragments;
                let delta = scalar_ref::outer_gradient(&oracle_global, reps);
                oracle.step_subset(&mut oracle_global, &delta, |leaf| {
                    frag.is_none_or(|f| leaf % p == f)
                });

                // bit-for-bit: same element-wise operation order
                for leaf in 0..layout.n_leaves() {
                    let got: Vec<f32> = flat.global().leaf(leaf).to_vec();
                    let want = &oracle_global[leaf];
                    for i in 0..want.len() {
                        if got[i].to_bits() != want[i].to_bits() {
                            return Err(format!(
                                "leaf {leaf}[{i}]: flat {} != oracle {} (frag {frag:?}, M={}, P={}, mu={})",
                                got[i], want[i], case.m, case.fragments, case.mu
                            ));
                        }
                    }
                    // and the literal cache always mirrors the arena
                    let cached = flat.global_literals().unwrap()[leaf]
                        .to_vec::<f32>()
                        .unwrap();
                    if cached != got {
                        return Err(format!("leaf {leaf}: stale literal cache"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---- (2) broadcast dedup + streaming staleness -----------------------

#[test]
fn streaming_broadcast_uploads_only_due_fragment_and_flush_clears_stale() {
    // 7 leaves, P=3: fragments {0,3,6}, {1,4}, {2,5}
    let layout = Arc::new(FlatLayout::new(
        (0..7).map(|i| vec![i + 1]).collect::<Vec<_>>(),
    ));
    let fragments = 3usize;
    let m = 2usize;
    let mut rng = Rng::new(0xB05);

    let init = random_leaf_values(&mut rng, &layout);
    let mut sync = OuterSync::new(
        Arc::clone(&layout),
        &to_host(&layout, &init),
        to_lits(&layout, &init),
        0.8,
        0.9,
        fragments,
    )
    .unwrap();

    // replica states as the coordinator holds them (params slice only)
    let mut states: Vec<Vec<Arc<xla::Literal>>> = (0..m)
        .map(|_| to_lits(&layout, &random_leaf_values(&mut rng, &layout)))
        .collect();

    let mut uploads_before = sync.uploads();
    assert_eq!(uploads_before, 0, "setup must not upload through the bus");

    // three fragment syncs (round-robin), then the final full flush
    let schedule: Vec<Option<usize>> = vec![Some(0), Some(1), Some(2), Some(0), None];
    for frag in schedule {
        // replicas drift between syncs (H inner steps)
        for s in states.iter_mut() {
            *s = to_lits(&layout, &random_leaf_values(&mut rng, &layout));
        }
        {
            let parts: Vec<&[Arc<xla::Literal>]> = states.iter().map(|v| &v[..]).collect();
            sync.sync(&parts, frag).unwrap();
        }
        let expected: Vec<usize> = sync.synced_leaves(frag).collect();
        let uploaded = sync.uploads() - uploads_before;
        assert_eq!(
            uploaded,
            expected.len() as u64,
            "frag {frag:?}: uploads must equal the due fragment's leaf count \
             (N per full sync, never M*N)"
        );
        uploads_before = sync.uploads();

        // broadcast: all replicas adopt the same literal per synced leaf
        for s in states.iter_mut() {
            for leaf in sync.synced_leaves(frag) {
                s[leaf] = Arc::clone(&sync.global_literals().unwrap()[leaf]);
            }
        }
        for leaf in sync.synced_leaves(frag) {
            assert!(
                Arc::ptr_eq(&states[0][leaf], &states[1][leaf]),
                "leaf {leaf}: replicas must share one uploaded literal"
            );
        }
    }

    // after the final full flush no leaf is stale: every replica points
    // at the current global literal, whose payload matches the arena
    for leaf in 0..layout.n_leaves() {
        for s in &states {
            assert!(
                Arc::ptr_eq(&s[leaf], &sync.global_literals().unwrap()[leaf]),
                "leaf {leaf} left stale after final flush"
            );
        }
        let cached = sync.global_literals().unwrap()[leaf].to_vec::<f32>().unwrap();
        assert_eq!(cached, sync.global().leaf(leaf).to_vec());
    }
}

// ---- (3) fragment schedule covers every leaf exactly once per cycle --

#[test]
fn fragment_round_robin_covers_all_leaves() {
    let layout = FlatLayout::new((0..10).map(|i| vec![i % 3 + 1]).collect::<Vec<_>>());
    for p in 1..=5usize {
        let mut seen = vec![0usize; layout.n_leaves()];
        for f in 0..p {
            for leaf in layout.leaves(p, Some(f)) {
                seen[leaf] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "P={p}: {seen:?}");
    }
}
