//! Report-harness integration: every experiment generator must produce
//! non-empty, well-formed output against (a) an empty store and (b) a
//! synthetic store shaped like real sweep data. This keeps `diloco
//! report --exp all` total even while sweeps are still running.

use std::path::Path;

use diloco::config::RepoConfig;
use diloco::coordinator::RunMetrics;
use diloco::report::{experiment_ids, generate};
use diloco::sweep::SweepStore;

fn repo() -> RepoConfig {
    RepoConfig::load(Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap()
}

fn fake_metrics(model: &str, algo: &str, n: usize, loss: f64, batch: usize, lr: f64, eta: f64, h: usize) -> RunMetrics {
    RunMetrics {
        model: model.into(),
        algo: algo.into(),
        replicas: algo.strip_prefix("diloco-m").and_then(|m| m.parse().ok()).unwrap_or(1),
        sync_every: h,
        global_batch_tokens: batch,
        inner_lr: lr,
        outer_lr: eta,
        overtrain: 1.0,
        seed: 17,
        param_count: n,
        steps: 100,
        tokens: 100 * batch,
        final_eval_loss: loss,
        final_train_loss: loss + 0.01,
        eval_curve: vec![(100, loss)],
        loss_curve: vec![(1, 6.2), (100, loss + 0.01)],
        downstream: vec![
            ("cloze-long".into(), 0.5),
            ("cloze-short".into(), 0.6),
            ("cloze-hard".into(), 0.4),
        ],
        outer_syncs: if h > 0 { 100 / h } else { 0 },
        wall_secs: 1.0,
        fragments: 1,
        overlap_tau: 0,
        outer_bits: 32,
        outer_bits_down: 32,
        wire_up_bytes: if h > 0 { (100 / h) as u64 * n as u64 * 4 } else { 0 },
        wire_down_bytes: if h > 0 { (100 / h) as u64 * n as u64 * 4 } else { 0 },
        wire_framed_bytes: if h > 0 { (100 / h) as u64 * (n as u64 * 8 + 72) } else { 0 },
        churn: String::new(),
        dropout_rate: 0.0,
        sync_encode_ms: 0.0,
        sync_wire_wait_ms: 0.0,
        sync_reduce_ms: 0.0,
        sync_step_ms: 0.0,
        sync_bcast_ms: 0.0,
    }
}

fn synthetic_store(dir: &Path) -> SweepStore {
    let mut store = SweepStore::open(&dir.join("synthetic.jsonl")).unwrap();
    // A plausible mini-sweep: loss follows a power law in N with small
    // per-algo offsets; optimal batch grows with M.
    let ladder = [("m0", 26264usize), ("m1", 53520), ("m2", 135664)];
    let algos = [("dp", 0.0f64), ("diloco-m1", -0.002), ("diloco-m2", 0.004), ("diloco-m4", 0.01), ("diloco-m8", 0.02)];
    let mut id = 0usize;
    for (model, n) in ladder {
        for (algo, off) in algos {
            for batch in [512usize, 1024, 2048] {
                for lr in [4e-3, 6e-3] {
                    let base = 18.0 * (n as f64).powf(-0.095);
                    let loss = base * (1.0 + off) + 0.02 * (batch as f64 / 1024.0 - 1.0).abs();
                    let m = fake_metrics(model, algo, n, loss, batch, lr, 0.6, if algo == "dp" { 0 } else { 30 });
                    store.insert(&format!("fake{id}"), &m).unwrap();
                    id += 1;
                }
            }
        }
    }
    // H-sweep entries
    for h in [1usize, 5, 10, 30, 100, 300] {
        for (algo, _) in &algos[1..4] {
            let m = fake_metrics("m0", algo, 26264, 4.0 + 0.01 * (h as f64).ln(), 1024, 6e-3, 0.6, h);
            store.insert(&format!("fakeh{id}"), &m).unwrap();
            id += 1;
        }
    }
    // stream-grid entries: overlap corners at matched hypers (the
    // (1, 0) row is the barrier baseline the deltas anchor on)
    for (p, tau) in [(1usize, 0usize), (2, 0), (2, 1), (2, 7)] {
        let mut m = fake_metrics("m0", "diloco-m2", 26264, 4.01 + 0.002 * tau as f64, 1024, 6e-3, 0.6, 30);
        m.fragments = p;
        m.overlap_tau = tau;
        store.insert(&format!("fakes{id}"), &m).unwrap();
        id += 1;
    }
    // churn-grid entries: fault plans at matched hypers (the empty
    // plan is the churn-free baseline the deltas anchor on)
    for (spec, rate) in [
        ("", 0.0f64),
        ("rate=0.1", 0.125),
        ("crash@2:r1,join@4:r4", 0.08),
    ] {
        let mut m = fake_metrics("m0", "diloco-m4", 26264, 4.02 + 0.5 * rate, 1024, 6e-3, 0.6, 30);
        m.churn = spec.into();
        m.dropout_rate = rate;
        store.insert(&format!("fakec{id}"), &m).unwrap();
        id += 1;
    }
    store
}

#[test]
fn all_generators_survive_empty_store() {
    let dir = std::env::temp_dir().join(format!("rep_empty_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let store = SweepStore::open(&dir.join("empty.jsonl")).unwrap();
    let repo = repo();
    for id in experiment_ids() {
        let text = generate(id, &store, &repo, 8).unwrap_or_else(|e| panic!("{id}: {e}"));
        assert!(!text.is_empty(), "{id} empty");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn generators_reflect_store_contents() {
    let dir = std::env::temp_dir().join(format!("rep_synth_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let store = synthetic_store(&dir);
    let repo = repo();

    let t4 = generate("table4", &store, &repo, 8).unwrap();
    assert!(t4.contains("m0") && t4.contains("m2"), "{t4}");
    assert!(t4.contains('%'), "percent diffs present");

    let t7 = generate("table7", &store, &repo, 8).unwrap();
    // our fitted alpha on the synthetic store is ~-0.095
    assert!(t7.contains("-0.09"), "{t7}");

    let f9 = generate("fig9", &store, &repo, 8).unwrap();
    assert!(f9.lines().filter(|l| l.contains(',')).count() >= 12, "{f9}");

    let f2 = generate("fig2", &store, &repo, 8).unwrap();
    assert!(f2.contains("pct_vs_dp"));

    // comm report: 32-bit records form the fp32 baseline rows, with
    // exact wire bytes surfaced from the metrics
    let comm = generate("comm", &store, &repo, 8).unwrap();
    assert!(comm.contains("baseline"), "{comm}");
    assert!(comm.contains("diloco-m2"), "{comm}");

    // stream report: the barrier row anchors the loss-vs-τ deltas,
    // and the analytic walltime-vs-τ section always renders
    let stream = generate("stream", &store, &repo, 8).unwrap();
    assert!(stream.contains("baseline"), "{stream}");
    assert!(stream.contains("| 2 | 7 |"), "deep-τ row present: {stream}");
    assert!(stream.contains("Walltime vs τ"), "{stream}");

    // churn report: the churn-free row anchors the loss-vs-dropout
    // deltas, and the analytic straggler section always renders
    let churn = generate("churn", &store, &repo, 8).unwrap();
    assert!(churn.contains("baseline"), "{churn}");
    assert!(churn.contains("rate=0.1"), "{churn}");
    assert!(churn.contains("Straggler cost"), "{churn}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn table6_generator_reports_calibration() {
    let dir = std::env::temp_dir().join(format!("rep_t6_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let store = SweepStore::open(&dir.join("empty.jsonl")).unwrap();
    let text = generate("table6", &store, &repo(), 8).unwrap();
    assert!(text.contains("Data-Parallel"));
    assert!(text.contains("paper: DiLoCo, H=300"));
    assert!(text.contains("cells matched"));
    // headline: >100x bandwidth reduction
    assert!(text.contains("less bandwidth"));
    std::fs::remove_dir_all(&dir).ok();
}
