//! Comm-plane invariants, both wire directions (see `diloco::comm`):
//!
//! (1) the Fp32 identity codec, driven through the encoded up-wire
//!     (`CommLink` + `OuterSync::sync_encoded`), is pinned
//!     **bit-for-bit** against the legacy literal-handle path
//!     (`OuterSync::sync`, today's uncompressed outer step) over random
//!     replica counts, shapes, fragments, and multi-round streaming
//!     schedules — the flat_bus oracle style;
//! (2) lossy round-trips obey the per-block error bound on **both**
//!     legs (up contributions and down broadcasts), and wire sizes are
//!     exact;
//! (3) error feedback makes repeated quantized syncs unbiased in both
//!     directions: the replica-side residual telescopes so quantized
//!     outer steps drive the global to the replica mean, and the
//!     coordinator-side residual telescopes so the time-averaged
//!     broadcast view converges to the true global;
//! (4) the worker-pool twin: a full DiLoCo schedule through
//!     `coordinator::pool::drive` is bit-identical at workers 1 vs 2
//!     vs 4 for EVERY (up, down) bit-width pair — encode seeds,
//!     residual ownership, broadcast decoding, and reduction order are
//!     all scheduling-independent;
//! (5) comm arenas are shared per worker: the measured
//!     `comm_arena_bytes` follows the 3-per-worker + 1-per-replica
//!     formula, ≤ ~1/3 of the retired 4-per-replica scheme at M=8.
//!
//! Host tier only: no PJRT, no artifacts.

use std::sync::Arc;

use diloco::comm::codec::BLOCK;
use diloco::comm::{
    codec_for, Channel, Direction, DownWire, OuterBits, ReplicaComm, WorkerComm,
};
use diloco::coordinator::{drive, DrivePlan, InnerEngine, OuterSync, ReplicaState};
use diloco::transport::frame::{reclaim_wires, WireSlice};
use diloco::data::synthetic::{CorpusSpec, TokenStream};
use diloco::runtime::{FlatLayout, HostTensor};
use diloco::util::prop;
use diloco::util::rng::Rng;

// ---- helpers ---------------------------------------------------------

fn random_shapes(rng: &mut Rng) -> Vec<Vec<usize>> {
    let leaves = 1 + rng.below(6) as usize;
    (0..leaves)
        .map(|_| {
            if rng.below(2) == 0 {
                vec![1 + rng.below(12) as usize]
            } else {
                vec![1 + rng.below(6) as usize, 1 + rng.below(6) as usize]
            }
        })
        .collect()
}

fn random_leaf_values(rng: &mut Rng, layout: &FlatLayout) -> Vec<Vec<f32>> {
    (0..layout.n_leaves())
        .map(|l| (0..layout.len(l)).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn to_host(layout: &FlatLayout, leaves: &[Vec<f32>]) -> Vec<HostTensor> {
    leaves
        .iter()
        .enumerate()
        .map(|(l, v)| HostTensor::from_vec(layout.shape(l), v.clone()))
        .collect()
}

fn to_lits(layout: &FlatLayout, leaves: &[Vec<f32>]) -> Vec<Arc<xla::Literal>> {
    to_host(layout, leaves)
        .iter()
        .map(|t| Arc::new(t.to_literal().unwrap()))
        .collect()
}

// ---- (1) fp32 wire == legacy literal path, bit for bit ----------------

#[test]
fn prop_fp32_encoded_sync_matches_legacy_path() {
    #[derive(Debug)]
    struct Case {
        shapes: Vec<Vec<usize>>,
        m: usize,
        fragments: usize,
        lr: f64,
        mu: f64,
        rounds: Vec<(Option<usize>, Vec<Vec<Vec<f32>>>)>,
        init: Vec<Vec<f32>>,
    }

    prop::check(
        0xC0DEC,
        32,
        |rng: &mut Rng| {
            let shapes = random_shapes(rng);
            let layout = FlatLayout::new(shapes.clone());
            let m = 1 + rng.below(8) as usize;
            let fragments = 1 + rng.below(4) as usize;
            let lr = rng.range_f64(0.1, 1.5);
            let mu = if rng.below(3) == 0 { 0.0 } else { rng.range_f64(0.0, 0.99) };
            let init = random_leaf_values(rng, &layout);
            let n_rounds = fragments + 1 + rng.below(3) as usize;
            let rounds = (0..n_rounds)
                .map(|k| {
                    let frag = if fragments > 1 && k + 1 != n_rounds {
                        Some(k % fragments)
                    } else {
                        None
                    };
                    let reps = (0..m).map(|_| random_leaf_values(rng, &layout)).collect();
                    (frag, reps)
                })
                .collect();
            Case {
                shapes,
                m,
                fragments,
                lr,
                mu,
                rounds,
                init,
            }
        },
        |case| {
            let layout = Arc::new(FlatLayout::new(case.shapes.clone()));
            let init_host = to_host(&layout, &case.init);

            // legacy side: literal handles straight into sync()
            let mut legacy = OuterSync::new(
                Arc::clone(&layout),
                &init_host,
                to_lits(&layout, &case.init),
                case.lr,
                case.mu,
                case.fragments,
            )
            .map_err(|e| e.to_string())?;

            // wire side: identity codec, worker-style encode per
            // replica through one shared arena set (the W=1 shape)
            let mut coded = OuterSync::new(
                Arc::clone(&layout),
                &init_host,
                to_lits(&layout, &case.init),
                case.lr,
                case.mu,
                case.fragments,
            )
            .map_err(|e| e.to_string())?
            .with_codec(codec_for(OuterBits::Fp32), 0xABC);
            let link = coded.link();
            let mut wc = WorkerComm::default();
            let mut rcs: Vec<ReplicaComm> =
                (0..case.m).map(|_| ReplicaComm::default()).collect();

            for (round, (frag, reps)) in case.rounds.iter().enumerate() {
                let rep_lits: Vec<Vec<Arc<xla::Literal>>> =
                    reps.iter().map(|r| to_lits(&layout, r)).collect();
                {
                    let parts: Vec<&[Arc<xla::Literal>]> =
                        rep_lits.iter().map(|v| &v[..]).collect();
                    legacy.sync(&parts, *frag).map_err(|e| e.to_string())?;
                }
                let payloads: Vec<WireSlice> = rep_lits
                    .iter()
                    .enumerate()
                    .map(|(r, lits)| {
                        link.encode_replica(r, lits, &mut wc, &mut rcs[r], *frag, round as u64)
                            .map_err(|e| e.to_string())
                    })
                    .collect::<Result<_, String>>()?;
                let frames: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
                coded
                    .sync_encoded(&frames, *frag)
                    .map_err(|e| e.to_string())?;

                for (i, (a, b)) in legacy
                    .global()
                    .data()
                    .iter()
                    .zip(coded.global().data())
                    .enumerate()
                {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "round {round} elem {i}: legacy {a} != coded {b} \
                             (M={}, P={}, frag {frag:?})",
                            case.m, case.fragments
                        ));
                    }
                }
            }
            // identity wire accounting agrees between the entry points
            if legacy.wire_stats().total() != coded.wire_stats().total() {
                return Err(format!(
                    "wire totals diverged: legacy {} coded {}",
                    legacy.wire_stats().total(),
                    coded.wire_stats().total()
                ));
            }
            Ok(())
        },
    );
}

// ---- (2) per-block round-trip error bounds, both legs ----------------

#[test]
fn prop_int_roundtrip_error_bounded_per_block() {
    prop::check(
        0x1B0,
        48,
        |rng: &mut Rng| {
            let n = 1 + rng.below(3 * BLOCK as u64 + 17) as usize;
            let scale = 10f64.powf(rng.range_f64(-4.0, 2.0)) as f32;
            let xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * scale).collect();
            let seed = rng.next_u64();
            (xs, seed)
        },
        |(xs, seed)| {
            for bits in [OuterBits::Int8, OuterBits::Int4] {
                let qmax = match bits {
                    OuterBits::Int8 => 127.0f32,
                    _ => 7.0,
                };
                let c = codec_for(bits);
                let mut wire = Vec::new();
                c.encode(xs, *seed, &mut wire);
                if wire.len() != c.wire_bytes(xs.len()) {
                    return Err(format!(
                        "{bits:?}: {} wire bytes, expected {}",
                        wire.len(),
                        c.wire_bytes(xs.len())
                    ));
                }
                let mut back = vec![0.0f32; xs.len()];
                c.decode(&wire, &mut back).map_err(|e| e.to_string())?;
                for (bi, block) in xs.chunks(BLOCK).enumerate() {
                    let maxabs = block.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                    let bound = maxabs / qmax * 1.0001;
                    for (i, &x) in block.iter().enumerate() {
                        let y = back[bi * BLOCK + i];
                        if (x - y).abs() > bound {
                            return Err(format!(
                                "{bits:?} block {bi}[{i}]: |{x} - {y}| > {bound}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_down_wire_broadcast_roundtrip_bounded_per_width() {
    // One broadcast through a fresh DownWire (residual 0): the decoded
    // view must land within the codec's error bound of the global —
    // per-block scale step for the int codecs, 2^-8 relative for bf16,
    // exact for fp32 — and the worker-side decode must reproduce the
    // coordinator's view bit for bit.
    prop::check(
        0xD0_B0,
        24,
        |rng: &mut Rng| {
            let shapes = random_shapes(rng);
            let layout = FlatLayout::new(shapes.clone());
            let init = random_leaf_values(rng, &layout);
            let global = random_leaf_values(rng, &layout);
            (shapes, init, global, rng.next_u64())
        },
        |(shapes, init, global, seed)| {
            let layout = Arc::new(FlatLayout::new(shapes.clone()));
            let flat = |leaves: &[Vec<f32>]| -> Vec<f32> {
                let mut v = Vec::new();
                for leaf in leaves {
                    v.extend_from_slice(leaf);
                }
                v
            };
            let init_flat = flat(init);
            let global_flat = flat(global);
            for bits in OuterBits::ALL {
                let chan = Channel::new(
                    Arc::clone(&layout),
                    codec_for(bits),
                    1,
                    *seed,
                    Direction::Down,
                );
                let mut dw = DownWire::new(chan.clone(), &init_flat);
                let bytes = dw
                    .encode_broadcast(&global_flat, None, 0)
                    .map_err(|e| e.to_string())?;
                if bytes.payload_len() != chan.payload_bytes(None) {
                    return Err(format!("{bits:?}: wrong broadcast size"));
                }
                // worker-side decode lands exactly on the view
                let mut dq = vec![0.0f32; layout.total()];
                chan.decode(bytes.payload(), None, &mut dq)
                    .map_err(|e| e.to_string())?;
                for i in 0..layout.total() {
                    let worker = init_flat[i] + dq[i];
                    if worker.to_bits() != dw.view()[i].to_bits() {
                        return Err(format!(
                            "{bits:?}[{i}]: worker view {worker} != coordinator {}",
                            dw.view()[i]
                        ));
                    }
                }
                // error bound on the view, per width
                let delta: Vec<f32> = global_flat
                    .iter()
                    .zip(&init_flat)
                    .map(|(g, v)| g - v)
                    .collect();
                for (bi, block) in delta.chunks(BLOCK).enumerate() {
                    let maxabs = block.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                    // every width gets a small absolute slack for the
                    // two f32 roundings in (global - view) and
                    // view += dq (values here are O(1) normals)
                    let bound = match bits {
                        OuterBits::Fp32 => 1e-5,
                        OuterBits::Bf16 => maxabs / 256.0 + 1e-5,
                        OuterBits::Int8 => maxabs / 127.0 * 1.0001 + 1e-5,
                        OuterBits::Int4 => maxabs / 7.0 * 1.0001 + 1e-5,
                    };
                    for (i, _) in block.iter().enumerate() {
                        let j = bi * BLOCK + i;
                        let err = (dw.view()[j] - global_flat[j]).abs();
                        if err > bound {
                            return Err(format!(
                                "{bits:?} block {bi}[{i}]: view error {err} > {bound}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---- (3) error feedback: unbiased over repeated syncs ----------------

#[test]
fn error_feedback_makes_repeated_quantization_unbiased() {
    // Quantize the SAME value K times with residual carry: the running
    // mean of the dequantized outputs telescopes to x +- residual/K,
    // so it converges at rate 1/K — without error feedback it would
    // plateau at the (biased) per-shot rounding error.
    let mut rng = Rng::new(0xEF);
    let n = 700usize; // multi-block + ragged tail
    let xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.3).collect();
    for bits in [OuterBits::Int8, OuterBits::Int4] {
        let qmax = match bits {
            OuterBits::Int8 => 127.0f32,
            _ => 7.0,
        };
        let c = codec_for(bits);
        let k = 64usize;
        let mut residual = vec![0.0f32; n];
        let mut staging = vec![0.0f32; n];
        let mut dq = vec![0.0f32; n];
        let mut mean = vec![0.0f64; n];
        for round in 0..k {
            for i in 0..n {
                staging[i] = xs[i] + residual[i];
            }
            let mut wire = Vec::new();
            c.encode(&staging, round as u64, &mut wire);
            c.decode(&wire, &mut dq).unwrap();
            for i in 0..n {
                residual[i] = staging[i] - dq[i];
                mean[i] += dq[i] as f64 / k as f64;
            }
        }
        // |mean - x| = |r_0 - r_K| / K <= (max step) / K; the staging
        // value can exceed max|x| by one step, so allow a 2x margin
        let maxabs = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let bound = (maxabs / qmax * 2.0) as f64 / k as f64 + 1e-7;
        for i in 0..n {
            assert!(
                (mean[i] - xs[i] as f64).abs() <= bound,
                "{bits:?}[{i}]: mean {} vs {} (bound {bound})",
                mean[i],
                xs[i]
            );
        }
    }
}

#[test]
fn coordinator_error_feedback_makes_repeated_broadcasts_unbiased() {
    // The down-wire mirror of the up-wire telescoping test: broadcast
    // a FIXED global K times through the DownWire. Each round's view
    // error is the residual increment (e_{k+1} = r_{k+1} - r_k), so
    // the TIME-AVERAGED view converges to the true global at rate
    // residual/K — the coordinator's error feedback never loses
    // broadcast mass, only defers it.
    let layout = Arc::new(FlatLayout::new(vec![vec![300], vec![7, 3], vec![40]]));
    let total = layout.total();
    let mut rng = Rng::new(0xB0);
    let init: Vec<f32> = (0..total).map(|_| rng.normal() as f32 * 0.5).collect();
    let global: Vec<f32> = (0..total).map(|_| rng.normal() as f32 * 0.5).collect();
    for bits in [OuterBits::Int8, OuterBits::Int4] {
        let mut dw = DownWire::new(
            Channel::new(Arc::clone(&layout), codec_for(bits), 1, 0x5151, Direction::Down),
            &init,
        );
        let err0 = dw
            .view()
            .iter()
            .zip(&global)
            .map(|(v, g)| (v - g).abs())
            .fold(0.0f32, f32::max);
        assert!(err0 > 0.1, "degenerate setup: view already at global");
        let k = 64u64;
        let mut avg = vec![0.0f64; total];
        for round in 0..k {
            dw.encode_broadcast(&global, None, round).unwrap();
            for (a, &v) in avg.iter_mut().zip(dw.view()) {
                *a += v as f64 / k as f64;
            }
            // per-round: the view stays inside the quantization band
            let errk = dw
                .view()
                .iter()
                .zip(&global)
                .map(|(v, g)| (v - g).abs())
                .fold(0.0f32, f32::max);
            assert!(errk <= err0, "{bits:?} round {round}: view drifted ({errk} > {err0})");
        }
        let avg_err = avg
            .iter()
            .zip(&global)
            .map(|(a, &g)| (a - g as f64).abs())
            .fold(0.0f64, f64::max);
        assert!(
            avg_err < 0.05 && avg_err < err0 as f64 / 15.0,
            "{bits:?}: coordinator EF must make broadcasts unbiased: \
             one-shot err {err0}, time-averaged err {avg_err}"
        );
        // residual itself stays bounded: nothing accumulates
        let r_max = dw.residual().iter().fold(0.0f32, |a, &r| a.max(r.abs()));
        assert!(r_max < err0, "{bits:?}: residual blew up ({r_max})");
    }
}

#[test]
fn frozen_replicas_leave_global_fixed_under_lossy_broadcast() {
    // Identity up-wire + int4 down-wire, eta=1, mu=0. After one real
    // sync the exact global and the quantized broadcast view disagree
    // (the lag sits in the coordinator's EF residual). If the replicas
    // then stop moving — theta pinned to exactly the view they were
    // handed — the outer gradient must be exactly zero: it measures
    // replica movement against the *view* (their true starting point),
    // never against the exact global, so the broadcast lag is not
    // double-counted as phantom replica progress. The down-wire's own
    // EF stream closes the lag on its own.
    let layout = Arc::new(FlatLayout::new(vec![vec![300], vec![7, 3], vec![40]]));
    let mut rng = Rng::new(0x51);
    let init = random_leaf_values(&mut rng, &layout);
    let theta_a = random_leaf_values(&mut rng, &layout);
    let theta_b = random_leaf_values(&mut rng, &layout);
    let mut sync = OuterSync::new(
        Arc::clone(&layout),
        &to_host(&layout, &init),
        to_lits(&layout, &init),
        1.0,
        0.0,
        1,
    )
    .unwrap()
    .with_codec(codec_for(OuterBits::Fp32), 7)
    .with_down_codec(codec_for(OuterBits::Int4));
    let link = sync.link();
    let mut wc = WorkerComm::default();
    link.init_snapshot(&mut wc, &to_lits(&layout, &init)).unwrap();

    // round 0: replicas actually moved — creates a global-vs-view lag
    let (ra, rb) = (to_lits(&layout, &theta_a), to_lits(&layout, &theta_b));
    sync.sync(&[&ra[..], &rb[..]], None).unwrap();
    let bytes = sync.take_broadcast_bytes().unwrap();
    let mut adopt = link.adopt_encoded(&mut wc, None, bytes.as_slice()).unwrap();
    let lag = |sync: &OuterSync| -> f32 {
        let dw = sync.down().unwrap();
        sync.global()
            .data()
            .iter()
            .zip(dw.view())
            .map(|(g, v)| (g - v).abs())
            .fold(0.0f32, f32::max)
    };
    let lag0 = lag(&sync);
    assert!(lag0 > 0.0, "int4 broadcast must leave some lag");

    // frozen: every subsequent round the replicas hold exactly the
    // view they were broadcast — the global must not move at all
    for round in 1..=20 {
        let theta: Vec<Arc<xla::Literal>> =
            adopt.iter().map(|(_, lit)| Arc::clone(lit)).collect();
        let g0: Vec<u32> = sync.global().data().iter().map(|x| x.to_bits()).collect();
        sync.sync(&[&theta[..], &theta[..]], None).unwrap();
        let g1: Vec<u32> = sync.global().data().iter().map(|x| x.to_bits()).collect();
        assert_eq!(g0, g1, "round {round}: frozen replicas moved the global");
        let bytes = sync.take_broadcast_bytes().unwrap();
        adopt = link.adopt_encoded(&mut wc, None, bytes.as_slice()).unwrap();
    }
    // ...while the broadcast EF stream alone keeps closing the lag
    assert!(
        lag(&sync) <= lag0,
        "down-wire EF must not let the lag grow: {} -> {}",
        lag0,
        lag(&sync)
    );
}

#[test]
fn int4_outer_sync_with_error_feedback_is_unbiased_over_syncs() {
    // eta=1, mu=0, replicas frozen: the exact outer step sets
    // global = mean(theta) in one shot. The 4-bit step fluctuates
    // around it by at most the quantization step — but with error
    // feedback the per-sync errors telescope (e_k = R_k - R_{k-1},
    // the mean-residual increments), so the TIME-AVERAGED global
    // converges to the replica mean at rate residual/K. That is the
    // unbiasedness claim: no quantization mass is ever lost, only
    // deferred to the next sync.
    let layout = Arc::new(FlatLayout::new(vec![vec![300], vec![7, 3], vec![40]]));
    let mut rng = Rng::new(0x44);
    let init = random_leaf_values(&mut rng, &layout);
    let theta_a = random_leaf_values(&mut rng, &layout);
    let theta_b = random_leaf_values(&mut rng, &layout);
    let mut sync = OuterSync::new(
        Arc::clone(&layout),
        &to_host(&layout, &init),
        to_lits(&layout, &init),
        1.0,
        0.0,
        1,
    )
    .unwrap()
    .with_codec(codec_for(OuterBits::Int4), 99);
    let link = sync.link();
    let rep_lits = [to_lits(&layout, &theta_a), to_lits(&layout, &theta_b)];
    let mut wc = WorkerComm::default();
    link.init_snapshot(&mut wc, &to_lits(&layout, &init)).unwrap();
    let mut rcs = [ReplicaComm::default(), ReplicaComm::default()];
    for rc in rcs.iter_mut() {
        link.init_replica(rc);
    }

    let mean: Vec<f32> = (0..layout.total())
        .map(|i| {
            let leaf_of = |vals: &[Vec<f32>]| {
                // flatten per-leaf vectors to the arena order
                let mut flat = Vec::new();
                for v in vals {
                    flat.extend_from_slice(v);
                }
                flat[i]
            };
            (leaf_of(&theta_a) + leaf_of(&theta_b)) / 2.0
        })
        .collect();
    let err = |sync: &OuterSync| -> f32 {
        sync.global()
            .data()
            .iter()
            .zip(&mean)
            .map(|(g, m)| (g - m).abs())
            .fold(0.0f32, f32::max)
    };
    let err0 = err(&sync);
    assert!(err0 > 0.1, "degenerate test setup: start already at mean");

    let rounds = 40u64;
    let mut avg = vec![0.0f64; layout.total()];
    for round in 0..rounds {
        let payloads: Vec<WireSlice> = rep_lits
            .iter()
            .enumerate()
            .map(|(r, lits)| {
                link.encode_replica(r, lits, &mut wc, &mut rcs[r], None, round)
                    .unwrap()
            })
            .collect();
        let frames: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        sync.sync_encoded(&frames, None).unwrap();
        for (a, &g) in avg.iter_mut().zip(sync.global().data()) {
            *a += g as f64 / rounds as f64;
        }
        // broadcast: the shared snapshot adopts the refreshed global
        let adopt: Vec<(usize, Arc<xla::Literal>)> = sync
            .global_literals()
            .unwrap()
            .iter()
            .enumerate()
            .map(|(l, lit)| (l, Arc::clone(lit)))
            .collect();
        link.adopt_literals(&mut wc, &adopt).unwrap();
    }
    // time-average: |avg - mean| = |R_K|/K <= one quantization step
    // over K — far inside the per-sync fluctuation band
    let avg_err = avg
        .iter()
        .zip(&mean)
        .map(|(a, &m)| (a - m as f64).abs())
        .fold(0.0f64, f64::max);
    assert!(
        avg_err < 0.05 && avg_err < err0 as f64 / 20.0,
        "EF must make quantized syncs unbiased: err0 {err0}, \
         time-averaged err {avg_err}"
    );
    // the last iterate stays inside the quantization band (no drift,
    // no lost mass) even though it never pins the mean exactly
    let errk = err(&sync);
    assert!(
        errk < err0 * 0.8,
        "final iterate drifted: err {err0} -> {errk}"
    );
    // wire bytes: 40 syncs, 2 replicas, ~8x smaller than fp32
    let w = sync.wire_stats();
    assert_eq!(w.syncs(), rounds);
    let fp32_per_replica = layout.total() as u64 * 4;
    assert!(
        w.records()[0].bytes_per_replica < fp32_per_replica / 6,
        "int4 payload {} vs fp32 {}",
        w.records()[0].bytes_per_replica,
        fp32_per_replica
    );
}

// ---- (4) worker-pool twin: bit-identical at every width pair ---------

/// Deterministic host-math inner step (same shape as
/// tests/worker_pool.rs): mixes the replica's private shard with the
/// step index; loss is a pure function of the post-step state.
struct ToyEngine {
    n: usize,
}

impl InnerEngine for ToyEngine {
    fn inner_step(
        &self,
        rep: usize,
        replica: &mut ReplicaState,
        t: usize,
    ) -> anyhow::Result<f64> {
        let toks = replica.shard.next_batch(2, 8);
        let mut loss = 0.0f64;
        for leaf in 0..self.n {
            let lit = &replica.state[leaf];
            let dims = lit.array_shape()?.dims().to_vec();
            let mut v = lit.to_vec::<f32>()?;
            for (i, x) in v.iter_mut().enumerate() {
                *x = 0.5 * *x
                    + 1e-3 * toks[(i + t) % toks.len()] as f32
                    + 1e-2 * (t as f32 + rep as f32 * 0.25).sin();
            }
            loss += v.iter().map(|&f| f as f64).sum::<f64>() / v.len() as f64;
            replica.state[leaf] = Arc::new(xla::Literal::vec1(&v).reshape(&dims)?);
        }
        Ok(loss / self.n as f64)
    }

    fn eval(&self, params: &[Arc<xla::Literal>]) -> anyhow::Result<f64> {
        let mut acc = 0.0f64;
        for (i, p) in params.iter().enumerate() {
            for x in p.to_vec::<f32>()? {
                acc += x as f64 * (i + 1) as f64;
            }
        }
        Ok(acc)
    }
}

fn twin_layout() -> Arc<FlatLayout> {
    Arc::new(FlatLayout::new(vec![
        vec![3, 2],
        vec![4],
        vec![2, 2],
        vec![5],
        vec![1],
    ]))
}

struct TwinResult {
    step_losses: Vec<f64>,
    eval_curve: Vec<(usize, f64)>,
    outer_syncs: usize,
    global_bits: Vec<u32>,
    finals: Vec<Vec<Vec<f32>>>,
    wire_up: u64,
    wire_down: u64,
    comm_arena_bytes: u64,
    down_wire_arena_bytes: u64,
}

fn twin_run(
    up: OuterBits,
    down: OuterBits,
    m: usize,
    workers: usize,
    fragments: usize,
) -> TwinResult {
    let l = twin_layout();
    let engine = ToyEngine { n: l.n_leaves() };
    let init: Vec<Arc<xla::Literal>> = (0..l.n_leaves())
        .map(|leaf| {
            let v: Vec<f32> = (0..l.len(leaf))
                .map(|i| ((leaf * 37 + i * 11 + 5) % 23) as f32 * 0.1 - 1.0)
                .collect();
            Arc::new(
                HostTensor::from_vec(l.shape(leaf), v)
                    .to_literal()
                    .unwrap(),
            )
        })
        .collect();
    let mut replicas: Vec<ReplicaState> = (0..m)
        .map(|r| ReplicaState {
            state: init.clone(),
            shard: TokenStream::new(CorpusSpec::default(), 5, r as u64),
        })
        .collect();
    let host: Vec<HostTensor> = (0..l.n_leaves())
        .map(|leaf| HostTensor::from_literal(&init[leaf]).unwrap())
        .collect();
    let mut sync = OuterSync::new(Arc::clone(&l), &host, init.clone(), 0.7, 0.9, fragments)
        .unwrap()
        .with_codec(codec_for(up), 42)
        .with_down_codec(codec_for(down));
    let plan = DrivePlan {
        total_steps: 22,
        sync_interval: 3,
        fragments,
        n_params: l.n_leaves(),
        eval_every: Some(7),
        log_every: 100,
        workers,
        overlap_tau: 0,
    };
    let out = drive(&engine, &mut replicas, Some(&mut sync), &plan).expect("drive");
    TwinResult {
        step_losses: out.step_losses,
        eval_curve: out.eval_curve,
        outer_syncs: out.outer_syncs,
        global_bits: sync.global().data().iter().map(|x| x.to_bits()).collect(),
        finals: replicas
            .iter()
            .map(|r| {
                (0..l.n_leaves())
                    .map(|leaf| r.state[leaf].to_vec::<f32>().unwrap())
                    .collect()
            })
            .collect(),
        wire_up: sync.wire_stats().total_up(),
        wire_down: sync.wire_stats().total_down(),
        comm_arena_bytes: out.comm_arena_bytes,
        down_wire_arena_bytes: out.down_wire_arena_bytes,
    }
}

#[test]
fn worker_pool_twin_bit_identical_at_every_width_pair() {
    for up in OuterBits::ALL {
        for down in OuterBits::ALL {
            let oracle = twin_run(up, down, 4, 1, 2);
            assert_eq!(oracle.step_losses.len(), 22, "{up:?}/{down:?}");
            assert!(oracle.outer_syncs > 0, "{up:?}/{down:?}");
            assert!(
                oracle.wire_up > 0 && oracle.wire_down > 0,
                "{up:?}/{down:?}"
            );
            for workers in [2usize, 4] {
                let par = twin_run(up, down, 4, workers, 2);
                let tag = format!("{up:?}/{down:?} w={workers}");
                assert_eq!(par.step_losses, oracle.step_losses, "{tag}");
                assert_eq!(par.eval_curve, oracle.eval_curve, "{tag}");
                assert_eq!(par.outer_syncs, oracle.outer_syncs, "{tag}");
                assert_eq!(
                    par.global_bits, oracle.global_bits,
                    "{tag}: global arena drifted"
                );
                assert_eq!(par.finals, oracle.finals, "{tag}");
                assert_eq!(par.wire_up, oracle.wire_up, "{tag}");
                assert_eq!(par.wire_down, oracle.wire_down, "{tag}");
            }
        }
    }
}

#[test]
fn narrower_up_wire_strictly_shrinks_payloads() {
    // Same schedule, descending up widths at a fixed f32 broadcast:
    // wire-up bytes must strictly decrease while sync counts and the
    // broadcast stay identical.
    let runs: Vec<TwinResult> = OuterBits::ALL
        .iter()
        .map(|&b| twin_run(b, OuterBits::Fp32, 2, 1, 1))
        .collect();
    for w in runs.windows(2) {
        assert_eq!(w[0].outer_syncs, w[1].outer_syncs);
        assert!(
            w[1].wire_up < w[0].wire_up,
            "narrower codec must ship fewer bytes: {} -> {}",
            w[0].wire_up,
            w[1].wire_up
        );
        // broadcast stays f32 while only the up-wire narrows
        assert_eq!(w[0].wire_down, w[1].wire_down);
    }
}

#[test]
fn narrower_down_wire_strictly_shrinks_the_broadcast() {
    // The mirror: descending down widths at a fixed f32 up-wire. The
    // int4 broadcast must come in ~8x under fp32 (4.125 bits/param
    // with the per-block scales) while the up-wire bytes stay put.
    let runs: Vec<TwinResult> = OuterBits::ALL
        .iter()
        .map(|&b| twin_run(OuterBits::Fp32, b, 2, 1, 1))
        .collect();
    for w in runs.windows(2) {
        assert_eq!(w[0].outer_syncs, w[1].outer_syncs);
        assert!(
            w[1].wire_down < w[0].wire_down,
            "narrower broadcast must ship fewer bytes: {} -> {}",
            w[0].wire_down,
            w[1].wire_down
        );
        assert_eq!(w[0].wire_up, w[1].wire_up);
    }
    // down bytes are the exact encoded broadcast sizes, once per sync
    let total = twin_layout().total();
    let syncs = runs[0].outer_syncs as u64;
    assert!(syncs > 0);
    assert_eq!(runs[0].wire_down, syncs * (total * 4) as u64, "fp32");
    assert_eq!(
        runs[3].wire_down,
        syncs * codec_for(OuterBits::Int4).wire_bytes(total) as u64,
        "int4"
    );
    // the tiny twin layout pays heavy per-block scale overhead; at
    // mini-ladder arena sizes the int4 leg amortizes to ~8x under f32
    // (4.125 bits/param) — the acceptance-criteria ratio
    let n = 100_000usize;
    let int4_big = codec_for(OuterBits::Int4).wire_bytes(n) as f64;
    let ratio = (n * 4) as f64 / int4_big;
    assert!(
        ratio > 7.5 && ratio < 8.0,
        "int4 wire should be ~8x under fp32 at scale: {ratio:.2}x"
    );
}

// ---- (5) comm arenas are shared per worker ---------------------------

#[test]
fn comm_arena_bytes_follow_shared_per_worker_formula() {
    let total = twin_layout().total() as u64;
    let arena = total * 4; // one f32 arena
    let m = 8usize;
    // the retired PR 3 scheme: 4 arenas (snap + residual + staging +
    // scratch) per replica, whatever the worker count
    let per_replica_baseline = m as u64 * 4 * arena;

    // lossy both ways, inline driver: 3 shared arenas + M residuals
    // worker-side, 3 coordinator-side down-wire arenas counted apart
    let w1 = twin_run(OuterBits::Int4, OuterBits::Int4, m, 1, 1);
    assert_eq!(w1.comm_arena_bytes, (3 + m as u64) * arena);
    assert_eq!(w1.down_wire_arena_bytes, 3 * arena);
    assert!(
        3 * w1.comm_arena_bytes <= per_replica_baseline + 3 * arena,
        "M=8 comm arenas must measure <= ~1/3 of the per-replica \
         baseline: {} vs {per_replica_baseline}",
        w1.comm_arena_bytes
    );

    // W workers: 3 arenas per worker, residuals unchanged
    for workers in [2usize, 4] {
        let wk = twin_run(OuterBits::Int4, OuterBits::Int4, m, workers, 1);
        assert_eq!(
            wk.comm_arena_bytes,
            (3 * workers as u64 + m as u64) * arena,
            "workers={workers}"
        );
        assert!(wk.comm_arena_bytes < per_replica_baseline, "workers={workers}");
    }

    // identity up-wire: no residuals and no pull scratch (nothing is
    // ever encoded) — just the snapshot + decode staging per worker
    let down_only = twin_run(OuterBits::Fp32, OuterBits::Int4, m, 1, 1);
    assert_eq!(down_only.comm_arena_bytes, 2 * arena);
    assert_eq!(down_only.down_wire_arena_bytes, 3 * arena);

    // identity/identity: the zero-copy path allocates nothing on
    // either side
    let exact = twin_run(OuterBits::Fp32, OuterBits::Fp32, m, 1, 1);
    assert_eq!(exact.comm_arena_bytes, 0);
    assert_eq!(exact.down_wire_arena_bytes, 0);

    // identity down-wire with a lossy up-wire: no coordinator arenas
    let up_only = twin_run(OuterBits::Int4, OuterBits::Fp32, m, 1, 1);
    assert_eq!(up_only.down_wire_arena_bytes, 0);
}

// ---- (6) chunked kernels == retired scalar oracles -------------------

/// The scalar codec bodies this repo shipped before the chunked
/// rewrite, transcribed verbatim and frozen here as oracles. The
/// chunked kernels are a pure re-staging of this math: byte-identical
/// wire out of `encode`, bit-identical f32 out of `decode`.
mod retired {
    use diloco::comm::codec::BLOCK;
    use diloco::comm::OuterBits;
    use diloco::util::rng::Rng;

    fn f32_to_bf16(x: f32) -> u16 {
        let bits = x.to_bits();
        let round = ((bits >> 16) & 1) + 0x7FFF;
        ((bits.wrapping_add(round)) >> 16) as u16
    }

    fn bf16_to_f32(h: u16) -> f32 {
        f32::from_bits((h as u32) << 16)
    }

    fn qmax(bits: OuterBits) -> f32 {
        match bits {
            OuterBits::Int8 => 127.0,
            _ => 7.0,
        }
    }

    fn code_bytes(bits: OuterBits, n: usize) -> usize {
        match bits {
            OuterBits::Int8 => n,
            _ => (n + 1) / 2,
        }
    }

    pub fn encode(bits: OuterBits, src: &[f32], seed: u64, out: &mut Vec<u8>) {
        match bits {
            OuterBits::Fp32 => {
                out.reserve(src.len() * 4);
                for &x in src {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            OuterBits::Bf16 => {
                out.reserve(src.len() * 2);
                for &x in src {
                    out.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
                }
            }
            _ => intq_encode(bits, src, seed, out),
        }
    }

    fn intq_encode(bits: OuterBits, src: &[f32], seed: u64, out: &mut Vec<u8>) {
        let qmax = qmax(bits);
        let root = Rng::new(seed);
        for (bi, block) in src.chunks(BLOCK).enumerate() {
            let maxabs = block.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            let scale = if maxabs > 0.0 { maxabs / qmax } else { 0.0 };
            out.extend_from_slice(&scale.to_le_bytes());
            if scale == 0.0 {
                out.extend(std::iter::repeat(0u8).take(code_bytes(bits, block.len())));
                continue;
            }
            let mut rng = root.child(bi as u64);
            let mut quantize = |x: f32| -> i32 {
                let y = (x / scale).clamp(-qmax, qmax);
                let f = y.floor();
                let frac = (y - f) as f64;
                let up = rng.f64() < frac;
                (f as i32) + if up { 1 } else { 0 }
            };
            match bits {
                OuterBits::Int8 => {
                    for &x in block {
                        out.push(quantize(x) as i8 as u8);
                    }
                }
                _ => {
                    for pair in block.chunks(2) {
                        let lo = (quantize(pair[0]) + 8) as u8 & 0x0F;
                        let hi = if pair.len() == 2 {
                            (quantize(pair[1]) + 8) as u8 & 0x0F
                        } else {
                            8
                        };
                        out.push(lo | (hi << 4));
                    }
                }
            }
        }
    }

    pub fn decode(bits: OuterBits, wire: &[u8], dst: &mut [f32]) {
        match bits {
            OuterBits::Fp32 => {
                for (d, b) in dst.iter_mut().zip(wire.chunks_exact(4)) {
                    *d = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                }
            }
            OuterBits::Bf16 => {
                for (d, b) in dst.iter_mut().zip(wire.chunks_exact(2)) {
                    *d = bf16_to_f32(u16::from_le_bytes([b[0], b[1]]));
                }
            }
            _ => intq_decode(bits, wire, dst),
        }
    }

    fn intq_decode(bits: OuterBits, wire: &[u8], dst: &mut [f32]) {
        let mut off = 0usize;
        for block in dst.chunks_mut(BLOCK) {
            let scale =
                f32::from_le_bytes([wire[off], wire[off + 1], wire[off + 2], wire[off + 3]]);
            off += 4;
            match bits {
                OuterBits::Int8 => {
                    for d in block.iter_mut() {
                        *d = (wire[off] as i8) as f32 * scale;
                        off += 1;
                    }
                }
                _ => {
                    for (i, d) in block.iter_mut().enumerate() {
                        let byte = wire[off + i / 2];
                        let nibble = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                        *d = (nibble as i32 - 8) as f32 * scale;
                    }
                    off += code_bytes(bits, block.len());
                }
            }
        }
    }
}

#[test]
fn prop_chunked_kernels_match_retired_scalar_codec_bit_for_bit() {
    // The chunked, branch-free kernels must be a pure re-staging of
    // the retired scalar codec: same wire bytes out of `encode`, same
    // f32 bits out of `decode`, and the fused `decode_add` equal to
    // decode-then-add. Lengths sweep odd int4 tails, exact BLOCK
    // multiples and forced all-zero blocks (the drawless zero-scale
    // path, where the chunked kernel must not consume any RNG draws).
    prop::check(
        0x0AC1E5,
        48,
        |rng: &mut Rng| {
            let n = match rng.below(4) {
                0 => 1 + rng.below(2 * BLOCK as u64 + 17) as usize,
                1 => BLOCK * (1 + rng.below(3) as usize),
                2 => BLOCK * (1 + rng.below(3) as usize) + 1 + rng.below(7) as usize,
                _ => 1 + rng.below(BLOCK as u64) as usize,
            };
            let mut xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.3).collect();
            if rng.below(2) == 0 {
                // every other block all-zero: scale == 0, no draws
                for b in xs.chunks_mut(BLOCK).step_by(2) {
                    b.fill(0.0);
                }
            }
            (xs, rng.next_u64())
        },
        |(xs, seed)| {
            for bits in OuterBits::ALL {
                let c = codec_for(bits);
                let mut want = Vec::new();
                retired::encode(bits, xs, *seed, &mut want);
                let mut got = Vec::new();
                c.encode(xs, *seed, &mut got);
                if got != want {
                    return Err(format!(
                        "{bits:?}: chunked encode wire differs from scalar oracle \
                         (n={}, wire {} vs {} bytes)",
                        xs.len(),
                        got.len(),
                        want.len()
                    ));
                }
                let mut a = vec![0.0f32; xs.len()];
                c.decode(&got, &mut a).map_err(|e| e.to_string())?;
                let mut b = vec![0.0f32; xs.len()];
                retired::decode(bits, &got, &mut b);
                for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!(
                            "{bits:?}: decode[{i}] = {x} != oracle {y} (n={})",
                            xs.len()
                        ));
                    }
                }
                // fused decode->accumulate == decode then add, bit for
                // bit, starting from a non-trivial accumulator
                let mut acc: Vec<f32> = (0..xs.len())
                    .map(|i| (i % 13) as f32 * 0.25 - 1.5)
                    .collect();
                let mut acc2 = acc.clone();
                c.decode_add(&got, &mut acc).map_err(|e| e.to_string())?;
                for (d, s) in acc2.iter_mut().zip(&b) {
                    *d += *s;
                }
                for (i, (x, y)) in acc.iter().zip(&acc2).enumerate() {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!(
                            "{bits:?}: decode_add[{i}] = {x} != decode+add {y}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sync_encoded_and_broadcast_invariant_to_sync_thread_count() {
    // --sync-threads is a pure wall-clock knob: the coordinator's
    // fused decode->reduce, sharded outer step and parallel broadcast
    // encode must produce the same f32 bits and the same wire bytes at
    // any thread count. Several syncs (so EF residuals evolve on both
    // wires) run at N=1, then globals + broadcast payloads are
    // compared bit-for-bit at N in {2, 3, 8}. Spent up-wire payloads
    // are recycled between rounds so dirty reused buffers are also
    // pinned as harmless.
    let layout = Arc::new(FlatLayout::new(vec![vec![700], vec![300, 2], vec![513]]));
    let mut rng = Rng::new(0x517AD5);
    let init = random_leaf_values(&mut rng, &layout);
    let thetas: Vec<Vec<Vec<f32>>> = (0..3)
        .map(|_| random_leaf_values(&mut rng, &layout))
        .collect();
    for (up, down) in [
        (OuterBits::Int8, OuterBits::Int4),
        (OuterBits::Int4, OuterBits::Bf16),
        (OuterBits::Bf16, OuterBits::Fp32),
    ] {
        let run = |threads: usize| -> (Vec<u32>, Vec<Vec<u8>>) {
            let mut sync = OuterSync::new(
                Arc::clone(&layout),
                &to_host(&layout, &init),
                to_lits(&layout, &init),
                0.7,
                0.9,
                1,
            )
            .unwrap()
            .with_codec(codec_for(up), 0xAB)
            .with_down_codec(codec_for(down))
            .with_sync_threads(threads);
            let link = sync.link();
            let mut wc = WorkerComm::default();
            link.init_snapshot(&mut wc, &to_lits(&layout, &init)).unwrap();
            let mut rcs: Vec<ReplicaComm> = (0..thetas.len())
                .map(|_| ReplicaComm::default())
                .collect();
            for rc in rcs.iter_mut() {
                link.init_replica(rc);
            }
            let mut wires: Vec<Vec<u8>> = Vec::new();
            for round in 0..4u64 {
                let rep_lits: Vec<Vec<Arc<xla::Literal>>> =
                    thetas.iter().map(|th| to_lits(&layout, th)).collect();
                let payloads: Vec<WireSlice> = rep_lits
                    .iter()
                    .enumerate()
                    .map(|(r, lits)| {
                        link.encode_replica(r, lits, &mut wc, &mut rcs[r], None, round)
                            .unwrap()
                    })
                    .collect();
                let frames: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
                sync.sync_encoded(&frames, None).unwrap();
                if let Some(bytes) = sync.take_broadcast_bytes() {
                    link.adopt_encoded(&mut wc, None, bytes.as_slice()).unwrap();
                    wires.push(bytes.as_slice().to_vec());
                } else {
                    // identity down-wire: adopt the exact literals
                    let adopt: Vec<(usize, Arc<xla::Literal>)> = sync
                        .global_literals()
                        .unwrap()
                        .iter()
                        .enumerate()
                        .map(|(l, lit)| (l, Arc::clone(lit)))
                        .collect();
                    link.adopt_literals(&mut wc, &adopt).unwrap();
                }
                for p in reclaim_wires(payloads) {
                    wc.recycle(p);
                }
            }
            (
                sync.global().data().iter().map(|x| x.to_bits()).collect(),
                wires,
            )
        };
        let base = run(1);
        for t in [2usize, 3, 8] {
            let got = run(t);
            assert_eq!(
                got.0, base.0,
                "{up:?}/{down:?} sync_threads={t}: global bits drifted"
            );
            assert_eq!(
                got.1, base.1,
                "{up:?}/{down:?} sync_threads={t}: broadcast wire drifted"
            );
        }
    }
}
