//! Comm-subsystem invariants (quantize → reduce → dequantize; see
//! `diloco::comm`):
//!
//! (1) the Fp32 identity codec, driven through the encoded wire path
//!     (`SyncEncoder` + `OuterSync::sync_encoded`), is pinned
//!     **bit-for-bit** against the legacy literal-handle path
//!     (`OuterSync::sync`, today's uncompressed outer step) over random
//!     replica counts, shapes, fragments, and multi-round streaming
//!     schedules — the flat_bus oracle style;
//! (2) int8/int4 round-trips obey the per-block error bound
//!     |x - dq(x)| <= max|block| / qmax, and wire sizes are exact;
//! (3) error feedback makes repeated quantized outer syncs unbiased:
//!     residual-compensated dq means converge to the true value, and a
//!     4-bit outer step drives the global model to the replica mean
//!     instead of stalling on quantization error;
//! (4) the worker-pool twin: a full DiLoCo schedule through
//!     `coordinator::pool::drive` is bit-identical at workers 1 vs 2
//!     vs 4 for EVERY bit width — encode seeds, residual ownership,
//!     and reduction order are all scheduling-independent.
//!
//! Host tier only: no PJRT, no artifacts.

use std::sync::Arc;

use diloco::comm::codec::BLOCK;
use diloco::comm::{codec_for, CommState, OuterBits};
use diloco::coordinator::{drive, DrivePlan, InnerEngine, OuterSync, ReplicaState};
use diloco::data::synthetic::{CorpusSpec, TokenStream};
use diloco::runtime::{FlatLayout, HostTensor};
use diloco::util::prop;
use diloco::util::rng::Rng;

// ---- helpers ---------------------------------------------------------

fn random_shapes(rng: &mut Rng) -> Vec<Vec<usize>> {
    let leaves = 1 + rng.below(6) as usize;
    (0..leaves)
        .map(|_| {
            if rng.below(2) == 0 {
                vec![1 + rng.below(12) as usize]
            } else {
                vec![1 + rng.below(6) as usize, 1 + rng.below(6) as usize]
            }
        })
        .collect()
}

fn random_leaf_values(rng: &mut Rng, layout: &FlatLayout) -> Vec<Vec<f32>> {
    (0..layout.n_leaves())
        .map(|l| (0..layout.len(l)).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn to_host(layout: &FlatLayout, leaves: &[Vec<f32>]) -> Vec<HostTensor> {
    leaves
        .iter()
        .enumerate()
        .map(|(l, v)| HostTensor::from_vec(layout.shape(l), v.clone()))
        .collect()
}

fn to_lits(layout: &FlatLayout, leaves: &[Vec<f32>]) -> Vec<Arc<xla::Literal>> {
    to_host(layout, leaves)
        .iter()
        .map(|t| Arc::new(t.to_literal().unwrap()))
        .collect()
}

// ---- (1) fp32 wire == legacy literal path, bit for bit ----------------

#[test]
fn prop_fp32_encoded_sync_matches_legacy_path() {
    #[derive(Debug)]
    struct Case {
        shapes: Vec<Vec<usize>>,
        m: usize,
        fragments: usize,
        lr: f64,
        mu: f64,
        rounds: Vec<(Option<usize>, Vec<Vec<Vec<f32>>>)>,
        init: Vec<Vec<f32>>,
    }

    prop::check(
        0xC0DEC,
        32,
        |rng: &mut Rng| {
            let shapes = random_shapes(rng);
            let layout = FlatLayout::new(shapes.clone());
            let m = 1 + rng.below(8) as usize;
            let fragments = 1 + rng.below(4) as usize;
            let lr = rng.range_f64(0.1, 1.5);
            let mu = if rng.below(3) == 0 { 0.0 } else { rng.range_f64(0.0, 0.99) };
            let init = random_leaf_values(rng, &layout);
            let n_rounds = fragments + 1 + rng.below(3) as usize;
            let rounds = (0..n_rounds)
                .map(|k| {
                    let frag = if fragments > 1 && k + 1 != n_rounds {
                        Some(k % fragments)
                    } else {
                        None
                    };
                    let reps = (0..m).map(|_| random_leaf_values(rng, &layout)).collect();
                    (frag, reps)
                })
                .collect();
            Case {
                shapes,
                m,
                fragments,
                lr,
                mu,
                rounds,
                init,
            }
        },
        |case| {
            let layout = Arc::new(FlatLayout::new(case.shapes.clone()));
            let init_host = to_host(&layout, &case.init);

            // legacy side: literal handles straight into sync()
            let mut legacy = OuterSync::new(
                Arc::clone(&layout),
                &init_host,
                to_lits(&layout, &case.init),
                case.lr,
                case.mu,
                case.fragments,
            )
            .map_err(|e| e.to_string())?;

            // wire side: identity codec, worker-style encode per replica
            let mut coded = OuterSync::new(
                Arc::clone(&layout),
                &init_host,
                to_lits(&layout, &case.init),
                case.lr,
                case.mu,
                case.fragments,
            )
            .map_err(|e| e.to_string())?
            .with_codec(codec_for(OuterBits::Fp32), 0xABC);
            let enc = coded.encoder();
            let mut comm: Vec<CommState> =
                (0..case.m).map(|_| CommState::default()).collect();

            for (round, (frag, reps)) in case.rounds.iter().enumerate() {
                let rep_lits: Vec<Vec<Arc<xla::Literal>>> =
                    reps.iter().map(|r| to_lits(&layout, r)).collect();
                {
                    let parts: Vec<&[Arc<xla::Literal>]> =
                        rep_lits.iter().map(|v| &v[..]).collect();
                    legacy.sync(&parts, *frag).map_err(|e| e.to_string())?;
                }
                let payloads: Vec<Vec<u8>> = rep_lits
                    .iter()
                    .enumerate()
                    .map(|(r, lits)| {
                        enc.encode_replica(r, lits, &mut comm[r], *frag, round as u64)
                            .map_err(|e| e.to_string())
                    })
                    .collect::<Result<_, String>>()?;
                let frames: Vec<&[u8]> = payloads.iter().map(|p| &p[..]).collect();
                coded
                    .sync_encoded(&frames, *frag)
                    .map_err(|e| e.to_string())?;

                for (i, (a, b)) in legacy
                    .global()
                    .data()
                    .iter()
                    .zip(coded.global().data())
                    .enumerate()
                {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!(
                            "round {round} elem {i}: legacy {a} != coded {b} \
                             (M={}, P={}, frag {frag:?})",
                            case.m, case.fragments
                        ));
                    }
                }
            }
            // identity wire accounting agrees between the entry points
            if legacy.wire_stats().total() != coded.wire_stats().total() {
                return Err(format!(
                    "wire totals diverged: legacy {} coded {}",
                    legacy.wire_stats().total(),
                    coded.wire_stats().total()
                ));
            }
            Ok(())
        },
    );
}

// ---- (2) per-block round-trip error bounds ---------------------------

#[test]
fn prop_int_roundtrip_error_bounded_per_block() {
    prop::check(
        0x1B0,
        48,
        |rng: &mut Rng| {
            let n = 1 + rng.below(3 * BLOCK as u64 + 17) as usize;
            let scale = 10f64.powf(rng.range_f64(-4.0, 2.0)) as f32;
            let xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * scale).collect();
            let seed = rng.next_u64();
            (xs, seed)
        },
        |(xs, seed)| {
            for bits in [OuterBits::Int8, OuterBits::Int4] {
                let qmax = match bits {
                    OuterBits::Int8 => 127.0f32,
                    _ => 7.0,
                };
                let c = codec_for(bits);
                let mut wire = Vec::new();
                c.encode(xs, *seed, &mut wire);
                if wire.len() != c.wire_bytes(xs.len()) {
                    return Err(format!(
                        "{bits:?}: {} wire bytes, expected {}",
                        wire.len(),
                        c.wire_bytes(xs.len())
                    ));
                }
                let mut back = vec![0.0f32; xs.len()];
                c.decode(&wire, &mut back).map_err(|e| e.to_string())?;
                for (bi, block) in xs.chunks(BLOCK).enumerate() {
                    let maxabs = block.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                    let bound = maxabs / qmax * 1.0001;
                    for (i, &x) in block.iter().enumerate() {
                        let y = back[bi * BLOCK + i];
                        if (x - y).abs() > bound {
                            return Err(format!(
                                "{bits:?} block {bi}[{i}]: |{x} - {y}| > {bound}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---- (3) error feedback: unbiased over repeated syncs ----------------

#[test]
fn error_feedback_makes_repeated_quantization_unbiased() {
    // Quantize the SAME value K times with residual carry: the running
    // mean of the dequantized outputs telescopes to x +- residual/K,
    // so it converges at rate 1/K — without error feedback it would
    // plateau at the (biased) per-shot rounding error.
    let mut rng = Rng::new(0xEF);
    let n = 700usize; // multi-block + ragged tail
    let xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.3).collect();
    for bits in [OuterBits::Int8, OuterBits::Int4] {
        let qmax = match bits {
            OuterBits::Int8 => 127.0f32,
            _ => 7.0,
        };
        let c = codec_for(bits);
        let k = 64usize;
        let mut residual = vec![0.0f32; n];
        let mut staging = vec![0.0f32; n];
        let mut dq = vec![0.0f32; n];
        let mut mean = vec![0.0f64; n];
        for round in 0..k {
            for i in 0..n {
                staging[i] = xs[i] + residual[i];
            }
            let mut wire = Vec::new();
            c.encode(&staging, round as u64, &mut wire);
            c.decode(&wire, &mut dq).unwrap();
            for i in 0..n {
                residual[i] = staging[i] - dq[i];
                mean[i] += dq[i] as f64 / k as f64;
            }
        }
        // |mean - x| = |r_0 - r_K| / K <= (max step) / K; the staging
        // value can exceed max|x| by one step, so allow a 2x margin
        let maxabs = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let bound = (maxabs / qmax * 2.0) as f64 / k as f64 + 1e-7;
        for i in 0..n {
            assert!(
                (mean[i] - xs[i] as f64).abs() <= bound,
                "{bits:?}[{i}]: mean {} vs {} (bound {bound})",
                mean[i],
                xs[i]
            );
        }
    }
}

#[test]
fn int4_outer_sync_with_error_feedback_is_unbiased_over_syncs() {
    // eta=1, mu=0, replicas frozen: the exact outer step sets
    // global = mean(theta) in one shot. The 4-bit step fluctuates
    // around it by at most the quantization step — but with error
    // feedback the per-sync errors telescope (e_k = R_k - R_{k-1},
    // the mean-residual increments), so the TIME-AVERAGED global
    // converges to the replica mean at rate residual/K. That is the
    // unbiasedness claim: no quantization mass is ever lost, only
    // deferred to the next sync.
    let layout = Arc::new(FlatLayout::new(vec![vec![300], vec![7, 3], vec![40]]));
    let mut rng = Rng::new(0x44);
    let init = random_leaf_values(&mut rng, &layout);
    let theta_a = random_leaf_values(&mut rng, &layout);
    let theta_b = random_leaf_values(&mut rng, &layout);
    let mut sync = OuterSync::new(
        Arc::clone(&layout),
        &to_host(&layout, &init),
        to_lits(&layout, &init),
        1.0,
        0.0,
        1,
    )
    .unwrap()
    .with_codec(codec_for(OuterBits::Int4), 99);
    let enc = sync.encoder();
    let rep_lits = [to_lits(&layout, &theta_a), to_lits(&layout, &theta_b)];
    let mut comm = [CommState::default(), CommState::default()];
    for (cm, _) in comm.iter_mut().zip(&rep_lits) {
        enc.init_snapshot(cm, &to_lits(&layout, &init)).unwrap();
    }

    let mean: Vec<f32> = (0..layout.total())
        .map(|i| {
            let leaf_of = |vals: &[Vec<f32>]| {
                // flatten per-leaf vectors to the arena order
                let mut flat = Vec::new();
                for v in vals {
                    flat.extend_from_slice(v);
                }
                flat[i]
            };
            (leaf_of(&theta_a) + leaf_of(&theta_b)) / 2.0
        })
        .collect();
    let err = |sync: &OuterSync| -> f32 {
        sync.global()
            .data()
            .iter()
            .zip(&mean)
            .map(|(g, m)| (g - m).abs())
            .fold(0.0f32, f32::max)
    };
    let err0 = err(&sync);
    assert!(err0 > 0.1, "degenerate test setup: start already at mean");

    let rounds = 40u64;
    let mut avg = vec![0.0f64; layout.total()];
    for round in 0..rounds {
        let payloads: Vec<Vec<u8>> = rep_lits
            .iter()
            .enumerate()
            .map(|(r, lits)| {
                enc.encode_replica(r, lits, &mut comm[r], None, round)
                    .unwrap()
            })
            .collect();
        let frames: Vec<&[u8]> = payloads.iter().map(|p| &p[..]).collect();
        sync.sync_encoded(&frames, None).unwrap();
        for (a, &g) in avg.iter_mut().zip(sync.global().data()) {
            *a += g as f64 / rounds as f64;
        }
        // broadcast: replicas' snapshots adopt the refreshed global
        let adopt: Vec<(usize, Arc<xla::Literal>)> = sync
            .global_literals()
            .iter()
            .enumerate()
            .map(|(l, lit)| (l, Arc::clone(lit)))
            .collect();
        for cm in comm.iter_mut() {
            enc.adopt(cm, &adopt).unwrap();
        }
    }
    // time-average: |avg - mean| = |R_K|/K <= one quantization step
    // over K — far inside the per-sync fluctuation band
    let avg_err = avg
        .iter()
        .zip(&mean)
        .map(|(a, &m)| (a - m as f64).abs())
        .fold(0.0f64, f64::max);
    assert!(
        avg_err < 0.05 && avg_err < err0 as f64 / 20.0,
        "EF must make quantized syncs unbiased: err0 {err0}, \
         time-averaged err {avg_err}"
    );
    // the last iterate stays inside the quantization band (no drift,
    // no lost mass) even though it never pins the mean exactly
    let errk = err(&sync);
    assert!(
        errk < err0 * 0.8,
        "final iterate drifted: err {err0} -> {errk}"
    );
    // wire bytes: 40 syncs, 2 replicas, ~8x smaller than fp32
    let w = sync.wire_stats();
    assert_eq!(w.syncs(), rounds);
    let fp32_per_replica = layout.total() as u64 * 4;
    assert!(
        w.records()[0].bytes_per_replica < fp32_per_replica / 6,
        "int4 payload {} vs fp32 {}",
        w.records()[0].bytes_per_replica,
        fp32_per_replica
    );
}

// ---- (4) worker-pool twin: bit-identical at every width --------------

/// Deterministic host-math inner step (same shape as
/// tests/worker_pool.rs): mixes the replica's private shard with the
/// step index; loss is a pure function of the post-step state.
struct ToyEngine {
    n: usize,
}

impl InnerEngine for ToyEngine {
    fn inner_step(
        &self,
        rep: usize,
        replica: &mut ReplicaState,
        t: usize,
    ) -> anyhow::Result<f64> {
        let toks = replica.shard.next_batch(2, 8);
        let mut loss = 0.0f64;
        for leaf in 0..self.n {
            let lit = &replica.state[leaf];
            let dims = lit.array_shape()?.dims().to_vec();
            let mut v = lit.to_vec::<f32>()?;
            for (i, x) in v.iter_mut().enumerate() {
                *x = 0.5 * *x
                    + 1e-3 * toks[(i + t) % toks.len()] as f32
                    + 1e-2 * (t as f32 + rep as f32 * 0.25).sin();
            }
            loss += v.iter().map(|&f| f as f64).sum::<f64>() / v.len() as f64;
            replica.state[leaf] = Arc::new(xla::Literal::vec1(&v).reshape(&dims)?);
        }
        Ok(loss / self.n as f64)
    }

    fn eval(&self, params: &[Arc<xla::Literal>]) -> anyhow::Result<f64> {
        let mut acc = 0.0f64;
        for (i, p) in params.iter().enumerate() {
            for x in p.to_vec::<f32>()? {
                acc += x as f64 * (i + 1) as f64;
            }
        }
        Ok(acc)
    }
}

fn twin_layout() -> Arc<FlatLayout> {
    Arc::new(FlatLayout::new(vec![
        vec![3, 2],
        vec![4],
        vec![2, 2],
        vec![5],
        vec![1],
    ]))
}

struct TwinResult {
    step_losses: Vec<f64>,
    eval_curve: Vec<(usize, f64)>,
    outer_syncs: usize,
    global_bits: Vec<u32>,
    finals: Vec<Vec<Vec<f32>>>,
    wire_up: u64,
    wire_down: u64,
}

fn twin_run(bits: OuterBits, m: usize, workers: usize, fragments: usize) -> TwinResult {
    let l = twin_layout();
    let engine = ToyEngine { n: l.n_leaves() };
    let init: Vec<Arc<xla::Literal>> = (0..l.n_leaves())
        .map(|leaf| {
            let v: Vec<f32> = (0..l.len(leaf))
                .map(|i| ((leaf * 37 + i * 11 + 5) % 23) as f32 * 0.1 - 1.0)
                .collect();
            Arc::new(
                HostTensor::from_vec(l.shape(leaf), v)
                    .to_literal()
                    .unwrap(),
            )
        })
        .collect();
    let mut replicas: Vec<ReplicaState> = (0..m)
        .map(|r| ReplicaState {
            state: init.clone(),
            shard: TokenStream::new(CorpusSpec::default(), 5, r as u64),
        })
        .collect();
    let host: Vec<HostTensor> = (0..l.n_leaves())
        .map(|leaf| HostTensor::from_literal(&init[leaf]).unwrap())
        .collect();
    let mut sync = OuterSync::new(Arc::clone(&l), &host, init.clone(), 0.7, 0.9, fragments)
        .unwrap()
        .with_codec(codec_for(bits), 42);
    let plan = DrivePlan {
        total_steps: 22,
        sync_interval: 3,
        fragments,
        n_params: l.n_leaves(),
        eval_every: Some(7),
        log_every: 100,
        workers,
    };
    let out = drive(&engine, &mut replicas, Some(&mut sync), &plan).expect("drive");
    TwinResult {
        step_losses: out.step_losses,
        eval_curve: out.eval_curve,
        outer_syncs: out.outer_syncs,
        global_bits: sync.global().data().iter().map(|x| x.to_bits()).collect(),
        finals: replicas
            .iter()
            .map(|r| {
                (0..l.n_leaves())
                    .map(|leaf| r.state[leaf].to_vec::<f32>().unwrap())
                    .collect()
            })
            .collect(),
        wire_up: sync.wire_stats().total_up(),
        wire_down: sync.wire_stats().total_down(),
    }
}

#[test]
fn worker_pool_twin_bit_identical_at_every_bit_width() {
    for bits in OuterBits::ALL {
        let oracle = twin_run(bits, 4, 1, 2);
        assert_eq!(oracle.step_losses.len(), 22, "{bits:?}");
        assert!(oracle.outer_syncs > 0, "{bits:?}");
        assert!(oracle.wire_up > 0 && oracle.wire_down > 0, "{bits:?}");
        for workers in [2usize, 4] {
            let par = twin_run(bits, 4, workers, 2);
            assert_eq!(par.step_losses, oracle.step_losses, "{bits:?} w={workers}");
            assert_eq!(par.eval_curve, oracle.eval_curve, "{bits:?} w={workers}");
            assert_eq!(par.outer_syncs, oracle.outer_syncs, "{bits:?} w={workers}");
            assert_eq!(
                par.global_bits, oracle.global_bits,
                "{bits:?} w={workers}: global arena drifted"
            );
            assert_eq!(par.finals, oracle.finals, "{bits:?} w={workers}");
            assert_eq!(par.wire_up, oracle.wire_up, "{bits:?} w={workers}");
            assert_eq!(par.wire_down, oracle.wire_down, "{bits:?} w={workers}");
        }
    }
}

#[test]
fn narrower_wire_strictly_shrinks_payloads() {
    // Same schedule, descending widths: wire-up bytes must strictly
    // decrease while sync counts stay identical.
    let runs: Vec<TwinResult> = OuterBits::ALL
        .iter()
        .map(|&b| twin_run(b, 2, 1, 1))
        .collect();
    for w in runs.windows(2) {
        assert_eq!(w[0].outer_syncs, w[1].outer_syncs);
        assert!(
            w[1].wire_up < w[0].wire_up,
            "narrower codec must ship fewer bytes: {} -> {}",
            w[0].wire_up,
            w[1].wire_up
        );
        // broadcast stays f32 regardless of the up-wire codec
        assert_eq!(w[0].wire_down, w[1].wire_down);
    }
}
