//! One bench per paper table/figure (deliverable d): times each
//! generator AND prints a digest of the rows it produces, so `cargo
//! bench | tee bench_output.txt` doubles as the reproduction record.
//! Generators read whatever the sweep store currently holds; analytic
//! ones (Table 6, Figure 10) are store-independent.

use std::path::Path;

use diloco::config::RepoConfig;
use diloco::report::{experiment_ids, generate};
use diloco::sweep::SweepStore;
use diloco::util::bench::Bencher;

fn main() -> anyhow::Result<()> {
    let repo = RepoConfig::load(Path::new(env!("CARGO_MANIFEST_DIR")))?;
    let store = SweepStore::open(&repo.root.join("runs/sweep.jsonl"))?;
    println!(
        "sweep store: {} completed runs (tables/figures reflect current data)\n",
        store.len()
    );
    let mut b = Bencher::new(2.0);
    for id in experiment_ids() {
        // parametric fitting (table13) is the only heavy generator;
        // keep restarts low in the bench loop.
        let restarts = 16;
        match generate(id, &store, &repo, restarts) {
            Ok(text) => {
                b.run(&format!("generate {id}"), || {
                    generate(id, &store, &repo, restarts).unwrap().len()
                });
                let digest: Vec<&str> = text.lines().take(6).collect();
                println!("--- {id} ---\n{}\n...\n", digest.join("\n"));
            }
            Err(e) => println!("--- {id} --- SKIPPED: {e}\n"),
        }
    }
    b.report("table/figure regeneration");
    Ok(())
}
