//! Hot-path benchmarks: the PJRT execution path the coordinator drives
//! every inner step, plus the flat-bus outer-sync path it drives every
//! H steps, plus the replica-parallel worker pool's measured inner-loop
//! wall-clock (vs the `netsim` analytic model), measured at each layer
//! so perf passes have precise before/after numbers.
//!
//! The PJRT cases need lowered artifacts (`make artifacts`) and are
//! skipped without them; the outer-sync / broadcast / pool cases run on
//! synthetic m0/m2-shaped layouts regardless, so every environment
//! records a perf trajectory. Results are printed as a table and
//! written to `BENCH_hot_path.json` (machine-readable, exact ns). Pass
//! `-- --diff OLD.json` to print per-case deltas against a previous
//! report (perf trend tracking; also `diloco bench-diff`).
//!
//! Run: cargo bench (harness=false; criterion unavailable offline).

use std::path::Path;
use std::sync::Arc;

use diloco::comm::codec::BLOCK;
use diloco::comm::{
    codec_for, Channel, CommLink, Direction, DownWire, OuterBits, ReplicaComm, WorkerComm,
};
use diloco::config::RepoConfig;
use diloco::coordinator::outer_opt::{acc_add, acc_finish, scalar_ref};
use diloco::coordinator::{
    drive, Checkpoint, DriveOutcome, DrivePlan, EventKind, InnerEngine, Journal, OuterOpt,
    OuterSync, ReplicaState,
};
use diloco::data::synthetic::{CorpusSpec, TokenStream};
use diloco::netsim::walltime::replica_parallel_speedup;
use diloco::runtime::{
    f32_scalar, i32_literal, u32_scalar, FlatLayout, FlatParams, HostTensor, ModelRuntime,
    Runtime,
};
use diloco::util::bench::{diff_reports, print_diff, Bencher};
use diloco::util::json::Json;
use diloco::util::par;
use diloco::util::rng::Rng;

/// The manifest leaf shapes of a mini-ladder rung (mirrors
/// python/compile/configs.py param_specs; head_dim 16, mlp_ratio 4,
/// vocab 512 from configs/models.json).
fn model_shapes(layers: usize, d: usize, heads: usize) -> Vec<Vec<usize>> {
    let (dh, vocab, f) = (16usize, 512usize, 4 * d);
    let mut s = vec![vec![vocab, d]];
    for _ in 0..layers {
        s.push(vec![d]);
        s.push(vec![d, heads * dh]);
        s.push(vec![d, heads * dh]);
        s.push(vec![d, heads * dh]);
        s.push(vec![heads * dh, d]);
        s.push(vec![dh]);
        s.push(vec![dh]);
        s.push(vec![d]);
        s.push(vec![d, f]);
        s.push(vec![f, d]);
    }
    s.push(vec![d]);
    s
}

fn randn_params(layout: &Arc<FlatLayout>, seed: u64) -> FlatParams {
    let mut rng = Rng::new(seed);
    let mut fp = FlatParams::zeros(layout);
    for x in fp.data_mut() {
        *x = rng.normal() as f32 * 0.02;
    }
    fp
}

/// Flat-bus outer sync + broadcast cases for one ladder rung.
fn bench_outer_sync(b: &mut Bencher, label: &str, layout: &Arc<FlatLayout>) {
    let n = layout.n_leaves();
    let n_elems = layout.total();
    let pristine = randn_params(layout, 7);
    let host: Vec<HostTensor> = pristine.to_host();

    // -- scalar oracle (the frozen seed implementation, M=2) --
    {
        let leaves: Vec<Vec<f32>> = (0..n).map(|l| pristine.leaf(l).to_vec()).collect();
        let replicas: Vec<Vec<Vec<f32>>> = vec![leaves.clone(), leaves.clone()];
        let mut opt = scalar_ref::ScalarOuterOpt::new(0.8, 0.9);
        b.run(&format!("{label}/outer sync scalar-oracle (M=2)"), || {
            let mut g = leaves.clone();
            let delta = scalar_ref::outer_gradient(&g, &replicas);
            opt.step_subset(&mut g, &delta, |_| true);
            g
        });
    }

    // -- flat bus, preallocated arenas (M in {2, 8}), sharded over the
    // host's cores exactly like `OuterSync::sync` does (block-aligned
    // deterministic ownership — bit-identical to the sequential walk,
    // pinned by coordinator::outer_opt tests) --
    let threads = std::thread::available_parallelism().map_or(1, |t| t.get());
    for m in [2usize, 8] {
        let replicas: Vec<FlatParams> = (1..=m as u64)
            .map(|s| randn_params(layout, 100 + s))
            .collect();
        let mut global = pristine.clone();
        let mut acc = FlatParams::zeros(layout);
        let full = layout.full_range();
        let shards = par::shard_ranges(&full, threads, BLOCK);
        let mut opt = OuterOpt::new(0.8, 0.9);
        // bytes per iteration: the global reset (read + write), the
        // fused zero/add/finish reduce (M payload reads + acc traffic),
        // and the Nesterov step (theta + velocity read/write)
        let bytes = 4 * n_elems as u64 * (2 + 1 + 3 * m as u64 + 3 + 5);
        b.run_throughput(
            &format!("{label}/outer sync: delta + Nesterov (M={m})"),
            bytes,
            (n_elems * m) as u64,
            || {
                // reset global (the scalar case pays an analogous clone)
                global.data_mut().copy_from_slice(pristine.data());
                let accs = par::split_pieces(acc.data_mut(), &shards);
                let items: Vec<_> = shards.iter().zip(accs).collect();
                par::map_shards(items, |_, (pieces, accs)| {
                    for (p, acc) in pieces.iter().zip(accs) {
                        acc.fill(0.0);
                        for rep in &replicas {
                            acc_add(&mut acc[..], &rep.data()[p.range.clone()]);
                        }
                        acc_finish(acc, &pristine.data()[p.range.clone()], m as f32);
                    }
                });
                opt.step_pieces(&mut global, &acc, &shards);
                global.data()[0]
            },
        );
    }

    // -- streaming fragment (P=4): one fragment's ranges only --
    {
        let fragments = 4usize;
        let replicas: Vec<FlatParams> =
            (1..=2u64).map(|s| randn_params(layout, 200 + s)).collect();
        let mut global = pristine.clone();
        let mut acc = FlatParams::zeros(layout);
        let ranges = layout.fragment_ranges(fragments, 1);
        let mut opt = OuterOpt::new(0.8, 0.9);
        b.run(
            &format!("{label}/outer sync: streaming fragment (P={fragments}, M=2)"),
            || {
                global.data_mut().copy_from_slice(pristine.data());
                for r in &ranges {
                    acc.data_mut()[r.clone()].fill(0.0);
                }
                for rep in &replicas {
                    for r in &ranges {
                        acc_add(&mut acc.data_mut()[r.clone()], &rep.data()[r.clone()]);
                    }
                }
                for r in &ranges {
                    acc_finish(
                        &mut acc.data_mut()[r.clone()],
                        &pristine.data()[r.clone()],
                        2.0,
                    );
                }
                opt.step_ranges(&mut global, &acc, &ranges);
                global.data()[0]
            },
        );
    }

    // -- end-to-end sync through the bus (literals in and out, M=2) --
    {
        let init_lits: Vec<Arc<xla::Literal>> = (0..n)
            .map(|l| Arc::new(pristine.leaf_literal(l).unwrap()))
            .collect();
        let mut sync = OuterSync::new(Arc::clone(layout), &host, init_lits, 0.8, 0.9, 1)
            .expect("bench sync setup");
        let rep_lits: Vec<Vec<Arc<xla::Literal>>> = (0..2)
            .map(|_| {
                (0..n)
                    .map(|l| Arc::new(pristine.leaf_literal(l).unwrap()))
                    .collect()
            })
            .collect();
        let parts: Vec<&[Arc<xla::Literal>]> = rep_lits.iter().map(|v| &v[..]).collect();
        b.run(&format!("{label}/outer sync end-to-end via bus (M=2)"), || {
            sync.sync(&parts, None).unwrap();
            sync.uploads()
        });
    }

    // -- broadcast: dedup (N uploads shared via Arc) vs seed (M*N) --
    {
        let m = 8usize;
        b.run(&format!("{label}/broadcast: N uploads, Arc-shared (M={m})"), || {
            let lits: Vec<Arc<xla::Literal>> = (0..n)
                .map(|l| Arc::new(pristine.leaf_literal(l).unwrap()))
                .collect();
            let states: Vec<Vec<Arc<xla::Literal>>> =
                (0..m).map(|_| lits.iter().cloned().collect()).collect();
            states
        });
        b.run(&format!("{label}/broadcast: M*N uploads (M={m}, seed path)"), || {
            let states: Vec<Vec<xla::Literal>> = (0..m)
                .map(|_| host.iter().map(|t| t.to_literal().unwrap()).collect())
                .collect();
            states
        });
    }
}

/// Comm-plane cases: encode/decode throughput per bit width over the
/// rung's full flat arena on **both legs** — raw codec passes, the
/// DownWire's error-compensated broadcast encode, and the worker-side
/// broadcast decode (decode + snap advance + literal rebuild) — plus
/// one end-to-end quantized sync through `sync_encoded` (encoder +
/// error feedback + reduce + publish). Exact wire bytes per width and
/// direction are printed and attached to BENCH_hot_path.json (the
/// codec's whole point is the byte column, not just the time column).
fn bench_comm(b: &mut Bencher, label: &str, layout: &Arc<FlatLayout>) {
    use diloco::transport::frame::{reclaim_wires, WireBuf, WireSlice};
    let pristine = randn_params(layout, 7);
    let n = layout.total();
    println!("\n== {label}: wire bytes per full sync, up (per replica) vs down (per sync) ({n} params) ==");
    let fp32_bytes = 4 * n;
    let mut wire_rows: Vec<Json> = Vec::new();
    for bits in OuterBits::ALL {
        let codec = codec_for(bits);
        let bytes = codec.wire_bytes(n);
        // one codec serves both directions: up ships per replica, the
        // broadcast ships once — the table records both meanings
        println!(
            "{:>6}: up {bytes:>10} B/replica   down {bytes:>10} B/sync  ({:.2}x vs fp32, {:.3} bits/param)",
            bits.label(),
            fp32_bytes as f64 / bytes as f64,
            bytes as f64 * 8.0 / n as f64
        );
        wire_rows.push(Json::obj(vec![
            ("bits", Json::str(bits.label())),
            ("params", Json::int(n as i128)),
            ("up_bytes_per_replica", Json::int(bytes as i128)),
            ("down_bytes_per_sync", Json::int(bytes as i128)),
            ("fp32_bytes", Json::int(fp32_bytes as i128)),
        ]));
        // bytes moved per pass: the f32 arena on one side of the codec
        // plus the wire bytes on the other
        let moved = (4 * n + bytes) as u64;
        let mut wire = Vec::with_capacity(bytes);
        b.run_throughput(
            &format!("{label}/comm encode {} (full arena)", bits.label()),
            moved,
            n as u64,
            || {
                wire.clear();
                codec.encode(pristine.data(), 0xC0DE, &mut wire);
                wire.len()
            },
        );
        let mut dst = vec![0.0f32; n];
        b.run_throughput(
            &format!("{label}/comm decode {} (full arena)", bits.label()),
            moved,
            n as u64,
            || {
                codec.decode(&wire, &mut dst).unwrap();
                dst[0]
            },
        );
    }
    b.extra(
        &format!("wire_bytes_{label}"),
        Json::arr(wire_rows.into_iter()),
    );

    // broadcast leg throughput per lossy width: coordinator-side
    // error-compensated encode (DownWire) and worker-side decode into
    // the shared snapshot + literal rebuild (CommLink::adopt_encoded)
    for bits in [OuterBits::Bf16, OuterBits::Int8, OuterBits::Int4] {
        let target = randn_params(layout, 31);
        let mut dw = DownWire::new(
            Channel::new(Arc::clone(layout), codec_for(bits), 1, 0xD0, Direction::Down),
            pristine.data(),
        );
        let wire_len = codec_for(bits).wire_bytes(n);
        let mut round = 0u64;
        let mut last = WireBuf::new();
        b.run_throughput(
            &format!("{label}/broadcast encode {} (EF, full arena)", bits.label()),
            (4 * n + wire_len) as u64,
            n as u64,
            || {
                dw.encode_broadcast_into(target.data(), None, round, 1, &mut last)
                    .unwrap();
                round += 1;
                last.payload_len()
            },
        );
        let link = CommLink::new(
            Channel::new(Arc::clone(layout), codec_for(OuterBits::Fp32), 1, 0xD0, Direction::Up),
            Channel::new(Arc::clone(layout), codec_for(bits), 1, 0xD0, Direction::Down),
        );
        let n_leaves = layout.n_leaves();
        let init_lits: Vec<Arc<xla::Literal>> = (0..n_leaves)
            .map(|l| Arc::new(pristine.leaf_literal(l).unwrap()))
            .collect();
        let mut wc = WorkerComm::default();
        link.init_snapshot(&mut wc, &init_lits).expect("bench snapshot");
        b.run_throughput(
            &format!("{label}/broadcast decode {} (snap + literals)", bits.label()),
            (4 * n + wire_len) as u64,
            n as u64,
            || link.adopt_encoded(&mut wc, None, last.payload()).unwrap().len(),
        );
    }

    // end-to-end int4/int4 sync: encode M=2 replicas with error
    // feedback, reduce + Nesterov + publish + broadcast encode on the
    // coordinator
    {
        let host: Vec<HostTensor> = pristine.to_host();
        let n_leaves = layout.n_leaves();
        let init_lits: Vec<Arc<xla::Literal>> = (0..n_leaves)
            .map(|l| Arc::new(pristine.leaf_literal(l).unwrap()))
            .collect();
        let threads = std::thread::available_parallelism().map_or(1, |t| t.get());
        let mut sync = OuterSync::new(Arc::clone(layout), &host, init_lits.clone(), 0.8, 0.9, 1)
            .expect("comm bench sync setup")
            .with_codec(codec_for(OuterBits::Int4), 0xBE)
            .with_down_codec(codec_for(OuterBits::Int4))
            .with_sync_threads(threads);
        let link = sync.link();
        let rep_lits: Vec<Vec<Arc<xla::Literal>>> = (1..=2u64)
            .map(|s| {
                let rp = randn_params(layout, 300 + s);
                (0..n_leaves)
                    .map(|l| Arc::new(rp.leaf_literal(l).unwrap()))
                    .collect()
            })
            .collect();
        let mut wc = WorkerComm::default();
        link.init_snapshot(&mut wc, &init_lits).expect("comm bench snapshot");
        let mut rcs: Vec<ReplicaComm> = (0..2).map(|_| ReplicaComm::default()).collect();
        for rc in rcs.iter_mut() {
            link.init_replica(rc);
        }
        let mut round = 0u64;
        b.run(&format!("{label}/comm sync end-to-end int4/int4 (M=2)"), || {
            let payloads: Vec<WireSlice> = rep_lits
                .iter()
                .enumerate()
                .map(|(r, lits)| {
                    link.encode_replica(r, lits, &mut wc, &mut rcs[r], None, round)
                        .unwrap()
                })
                .collect();
            let frames: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
            sync.sync_encoded(&frames, None).unwrap();
            // worker side of the broadcast: decode into the snapshot
            let bytes = sync.take_broadcast_bytes().expect("lossy down broadcast");
            link.adopt_encoded(&mut wc, None, bytes.as_slice()).unwrap();
            // steady state: spent wire buffers feed the next round's
            // encodes (the broadcast frame back to the coordinator's
            // pool, the report frames back to the worker) — the drive
            // loop does exactly this
            drop(frames);
            for p in reclaim_wires(vec![bytes]) {
                sync.recycle_wire(p);
            }
            for p in reclaim_wires(payloads) {
                wc.recycle(p);
            }
            round += 1;
            sync.wire_stats().total()
        });
    }
}

/// Transport frame path: the zero-copy framed write (header stamped
/// over the `WireBuf`'s reserved prefix, one contiguous write) against
/// the retained copying baseline (`write_frame_copying`: fresh buffer
/// plus a payload memcpy per frame), and the recycled-buffer frame
/// read, at real sync-payload sizes (the per-replica up-wire bytes a
/// TCP lane ships every H/P steps, fp32 and int4). These rows ride the
/// CI bench-diff *tight* gate — a staging copy creeping back into the
/// wire path shows up here first, as a throughput drop toward the
/// copying row.
fn bench_transport(b: &mut Bencher, label: &str, layout: &Arc<FlatLayout>) {
    use diloco::transport::frame::{
        encode_frame, read_frame_into, write_frame_copying, FrameHeader, MsgKind, WireBuf,
        HEADER_LEN,
    };
    use std::io::Write;
    let n = layout.total();
    for bits in [OuterBits::Fp32, OuterBits::Int4] {
        let payload_len = codec_for(bits).wire_bytes(n);
        let h = FrameHeader {
            kind: MsgKind::Report,
            up_bits: bits.bits() as u8,
            down_bits: bits.bits() as u8,
            fingerprint: 0xFEED_F00D,
            sync_index: 3,
            frag: Some(1),
        };
        let moved = (HEADER_LEN + payload_len) as u64;
        let payload = vec![0x5Au8; payload_len];
        let mut sink = std::io::sink();
        // zero-copy leg: the payload already lives framed in a WireBuf;
        // per frame, stamp the 36-byte header and write one slice
        let mut buf = WireBuf::new();
        buf.extend_payload(&payload);
        b.run_throughput(
            &format!("{label}/transport frame write zero-copy {}", bits.label()),
            moved,
            n as u64,
            || {
                let bytes = buf.frame(&h).unwrap();
                sink.write_all(bytes).unwrap();
                bytes.len()
            },
        );
        // the retired baseline: stage header + payload into a fresh Vec
        b.run_throughput(
            &format!("{label}/transport frame write copying {}", bits.label()),
            moved,
            n as u64,
            || {
                write_frame_copying(&mut sink, &h, &payload).unwrap();
                payload.len()
            },
        );
        // read leg: parse into a recycled WireBuf (no allocation)
        let mut framed = Vec::with_capacity(HEADER_LEN + payload_len);
        encode_frame(&h, &payload, &mut framed).unwrap();
        let mut rbuf = WireBuf::new();
        b.run_throughput(
            &format!("{label}/transport frame read recycled {}", bits.label()),
            moved,
            n as u64,
            || {
                let mut rd = &framed[..];
                let hdr = read_frame_into(&mut rd, &mut rbuf).unwrap();
                (hdr.sync_index, rbuf.payload_len())
            },
        );
    }
}

/// Loopback sync latency through the real socket stack: one lane
/// reactor and one `TcpWorkerLink` over 127.0.0.1, measuring a full
/// round — streamed broadcast down, `Run`, encoded report back up —
/// with every wire buffer recycled, at real per-sync payload sizes.
/// The medians feed the blocking bench-diff tight gate: a stray copy
/// or allocation on the steady-state socket path lands here as
/// latency.
fn bench_loopback(b: &mut Bencher, layout: &Arc<FlatLayout>) {
    use diloco::transport::frame::{reclaim_wires, WireBuf, WireSlice};
    use diloco::transport::msg::{
        Broadcast, Cmd, PayloadSpec, SegmentChurn, SyncPayload, WorkerReport,
    };
    use diloco::transport::tcp::{
        accept_workers, connect_with_backoff, worker_handshake, LaneReactor, SessionInfo,
        TcpWorkerLink, CONNECT_ATTEMPTS, ENGINE_TOY,
    };
    use diloco::transport::WorkerLink;
    use std::net::TcpListener;

    let n = layout.total();
    for bits in [OuterBits::Fp32, OuterBits::Int4] {
        let wire_len = codec_for(bits).wire_bytes(n);
        let listener = TcpListener::bind("127.0.0.1:0").expect("loopback bench bind");
        let addr = listener.local_addr().expect("loopback bench addr").to_string();
        let info = SessionInfo {
            fingerprint: 0xBE7C,
            up_bits: bits.bits() as u8,
            down_bits: bits.bits() as u8,
            engine: ENGINE_TOY,
            live: vec![true],
            config_json: String::from("{}"),
        };
        let up = vec![0x5Au8; wire_len];
        let worker = std::thread::spawn(move || {
            let mut stream =
                connect_with_backoff(&addr, CONNECT_ATTEMPTS).expect("loopback bench connect");
            let got = worker_handshake(&mut stream, &[0], 0, 0, 0).expect("loopback handshake");
            let mut link = TcpWorkerLink::new(stream, &got).expect("loopback bench link");
            // encode buffers reclaimed from shipped reports, reused
            let mut bank: Vec<WireBuf> = Vec::new();
            loop {
                match link.recv_cmd() {
                    Some(Cmd::Spares(bufs)) => bank.extend(bufs),
                    Some(Cmd::Run { broadcast, .. }) => {
                        drop(broadcast);
                        let mut buf = bank.pop().unwrap_or_default();
                        buf.reset();
                        buf.extend_payload(&up);
                        link.send_report(Ok(WorkerReport {
                            reps: vec![(
                                0,
                                vec![0.0],
                                SyncPayload::Encoded(WireSlice::whole(Arc::new(buf))),
                            )],
                        }))
                        .expect("loopback bench report");
                    }
                    Some(Cmd::Finish { .. }) | None => break,
                }
            }
        });
        let lanes = accept_workers(&listener, 1, &info).expect("loopback bench accept");
        let mut reactor = LaneReactor::new(lanes).expect("loopback bench reactor");
        let down = vec![0xC3u8; wire_len];
        let mut round = 0u64;
        b.run_throughput(
            &format!("transport/loopback sync latency {} (1 worker)", bits.label()),
            2 * wire_len as u64,
            n as u64,
            || {
                reactor
                    .bcast_begin(None, round, down.len() as u64)
                    .expect("loopback bench bcast");
                reactor.bcast_chunk(&down).expect("loopback bench chunk");
                reactor
                    .send_cmd(&Cmd::Run {
                        from: round as usize,
                        to: round as usize + 1,
                        broadcast: Broadcast::Pending { frag: None },
                        payload: PayloadSpec::None,
                        churn: SegmentChurn::default(),
                    })
                    .expect("loopback bench run");
                let reports = reactor.collect_reports().expect("loopback bench collect");
                let spent: Vec<WireSlice> = reports
                    .into_iter()
                    .flat_map(|r| r.reps)
                    .filter_map(|(_, _, p)| match p {
                        SyncPayload::Encoded(ws) => Some(ws),
                        _ => None,
                    })
                    .collect();
                let got = spent.len();
                reactor.recycle(reclaim_wires(spent));
                round += 1;
                got
            },
        );
        reactor.send_finish(&Broadcast::empty());
        worker.join().expect("loopback bench worker");
    }
}

/// The arrival-pipelined up-leg against its one-shot twin, M=4 over
/// int4/int4 on real sockets: both rows drive the identical sync —
/// four worker links, the same encoded contribution bytes, the same
/// fused reduce + Nesterov step on the coordinator — but the streamed
/// row ships block-aligned `ContribChunk` frames and reduces behind
/// arrival. The delta between the rows is the wire-wait the pipeline
/// reclaims. A warmup round asserts `fired_early > 0` — some shard
/// reduced before the last contribution byte landed — so the streamed
/// row measures a real pipeline, not a renamed barrier.
fn bench_loopback_streamed(b: &mut Bencher, layout: &Arc<FlatLayout>) {
    use diloco::transport::frame::{reclaim_wires, WireBuf, WireSlice};
    use diloco::transport::msg::{
        Broadcast, Cmd, EncodeSpec, PayloadSpec, SegmentChurn, SyncPayload, WorkerReport,
    };
    use diloco::transport::tcp::{
        accept_workers, connect_with_backoff, worker_handshake, LaneReactor, SessionInfo,
        TcpWorkerLink, CONNECT_ATTEMPTS, ENGINE_TOY,
    };
    use diloco::transport::WorkerLink;
    use std::net::TcpListener;

    const M: usize = 4;
    let bits = OuterBits::Int4;
    let n = layout.total();
    let n_leaves = layout.n_leaves();
    let pristine = randn_params(layout, 7);
    let host: Vec<HostTensor> = pristine.to_host();
    let init_lits: Vec<Arc<xla::Literal>> = (0..n_leaves)
        .map(|l| Arc::new(pristine.leaf_literal(l).unwrap()))
        .collect();
    let mut sync = OuterSync::new(Arc::clone(layout), &host, init_lits.clone(), 0.8, 0.9, 1)
        .expect("streamed bench sync setup")
        .with_codec(codec_for(bits), 7)
        .with_down_codec(codec_for(bits))
        .with_sync_threads(M);
    let link = sync.link();
    let payload_len = link.payload_bytes(None);
    // real int4 contribution bytes per replica, encoded once up front
    let payloads: Vec<Vec<u8>> = (0..M)
        .map(|r| {
            let p = randn_params(layout, 300 + r as u64);
            let state: Vec<Arc<xla::Literal>> = (0..n_leaves)
                .map(|l| Arc::new(p.leaf_literal(l).unwrap()))
                .collect();
            let mut wc = WorkerComm::default();
            let mut rc = ReplicaComm::default();
            link.init_snapshot(&mut wc, &init_lits).unwrap();
            link.init_replica(&mut rc);
            link.encode_replica(r, &state, &mut wc, &mut rc, None, 0)
                .unwrap()
                .as_slice()
                .to_vec()
        })
        .collect();
    // ~8 block-aligned cuts per contribution — the wire grid the
    // arrival reduce reassembles on
    let cuts: Vec<usize> = {
        let codec = codec_for(bits);
        let mut grid = Vec::new();
        let mut off = 0usize;
        for r in link.up().ranges(None) {
            let mut e = BLOCK;
            while e < r.len() {
                grid.push(off + codec.wire_bytes(e));
                e += BLOCK;
            }
            off += codec.wire_bytes(r.len());
            grid.push(off);
        }
        grid.pop();
        let stride = (grid.len() / 7).max(1);
        grid.into_iter().step_by(stride).collect()
    };

    let listener = TcpListener::bind("127.0.0.1:0").expect("streamed bench bind");
    let addr = listener.local_addr().expect("streamed bench addr").to_string();
    let info = SessionInfo {
        fingerprint: 0xBE7D,
        up_bits: bits.bits() as u8,
        down_bits: bits.bits() as u8,
        engine: ENGINE_TOY,
        live: vec![true; M],
        config_json: String::from("{}"),
    };
    let handles: Vec<_> = (0..M)
        .map(|rid| {
            let addr = addr.clone();
            let payload = payloads[rid].clone();
            let chunks: Vec<(usize, Vec<u8>)> = {
                let mut bounds = vec![0usize];
                bounds.extend(cuts.iter().copied());
                bounds.push(payload.len());
                bounds
                    .windows(2)
                    .filter(|w| w[0] < w[1])
                    .map(|w| (w[0], payload[w[0]..w[1]].to_vec()))
                    .collect()
            };
            std::thread::spawn(move || {
                let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS)
                    .expect("streamed bench connect");
                let got = worker_handshake(&mut stream, &[rid], 0, 0, 0)
                    .expect("streamed bench handshake");
                let mut link = TcpWorkerLink::new(stream, &got).expect("streamed bench link");
                let mut bank: Vec<WireBuf> = Vec::new();
                loop {
                    match link.recv_cmd() {
                        Some(Cmd::Spares(bufs)) => bank.extend(bufs),
                        Some(Cmd::Run { broadcast, payload: spec, .. }) => {
                            drop(broadcast);
                            let PayloadSpec::Encoded(spec) = spec else {
                                panic!("streamed bench expects an encoded payload spec");
                            };
                            if spec.stream {
                                for (off, bytes) in &chunks {
                                    link.send_contrib_chunk(
                                        rid,
                                        spec.sync_index,
                                        spec.frag,
                                        *off,
                                        bytes,
                                    )
                                    .expect("streamed bench chunk");
                                }
                                link.send_report(Ok(WorkerReport {
                                    reps: vec![(rid, vec![0.0], SyncPayload::Streamed)],
                                }))
                                .expect("streamed bench report");
                            } else {
                                let mut buf = bank.pop().unwrap_or_default();
                                buf.reset();
                                buf.extend_payload(&payload);
                                link.send_report(Ok(WorkerReport {
                                    reps: vec![(
                                        rid,
                                        vec![0.0],
                                        SyncPayload::Encoded(WireSlice::whole(Arc::new(buf))),
                                    )],
                                }))
                                .expect("streamed bench report");
                            }
                        }
                        Some(Cmd::Finish { .. }) | None => break,
                    }
                }
            })
        })
        .collect();
    let lanes = accept_workers(&listener, M, &info).expect("streamed bench accept");
    let mut reactor = LaneReactor::new(lanes).expect("streamed bench reactor");

    // any pending broadcast from the previous round ships first, so
    // every timed iteration is a full down + up + reduce + step round
    fn ship_pending(sync: &mut OuterSync, reactor: &mut LaneReactor, round: u64) -> Broadcast {
        match sync.take_broadcast_bytes() {
            Some(ws) => {
                reactor
                    .bcast_begin(None, round, ws.len() as u64)
                    .expect("streamed bench bcast");
                reactor.bcast_chunk(ws.as_slice()).expect("streamed bench bcast chunk");
                for p in reclaim_wires(vec![ws]) {
                    sync.recycle_wire(p);
                }
                Broadcast::Pending { frag: None }
            }
            None => Broadcast::empty(),
        }
    }

    fn one_shot_round(sync: &mut OuterSync, reactor: &mut LaneReactor, round: u64) -> usize {
        let broadcast = ship_pending(sync, reactor, round);
        reactor
            .send_cmd(&Cmd::Run {
                from: round as usize,
                to: round as usize + 1,
                broadcast,
                payload: PayloadSpec::Encoded(EncodeSpec {
                    frag: None,
                    sync_index: round,
                    stream: false,
                }),
                churn: SegmentChurn::default(),
            })
            .expect("streamed bench run");
        let reports = reactor.collect_reports().expect("streamed bench collect");
        let mut slots: Vec<Option<WireSlice>> = vec![None; M];
        for rep in reports {
            for (rid, _, p) in rep.reps {
                if let SyncPayload::Encoded(ws) = p {
                    slots[rid] = Some(ws);
                }
            }
        }
        let spent: Vec<WireSlice> = slots
            .into_iter()
            .map(|s| s.expect("streamed bench payload"))
            .collect();
        {
            let frames: Vec<&[u8]> = spent.iter().map(|s| s.as_slice()).collect();
            sync.sync_encoded(&frames, None).expect("streamed bench one-shot sync");
        }
        let got = spent.len();
        reactor.recycle(reclaim_wires(spent));
        got
    }

    fn streamed_round(
        sync: &mut OuterSync,
        reactor: &mut LaneReactor,
        round: u64,
        rids: &[usize],
    ) -> (usize, usize) {
        let broadcast = ship_pending(sync, reactor, round);
        let mut ar = sync.arrival_begin(rids, None).expect("streamed bench arrival");
        reactor
            .send_cmd(&Cmd::Run {
                from: round as usize,
                to: round as usize + 1,
                broadcast,
                payload: PayloadSpec::Encoded(EncodeSpec {
                    frag: None,
                    sync_index: round,
                    stream: true,
                }),
                churn: SegmentChurn::default(),
            })
            .expect("streamed bench run");
        let reports = reactor
            .collect_reports_streamed(round, None, &mut |rid, off, ws| {
                sync.arrival_chunk(&mut ar, rid, off, ws)
            })
            .expect("streamed bench collect");
        for rep in &reports {
            for (_, _, p) in &rep.reps {
                assert!(
                    matches!(p, SyncPayload::Streamed),
                    "streamed bench expects streamed payloads"
                );
            }
        }
        let early = ar.fired_early();
        let spent = sync.sync_arrival(ar, rids, None).expect("streamed bench arrival sync");
        let got = spent.len();
        reactor.recycle(reclaim_wires(spent));
        (got, early)
    }

    let rids: Vec<usize> = (0..M).collect();
    let mut round = 0u64;
    // warmup, and the acceptance proof: the reduce starts before the
    // last contribution byte arrives
    let (_, early) = streamed_round(&mut sync, &mut reactor, round, &rids);
    assert!(early > 0, "streamed loopback sync never reduced behind arrival");
    round += 1;
    let moved = ((M + 1) * payload_len) as u64;
    b.run_throughput(
        &format!("transport/loopback sync latency {} one-shot ({M} workers)", bits.label()),
        moved,
        n as u64,
        || {
            let got = one_shot_round(&mut sync, &mut reactor, round);
            round += 1;
            got
        },
    );
    b.run_throughput(
        &format!("transport/loopback sync latency {} streamed ({M} workers)", bits.label()),
        moved,
        n as u64,
        || {
            let (got, _) = streamed_round(&mut sync, &mut reactor, round, &rids);
            round += 1;
            got
        },
    );
    reactor.send_finish(&Broadcast::empty());
    for h in handles {
        h.join().expect("streamed bench worker");
    }
}

/// PJRT execution cases (need `make artifacts`).
fn bench_pjrt(b: &mut Bencher, repo: &RepoConfig) -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    for model in ["m0", "m2"] {
        let mr = ModelRuntime::load(Arc::clone(&rt), &repo.model_dir(model))?;
        let n = mr.n_leaves();
        let seq = mr.manifest.model.seq_len;
        let init = mr.artifact("init")?;
        let ts = mr.artifact("train_step")?;
        let gs = mr.artifact("grad_step_mb8")?;
        let ev = mr.artifact("eval_step")?;

        let params = init.call(&[&u32_scalar(0)])?;
        let zeros: Vec<xla::Literal> = mr
            .manifest
            .params
            .iter()
            .map(|s| HostTensor::zeros(&s.shape).to_literal().unwrap())
            .collect();
        let zeros2: Vec<xla::Literal> = mr
            .manifest
            .params
            .iter()
            .map(|s| HostTensor::zeros(&s.shape).to_literal().unwrap())
            .collect();
        let state: Vec<xla::Literal> =
            params.into_iter().chain(zeros).chain(zeros2).collect();

        let mut stream = TokenStream::new(CorpusSpec::default(), 0, 0);
        let toks8 = i32_literal(&[8, seq], &stream.next_batch(8, seq))?;
        let tokse = i32_literal(
            &[mr.manifest.eval_batch, seq],
            &stream.next_batch(mr.manifest.eval_batch, seq),
        )?;
        let (step_l, lr, wd) = (f32_scalar(5.0), f32_scalar(4e-3), f32_scalar(1e-4));

        b.run(&format!("{model}/train_step fused (mb=8, full roundtrip)"), || {
            let mut args: Vec<&xla::Literal> = state.iter().collect();
            args.push(&toks8);
            args.push(&step_l);
            args.push(&lr);
            args.push(&wd);
            ts.call(&args).unwrap()
        });

        b.run(&format!("{model}/grad_step mb=8 (fwd+bwd only)"), || {
            let mut args: Vec<&xla::Literal> = state[..n].iter().collect();
            args.push(&toks8);
            gs.call(&args).unwrap()
        });

        b.run(&format!("{model}/eval_step (batch {})", mr.manifest.eval_batch), || {
            let mut args: Vec<&xla::Literal> = state[..n].iter().collect();
            args.push(&tokse);
            ev.call(&args).unwrap()
        });

        // the H-cadence device<->host edges, over the flat bus
        let layout = Arc::new(FlatLayout::from_specs(&mr.manifest.params));
        let mut pull = FlatParams::zeros(&layout);
        b.run(&format!("{model}/outer sync: pull params to host (bus)"), || {
            for leaf in 0..layout.n_leaves() {
                pull.read_leaf_literal(leaf, &state[leaf]).unwrap();
            }
            pull.data()[0]
        });
        b.run(&format!("{model}/outer sync: push params to device (bus)"), || {
            (0..layout.n_leaves())
                .map(|l| pull.leaf_literal(l).unwrap())
                .collect::<Vec<_>>()
        });
    }
    Ok(())
}

/// Host-math surrogate inner step for the pool cases: reads every
/// state literal to host, runs a few deterministic element-wise passes
/// (the FLOP burn standing in for a PJRT inner step), and re-uploads —
/// so the pool's scheduling, channels, and barrier are measured with
/// realistic per-step literal traffic but no artifacts required.
struct HostMathEngine {
    layout: Arc<FlatLayout>,
    passes: usize,
}

impl InnerEngine for HostMathEngine {
    fn inner_step(
        &self,
        rep: usize,
        replica: &mut ReplicaState,
        t: usize,
    ) -> anyhow::Result<f64> {
        let mut loss = 0.0f64;
        for leaf in 0..self.layout.n_leaves() {
            let mut v = replica.state[leaf].to_vec::<f32>()?;
            for _ in 0..self.passes {
                for (i, x) in v.iter_mut().enumerate() {
                    *x = *x * 0.9995 + ((t * 31 + rep * 7 + i) % 101) as f32 * 1e-6;
                }
            }
            loss += v[0] as f64;
            let dims: Vec<i64> = self.layout.shape(leaf).iter().map(|&d| d as i64).collect();
            replica.state[leaf] = Arc::new(xla::Literal::vec1(&v).reshape(&dims)?);
        }
        Ok(loss)
    }

    fn eval(&self, params: &[Arc<xla::Literal>]) -> anyhow::Result<f64> {
        Ok(params.len() as f64)
    }
}

/// Replica-parallel inner loop: measured wall-clock through the worker
/// pool for M in {1, 2, 4, 8}, sequential (workers=1) vs fully
/// parallel (workers=M), full DiLoCo schedule (outer sync every H).
fn bench_pool(b: &mut Bencher, layout: &Arc<FlatLayout>) {
    let engine = HostMathEngine {
        layout: Arc::clone(layout),
        passes: 4,
    };
    let n = layout.n_leaves();
    let pristine = randn_params(layout, 7);
    let host: Vec<HostTensor> = pristine.to_host();
    let (steps, h) = (12usize, 4usize);
    for m in [1usize, 2, 4, 8] {
        for workers in if m == 1 { vec![1usize] } else { vec![1usize, m] } {
            b.run(
                &format!("pool/inner loop M={m} workers={workers} ({steps} steps, H={h})"),
                || {
                    let init_lits: Vec<Arc<xla::Literal>> = (0..n)
                        .map(|l| Arc::new(pristine.leaf_literal(l).unwrap()))
                        .collect();
                    let mut replicas: Vec<ReplicaState> = (0..m)
                        .map(|r| ReplicaState {
                            state: init_lits.clone(),
                            shard: TokenStream::new(CorpusSpec::default(), 11, r as u64),
                        })
                        .collect();
                    let mut sync =
                        OuterSync::new(Arc::clone(layout), &host, init_lits, 0.8, 0.9, 1)
                            .expect("pool bench sync setup");
                    let plan = DrivePlan {
                        total_steps: steps,
                        sync_interval: h,
                        fragments: 1,
                        n_params: n,
                        eval_every: None,
                        log_every: usize::MAX,
                        workers,
                        overlap_tau: 0,
                    };
                    let out = drive(&engine, &mut replicas, Some(&mut sync), &plan)
                        .expect("pool bench drive");
                    (out.step_losses.len(), sync.uploads())
                },
            );
        }
    }
}

/// Overlapped outer sync: measured wall-clock through the pool for
/// the barrier schedule (τ=0) vs delayed application (τ ∈ {1, 4}),
/// int4 wires both ways so the coordinator's reduce + EF encode is
/// real work to hide under the workers' inner steps. A
/// model-vs-measured summary lands in BENCH_hot_path.json via
/// `Bencher::extra` (the netsim column is the analytic
/// `max(0, t_comm − τ·t_step)` outer-term scale at paper dimensions —
/// the expected *shape*, not a calibration of the host-math
/// surrogate) and the measured cases feed the blocking bench-diff
/// gate like every other case.
fn bench_overlap(b: &mut Bencher, layout: &Arc<FlatLayout>) {
    let engine = HostMathEngine {
        layout: Arc::clone(layout),
        passes: 4,
    };
    let n = layout.n_leaves();
    let pristine = randn_params(layout, 7);
    let host: Vec<HostTensor> = pristine.to_host();
    let (m, workers, steps, h) = (4usize, 4usize, 24usize, 6usize);
    let taus = [0usize, 1, 4];
    for tau in taus {
        b.run(
            &format!("pool/overlap M={m} workers={workers} tau={tau} ({steps} steps, H={h}, int4/int4)"),
            || {
                let init_lits: Vec<Arc<xla::Literal>> = (0..n)
                    .map(|l| Arc::new(pristine.leaf_literal(l).unwrap()))
                    .collect();
                let mut replicas: Vec<ReplicaState> = (0..m)
                    .map(|r| ReplicaState {
                        state: init_lits.clone(),
                        shard: TokenStream::new(CorpusSpec::default(), 13, r as u64),
                    })
                    .collect();
                let mut sync =
                    OuterSync::new(Arc::clone(layout), &host, init_lits, 0.8, 0.9, 1)
                        .expect("overlap bench sync setup")
                        .with_codec(codec_for(OuterBits::Int4), 0xA7)
                        .with_down_codec(codec_for(OuterBits::Int4));
                let plan = DrivePlan {
                    total_steps: steps,
                    sync_interval: h,
                    fragments: 1,
                    n_params: n,
                    eval_every: None,
                    log_every: usize::MAX,
                    workers,
                    overlap_tau: tau,
                };
                let out = drive(&engine, &mut replicas, Some(&mut sync), &plan)
                    .expect("overlap bench drive");
                (out.outer_syncs, sync.wire_stats().total())
            },
        );
    }
    // model-vs-measured table: measured medians against the analytic
    // outer-term scale max(0, 1 − τ·t_step/t_comm) at paper scale
    use diloco::netsim::walltime::{walltime, WalltimeAlgo, WalltimeInput};
    use diloco::netsim::LOW;
    let model_outer = |tau: f64| -> f64 {
        let mk = |sync_every: usize, tau: f64| {
            walltime(&WalltimeInput {
                algo: WalltimeAlgo::DiLoCo {
                    replicas: 4,
                    sync_every,
                },
                params: 1e9,
                tokens: 20e9,
                batch_tokens: 2f64.powi(20),
                cross_dc: LOW,
                outer_bits: 4.125,
                outer_bits_down: 4.125,
                overlap_tau: tau,
                churn: None,
            })
            .comm_s
        };
        mk(30, tau) - mk(usize::MAX, 0.0)
    };
    let median = |tau: usize| {
        b.results()
            .iter()
            .find(|r| {
                r.name
                    == format!(
                        "pool/overlap M={m} workers={workers} tau={tau} ({steps} steps, H={h}, int4/int4)"
                    )
            })
            .map(|r| r.median.as_nanos() as u64)
    };
    let base_ns = median(0);
    let outer0 = model_outer(0.0);
    println!("\n== overlapped outer sync: measured vs netsim model ==");
    println!("{:<6} {:>14} {:>12} {:>18}", "tau", "measured", "vs tau=0", "model outer scale");
    let mut rows: Vec<Json> = Vec::new();
    for tau in taus {
        let (ns, delta_pct) = match (median(tau), base_ns) {
            (Some(ns), Some(b0)) if b0 > 0 => {
                (ns, (ns as f64 - b0 as f64) / b0 as f64 * 100.0)
            }
            (Some(ns), _) => (ns, 0.0),
            _ => continue,
        };
        let scale = if outer0 > 0.0 { model_outer(tau as f64) / outer0 } else { 1.0 };
        println!("{tau:<6} {ns:>12}ns {delta_pct:>+11.1}% {scale:>17.3}");
        rows.push(Json::obj(vec![
            ("tau", Json::int(tau as i128)),
            ("measured_ns", Json::int(ns as i128)),
            ("delta_vs_barrier_pct", Json::num(delta_pct)),
            ("model_outer_scale", Json::num(scale)),
        ]));
    }
    b.extra("overlap_pipeline", Json::arr(rows.into_iter()));
}

/// Robustness-path overhead: the event journal the coordinator appends
/// to at every outer sync, and the boundary checkpoint that
/// `diloco checkpoint` snapshots (capture + JSON serialize, then parse
/// + rebuild on the resume side) — measured per sync so the
/// crash-tolerance machinery's cost stays pinned by the blocking
/// bench-diff gate like every other hot-path case.
fn bench_journal(b: &mut Bencher, layout: &Arc<FlatLayout>) {
    let n = layout.n_leaves();
    let pristine = randn_params(layout, 7);
    let host: Vec<HostTensor> = pristine.to_host();
    let m = 4usize;

    // -- journal append: the per-sync event pair (send + merge) --
    {
        let mut journal = Journal::new();
        let mut sync_idx = 0u64;
        b.run("journal/append per outer sync (send + merge)", || {
            journal.append(
                30,
                sync_idx,
                EventKind::SyncSend,
                None,
                "fragment 0, 4 contributors",
            );
            journal.append(30, sync_idx, EventKind::SyncMerge, None, "fragment 0");
            sync_idx += 1;
            journal.events().len()
        });
    }

    // -- boundary checkpoint: capture + serialize, then parse back --
    {
        let init_lits: Vec<Arc<xla::Literal>> = (0..n)
            .map(|l| Arc::new(pristine.leaf_literal(l).unwrap()))
            .collect();
        let replicas: Vec<ReplicaState> = (0..m)
            .map(|r| ReplicaState {
                state: init_lits.clone(),
                shard: TokenStream::new(CorpusSpec::default(), 17, r as u64),
            })
            .collect();
        let sync = OuterSync::new(Arc::clone(layout), &host, init_lits, 0.8, 0.9, 1)
            .expect("journal bench sync setup");
        let residuals: Vec<Vec<f32>> = (0..m).map(|_| Vec::new()).collect();
        let live = vec![true; m];
        let mut journal = Journal::new();
        for k in 0..8u64 {
            let step = 30 * (k as usize + 1);
            journal.append(step, k, EventKind::SyncSend, None, "fragment 0");
            journal.append(step, k, EventKind::SyncMerge, None, "fragment 0");
        }
        let outcome = DriveOutcome {
            step_losses: (0..240).map(|t| 6.0 - t as f64 * 1e-3).collect(),
            loss_curve: (0..24).map(|i| (i * 10, 6.0 - i as f64 * 1e-2)).collect(),
            eval_curve: (0..8).map(|i| (i * 30, 6.0 - i as f64 * 1e-2)).collect(),
            outer_syncs: 8,
            comm_arena_bytes: 0,
            down_wire_arena_bytes: 0,
        };
        b.run(&format!("checkpoint/capture + serialize (m0-shaped, M={m})"), || {
            let ck = Checkpoint::capture(
                240,
                &replicas,
                &residuals,
                &live,
                Some(&sync),
                &outcome,
                &journal,
            )
            .expect("bench capture");
            ck.to_json().to_string_compact().len()
        });
        let ck = Checkpoint::capture(
            240,
            &replicas,
            &residuals,
            &live,
            Some(&sync),
            &outcome,
            &journal,
        )
        .expect("bench capture");
        let text = ck.to_json().to_string_compact();
        b.run(&format!("checkpoint/parse + rebuild (m0-shaped, M={m})"), || {
            Checkpoint::from_json(&Json::parse(&text).expect("bench parse"))
                .expect("bench rebuild")
                .step
        });
    }
}

/// Measured pool speedup vs the netsim analytic model (Appendix A
/// assumes the M inner loops are perfectly concurrent; the pool should
/// approach M/ceil(M/W) on an unloaded multi-core host).
fn report_pool_speedups(b: &Bencher) {
    println!("\n== replica-parallel inner loop: measured vs analytic model ==");
    println!("{:<8} {:>14} {:>14}", "M", "measured", "model (W=M)");
    let median_of = |name: &str| {
        b.results()
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.median.as_secs_f64())
    };
    for m in [2usize, 4, 8] {
        let seq = median_of(&format!("pool/inner loop M={m} workers=1 (12 steps, H=4)"));
        let par = median_of(&format!("pool/inner loop M={m} workers={m} (12 steps, H=4)"));
        if let (Some(seq), Some(par)) = (seq, par) {
            let measured = seq / par;
            let model = replica_parallel_speedup(m, m);
            println!("{m:<8} {measured:>13.2}x {model:>13.1}x");
        }
    }
    println!("(measured < model when cores < M or inner steps are too short to amortize)");
}

fn main() -> anyhow::Result<()> {
    // `-- --diff OLD.json`: read the old report BEFORE benching, so a
    // bad path fails fast and diffing against the default output path
    // compares the previous run's numbers, not the file this run is
    // about to overwrite.
    let argv: Vec<String> = std::env::args().collect();
    let old_report: Option<(String, Json)> = match argv.iter().position(|a| a == "--diff") {
        Some(i) => {
            let path = argv
                .get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("--diff needs a path to an old BENCH json"))?;
            Some((path.clone(), Json::parse_file(Path::new(path))?))
        }
        None => None,
    };

    let mut b = Bencher::new(4.0);
    // a broken config is an error; only *missing artifacts* downgrade
    // to the host-path-only run
    let repo = RepoConfig::load(Path::new(env!("CARGO_MANIFEST_DIR")))?;
    let have_artifacts = repo.model_dir("m0").join("manifest.json").is_file();

    if have_artifacts && Runtime::cpu().is_ok() {
        bench_pjrt(&mut b, &repo)?;
    } else {
        println!(
            "bench_hot_path: artifacts or PJRT backend missing (make artifacts; \
             offline xla stub gates execution); PJRT cases skipped, host cases follow"
        );
    }

    // flat-bus outer sync + broadcast on mini-ladder-shaped layouts
    // (host path: runs in every environment)
    for (label, layers, d, heads) in [("m0", 2usize, 64usize, 4usize), ("m2", 4, 128, 8)] {
        let layout = Arc::new(FlatLayout::new(model_shapes(layers, d, heads)));
        bench_outer_sync(&mut b, label, &layout);
        bench_comm(&mut b, label, &layout);
        bench_transport(&mut b, label, &layout);
    }

    // replica-parallel inner loop (worker pool) on the m0-shaped layout
    {
        let layout = Arc::new(FlatLayout::new(model_shapes(2, 64, 4)));
        bench_pool(&mut b, &layout);
        // overlapped outer sync: barrier vs delayed application
        bench_overlap(&mut b, &layout);
        // event journal + boundary checkpoint (crash-tolerance path)
        bench_journal(&mut b, &layout);
        // socket sync latency over 127.0.0.1 (reactor + worker link)
        bench_loopback(&mut b, &layout);
        // arrival-pipelined up-leg vs its one-shot twin (M=4, int4)
        bench_loopback_streamed(&mut b, &layout);
    }

    // data pipeline throughput
    let mut stream = TokenStream::new(CorpusSpec::default(), 0, 0);
    b.run("data/synthetic batch 16x64 tokens", || {
        stream.next_batch(16, 64)
    });

    let title = "hot path (L3 coordinator: PJRT inner step + pool inner loop + flat-bus outer sync)";
    b.report(title);
    report_pool_speedups(&b);

    // before/after throughput table over the codec + reduce cases (the
    // rows that declared bytes/elems): new-rate rows always, old median
    // and speedup columns when an old report was given via `--diff`.
    // Attached to BENCH_hot_path.json as `throughput_table`.
    {
        let old_medians: std::collections::BTreeMap<String, u64> = match &old_report {
            Some((_, old)) => old
                .arr_of("results")?
                .iter()
                .filter_map(|r| Some((r.str_of("name").ok()?, r.u64_of("median_ns").ok()?)))
                .collect(),
            None => Default::default(),
        };
        println!("\n== codec + reduce throughput (median) ==");
        println!(
            "{:<52} {:>9} {:>9} {:>10}",
            "benchmark", "GiB/s", "Melem/s", "speedup"
        );
        let mut rows: Vec<Json> = Vec::new();
        for r in b.results() {
            let (Some(gib), Some(melem)) = (r.gib_per_s(), r.melem_per_s()) else {
                continue;
            };
            let new_ns = r.median.as_nanos() as u64;
            let mut fields = vec![
                ("name", Json::str(&r.name)),
                ("median_ns", Json::int(new_ns as i128)),
                ("gib_per_s", Json::num(gib)),
                ("melem_per_s", Json::num(melem)),
            ];
            let speedup = old_medians
                .get(&r.name)
                .filter(|&&o| o > 0 && new_ns > 0)
                .map(|&o| o as f64 / new_ns as f64);
            if let Some(x) = speedup {
                fields.push(("old_median_ns", Json::int(old_medians[&r.name] as i128)));
                fields.push(("speedup_x", Json::num(x)));
            }
            println!(
                "{:<52} {:>9.2} {:>9.1} {:>10}",
                r.name,
                gib,
                melem,
                match speedup {
                    Some(x) => format!("{x:.2}x"),
                    None => "-".into(),
                }
            );
            rows.push(Json::obj(fields));
        }
        b.extra("throughput_table", Json::arr(rows.into_iter()));
    }

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_hot_path.json");
    b.write_json(&out, title)?;
    println!("\nwrote {}", out.display());

    // perf trend tracking (old report was loaded before the run)
    if let Some((path, old)) = old_report {
        println!("\n== diff vs {path} ==");
        print_diff(&diff_reports(&old, &b.to_json(title))?);
    }
    Ok(())
}
