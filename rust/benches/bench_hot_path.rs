//! Hot-path benchmarks (deliverable e): the PJRT execution path the
//! coordinator drives every inner step, measured at each layer so the
//! perf pass in EXPERIMENTS.md §Perf has precise before/after numbers.
//!
//! Run: cargo bench (harness=false; criterion unavailable offline).

use std::path::Path;
use std::rc::Rc;

use diloco::config::RepoConfig;
use diloco::coordinator::{outer_gradient, OuterOpt};
use diloco::data::synthetic::{CorpusSpec, TokenStream};
use diloco::runtime::{f32_scalar, i32_literal, u32_scalar, HostTensor, ModelRuntime, Runtime};
use diloco::util::bench::Bencher;

fn main() -> anyhow::Result<()> {
    let repo = RepoConfig::load(Path::new(env!("CARGO_MANIFEST_DIR")))?;
    if !repo.model_dir("m0").join("manifest.json").is_file() {
        println!("bench_hot_path: artifacts missing; run `make artifacts`");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let mut b = Bencher::new(4.0);

    for model in ["m0", "m2"] {
        let mr = ModelRuntime::load(Rc::clone(&rt), &repo.model_dir(model))?;
        let n = mr.n_leaves();
        let seq = mr.manifest.model.seq_len;
        let init = mr.artifact("init")?;
        let ts = mr.artifact("train_step")?;
        let gs = mr.artifact("grad_step_mb8")?;
        let ev = mr.artifact("eval_step")?;

        let params = init.call(&[&u32_scalar(0)])?;
        let zeros: Vec<xla::Literal> = mr
            .manifest
            .params
            .iter()
            .map(|s| HostTensor::zeros(&s.shape).to_literal().unwrap())
            .collect();
        let zeros2: Vec<xla::Literal> = mr
            .manifest
            .params
            .iter()
            .map(|s| HostTensor::zeros(&s.shape).to_literal().unwrap())
            .collect();
        let state: Vec<xla::Literal> =
            params.into_iter().chain(zeros).chain(zeros2).collect();

        let mut stream = TokenStream::new(CorpusSpec::default(), 0, 0);
        let toks8 = i32_literal(&[8, seq], &stream.next_batch(8, seq))?;
        let tokse = i32_literal(
            &[mr.manifest.eval_batch, seq],
            &stream.next_batch(mr.manifest.eval_batch, seq),
        )?;
        let (step_l, lr, wd) = (f32_scalar(5.0), f32_scalar(4e-3), f32_scalar(1e-4));

        b.run(&format!("{model}/train_step fused (mb=8, full roundtrip)"), || {
            let mut args: Vec<&xla::Literal> = state.iter().collect();
            args.push(&toks8);
            args.push(&step_l);
            args.push(&lr);
            args.push(&wd);
            ts.call(&args).unwrap()
        });

        b.run(&format!("{model}/grad_step mb=8 (fwd+bwd only)"), || {
            let mut args: Vec<&xla::Literal> = state[..n].iter().collect();
            args.push(&toks8);
            gs.call(&args).unwrap()
        });

        b.run(&format!("{model}/eval_step (batch {})", mr.manifest.eval_batch), || {
            let mut args: Vec<&xla::Literal> = state[..n].iter().collect();
            args.push(&tokse);
            ev.call(&args).unwrap()
        });

        // the H-cadence host path: literal -> host tensors -> outer step -> literals
        let host: Vec<HostTensor> = state[..n]
            .iter()
            .map(|l| HostTensor::from_literal(l).unwrap())
            .collect();
        b.run(&format!("{model}/outer sync: pull params to host"), || {
            state[..n]
                .iter()
                .map(|l| HostTensor::from_literal(l).unwrap())
                .collect::<Vec<_>>()
        });
        let replicas = vec![host.clone(), host.clone()];
        let mut opt = OuterOpt::new(0.8, 0.9);
        b.run(&format!("{model}/outer sync: delta + Nesterov (M=2)"), || {
            let mut g = host.clone();
            let delta = outer_gradient(&g, &replicas);
            opt.step(&mut g, &delta);
            g
        });
        b.run(&format!("{model}/outer sync: push params to device"), || {
            host.iter()
                .map(|t| t.to_literal().unwrap())
                .collect::<Vec<_>>()
        });
    }

    // data pipeline throughput
    let mut stream = TokenStream::new(CorpusSpec::default(), 0, 0);
    b.run("data/synthetic batch 16x64 tokens", || {
        stream.next_batch(16, 64)
    });

    b.report("hot path (L3 coordinator over PJRT)");
    Ok(())
}
