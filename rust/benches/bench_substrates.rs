//! Substrate micro-benchmarks: JSON, RNG, data generator, fitting
//! machinery, netsim. These guard against regressions in the pieces
//! the coordinator and report paths lean on.

use diloco::netsim::utilization::{SimAlgo, SimModel, CHINCHILLA_10B};
use diloco::netsim::walltime::{walltime, WalltimeAlgo, WalltimeInput};
use diloco::netsim::MEDIUM;
use diloco::scaling::parametric::{fit_parametric, Obs, ParametricForm};
use diloco::scaling::{JointFit, PowerLaw};
use diloco::util::bench::Bencher;
use diloco::util::json::Json;
use diloco::util::rng::Rng;

fn main() {
    let mut b = Bencher::new(2.0);

    // JSON
    let value = Json::obj(vec![
        ("curve", Json::arr((0..500).map(|i| {
            Json::arr([Json::num(i as f64), Json::num(6.0 - i as f64 * 1e-3)])
        }))),
        ("meta", Json::obj(vec![("algo", Json::str("diloco-m2"))])),
    ]);
    let text = value.to_string_compact();
    b.run("json/serialize 500-point record", || value.to_string_compact());
    b.run("json/parse 500-point record", || Json::parse(&text).unwrap());

    // RNG
    let mut rng = Rng::new(1);
    b.run("rng/1e6 next_u64", || {
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc ^= rng.next_u64();
        }
        acc
    });

    // scaling fits
    let n: Vec<f64> = (0..8).map(|i| 1e4 * 4f64.powi(i)).collect();
    let y: Vec<f64> = n.iter().map(|&x| 18.0 * x.powf(-0.095)).collect();
    b.run("scaling/power-law fit (8 points)", || {
        PowerLaw::fit(&n, &y).unwrap()
    });
    let mut jn = Vec::new();
    let mut jm = Vec::new();
    let mut jy = Vec::new();
    for &ni in &n {
        for m in [1.0f64, 2.0, 4.0, 8.0] {
            jn.push(ni);
            jm.push(m);
            jy.push(19.2 * ni.powf(-0.0985) * m.powf(0.0116));
        }
    }
    b.run("scaling/joint fit (32 points)", || {
        JointFit::fit(&jn, &jm, &jy).unwrap()
    });
    let obs: Vec<Obs> = jn
        .iter()
        .zip(&jm)
        .zip(&jy)
        .map(|((&n, &m), &loss)| Obs { n, m, loss })
        .collect();
    let (train, holdout) = obs.split_at(24);
    b.run("scaling/parametric fit (16 restarts)", || {
        fit_parametric(ParametricForm::PowerLawPlusC, train, holdout, 1, 16).unwrap()
    });

    // netsim
    b.run("netsim/walltime eval", || {
        walltime(&WalltimeInput {
            algo: WalltimeAlgo::DiLoCo { replicas: 4, sync_every: 30 },
            params: 1e9,
            tokens: 2e10,
            batch_tokens: 2f64.powi(20),
            cross_dc: MEDIUM,
            outer_bits: diloco::netsim::walltime::BITS_PER_PARAM,
            outer_bits_down: diloco::netsim::walltime::BITS_PER_PARAM,
            overlap_tau: 0.0,
            churn: None,
        })
    });
    let sim = SimModel::default();
    b.run("netsim/table6 block (6 algos x 5 targets)", || {
        sim.table6_block(&CHINCHILLA_10B)
    });
    b.run("netsim/required bandwidth (single cell)", || {
        sim.required_bandwidth_gbps(&CHINCHILLA_10B, SimAlgo::DiLoCo { sync_every: 50 }, 0.9)
    });

    b.report("substrates");
}
