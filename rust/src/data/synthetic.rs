//! Synthetic pre-training corpus (the C4/Dolma substitute — DESIGN.md §3).
//!
//! A deterministic generative "language": Zipfian unigrams mixed with a
//! Markov process whose transition table is derived by hashing, plus
//! document structure (BOS boundaries, geometric lengths). The Markov
//! component gives the model learnable low-entropy structure (so loss
//! falls with compute, power-law style); the Zipf tail keeps the task
//! from saturating. Train/heldout/overtrain splits are independent
//! child streams of one seed, mirroring C4-train/C4-validation.
//!
//! The Markov order matters: with order 1 the transition table has
//! `vocab` contexts, so every context repeats thousands of times in
//! even a 1M-token budget and the structure is learnable; order 2
//! (vocab^2 hashed contexts) almost never repeats a context and is
//! indistinguishable from noise to the model. Order 1 is the default;
//! order 2 contexts blend in at a low rate to add depth for larger
//! models.

use crate::util::rng::{splitmix64, Rng};

/// Generator parameters. Defaults tuned so mini-ladder models land in
/// the interesting loss regime (well below ln(vocab), far above 0).
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub vocab: usize,
    pub bos_id: i32,
    /// Probability a token follows the Markov component (vs unigram draw).
    pub markov_prob: f64,
    /// Probability (within the Markov component) of using the order-2
    /// context instead of order-1; keeps some hard structure in the tail.
    pub order2_prob: f64,
    /// Branching factor of each context.
    pub branch: usize,
    /// Zipf exponent for unigram draws.
    pub zipf_s: f64,
    /// Mean document length in tokens (geometric).
    pub mean_doc_len: f64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            vocab: 512,
            bos_id: 0,
            markov_prob: 0.72,
            order2_prob: 0.15,
            branch: 4,
            zipf_s: 1.1,
            mean_doc_len: 180.0,
        }
    }
}

/// An infinite deterministic token stream for one data shard.
///
/// Paper Algorithm 1: each DiLoCo replica m draws from its own shard
/// `D_m`; shards here are independent child streams (`stream_id`).
pub struct TokenStream {
    spec: CorpusSpec,
    rng: Rng,
    /// cumulative Zipf weights for inverse-CDF sampling
    zipf_cdf: Vec<f64>,
    prev2: i32,
    prev1: i32,
    remaining_in_doc: usize,
    table_salt: u64,
    /// Tokens drawn so far — the stream's checkpointable position. The
    /// RNG state itself stays private; a resumed shard is rebuilt by
    /// replaying `consumed` tokens from the (corpus_seed, stream_id)
    /// origin, which is exact because the stream is pure in those.
    consumed: u64,
}

impl TokenStream {
    /// `corpus_seed` selects the language (shared across shards so all
    /// replicas learn the same distribution); `stream_id` selects the
    /// shard (so replicas see disjoint data).
    pub fn new(spec: CorpusSpec, corpus_seed: u64, stream_id: u64) -> TokenStream {
        let mut cdf = Vec::with_capacity(spec.vocab);
        let mut total = 0.0;
        // ids 1..vocab are real tokens (0 is BOS)
        for i in 1..spec.vocab {
            total += 1.0 / ((i as f64 + 8.0).powf(spec.zipf_s));
            cdf.push(total);
        }
        for w in cdf.iter_mut() {
            *w /= total;
        }
        let mut salt_src = corpus_seed ^ 0xD1CE_C0DE_D15C_0C0A;
        let table_salt = splitmix64(&mut salt_src);
        let rng = Rng::new(corpus_seed).child(stream_id);
        let mut s = TokenStream {
            spec,
            rng,
            zipf_cdf: cdf,
            prev2: 0,
            prev1: 0,
            remaining_in_doc: 0,
            table_salt,
            consumed: 0,
        };
        s.start_doc();
        s
    }

    fn start_doc(&mut self) {
        // Geometric document length.
        let p = 1.0 / self.spec.mean_doc_len;
        let u = self.rng.f64().max(1e-12);
        self.remaining_in_doc = ((u.ln() / (1.0 - p).ln()).ceil() as usize).max(8);
        self.prev2 = self.spec.bos_id;
        self.prev1 = self.spec.bos_id;
    }

    fn unigram(&mut self) -> i32 {
        let u = self.rng.f64();
        // binary search inverse CDF
        let idx = self.zipf_cdf.partition_point(|&c| c < u);
        (idx + 1).min(self.spec.vocab - 1) as i32
    }

    /// The language's transition table: candidate successors of a
    /// context, derived by hashing (fixed per corpus_seed, shared by
    /// all shards). `use_order2` selects the (prev2, prev1) context;
    /// otherwise only prev1 is used (order 1 — the learnable bulk).
    fn markov_candidate(&mut self, slot: usize, use_order2: bool) -> i32 {
        let p2 = if use_order2 { self.prev2 as u64 } else { 0 };
        let mut h = self.table_salt
            ^ p2.wrapping_mul(0x9E3779B97F4A7C15)
            ^ (self.prev1 as u64).wrapping_mul(0xC2B2AE3D27D4EB4F)
            ^ (slot as u64 + 1).wrapping_mul(0x165667B19E3779F9)
            ^ if use_order2 { 0x5EED } else { 0 };
        let v = splitmix64(&mut h);
        (1 + (v % (self.spec.vocab as u64 - 1))) as i32
    }

    /// Next token (never BOS; BOS only appears at doc boundaries via
    /// `next_token`'s doc handling).
    fn next_content_token(&mut self) -> i32 {
        if self.rng.f64() < self.spec.markov_prob {
            // Zipf-weighted choice among the context's `branch` successors.
            let weights: Vec<f64> = (0..self.spec.branch)
                .map(|i| 1.0 / (i as f64 + 1.0))
                .collect();
            let slot = self.rng.weighted(&weights);
            let use_order2 = self.rng.f64() < self.spec.order2_prob;
            self.markov_candidate(slot, use_order2)
        } else {
            self.unigram()
        }
    }

    /// Produce the next token of the shard's infinite stream.
    pub fn next_token(&mut self) -> i32 {
        self.consumed += 1;
        if self.remaining_in_doc == 0 {
            self.start_doc();
            return self.spec.bos_id;
        }
        self.remaining_in_doc -= 1;
        let t = self.next_content_token();
        self.prev2 = self.prev1;
        self.prev1 = t;
        t
    }

    /// Tokens drawn from this shard so far (checkpoint position).
    pub fn consumed(&self) -> u64 {
        self.consumed
    }

    /// Fast-forward by drawing and discarding `n` tokens — how a
    /// resumed run re-seats a shard at its checkpointed `consumed`
    /// position (the stream is pure in seed and stream id, so replay
    /// is exact).
    pub fn skip(&mut self, n: u64) {
        for _ in 0..n {
            self.next_token();
        }
    }

    /// Fill a [seqs, seq_len] row-major batch.
    pub fn next_batch(&mut self, seqs: usize, seq_len: usize) -> Vec<i32> {
        (0..seqs * seq_len).map(|_| self.next_token()).collect()
    }

    pub fn vocab(&self) -> usize {
        self.spec.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(seed: u64, id: u64) -> TokenStream {
        TokenStream::new(CorpusSpec::default(), seed, id)
    }

    #[test]
    fn deterministic_replay() {
        let a: Vec<i32> = (0..500).map(|_| stream(1, 0).next_token()).collect();
        // note: recreating the stream each token must give the same first token
        let mut s1 = stream(1, 0);
        let mut s2 = stream(1, 0);
        for _ in 0..2000 {
            assert_eq!(s1.next_token(), s2.next_token());
        }
        drop(a);
    }

    #[test]
    fn shards_disjoint_but_same_language() {
        let mut s0 = stream(1, 0);
        let mut s1 = stream(1, 1);
        let a: Vec<i32> = (0..256).map(|_| s0.next_token()).collect();
        let b: Vec<i32> = (0..256).map(|_| s1.next_token()).collect();
        assert_ne!(a, b, "shards must differ");
    }

    #[test]
    fn tokens_in_range() {
        let mut s = stream(3, 0);
        for _ in 0..5000 {
            let t = s.next_token();
            assert!((0..512).contains(&t));
        }
    }

    #[test]
    fn has_bos_boundaries() {
        let mut s = stream(4, 0);
        let toks: Vec<i32> = (0..20_000).map(|_| s.next_token()).collect();
        let bos = toks.iter().filter(|&&t| t == 0).count();
        // mean doc len 180 -> expect roughly 110 boundaries in 20k tokens
        assert!(bos > 40 && bos < 400, "bos count {bos}");
    }

    #[test]
    fn distribution_is_skewed() {
        // Zipf tail: the most common token should be much more frequent
        // than the median one.
        let mut s = stream(5, 0);
        let mut counts = vec![0usize; 512];
        for _ in 0..100_000 {
            counts[s.next_token() as usize] += 1;
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        // far from uniform (uniform would be ~195 per token)
        assert!(sorted[0] > 4 * sorted[255].max(1), "{} vs {}", sorted[0], sorted[255]);
        assert!(sorted[0] > 1000);
    }

    #[test]
    fn batch_shape() {
        let mut s = stream(6, 0);
        assert_eq!(s.next_batch(4, 64).len(), 256);
    }

    #[test]
    fn skip_replays_to_the_same_position() {
        let mut full = stream(8, 3);
        let reference: Vec<i32> = (0..1000).map(|_| full.next_token()).collect();
        assert_eq!(full.consumed(), 1000);
        // a fresh stream skipped to position 700 continues identically
        let mut resumed = stream(8, 3);
        resumed.skip(700);
        assert_eq!(resumed.consumed(), 700);
        let tail: Vec<i32> = (0..300).map(|_| resumed.next_token()).collect();
        assert_eq!(tail, reference[700..]);
    }

    #[test]
    fn markov_structure_lowers_conditional_entropy() {
        // Empirically verify the learnable structure: distribution of
        // next token given prev1 (order-1 context) is concentrated
        // relative to the unigram — this is what the models learn.
        let mut s = stream(7, 0);
        use std::collections::HashMap;
        let mut ctx_counts: HashMap<i32, HashMap<i32, usize>> = HashMap::new();
        let mut prev = 0;
        for _ in 0..200_000 {
            let t = s.next_token();
            if t != 0 {
                ctx_counts.entry(prev).or_default().entry(t).and_modify(|c| *c += 1).or_insert(1);
            }
            prev = t;
        }
        // For contexts with enough mass, the top successor should carry
        // a large fraction (markov_prob * top-branch weight ~ 0.3+).
        let mut checked = 0;
        let mut concentrated = 0;
        for (_, succ) in ctx_counts.iter() {
            let total: usize = succ.values().sum();
            if total >= 50 {
                checked += 1;
                let top = *succ.values().max().unwrap();
                if top as f64 / total as f64 > 0.2 {
                    concentrated += 1;
                }
            }
        }
        assert!(checked > 50, "not enough repeated contexts: {checked}");
        assert!(
            concentrated as f64 / checked as f64 > 0.7,
            "{concentrated}/{checked} contexts concentrated"
        );
    }
}
