//! Real-text corpus path: a deterministic word-hash tokenizer and a
//! file-backed token stream, so the trainer can consume actual text
//! (e.g. a local file standing in for C4) instead of the synthetic
//! language. Same sharding contract as `synthetic::TokenStream`.

use std::path::Path;

use anyhow::{bail, Result};

use crate::util::rng::splitmix64;

/// Deterministic word-level hash tokenizer: lowercased alphanumeric
/// words hash into [2, vocab); 0 is BOS (paragraph boundary), 1 is OOV
/// punctuation. No learned vocabulary — ids are stable across runs and
/// machines, which is what the reproduction needs (the paper's 32,768
/// sentence-piece vocab is a data asset we don't have).
#[derive(Debug, Clone)]
pub struct WordHashTokenizer {
    pub vocab: usize,
    pub bos_id: i32,
    salt: u64,
}

impl WordHashTokenizer {
    pub fn new(vocab: usize) -> WordHashTokenizer {
        assert!(vocab > 8);
        WordHashTokenizer {
            vocab,
            bos_id: 0,
            salt: 0x7E0C_A11E_D70C_0DE5,
        }
    }

    fn word_id(&self, word: &str) -> i32 {
        let mut h = self.salt;
        for b in word.as_bytes() {
            h = splitmix64(&mut h) ^ u64::from(*b);
        }
        (2 + (splitmix64(&mut h) % (self.vocab as u64 - 2))) as i32
    }

    /// Tokenize text: words -> hashed ids, blank lines -> BOS,
    /// punctuation runs -> OOV marker.
    pub fn tokenize(&self, text: &str) -> Vec<i32> {
        let mut out = vec![self.bos_id];
        for line in text.lines() {
            if line.trim().is_empty() {
                if out.last() != Some(&self.bos_id) {
                    out.push(self.bos_id);
                }
                continue;
            }
            let mut word = String::new();
            let mut flush = |word: &mut String, out: &mut Vec<i32>| {
                if !word.is_empty() {
                    out.push(self.word_id(word));
                    word.clear();
                }
            };
            for c in line.chars() {
                if c.is_alphanumeric() {
                    word.extend(c.to_lowercase());
                } else {
                    flush(&mut word, &mut out);
                    if !c.is_whitespace() {
                        out.push(1); // OOV/punct marker
                    }
                }
            }
            flush(&mut word, &mut out);
        }
        out
    }
}

/// A sharded, infinitely-repeating token stream over a tokenized file.
/// Shard s of S reads tokens s, s+S, s+2S... giving disjoint, equal-
/// rate shards regardless of file size (Algorithm 1's D_m).
pub struct TextStream {
    tokens: Vec<i32>,
    stride: usize,
    pos: usize,
}

impl TextStream {
    pub fn from_file(
        path: &Path,
        tokenizer: &WordHashTokenizer,
        shard: usize,
        num_shards: usize,
    ) -> Result<TextStream> {
        let text = std::fs::read_to_string(path)?;
        Self::from_text(&text, tokenizer, shard, num_shards)
    }

    pub fn from_text(
        text: &str,
        tokenizer: &WordHashTokenizer,
        shard: usize,
        num_shards: usize,
    ) -> Result<TextStream> {
        if num_shards == 0 || shard >= num_shards {
            bail!("bad shard {shard}/{num_shards}");
        }
        let tokens = tokenizer.tokenize(text);
        if tokens.len() < num_shards * 2 {
            bail!("corpus too small: {} tokens for {num_shards} shards", tokens.len());
        }
        Ok(TextStream {
            tokens,
            stride: num_shards,
            pos: shard,
        })
    }

    pub fn next_token(&mut self) -> i32 {
        let t = self.tokens[self.pos];
        self.pos += self.stride;
        if self.pos >= self.tokens.len() {
            self.pos %= self.stride.max(1);
        }
        t
    }

    pub fn next_batch(&mut self, seqs: usize, seq_len: usize) -> Vec<i32> {
        (0..seqs * seq_len).map(|_| self.next_token()).collect()
    }

    pub fn len_tokens(&self) -> usize {
        self.tokens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "The quick brown fox jumps over the lazy dog.\n\
                          The quick brown fox, again!\n\n\
                          A new paragraph begins here with different words.\n";

    #[test]
    fn tokenizer_is_deterministic_and_in_range() {
        let tok = WordHashTokenizer::new(512);
        let a = tok.tokenize(SAMPLE);
        let b = tok.tokenize(SAMPLE);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn same_word_same_id_different_words_differ() {
        let tok = WordHashTokenizer::new(4096);
        let ids = tok.tokenize("alpha beta alpha");
        assert_eq!(ids[1], ids[3]); // both "alpha" (ids[0] is BOS)
        assert_ne!(ids[1], ids[2]);
        // case-insensitive
        let ids2 = tok.tokenize("Alpha ALPHA");
        assert_eq!(ids2[1], ids2[2]);
    }

    #[test]
    fn blank_lines_become_bos() {
        let tok = WordHashTokenizer::new(512);
        let ids = tok.tokenize("one\n\ntwo");
        let bos_count = ids.iter().filter(|&&t| t == 0).count();
        assert_eq!(bos_count, 2); // leading + paragraph break
    }

    #[test]
    fn shards_are_disjoint_and_cover() {
        let tok = WordHashTokenizer::new(512);
        let full = tok.tokenize(SAMPLE);
        let mut s0 = TextStream::from_text(SAMPLE, &tok, 0, 2).unwrap();
        let mut s1 = TextStream::from_text(SAMPLE, &tok, 1, 2).unwrap();
        let n = full.len();
        let a: Vec<i32> = (0..n / 2).map(|_| s0.next_token()).collect();
        let b: Vec<i32> = (0..n / 2).map(|_| s1.next_token()).collect();
        // interleave recovers a prefix of the full token sequence
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(*x, full[2 * i]);
            assert_eq!(*y, full[2 * i + 1]);
        }
    }

    #[test]
    fn stream_wraps_around() {
        let tok = WordHashTokenizer::new(512);
        let mut s = TextStream::from_text(SAMPLE, &tok, 0, 1).unwrap();
        let n = s.len_tokens();
        let first = s.next_token();
        for _ in 0..n - 1 {
            s.next_token();
        }
        assert_eq!(s.next_token(), first);
    }

    #[test]
    fn rejects_bad_shards() {
        let tok = WordHashTokenizer::new(512);
        assert!(TextStream::from_text(SAMPLE, &tok, 2, 2).is_err());
        assert!(TextStream::from_text("tiny", &tok, 0, 64).is_err());
    }
}
