//! Data pipeline substrate: synthetic corpus (C4/Dolma substitute),
//! per-replica sharding, and synthetic zero-shot downstream suites.

pub mod downstream;
pub mod synthetic;
pub mod text;
