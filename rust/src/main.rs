//! `diloco` binary: the leader entrypoint. See `diloco help`.

fn main() {
    diloco::util::init_logging();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = diloco::cli::dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
