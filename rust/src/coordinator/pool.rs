//! Replica-parallel inner loop: the worker pool that makes Algorithm
//! 1's "parallel for over replicas" actually parallel, and the
//! **non-blocking fragment pipeline** that hides the outer sync's
//! communication under inner-step compute (Streaming DiLoCo's
//! delayed application, arXiv:2501.18512 §4; DiLoCoX's one-step
//! delayed overlap, arXiv:2506.21263).
//!
//! # Concurrency model
//!
//! Training runs as a sequence of **segments** — the step ranges
//! between consecutive pipeline events (plus eval boundaries for
//! Data-Parallel). Each worker thread *owns* a fixed subset of
//! replicas for the whole run (`replica r -> worker r % workers`): the
//! replica's literal-handle state, its `TokenStream` shard, and its
//! per-replica comm residual live inside the worker; the worker
//! additionally owns one set of **shared comm arenas** (the broadcast
//! snapshot + staging/scratch, identical across its replicas — see
//! `crate::comm`). The coordinator sends each worker a `Run` command
//! for the segment; workers execute their replicas' inner steps
//! concurrently and hand back per-step losses plus each replica's
//! **sync payload** over a channel: under a *lossy* up-wire
//! (`--outer-bits` below 32) that payload is the replica's encoded
//! wire contribution — error-compensated quantized outer deltas, the
//! quantize stage running on the worker, where the replica lives.
//! Uncompressed runs (the identity codec) and Data-Parallel keep the
//! zero-copy `Arc` literal handoff from PR 2 — no serialization on
//! the default path; `OuterSync::sync` counts the identity wire
//! bytes itself.
//!
//! # The send/merge pipeline (delayed application)
//!
//! A DiLoCo schedule is driven by two kinds of events, not one:
//!
//! - **send** — at a sync-cadence boundary, workers capture their
//!   replicas' contributions for the due fragment (payloads are
//!   immutable: `Arc` literal handles or encoded bytes) and
//!   *immediately continue* inner steps on their current params; the
//!   coordinator holds the payloads in flight.
//! - **merge** — exactly `overlap_tau` inner steps later (clamped to
//!   the end of training), the coordinator has reduced the in-flight
//!   payloads, run the flat-bus outer step, and built the broadcast;
//!   workers merge it into their live replica params before their
//!   next inner step. The merge adopts the broadcast fragment
//!   outright — the α=1 corner of Streaming DiLoCo's mixing rule,
//!   which is what lets the deduplicated `Arc`-literal handoff (one
//!   upload per leaf, never per replica) survive the overlap and
//!   makes `overlap_tau = 0` reproduce the retired barrier schedule
//!   bit for bit: send and merge collapse into a single boundary,
//!   which is exactly the old barrier.
//!
//! The coordinator's reduce + outer step + broadcast encode run
//! *while the workers compute the overlap window*: a segment is
//! [`SegmentExec::dispatch`]ed first, the in-flight sync (whose
//! payloads were captured at an earlier boundary) is reduced under
//! it, and only then does the coordinator [`SegmentExec::collect`]
//! the segment's results. `netsim::walltime` models the payoff as
//! `max(0, t_comm - τ·t_step)` per outer sync.
//!
//! At most one sync is ever in flight (`overlap_tau` must be smaller
//! than the per-fragment sync interval — enforced fail-loud), and the
//! end of training drains the pipeline: a sync still in flight at T
//! merges first, then the final full flush is captured by a
//! zero-step trailing segment so nothing stale ever survives the run.
//! The broadcast takes one of two forms: deduplicated global `Arc`
//! literals (identity down-wire — PR 2's zero-copy handoff,
//! unchanged), or the [`DownWire`]'s single encoded payload (lossy
//! `--outer-bits-down`), which each worker decodes once into its
//! shared snapshot before rebuilding the synced leaves' literals for
//! all the replicas it owns. Only the coordinator ever touches the
//! flat arenas; workers only ever read literals or broadcast bytes.
//!
//! [`DownWire`]: crate::comm::DownWire
//!
//! # Why determinism holds
//!
//! Bit-identical results for any worker count follow from three
//! invariants, each pinned by `tests/worker_pool.rs`,
//! `tests/overlap_pipeline.rs`, and (per (up, down) width pair)
//! `tests/comm_codec.rs`:
//!
//! 1. replica state, data shard, and comm residual are owned by
//!    exactly one worker and advance in step/sync order — scheduling
//!    cannot reorder a replica's own computation, and encode seeds
//!    derive from (run seed, direction, sync index, replica), never
//!    the schedule;
//! 2. cross-replica reduction (the per-step mean loss and the outer
//!    gradient accumulation) happens on the coordinator in replica
//!    index order, identical to the sequential loop's summation order
//!    — and the broadcast is one byte stream decoded identically by
//!    every worker, so the shared snapshots never diverge. Payloads
//!    captured at a send are immutable snapshots (inner steps replace
//!    literal handles, never mutate literals), so reducing them τ
//!    steps later reads exactly the send-time values;
//! 3. evaluation is re-grounded on the **merge schedule**, not the
//!    send schedule: an eval at step t reads the global with every
//!    merge at or before t applied and nothing fresher — no replica
//!    has seen an in-flight sync, so this is the only consistent
//!    answer, and at τ=0 it degenerates to the old barrier rule
//!    (in-segment evals see the previous sync, boundary evals see the
//!    fresh one).
//!
//! `workers == 1` (the default, and `--workers 1` on the CLI) runs the
//! whole schedule inline on the caller's thread with the classic
//! step-major/replica-minor loop — the sequential oracle the parallel
//! path is tested against. Overlap changes nothing there (no
//! concurrency to hide work under), but the *schedule* — and
//! therefore every loss and parameter bit — is identical at any
//! worker count for any τ.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::comm::{CommLink, ReplicaComm, WorkerComm};
use crate::coordinator::fsm::{CoordinatorFsm, Phase};
use crate::coordinator::journal::{EventKind, Journal};
use crate::coordinator::membership::{FaultEvent, FaultKind};
use crate::coordinator::sync::{ArrivalReduce, OuterSync};
use crate::data::synthetic::TokenStream;
use crate::transport::frame::{reclaim_wires, WireBuf, WireSlice};
use crate::transport::msg::{
    Adopt, Broadcast, Cmd, EncodeSpec, PayloadSpec, SegmentChurn, SegmentData, SyncPayload,
    WorkerReport,
};
use crate::transport::tcp::LaneReactor;
use crate::transport::{inproc, Lane, WorkerLink};

/// One replica as the pool owns it: params ++ m ++ v literal handles
/// (manifest leaf order; only the first `n_params` leaves take part in
/// outer syncs) plus the replica's private data shard.
pub struct ReplicaState {
    pub state: Vec<Arc<xla::Literal>>,
    pub shard: TokenStream,
}

impl ReplicaState {
    /// Apply a broadcast: adopt the shared literal for each synced
    /// leaf (every replica of a worker ends up pointing at the same
    /// upload).
    fn adopt(&mut self, adopt: &Adopt) {
        for (leaf, lit) in adopt {
            self.state[*leaf] = Arc::clone(lit);
        }
    }
}

/// The inner computation the pool schedules. Implementations must be
/// `Sync` (shared by reference across workers) and deterministic per
/// `(rep, replica state, t)` — the PJRT path satisfies both, and tests
/// substitute host-math engines.
pub trait InnerEngine: Sync {
    /// One inner optimizer step for replica `rep` at 1-based global
    /// step `t`; replaces `replica.state` handles and returns the
    /// replica's mean loss for the step.
    fn inner_step(&self, rep: usize, replica: &mut ReplicaState, t: usize) -> Result<f64>;

    /// Eval loss of a parameter literal set (first `n_params` leaves).
    /// Must be stateless and safe to call concurrently with
    /// `inner_step` running on worker threads — the overlap pipeline
    /// evaluates mid-segment while workers compute (PJRT CPU
    /// execution is thread-safe per client; test surrogates read
    /// immutable literals).
    fn eval(&self, params: &[Arc<xla::Literal>]) -> Result<f64>;

    /// Effective inner learning rate at step `t`, for log lines only
    /// (None when the engine has no schedule — e.g. test surrogates).
    fn inner_lr(&self, _t: usize) -> Option<f64> {
        None
    }
}

/// Schedule parameters for one training run.
#[derive(Debug, Clone)]
pub struct DrivePlan {
    pub total_steps: usize,
    /// Steps between outer-sync events (H, or H/P with streaming
    /// fragments). Ignored when no `OuterSync` is supplied.
    pub sync_interval: usize,
    /// Streaming fragment count P (1 = vanilla DiLoCo).
    pub fragments: usize,
    /// Number of parameter leaves (the prefix of `state` that syncs).
    pub n_params: usize,
    /// Evaluate every k steps (None = final only).
    pub eval_every: Option<usize>,
    pub log_every: usize,
    /// Worker threads for the inner loop; clamped to [1, M]. 1 =
    /// sequential oracle (no threads spawned).
    pub workers: usize,
    /// Delayed-application window τ (Streaming DiLoCo overlap): a
    /// fragment's broadcast merges into live replica params exactly τ
    /// inner steps after its contributions were sent, hiding the
    /// outer sync's communication under compute. 0 = barrier
    /// semantics, bit-identical to the retired segment loop. Requires
    /// τ < `sync_interval` so at most one sync is ever in flight;
    /// ignored (and rejected when nonzero) without an `OuterSync`.
    pub overlap_tau: usize,
}

/// Everything the drive loop measures (the caller owns final-eval and
/// metric assembly).
#[derive(Debug, Default)]
pub struct DriveOutcome {
    /// Mean loss across replicas for every step, in step order.
    pub step_losses: Vec<f64>,
    /// Sampled (step, loss) points (log_every cadence, as before).
    pub loss_curve: Vec<(usize, f64)>,
    /// Intermediate (step, eval loss) points (eval_every cadence).
    pub eval_curve: Vec<(usize, f64)>,
    pub outer_syncs: usize,
    /// Worker-side comm arena footprint: the shared per-worker arenas
    /// plus every replica's residual, in bytes (0 for identity /
    /// Data-Parallel runs). Pinned by `tests/comm_codec.rs` so the
    /// per-worker sharing can't silently regress to per-replica.
    pub comm_arena_bytes: u64,
    /// Coordinator-side down-wire arena footprint (view + residual +
    /// staging; 0 unless the broadcast is lossy) — accounted
    /// separately from the worker-side number so the arena-sharing
    /// comparison against the retired per-replica scheme stays
    /// apples-to-apples, while coordinator comm memory still can't
    /// grow unnoticed.
    pub down_wire_arena_bytes: u64,
}

/// Apply one broadcast to a worker's shared comm state and return the
/// literal adopt list its replicas apply: the identity form passes the
/// coordinator's deduplicated literals through (refreshing the
/// snapshot when comm state is live), the encoded form decodes the
/// byte payload once and rebuilds the synced leaves' literals from the
/// worker's snapshot.
fn broadcast_adopt(
    link: Option<&CommLink>,
    wc: &mut WorkerComm,
    b: &Broadcast,
) -> Result<Adopt> {
    match b {
        Broadcast::Literals(list) => {
            if let Some(l) = link {
                l.adopt_literals(wc, list)?;
            }
            Ok(list.clone())
        }
        Broadcast::Encoded { frag, bytes } => {
            let l = link.ok_or_else(|| {
                anyhow!("drive: encoded broadcast without a comm link")
            })?;
            l.adopt_encoded(wc, *frag, bytes.as_slice())
        }
        // a Pending marker is resolved to Encoded by the transport's
        // worker link (the stashed Bcast frame); seeing one here means
        // a streamed broadcast leaked past a non-streaming path
        Broadcast::Pending { frag } => Err(anyhow!(
            "drive: unresolved streamed broadcast (fragment {frag:?}) — \
             the transport never delivered its Bcast frame"
        )),
    }
}

/// Elastic-membership and resume controls threaded through
/// [`drive_ctl`]. [`DriveCtl::fresh`] is the churn-free default —
/// [`drive`] uses it, and with it `drive_ctl` is bit-identical to the
/// pre-membership drive loop (pinned by `tests/churn_resume.rs`).
///
/// `live` spans the replica *universe*: `replicas[r]` takes part in
/// segments and syncs only while `live[r]` — dead entries are frozen
/// placeholders (future joiners, or crash/leave remains kept for
/// salvage). Fault events fire deterministically against absolute
/// outer-sync indices, so a resumed run replays the same schedule.
#[derive(Debug, Default)]
pub struct DriveCtl {
    /// Deterministic fault schedule (sorted; see `membership::FaultPlan`).
    pub events: Vec<FaultEvent>,
    /// In: initial liveness per universe slot. Out: final liveness.
    pub live: Vec<bool>,
    /// Stop (checkpoint) once this many outer syncs have merged,
    /// counted absolutely (resume offsets included). None = run to T.
    pub stop_after_sync: Option<u64>,
    /// First inner step already completed (0 fresh; checkpoint step on
    /// resume). `plan.total_steps` stays the uninterrupted total.
    pub start_step: usize,
    /// Resuming from a checkpoint: skip the Algorithm 1 line 2 entry
    /// check (replicas have stepped) and restore comm-plane state from
    /// `residuals` / `snap_init` instead of fresh-initializing it.
    pub resume: bool,
    /// In: journal to continue (checkpoint's on resume). Out: with
    /// this run's membership/sync/phase events appended.
    pub journal: Journal,
    /// In (resume): per-replica up-wire EF residuals. Out: final
    /// residuals, always repopulated — checkpoint fodder.
    pub residuals: Vec<Vec<f32>>,
    /// Resume only: the broadcast view the worker snapshots restart
    /// from (`OuterSync::broadcast_view` at capture). Required when
    /// resuming with a lossy wire on either direction.
    pub snap_init: Option<Vec<f32>>,
    /// Out: the step the run stopped at (`stop_after_sync` hit), or
    /// None when it ran to `total_steps`.
    pub stopped_at: Option<usize>,
}

impl DriveCtl {
    /// No churn, no resume: the plain schedule over `m` replicas.
    pub fn fresh(m: usize) -> DriveCtl {
        DriveCtl {
            events: Vec::new(),
            live: vec![true; m],
            stop_after_sync: None,
            start_step: 0,
            resume: false,
            journal: Journal::new(),
            residuals: vec![Vec::new(); m],
            snap_init: None,
            stopped_at: None,
        }
    }
}

/// Run one training schedule over the replicas, parallelizing the
/// inner loop across `plan.workers` threads. On return `replicas`
/// holds the final states (broadcasts applied), whatever the worker
/// count; `sync`, when supplied, has performed every due outer step.
///
/// When `sync` carries a lossy codec on either wire, replicas must
/// enter with state equal to the sync'd global for the synced leaves
/// (Algorithm 1 line 2 guarantees this) — each worker's shared comm
/// snapshot is captured here, before the first inner step.
pub fn drive<E: InnerEngine>(
    engine: &E,
    replicas: &mut Vec<ReplicaState>,
    sync: Option<&mut OuterSync>,
    plan: &DrivePlan,
) -> Result<DriveOutcome> {
    let mut ctl = DriveCtl::fresh(replicas.len());
    drive_ctl(engine, replicas, sync, plan, &mut ctl)
}

/// [`drive`] with elastic membership, fault injection, and
/// checkpoint/resume controls. See [`DriveCtl`].
pub fn drive_ctl<E: InnerEngine>(
    engine: &E,
    replicas: &mut Vec<ReplicaState>,
    sync: Option<&mut OuterSync>,
    plan: &DrivePlan,
    ctl: &mut DriveCtl,
) -> Result<DriveOutcome> {
    let m = replicas.len();
    if m == 0 {
        bail!("drive: zero replicas");
    }
    if ctl.live.len() != m {
        bail!(
            "drive: {} live flags for {} replicas (the universe must match)",
            ctl.live.len(),
            m
        );
    }
    if !ctl.live.iter().any(|&l| l) {
        bail!("drive: no live replicas at start");
    }
    if !ctl.events.is_empty() && sync.is_none() {
        bail!("drive: fault events without an outer sync — Data-Parallel has no membership");
    }
    if ctl.start_step >= plan.total_steps {
        bail!(
            "drive: start_step ({}) must be below total_steps ({})",
            ctl.start_step,
            plan.total_steps
        );
    }
    if ctl.residuals.len() != m {
        if ctl.resume {
            bail!(
                "drive: resume with {} residuals for {} replicas",
                ctl.residuals.len(),
                m
            );
        }
        ctl.residuals = vec![Vec::new(); m];
    }
    if plan.n_params == 0 {
        bail!("drive: n_params must be >= 1");
    }
    if plan.log_every == 0 {
        bail!("drive: log_every must be >= 1");
    }
    if plan.eval_every == Some(0) {
        bail!("drive: eval_every must be >= 1");
    }
    if sync.is_some() && plan.sync_interval == 0 {
        bail!("drive: sync_interval must be >= 1");
    }
    if plan.overlap_tau > 0 {
        // merge-ordering guards, fail-loud: a broadcast can only be
        // delayed when there is a broadcast, and it must land before
        // the fragment's next send so at most one sync is in flight
        if sync.is_none() {
            bail!(
                "drive: overlap_tau ({}) without an outer sync — \
                 Data-Parallel has no broadcast to delay",
                plan.overlap_tau
            );
        }
        if plan.overlap_tau >= plan.sync_interval {
            bail!(
                "drive: overlap_tau ({}) must be smaller than the sync \
                 interval ({}) so a fragment's merge lands before the \
                 next send (one sync in flight at a time)",
                plan.overlap_tau,
                plan.sync_interval
            );
        }
    }
    for (r, rep) in replicas.iter().enumerate() {
        if rep.state.len() < plan.n_params {
            bail!(
                "drive: replica {r} has {} state leaves, need >= {}",
                rep.state.len(),
                plan.n_params
            );
        }
    }
    let workers = plan.workers.clamp(1, m);

    // Comm-side recipe: both channels of the plane, shared by every
    // worker. Identity/identity runs take none of this — they keep
    // the PR 2 zero-copy literal handoff (OuterSync::sync counts their
    // wire bytes itself), so the comm arenas exist only when a wire is
    // actually lossy.
    let link: Option<CommLink> = match sync.as_deref() {
        Some(s) => {
            let l = s.link();
            l.is_active().then_some(l)
        }
        None => None,
    };
    // coordinator-side down-wire arenas are sized once at build, so
    // they can be accounted now, before `sync` moves into coordinate()
    let down_wire_arena_bytes = sync
        .as_deref()
        .and_then(|s| s.down())
        .map_or(0, |dw| dw.arena_bytes());

    if ctl.resume && link.is_some() && ctl.snap_init.is_none() {
        bail!(
            "drive: resuming with a lossy comm wire requires the checkpointed \
             broadcast view (snap_init) to rebuild the worker snapshots"
        );
    }

    // The shared per-worker snapshot (and the down-wire's single view
    // stream, both initialized from the coordinator's global) require
    // every replica to enter AT the sync'd global — the documented
    // Algorithm 1 line 2 precondition. A violation under per-replica
    // snapshots (PR 3) was merely odd; under shared snapshots it would
    // bias every view-referenced outer gradient by the offset, so
    // fail loud: each replica is checked bitwise against the sync
    // engine's global (replicas that share replica 0's literal `Arc`s
    // — the common case — pay one pointer compare, not a read).
    // Skipped on resume: replicas re-enter mid-run, having stepped —
    // the checkpoint vouches for consistency instead.
    if link.is_some() && !ctl.resume {
        let s = sync.as_deref().expect("link implies sync");
        let layout = Arc::clone(s.global().layout());
        let global = s.global().data();
        for (r, rep) in replicas.iter().enumerate() {
            for leaf in 0..layout.n_leaves().min(plan.n_params) {
                if r > 0 && Arc::ptr_eq(&rep.state[leaf], &replicas[0].state[leaf]) {
                    continue; // replica 0 already vouched for this literal
                }
                let v = rep.state[leaf].to_vec::<f32>()?;
                let want = &global[layout.range(leaf)];
                if v.len() != want.len()
                    || v.iter().zip(want).any(|(x, y)| x.to_bits() != y.to_bits())
                {
                    bail!(
                        "drive: lossy comm wires require every replica to enter \
                         at the sync'd global (Algorithm 1 line 2), but replica \
                         {r} leaf {leaf} differs from the sync engine's global"
                    );
                }
            }
        }
    }

    if workers == 1 {
        let mut wc = WorkerComm::default();
        let mut rcs: Vec<ReplicaComm> = if ctl.resume && link.is_some() {
            (0..m)
                .map(|r| ReplicaComm::restore(std::mem::take(&mut ctl.residuals[r])))
                .collect()
        } else {
            (0..m).map(|_| ReplicaComm::default()).collect()
        };
        if let Some(l) = &link {
            if ctl.resume {
                let view = ctl.snap_init.as_ref().expect("checked above");
                l.init_snapshot_from(&mut wc, view)?;
            } else {
                l.init_snapshot(&mut wc, &replicas[0].state)?;
                for rc in rcs.iter_mut() {
                    l.init_replica(rc);
                }
            }
        }
        let init_live = ctl.live.clone();
        let (outcome, pending) = {
            let mut exec = InlineExec {
                engine,
                replicas: &mut replicas[..],
                n_params: plan.n_params,
                link: link.as_ref(),
                wc: &mut wc,
                rcs: &mut rcs,
                live: init_live,
                staged: None,
                encode_s: 0.0,
            };
            coordinate(engine, &mut exec, sync, plan, m, ctl)?
        };
        // final broadcast (the full flush at t = total_steps, or the
        // stop boundary's merge when checkpointing) — dead replicas
        // stay frozen at their death state
        let adopt = broadcast_adopt(link.as_ref(), &mut wc, &pending)?;
        for (r, rep) in replicas.iter_mut().enumerate() {
            if ctl.live[r] {
                rep.adopt(&adopt);
            }
        }
        for (r, rc) in rcs.into_iter().enumerate() {
            ctl.residuals[r] = rc.into_residual();
        }
        let mut outcome = outcome;
        outcome.comm_arena_bytes =
            wc.arena_bytes() + ctl.residuals.iter().map(|r| r.len() as u64 * 4).sum::<u64>();
        outcome.down_wire_arena_bytes = down_wire_arena_bytes;
        return Ok(outcome);
    }

    let n_params = plan.n_params;
    std::thread::scope(|scope| -> Result<DriveOutcome> {
        // Partition ownership: replica r lives on worker r % workers
        // for the whole run (its TokenStream and comm residual advance
        // only there).
        let mut owned: Vec<Vec<OwnedReplica>> = (0..workers).map(|_| Vec::new()).collect();
        for (r, rep) in replicas.drain(..).enumerate() {
            let mut rc = ReplicaComm::default();
            if let Some(l) = &link {
                if ctl.resume {
                    rc = ReplicaComm::restore(std::mem::take(&mut ctl.residuals[r]));
                } else {
                    l.init_replica(&mut rc);
                }
            }
            owned[r % workers].push(OwnedReplica {
                rid: r,
                live: ctl.live[r],
                rep,
                rc,
            });
        }
        // who owns what, recorded up front: if a worker panics this is
        // the only way to name the replicas that died with it
        let owned_ids: Vec<Vec<usize>> = owned
            .iter()
            .map(|set| set.iter().map(|o| o.rid).collect())
            .collect();
        let mut lanes = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for set in owned {
            // one shared arena set per worker, snapshotted from any of
            // its replicas (all identical at t=0 — Algorithm 1 line
            // 2), or from the checkpointed broadcast view on resume
            let mut wc = WorkerComm::default();
            if let Some(l) = &link {
                if ctl.resume {
                    let view = ctl.snap_init.as_ref().expect("checked above");
                    l.init_snapshot_from(&mut wc, view)?;
                } else {
                    let first = set.first().expect("each worker owns >= 1 replica");
                    l.init_snapshot(&mut wc, &first.rep.state)?;
                }
            }
            let (lane, mut wl) = inproc::pair();
            lanes.push((lane, set.iter().map(|o| o.rid).collect::<Vec<_>>()));
            let lk = link.clone();
            handles.push(
                scope.spawn(move || worker_session(engine, n_params, lk, wc, set, &mut wl)),
            );
        }

        // fail_on_death: an in-proc lane dying means a worker thread
        // vanished without reporting — a bug, never tolerable churn
        let mut exec = LaneExec::new(lanes, m, /* fail_on_death */ true);
        let res = coordinate(engine, &mut exec, sync, plan, m, ctl);

        // Shut down and reclaim replica states whether or not the run
        // succeeded; workers apply the final broadcast before exiting.
        let pending = match &res {
            Ok((_, p)) => p.clone(),
            Err(_) => Broadcast::empty(),
        };
        exec.finish(&pending);
        drop(exec); // closes the command channels
        let mut returned: Vec<OwnedReplica> = Vec::with_capacity(m);
        let mut comm_bytes = 0u64;
        let mut dead_workers: Vec<usize> = Vec::new();
        let mut finish_err: Option<anyhow::Error> = None;
        for (w, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok((set, bytes, finish)) => {
                    returned.extend(set);
                    comm_bytes += bytes;
                    if let Err(e) = finish {
                        finish_err.get_or_insert(e);
                    }
                }
                Err(_) => dead_workers.push(w),
            }
        }
        // Salvage whatever came back — surviving replica states (and
        // their residuals) reach the caller even when the run failed.
        returned.sort_by_key(|o| o.rid);
        for o in returned {
            ctl.residuals[o.rid] = o.rc.into_residual();
            replicas.push(o.rep);
        }
        if !dead_workers.is_empty() {
            let lost: Vec<usize> = dead_workers
                .iter()
                .flat_map(|&w| owned_ids[w].iter().copied())
                .collect();
            let base = match res {
                Err(e) => e,
                Ok(_) => anyhow!("drive: worker thread panicked"),
            };
            return Err(base.context(format!(
                "drive: worker(s) {dead_workers:?} panicked, losing replica(s) {lost:?}; \
                 salvaged {} of {m} replica states",
                replicas.len()
            )));
        }
        let (mut outcome, _) = res?;
        if replicas.len() != m {
            bail!(
                "drive: only {} of {m} replica states returned from the pool",
                replicas.len()
            );
        }
        if let Some(e) = finish_err {
            return Err(e.context("drive: final broadcast failed on a worker"));
        }
        outcome.comm_arena_bytes = comm_bytes;
        outcome.down_wire_arena_bytes = down_wire_arena_bytes;
        Ok(outcome)
    })
}

// ---- the coordinator loop (shared by inline and threaded paths) ------

/// Executes one segment of inner steps across all replicas. Split
/// into a begin/finish pair so the coordinator can reduce an
/// in-flight sync *while* the workers compute the segment — the
/// overlap pipeline's wall-clock win. Calls always pair up:
/// `dispatch(a, b)` then `collect(a, b)`, never nested.
trait SegmentExec {
    /// Begin one segment: workers apply membership changes and
    /// `broadcast` (the last merge's result), run steps (from, to],
    /// then build the boundary payloads `payload` asks for. The
    /// pooled implementation returns without waiting; the inline
    /// oracle runs the segment here (no concurrency to hide work
    /// under — results are bit-identical either way).
    fn dispatch(
        &mut self,
        from: usize,
        to: usize,
        broadcast: &Broadcast,
        payload: &PayloadSpec,
        churn: &SegmentChurn,
    ) -> Result<()>;

    /// Block until the dispatched segment completes; hand back its
    /// per-replica per-step losses + boundary sync payloads.
    fn collect(&mut self, from: usize, to: usize) -> Result<SegmentData>;

    /// Whether this executor's transport streams up-leg contributions:
    /// workers ship `ContribChunk` frames ahead of their reports and
    /// the coordinator collects send boundaries through
    /// [`SegmentExec::collect_streamed`], feeding an arrival-pipelined
    /// reduce. Default: no — contributions ride whole in the reports.
    fn stream_up(&self) -> bool {
        false
    }

    /// [`SegmentExec::collect`], feeding every streamed contribution
    /// chunk into `sink` as `(rid, wire-byte offset, bytes)` the
    /// moment it arrives — before the reports complete, which is the
    /// whole point: the reduce runs behind arrival instead of after
    /// the last byte. `sync_index`/`frag` pin which sync the chunks
    /// must belong to (a stale or future chunk is a protocol error).
    fn collect_streamed(
        &mut self,
        _from: usize,
        _to: usize,
        _sync_index: u64,
        _frag: Option<usize>,
        _sink: &mut dyn FnMut(usize, usize, WireSlice) -> Result<()>,
    ) -> Result<SegmentData> {
        bail!("drive: this executor does not stream contributions")
    }

    /// Up-leg encode seconds observed since the last call (inline
    /// oracle only — it encodes on the coordinator's thread; pooled
    /// workers encode concurrently, where the clock is invisible and
    /// the time folds into the wire wait). Purely a latency-breakdown
    /// channel; the default reports nothing.
    fn take_encode_time(&mut self) -> f64 {
        0.0
    }

    /// Return spent wire buffers from a completed reduce to the
    /// workers' encode pools. Purely an allocation-reuse channel —
    /// buffers carry no data (every byte is rewritten on reuse), so
    /// dropping them is always correct; the default does exactly that.
    fn recycle_wires(&mut self, _bufs: Vec<WireBuf>) {}

    /// Whether this executor can stream a lossy broadcast onto its
    /// transport while it encodes: the payload goes out as a dedicated
    /// `Bcast` frame, shard by shard as the encode finishes each one,
    /// and the next `Run` carries only a [`Broadcast::Pending`] marker.
    /// Default: no — the broadcast rides whole inside the `Run`.
    fn stream_down(&self) -> bool {
        false
    }

    /// Open the streamed broadcast frame (exactly `payload_len` bytes
    /// to follow) on every live lane. Only called when
    /// [`SegmentExec::stream_down`] returned true for this merge.
    fn bcast_begin(
        &mut self,
        _frag: Option<usize>,
        _sync_index: u64,
        _payload_len: u64,
    ) -> Result<()> {
        bail!("drive: this executor does not stream broadcasts")
    }

    /// Append the next encoded chunk to the open broadcast frame.
    fn bcast_chunk(&mut self, _chunk: &[u8]) -> Result<()> {
        bail!("drive: this executor does not stream broadcasts")
    }

    /// Replicas lost to transport-level lane deaths since the last
    /// call (a TCP worker hung up or timed out mid-run). The
    /// coordinator consumes this right after every `collect` and turns
    /// each loss into journaled `Crash` membership. In-process and
    /// inline executors never lose lanes, so the default is empty —
    /// which is what keeps crash-free runs bit-identical through the
    /// transport abstraction.
    fn take_lost(&mut self) -> Vec<usize> {
        Vec::new()
    }
}

/// A sync between its send and its merge: the coordinator holds the
/// boundary payloads (immutable snapshots — `Arc` literal handles or
/// encoded bytes) until the merge boundary reduces them.
struct InFlight {
    frag: Option<usize>,
    /// Boundary whose processing merges the reduced broadcast: the
    /// send step + τ, clamped to the end of training (the drain).
    merge_at: usize,
    /// Payloads indexed by universe slot; only `contributors` reduce.
    payloads: Vec<SyncPayload>,
    /// Replicas live at send time (the reduce averages over exactly
    /// these — mean over survivors when membership churned).
    contributors: Vec<usize>,
    /// Streamed sends carry their arrival-pipelined reduce state: the
    /// contributions were decoded and reduced as their chunks arrived
    /// (during the send boundary's collect), so the merge only runs
    /// the outer step + broadcast. `None` = one-shot payloads.
    arrival: Option<ArrivalReduce>,
}

/// End of the segment starting after `t0`: the next outer-sync send
/// boundary (DiLoCo), the pending merge point when a sync is in
/// flight, the next eval point (Data-Parallel, whose eval reads
/// per-step replica state), or the end of training.
fn next_boundary(t0: usize, plan: &DrivePlan, diloco: bool, merge_at: Option<usize>) -> usize {
    let mut b = plan.total_steps;
    if diloco {
        b = b.min((t0 / plan.sync_interval + 1).saturating_mul(plan.sync_interval));
        if let Some(m) = merge_at {
            b = b.min(m);
        }
    } else if let Some(k) = plan.eval_every {
        b = b.min((t0 / k + 1).saturating_mul(k));
    }
    b
}

/// The streaming fragment due at boundary `t1` (None = full sync —
/// vanilla DiLoCo, or the final full flush so nothing stays stale).
fn due_fragment(t1: usize, plan: &DrivePlan) -> Option<usize> {
    if plan.fragments > 1 && t1 != plan.total_steps {
        Some(((t1 / plan.sync_interval).wrapping_sub(1)) % plan.fragments)
    } else {
        None
    }
}

/// Merge one in-flight sync: reduce its payloads into the flat-bus
/// outer step (Algorithm 1 lines 8-12) and build the broadcast the
/// replicas merge — encoded wire frames under a lossy up-wire,
/// literal handles otherwise. With overlap this runs τ steps after
/// the send, dispatched *under* the workers' segment compute.
///
/// Also returns the spent wire buffers (empty for literal merges):
/// one is kept on the bus for its next broadcast encode, the rest go
/// back to the workers so steady-state syncs stop allocating.
///
/// When the executor streams ([`SegmentExec::stream_down`]) and both
/// wires are lossy, the broadcast payload is flushed onto the lanes
/// shard by shard *while it encodes* — overlapping the encode with the
/// socket write inside the overlap window — and the returned broadcast
/// is a [`Broadcast::Pending`] marker the workers resolve against the
/// `Bcast` frame they already received. On-wire payload bytes are
/// pinned identical to the one-shot frame.
fn reduce_and_broadcast<X: SegmentExec>(
    exec: &mut X,
    bus: &mut OuterSync,
    infl: InFlight,
    wire_codec: bool,
    wire_down: bool,
    out: &mut DriveOutcome,
) -> Result<(Broadcast, Vec<WireBuf>)> {
    let InFlight {
        frag,
        payloads,
        contributors,
        arrival,
        ..
    } = infl;
    if contributors.is_empty() {
        bail!("drive: outer sync with zero contributors");
    }
    let mut spent: Vec<WireBuf> = Vec::new();
    let mut streamed = false;
    if let Some(ar) = arrival {
        // Arrival-pipelined merge: the fused decode→reduce already ran
        // behind the chunks' arrival (shard by shard, replica-index
        // accumulation order — the one-shot path's exact arithmetic),
        // so the merge verifies completeness and runs only the outer
        // step + broadcast. Spent chunk views reclaim like payloads.
        if !wire_codec {
            bail!("drive: arrival-pipelined merge under an identity up-wire");
        }
        let slices = if wire_down && exec.stream_down() {
            let payload_len = bus
                .down_payload_bytes(frag)
                .ok_or_else(|| anyhow!("drive: lossy down-wire without a payload size"))?;
            let sync_index = bus.wire_stats().syncs();
            exec.bcast_begin(frag, sync_index, payload_len)?;
            let slices =
                bus.sync_arrival(ar, &contributors, Some(&mut |chunk| exec.bcast_chunk(chunk)))?;
            streamed = true;
            slices
        } else {
            bus.sync_arrival(ar, &contributors, None)?
        };
        spent = reclaim_wires(slices);
        if let Some(buf) = spent.pop() {
            bus.recycle_wire(buf);
        }
    } else if wire_codec {
        {
            let frames: Vec<&[u8]> = contributors
                .iter()
                .map(|&r| match &payloads[r] {
                    SyncPayload::Encoded(bytes) => Ok(bytes.as_slice()),
                    _ => Err(anyhow!("drive: wire-codec merge without an encoded payload")),
                })
                .collect::<Result<_>>()?;
            if wire_down && exec.stream_down() {
                let payload_len = bus.down_payload_bytes(frag).ok_or_else(|| {
                    anyhow!("drive: lossy down-wire without a payload size")
                })?;
                let sync_index = bus.wire_stats().syncs();
                exec.bcast_begin(frag, sync_index, payload_len)?;
                bus.sync_encoded_streamed(&frames, frag, &mut |chunk| {
                    exec.bcast_chunk(chunk)
                })?;
                streamed = true;
            } else {
                bus.sync_encoded(&frames, frag)?;
            }
        }
        // The reduce is done with the frames; their allocations are
        // still warm. Views of one shared receive buffer collapse to
        // that single buffer here. One refills the bus's broadcast
        // pool, the rest ride back to the worker pool with the next
        // dispatch.
        spent = reclaim_wires(
            payloads
                .into_iter()
                .filter_map(|p| match p {
                    SyncPayload::Encoded(bytes) => Some(bytes),
                    _ => None,
                })
                .collect(),
        );
        if let Some(buf) = spent.pop() {
            bus.recycle_wire(buf);
        }
    } else {
        let parts: Vec<&[Arc<xla::Literal>]> = contributors
            .iter()
            .map(|&r| match &payloads[r] {
                SyncPayload::Params(v) => Ok(&v[..]),
                _ => Err(anyhow!("drive: identity merge without a literal payload")),
            })
            .collect::<Result<_>>()?;
        bus.sync(&parts, frag)?;
    }
    out.outer_syncs += 1;
    // Broadcast = the merge boundary's payload: the deduplicated
    // freshly-uploaded literal per synced leaf (identity down-wire: N
    // uploads, never M×N), or the DownWire's single encoded fragment
    // (lossy down-wire: one buffer, decoded once per worker) — already
    // on the wire when the executor streamed it.
    let broadcast = if streamed {
        Broadcast::Pending { frag }
    } else if wire_down {
        Broadcast::Encoded {
            frag,
            bytes: bus.take_broadcast_bytes().ok_or_else(|| {
                anyhow!("drive: lossy down-wire produced no broadcast payload")
            })?,
        }
    } else {
        let leaves: Vec<usize> = bus.synced_leaves(frag).collect();
        let lits = bus.global_literals()?;
        Broadcast::Literals(
            leaves
                .into_iter()
                .map(|leaf| (leaf, Arc::clone(&lits[leaf])))
                .collect(),
        )
    };
    Ok((broadcast, spent))
}

/// Feed any one-shot `Encoded` payloads from live contributors into a
/// send boundary's arrival reduce — a worker that can't stream on a
/// streaming transport reported its whole contribution at once, which
/// is just a single chunk at offset 0 (bit-identical by construction:
/// the streamed chunks concatenate to exactly the one-shot payload).
fn arrival_absorb(
    bus: &mut OuterSync,
    ar: &mut ArrivalReduce,
    payloads: &mut [SyncPayload],
) -> Result<()> {
    for rid in ar.contributors().to_vec() {
        if matches!(payloads[rid], SyncPayload::Encoded(_)) {
            let SyncPayload::Encoded(bytes) =
                std::mem::replace(&mut payloads[rid], SyncPayload::Streamed)
            else {
                unreachable!("matched above")
            };
            bus.arrival_chunk(ar, rid, 0, bytes)?;
        }
    }
    Ok(())
}

fn coordinate<E: InnerEngine, X: SegmentExec>(
    engine: &E,
    exec: &mut X,
    mut sync: Option<&mut OuterSync>,
    plan: &DrivePlan,
    m: usize,
    ctl: &mut DriveCtl,
) -> Result<(DriveOutcome, Broadcast)> {
    let diloco = sync.is_some();
    // Lossy up-wires route through the encoded wire; identity runs
    // keep the zero-copy literal handoff into OuterSync::sync.
    let wire_codec = sync
        .as_deref()
        .map(|b| !b.codec().is_identity())
        .unwrap_or(false);
    // Lossy down-wires broadcast encoded bytes instead of literals.
    let wire_down = sync
        .as_deref()
        .map(|b| !b.down_codec().is_identity())
        .unwrap_or(false);
    // Workers keep a shared snapshot only when a wire is lossy; with
    // identity wires the coordinator must build joiners' views itself.
    let have_link = sync.as_deref().is_some_and(|s| s.link().is_active());
    // Streamed up-leg: transport can ship contribution chunks ahead of
    // the reports AND the up-wire is lossy (identity sends are literal
    // handoffs with no bytes to stream). When set, send boundaries
    // collect through the arrival-pipelined reduce.
    let stream_up = wire_codec && exec.stream_up();
    let tau = if diloco { plan.overlap_tau } else { 0 };
    // Absolute outer-sync indexing: a resumed run continues the
    // counter where the checkpoint left it (the restored WireStats
    // carries it), so encode seeds, fault keying, and the journal all
    // line up with the uninterrupted run.
    let start_syncs = sync.as_deref().map_or(0, |s| s.wire_stats().syncs());
    let mut sends: u64 = 0;
    // Fault events already in effect at the resume point replay as
    // no-ops; joins are re-keyed off the live flags (a join due
    // exactly at the checkpoint boundary fires on the first segment).
    let events: Vec<FaultEvent> = ctl.events.clone();
    let mut applied: Vec<bool> = events
        .iter()
        .map(|ev| match ev.kind {
            FaultKind::Join => ctl.live[ev.replica],
            _ => ev.at_sync < start_syncs,
        })
        .collect();
    // Leavers contribute to the send at their boundary, then freeze at
    // the *next* dispatch — queued here between iterations.
    let mut next_deaths: Vec<usize> = Vec::new();

    // The ticked phase machine: every transition is journaled, and an
    // out-of-order tick is a coordinator bug that fails loud.
    let mut fsm = CoordinatorFsm::new();
    let mut out = DriveOutcome::default();
    let mut pending = Broadcast::empty();
    let mut in_flight: Option<InFlight> = None;
    let mut t0 = ctl.start_step;

    fsm.advance(Phase::Warmup)?;
    ctl.journal
        .append(t0, start_syncs, EventKind::PhaseEnter, None, Phase::Warmup.label());
    if ctl.resume {
        ctl.journal.append(
            t0,
            start_syncs,
            EventKind::Resume,
            None,
            format!("resumed at step {t0} after {start_syncs} outer syncs"),
        );
    }
    fsm.advance(Phase::Train)?;
    ctl.journal
        .append(t0, start_syncs, EventKind::PhaseEnter, None, Phase::Train.label());

    while t0 < plan.total_steps {
        // ---- membership events due at this boundary ----------------
        // Deaths queued by the last send's leavers freeze first; then
        // crashes keyed to the upcoming send index; then joins keyed
        // to completed merges (the joiner's view — the last merge's
        // broadcast — ships with this very dispatch).
        let sends_abs = start_syncs + sends;
        let merges_abs = start_syncs + out.outer_syncs as u64;
        let mut churn = SegmentChurn {
            deaths: std::mem::take(&mut next_deaths),
            ..SegmentChurn::default()
        };
        for (ev, done) in events.iter().zip(applied.iter_mut()) {
            if *done {
                continue;
            }
            match ev.kind {
                FaultKind::Crash => {
                    if sends_abs >= ev.at_sync {
                        *done = true;
                        if ctl.live[ev.replica] {
                            ctl.live[ev.replica] = false;
                            churn.deaths.push(ev.replica);
                            ctl.journal.append(
                                t0,
                                sends_abs,
                                EventKind::Crash,
                                Some(ev.replica),
                                "mid-segment death; dropped from the next reduce",
                            );
                        }
                    }
                }
                FaultKind::Join => {
                    if merges_abs > ev.at_sync {
                        *done = true;
                        if !ctl.live[ev.replica] {
                            ctl.live[ev.replica] = true;
                            churn.joins.push(ev.replica);
                            ctl.journal.append(
                                t0,
                                merges_abs,
                                EventKind::Join,
                                Some(ev.replica),
                                "joined from the current broadcast view",
                            );
                        }
                    }
                }
                FaultKind::Straggle => {
                    if sends_abs >= ev.at_sync {
                        *done = true;
                        ctl.journal.append(
                            t0,
                            sends_abs,
                            EventKind::Straggle,
                            Some(ev.replica),
                            "walltime-only (netsim churn model); math unaffected",
                        );
                    }
                }
                FaultKind::Leave => {} // handled at send capture below
            }
        }
        if !ctl.live.iter().any(|&l| l) {
            bail!("drive: membership churn left zero live replicas at step {t0}");
        }
        // Joiners initialize from the current broadcast view. With a
        // lossy wire the worker's decoded snapshot *is* that view (and
        // carries the down-wire EF stream state); with identity wires
        // there is no snapshot, so the coordinator hands the global's
        // literals over directly.
        if !churn.joins.is_empty() && !have_link {
            let bus = sync.as_deref_mut().expect("join implies an outer sync");
            churn.join_view = bus
                .global_literals()?
                .iter()
                .enumerate()
                .map(|(leaf, lit)| (leaf, Arc::clone(lit)))
                .collect();
        }
        // Liveness for this segment (crashes and joins above applied;
        // leavers still run it): who steps, whose losses count, who
        // contributes to a send at its boundary.
        let mut seg_live: Vec<bool> = ctl.live.clone();

        let t1 = next_boundary(t0, plan, diloco, in_flight.as_ref().map(|f| f.merge_at));
        let merge_due = in_flight.as_ref().is_some_and(|f| f.merge_at == t1);
        // Send boundaries follow the sync cadence, plus the final full
        // flush; merge-only boundaries (send + τ) land strictly
        // between sends because τ < sync_interval.
        let send_due = diloco && (t1 == plan.total_steps || t1 % plan.sync_interval == 0);
        // End-of-training drain: a sync still in flight at T merges
        // first, and the full flush is captured only after its
        // broadcast is applied — by a zero-step trailing segment
        // below, so the flush payloads see the merged params.
        let defer_final = send_due && t1 == plan.total_steps && merge_due;
        let frag = if send_due { due_fragment(t1, plan) } else { None };
        // The sync index a send at this boundary belongs to — stamped
        // into the workers' encode spec and into the arrival reduce, so
        // both ends of the stream agree on which sync the chunks feed.
        // (merge_due never coincides with send_due short of the drain,
        // so outer_syncs cannot move between here and the collect.)
        let send_sync_index = start_syncs + out.outer_syncs as u64;
        // Merge-only boundaries (and the drain's main segment) ask the
        // workers for nothing — the coordinator would only discard it.
        let payload_spec = if !diloco {
            PayloadSpec::Params // DP boundary evals read replica state
        } else if send_due && !defer_final {
            if wire_codec {
                PayloadSpec::Encoded(EncodeSpec {
                    frag,
                    sync_index: send_sync_index,
                    stream: stream_up,
                })
            } else {
                PayloadSpec::Params
            }
        } else {
            PayloadSpec::None
        };
        exec.dispatch(t0, t1, &pending, &payload_spec, &churn)?;
        pending = Broadcast::empty();

        // DiLoCo evals strictly inside the segment read the global as
        // of the last *merge* — no replica has adopted anything
        // fresher at those steps (an in-flight sync is invisible to
        // the fleet), and at τ=0 this is exactly the old barrier rule
        // (the previous sync's global). Runs while workers compute.
        if let (Some(bus), Some(k)) = (sync.as_deref_mut(), plan.eval_every) {
            for t in t0 + 1..t1 {
                if t % k == 0 && t != plan.total_steps {
                    let e = engine.eval(bus.global_literals()?)?;
                    out.eval_curve.push((t, e));
                    log::info!("  step {t} eval_loss={e:.4}");
                }
            }
        }

        // Merge due at this boundary: reduce the payloads captured τ
        // steps ago and run the outer step — coordinator work hidden
        // under the segment's inner compute (the pipeline's point).
        if merge_due {
            let infl = in_flight.take().expect("merge_due implies a sync in flight");
            let bus = sync
                .as_deref_mut()
                .expect("a sync can only be in flight with an OuterSync");
            let (b, spent) =
                reduce_and_broadcast(exec, bus, infl, wire_codec, wire_down, &mut out)?;
            pending = b;
            exec.recycle_wires(spent);
            ctl.journal.append(
                t1,
                start_syncs + out.outer_syncs as u64 - 1,
                EventKind::SyncMerge,
                None,
                "delayed merge (overlap window closed)",
            );
        }

        // Send boundaries on a streaming transport collect through the
        // arrival-pipelined reduce: every contribution chunk feeds the
        // fused decode→reduce the moment it lands, so reduce work runs
        // *behind arrival* instead of after the last report — and the
        // merge τ steps later only runs the outer step + broadcast.
        let stream_this = stream_up && send_due && !defer_final;
        let reduce_before = sync.as_deref().map_or(0.0, |b| b.reduce_time_so_far());
        let collect_t0 = Instant::now();
        let mut arrival: Option<ArrivalReduce> = None;
        let (losses, mut payloads) = if stream_this {
            let live_rids: Vec<usize> = seg_live
                .iter()
                .enumerate()
                .filter_map(|(r, &l)| l.then_some(r))
                .collect();
            let bus = sync.as_deref_mut().expect("streamed send implies an outer sync");
            let mut ar = bus.arrival_begin(&live_rids, frag)?;
            let data = exec.collect_streamed(t0, t1, send_sync_index, frag, &mut |rid, off, ws| {
                bus.arrival_chunk(&mut ar, rid, off, ws)
            })?;
            arrival = Some(ar);
            data
        } else {
            exec.collect(t0, t1)?
        };
        // Sync-stage latency breakdown: the collect's wall time minus
        // any reduce work that ran inside it is the wire wait (what the
        // coordinator truly spent blocked on workers + socket).
        if let Some(bus) = sync.as_deref_mut() {
            let in_collect = bus.reduce_time_so_far() - reduce_before;
            bus.note_wire_wait((collect_t0.elapsed().as_secs_f64() - in_collect).max(0.0));
            let enc = exec.take_encode_time();
            if enc > 0.0 {
                bus.note_encode_time(enc);
            }
        }
        // Transport-level lane deaths (a remote worker hung up or
        // timed out) surface here as crashes: the lane's replicas took
        // no (complete) part in this segment, so they are dead for the
        // whole of it — the PR 6 crash rule — and drop from this
        // reduce onward. Survivors complete the run.
        let lost = exec.take_lost();
        if !lost.is_empty() {
            if let (Some(ar), Some(bus)) = (arrival.as_mut(), sync.as_deref_mut()) {
                // the dead replicas' chunks leave the arrival reduce;
                // survivor shards re-fire from their buffered bytes
                bus.arrival_drop(ar, &lost)?;
            }
        }
        for r in lost {
            if ctl.live[r] {
                ctl.live[r] = false;
                seg_live[r] = false;
                ctl.journal.append(
                    t1,
                    sends_abs,
                    EventKind::Crash,
                    Some(r),
                    "transport lane died; dropped from this reduce onward",
                );
            }
        }
        if !ctl.live.iter().any(|&l| l) {
            bail!("drive: every transport lane died by step {t1}");
        }
        let live_n = seg_live.iter().filter(|&&l| l).count();
        for (r, l) in losses.iter().enumerate() {
            let want = if seg_live[r] { t1 - t0 } else { 0 };
            if l.len() != want {
                bail!(
                    "replica {r}: incomplete segment report ({} of {} steps)",
                    l.len(),
                    want
                );
            }
        }

        // Per-step mean loss over the live fleet, summed in replica
        // index order — the same order as the sequential loop, so
        // results are bit-identical (and identical to the
        // pre-membership loop when nothing churns: live_n == m).
        for t in t0 + 1..=t1 {
            let mut step_loss = 0.0f64;
            for (r, rep_losses) in losses.iter().enumerate() {
                if !seg_live[r] {
                    continue;
                }
                step_loss += rep_losses[t - t0 - 1] / live_n as f64;
            }
            out.step_losses.push(step_loss);
            if t % plan.log_every == 0 || t == 1 || t == plan.total_steps {
                out.loss_curve.push((t, step_loss));
                match engine.inner_lr(t) {
                    Some(lr) => log::info!(
                        "  step {t}/{} loss={step_loss:.4} lr={lr:.2e}",
                        plan.total_steps
                    ),
                    None => log::info!("  step {t}/{} loss={step_loss:.4}", plan.total_steps),
                }
            }
        }

        // Data-Parallel eval due exactly at the boundary reads the
        // boundary-step replica state (its segments end at eval
        // points; the DiLoCo twin of this block runs post-merge,
        // after send handling consumes the payloads).
        if !diloco {
            if let Some(k) = plan.eval_every {
                if t1 % k == 0 && t1 != plan.total_steps {
                    let e = match &payloads[0] {
                        SyncPayload::Params(p) => engine.eval(p)?,
                        _ => bail!("drive: Data-Parallel boundary without replica params"),
                    };
                    out.eval_curve.push((t1, e));
                    log::info!("  step {t1} eval_loss={e:.4}");
                }
            }
        }

        // A worker that can't stream on a streaming transport reports
        // a one-shot payload; its whole contribution feeds the arrival
        // reduce as a single chunk so every merge runs one code path.
        if let (Some(ar), Some(bus)) = (arrival.as_mut(), sync.as_deref_mut()) {
            arrival_absorb(bus, ar, &mut payloads)?;
        }

        if send_due && !defer_final {
            // Capture the boundary payloads; they merge τ steps later
            // — immediately when τ=0 (the barrier), or at the clamped
            // end of training. Contributors are the replicas live
            // through the segment: a replica that crashed at the
            // boundary is gone, one leaving at it still counts (its
            // last contribution), and the reduce averages over exactly
            // this set.
            let contributors: Vec<usize> = seg_live
                .iter()
                .enumerate()
                .filter_map(|(r, &l)| l.then_some(r))
                .collect();
            ctl.journal.append(
                t1,
                sends_abs,
                EventKind::SyncSend,
                None,
                match frag {
                    Some(f) => format!("fragment {f}; {} contributors", contributors.len()),
                    None => format!("full sync; {} contributors", contributors.len()),
                },
            );
            let merge_at = (t1 + tau).min(plan.total_steps);
            in_flight = Some(InFlight {
                frag,
                merge_at,
                payloads,
                contributors,
                arrival: arrival.take(),
            });
            if merge_at == t1 {
                let infl = in_flight.take().expect("stashed above");
                let bus = sync.as_deref_mut().expect("send implies sync");
                let (b, spent) =
                    reduce_and_broadcast(exec, bus, infl, wire_codec, wire_down, &mut out)?;
                pending = b;
                exec.recycle_wires(spent);
                ctl.journal.append(
                    t1,
                    start_syncs + out.outer_syncs as u64 - 1,
                    EventKind::SyncMerge,
                    None,
                    "barrier merge (tau = 0 or end of training)",
                );
            }
            // Leavers announced for this boundary contributed above
            // and freeze at the next dispatch.
            for (ev, done) in events.iter().zip(applied.iter_mut()) {
                if !*done && matches!(ev.kind, FaultKind::Leave) && ev.at_sync <= sends_abs {
                    *done = true;
                    if ctl.live[ev.replica] {
                        ctl.live[ev.replica] = false;
                        next_deaths.push(ev.replica);
                        ctl.journal.append(
                            t1,
                            sends_abs,
                            EventKind::Leave,
                            Some(ev.replica),
                            "left after contributing to this sync",
                        );
                    }
                }
            }
            sends += 1;
        } else if defer_final {
            // Drain: the merged broadcast (in `pending`) is applied by
            // a zero-step trailing segment whose boundary payloads are
            // the final full flush — nothing in flight survives the
            // end of training.
            let flush_sync_index = start_syncs + out.outer_syncs as u64;
            let flush_spec = if wire_codec {
                PayloadSpec::Encoded(EncodeSpec {
                    frag: None,
                    sync_index: flush_sync_index,
                    stream: stream_up,
                })
            } else {
                PayloadSpec::Params
            };
            exec.dispatch(t1, t1, &pending, &flush_spec, &SegmentChurn::default())?;
            pending = Broadcast::empty();
            // The flush streams like any other send — its chunks feed
            // an arrival reduce that merges immediately below.
            let mut flush_arrival: Option<ArrivalReduce> = None;
            let (_, mut flush) = if stream_up {
                let live_rids: Vec<usize> = ctl
                    .live
                    .iter()
                    .enumerate()
                    .filter_map(|(r, &l)| l.then_some(r))
                    .collect();
                let bus = sync.as_deref_mut().expect("flush implies sync");
                let mut ar = bus.arrival_begin(&live_rids, None)?;
                let data =
                    exec.collect_streamed(t1, t1, flush_sync_index, None, &mut |rid, off, ws| {
                        bus.arrival_chunk(&mut ar, rid, off, ws)
                    })?;
                flush_arrival = Some(ar);
                data
            } else {
                exec.collect(t1, t1)?
            };
            let lost = exec.take_lost();
            if !lost.is_empty() {
                if let (Some(ar), Some(bus)) = (flush_arrival.as_mut(), sync.as_deref_mut()) {
                    bus.arrival_drop(ar, &lost)?;
                }
            }
            for r in lost {
                if ctl.live[r] {
                    ctl.live[r] = false;
                    ctl.journal.append(
                        t1,
                        start_syncs + sends,
                        EventKind::Crash,
                        Some(r),
                        "transport lane died; dropped from the final flush",
                    );
                }
            }
            if let (Some(ar), Some(bus)) = (flush_arrival.as_mut(), sync.as_deref_mut()) {
                arrival_absorb(bus, ar, &mut flush)?;
            }
            let contributors: Vec<usize> = ctl
                .live
                .iter()
                .enumerate()
                .filter_map(|(r, &l)| l.then_some(r))
                .collect();
            ctl.journal.append(
                t1,
                start_syncs + sends,
                EventKind::SyncSend,
                None,
                format!("final full flush; {} contributors", contributors.len()),
            );
            sends += 1;
            let bus = sync.as_deref_mut().expect("flush implies sync");
            let (b, spent) = reduce_and_broadcast(
                exec,
                bus,
                InFlight {
                    frag: None,
                    merge_at: t1,
                    payloads: flush,
                    contributors,
                    arrival: flush_arrival,
                },
                wire_codec,
                wire_down,
                &mut out,
            )?;
            pending = b;
            exec.recycle_wires(spent);
            ctl.journal.append(
                t1,
                start_syncs + out.outer_syncs as u64 - 1,
                EventKind::SyncMerge,
                None,
                "final flush merged",
            );
        }

        // DiLoCo eval due exactly at the boundary sees the post-merge
        // global (at a send-only boundary under τ>0 nothing merged, so
        // it correctly reads the last merged state — the in-flight
        // sync has reached no replica yet).
        if diloco {
            if let Some(k) = plan.eval_every {
                if t1 % k == 0 && t1 != plan.total_steps {
                    let bus = sync.as_deref_mut().expect("diloco implies sync");
                    let e = engine.eval(bus.global_literals()?)?;
                    out.eval_curve.push((t1, e));
                    log::info!("  step {t1} eval_loss={e:.4}");
                }
            }
        }
        t0 = t1;

        // Checkpoint stop: once the requested number of outer syncs
        // has merged and nothing is in flight, this boundary is a
        // clean cut — the caller snapshots replicas + sync state and a
        // resumed run continues bit-identically.
        if let Some(stop) = ctl.stop_after_sync {
            if t1 < plan.total_steps
                && in_flight.is_none()
                && start_syncs + out.outer_syncs as u64 >= stop
            {
                ctl.stopped_at = Some(t1);
                ctl.journal.append(
                    t1,
                    start_syncs + out.outer_syncs as u64,
                    EventKind::Checkpoint,
                    None,
                    format!("stopped for checkpoint after {stop} outer syncs"),
                );
                break;
            }
        }
    }
    // Structurally unreachable (merges are clamped to T, the drain
    // handles the collision with the final flush, and the checkpoint
    // stop waits out the overlap window), but a silent stale fragment
    // would corrupt every consumer of the global — refuse.
    if let Some(infl) = in_flight {
        bail!(
            "drive: fragment {:?} was sent but never merged (merge was \
             scheduled at step {}, training ended at {})",
            infl.frag,
            infl.merge_at,
            plan.total_steps
        );
    }
    fsm.advance(Phase::Cooldown)?;
    ctl.journal.append(
        t0,
        start_syncs + out.outer_syncs as u64,
        EventKind::PhaseEnter,
        None,
        Phase::Cooldown.label(),
    );
    fsm.advance(Phase::Done)?;
    ctl.journal.append(
        t0,
        start_syncs + out.outer_syncs as u64,
        EventKind::PhaseEnter,
        None,
        Phase::Done.label(),
    );
    Ok((out, pending))
}

// ---- sequential oracle ------------------------------------------------

struct InlineExec<'a, E: InnerEngine> {
    engine: &'a E,
    replicas: &'a mut [ReplicaState],
    n_params: usize,
    link: Option<&'a CommLink>,
    wc: &'a mut WorkerComm,
    rcs: &'a mut Vec<ReplicaComm>,
    /// Liveness per universe slot, kept in lockstep with the
    /// coordinator's via the dispatched `SegmentChurn` messages.
    live: Vec<bool>,
    /// The dispatched segment's results, awaiting `collect` (the
    /// sequential oracle has no concurrency to overlap with, so the
    /// segment runs eagerly at dispatch).
    staged: Option<SegmentData>,
    /// Up-leg encode seconds since the driver last drained them (the
    /// oracle encodes on this thread, so the clock is visible here).
    encode_s: f64,
}

impl<E: InnerEngine> SegmentExec for InlineExec<'_, E> {
    fn dispatch(
        &mut self,
        from: usize,
        to: usize,
        broadcast: &Broadcast,
        payload: &PayloadSpec,
        churn: &SegmentChurn,
    ) -> Result<()> {
        if self.staged.is_some() {
            bail!("drive: segment dispatched while another is uncollected");
        }
        // deaths freeze before the broadcast: a replica that crashed
        // or left never adopts a merge it wasn't part of
        for &d in &churn.deaths {
            self.live[d] = false;
        }
        let adopt = broadcast_adopt(self.link, self.wc, broadcast)?;
        for (r, rep) in self.replicas.iter_mut().enumerate() {
            if self.live[r] {
                rep.adopt(&adopt);
            }
        }
        // joiners come alive on the post-broadcast view
        if !churn.joins.is_empty() {
            let view: Adopt = if !churn.join_view.is_empty() {
                churn.join_view.clone()
            } else {
                let link = self
                    .link
                    .ok_or_else(|| anyhow!("drive: join without a view or comm link"))?;
                link.snap_literals(self.wc)?
            };
            for &j in &churn.joins {
                self.replicas[j].adopt(&view);
                self.live[j] = true;
            }
        }
        let m = self.replicas.len();
        let mut losses = vec![Vec::new(); m];
        // the classic sequential shape: step-major, replica-minor
        // (dead replicas are frozen — no steps, no losses)
        for t in from + 1..=to {
            for (r, rep) in self.replicas.iter_mut().enumerate() {
                if self.live[r] {
                    losses[r].push(self.engine.inner_step(r, rep, t)?);
                }
            }
        }
        let payloads: Vec<SyncPayload> = match payload {
            PayloadSpec::Encoded(spec) => {
                let link = self.link.ok_or_else(|| {
                    anyhow!("drive: encode requested without a comm link")
                })?;
                let wc = &mut *self.wc;
                let live = &self.live;
                let t0 = Instant::now();
                let payloads = self
                    .replicas
                    .iter()
                    .zip(self.rcs.iter_mut())
                    .enumerate()
                    .map(|(r, (rep, rc))| {
                        if !live[r] {
                            return Ok(SyncPayload::Skipped);
                        }
                        Ok(SyncPayload::Encoded(link.encode_replica(
                            r,
                            &rep.state,
                            wc,
                            rc,
                            spec.frag,
                            spec.sync_index,
                        )?))
                    })
                    .collect::<Result<_>>()?;
                self.encode_s += t0.elapsed().as_secs_f64();
                payloads
            }
            PayloadSpec::Params => self
                .replicas
                .iter()
                .enumerate()
                .map(|(r, rep)| {
                    if self.live[r] {
                        SyncPayload::Params(rep.state[..self.n_params].to_vec())
                    } else {
                        SyncPayload::Skipped
                    }
                })
                .collect(),
            PayloadSpec::None => (0..m).map(|_| SyncPayload::Skipped).collect(),
        };
        self.staged = Some((losses, payloads));
        Ok(())
    }

    fn collect(&mut self, _from: usize, _to: usize) -> Result<SegmentData> {
        self.staged
            .take()
            .ok_or_else(|| anyhow!("drive: collect without a dispatched segment"))
    }

    fn take_encode_time(&mut self) -> f64 {
        std::mem::take(&mut self.encode_s)
    }

    fn recycle_wires(&mut self, bufs: Vec<WireBuf>) {
        for b in bufs {
            self.wc.recycle(b);
        }
    }
}

// ---- worker pool ------------------------------------------------------

/// One replica as a worker owns it: id, liveness, state, and up-wire
/// EF residual. Dead entries (pre-join placeholders, crash/leave
/// remains) are frozen — no steps, no adopts — until a join revives
/// them or the run ends and they return for salvage/checkpointing.
pub struct OwnedReplica {
    pub rid: usize,
    pub live: bool,
    pub rep: ReplicaState,
    pub rc: ReplicaComm,
}

/// One worker's whole life: loop on commands from a [`WorkerLink`]
/// (any transport), run segments over the owned replicas, report
/// back; exit on `Finish` or when the link closes. Returns replica
/// ownership, the worker-side comm arena footprint, and the final
/// broadcast's verdict. The in-process pool and the remote
/// `diloco worker` verb both run exactly this function — which is why
/// a remote run cannot diverge from the oracle.
pub fn worker_session<E: InnerEngine>(
    engine: &E,
    n_params: usize,
    link: Option<CommLink>,
    mut wc: WorkerComm,
    mut owned: Vec<OwnedReplica>,
    lk: &mut dyn WorkerLink,
) -> (Vec<OwnedReplica>, u64, Result<()>) {
    let mut finish: Result<()> = Ok(());
    while let Some(cmd) = lk.recv_cmd() {
        match cmd {
            Cmd::Run {
                from,
                to,
                broadcast,
                payload: want,
                churn,
            } => {
                let mut report = WorkerReport {
                    reps: Vec::with_capacity(owned.len()),
                };
                let mut err: Option<anyhow::Error> = None;
                // deaths freeze before the broadcast (same order as
                // the inline oracle): a crashed/left replica never
                // adopts a merge it wasn't part of
                for d in &churn.deaths {
                    if let Some(o) = owned.iter_mut().find(|o| o.rid == *d) {
                        o.live = false;
                    }
                }
                // the broadcast is decoded (or the snapshot refreshed)
                // once per worker — even when every owned replica is
                // dead, so the shared snapshot (the down-wire EF
                // stream's decode state) never falls behind the fleet
                match broadcast_adopt(link.as_ref(), &mut wc, &broadcast) {
                    Ok(adopt) => {
                        for o in owned.iter_mut() {
                            if o.live {
                                o.rep.adopt(&adopt);
                            }
                        }
                    }
                    Err(e) => err = Some(e),
                }
                // joiners come alive on the post-broadcast view: the
                // coordinator's literal list (identity wires) or this
                // worker's decoded snapshot (lossy wires)
                if err.is_none() && !churn.joins.is_empty() {
                    let mut view: Option<Adopt> = None;
                    for j in &churn.joins {
                        let Some(o) = owned.iter_mut().find(|o| o.rid == *j) else {
                            continue; // another worker's joiner
                        };
                        if view.is_none() {
                            view = Some(if !churn.join_view.is_empty() {
                                churn.join_view.clone()
                            } else {
                                match &link {
                                    Some(l) => match l.snap_literals(&wc) {
                                        Ok(v) => v,
                                        Err(e) => {
                                            err = Some(e);
                                            break;
                                        }
                                    },
                                    None => {
                                        err = Some(anyhow!(
                                            "worker: join without a view or comm link"
                                        ));
                                        break;
                                    }
                                }
                            });
                        }
                        o.rep.adopt(view.as_ref().expect("built above"));
                        o.live = true;
                    }
                }
                if err.is_none() {
                    'replicas: for o in owned.iter_mut() {
                        if !o.live {
                            // frozen: reports empty losses and no
                            // payload so the coordinator's books stay
                            // index-aligned with the universe
                            report.reps.push((o.rid, Vec::new(), SyncPayload::Skipped));
                            continue;
                        }
                        let mut losses = Vec::with_capacity(to - from);
                        for t in from + 1..=to {
                            match engine.inner_step(o.rid, &mut o.rep, t) {
                                Ok(l) => losses.push(l),
                                Err(e) => {
                                    err = Some(e);
                                    break 'replicas;
                                }
                            }
                        }
                        let payload = match (&want, &link) {
                            (PayloadSpec::Encoded(spec), Some(l))
                                if spec.stream
                                    && lk.stream_contrib()
                                    && !l.up().is_identity() =>
                            {
                                // Streamed up-leg: each block-aligned
                                // chunk ships the moment it encodes;
                                // chunks then the report ride one FIFO
                                // lane, so the report closing the
                                // stream proves every chunk arrived.
                                let chunks = l.stream_chunks(spec.frag);
                                match l.encode_replica_streamed(
                                    o.rid,
                                    &o.rep.state,
                                    &mut wc,
                                    &mut o.rc,
                                    spec.frag,
                                    spec.sync_index,
                                    chunks,
                                    &mut |off, b| {
                                        lk.send_contrib_chunk(
                                            o.rid,
                                            spec.sync_index,
                                            spec.frag,
                                            off,
                                            b,
                                        )
                                    },
                                ) {
                                    Ok(()) => SyncPayload::Streamed,
                                    Err(e) => {
                                        err = Some(e);
                                        break 'replicas;
                                    }
                                }
                            }
                            (PayloadSpec::Encoded(spec), Some(l)) => {
                                match l.encode_replica(
                                    o.rid,
                                    &o.rep.state,
                                    &mut wc,
                                    &mut o.rc,
                                    spec.frag,
                                    spec.sync_index,
                                ) {
                                    Ok(bytes) => SyncPayload::Encoded(bytes),
                                    Err(e) => {
                                        err = Some(e);
                                        break 'replicas;
                                    }
                                }
                            }
                            (PayloadSpec::Encoded(_), None) => {
                                err = Some(anyhow!("worker: encode requested without a comm link"));
                                break 'replicas;
                            }
                            (PayloadSpec::Params, _) => {
                                SyncPayload::Params(o.rep.state[..n_params].to_vec())
                            }
                            (PayloadSpec::None, _) => SyncPayload::Skipped,
                        };
                        report.reps.push((o.rid, losses, payload));
                    }
                }
                let msg = match err {
                    Some(e) => Err(e),
                    None => Ok(report),
                };
                let failed = msg.is_err();
                if lk.send_report(msg).is_err() || failed {
                    break;
                }
            }
            Cmd::Spares(bufs) => {
                for b in bufs {
                    wc.recycle(b);
                }
            }
            Cmd::Finish { broadcast } => {
                // a failed final broadcast must fail the run (the
                // inline path propagates the same error with `?`), so
                // it travels back through the join value — the result
                // channel is already torn down at shutdown
                match broadcast_adopt(link.as_ref(), &mut wc, &broadcast) {
                    Ok(adopt) => {
                        for o in owned.iter_mut() {
                            if o.live {
                                o.rep.adopt(&adopt);
                            }
                        }
                    }
                    Err(e) => finish = Err(e),
                }
                break;
            }
        }
    }
    let comm_bytes = wc.arena_bytes() + owned.iter().map(|o| o.rc.arena_bytes()).sum::<u64>();
    (owned, comm_bytes, finish)
}

/// One worker connection as the coordinator's executor sees it.
struct LaneSlot<L: Lane> {
    lane: L,
    /// Replica ids this lane owns (fixed at connection).
    rids: Vec<usize>,
    alive: bool,
}

/// The transport-generic segment executor: one [`Lane`] per worker,
/// whatever carries it — in-proc channels (the pool) or TCP sockets
/// (`diloco coordinate`). Dispatch fires every lane and returns
/// immediately (the coordinator reduces the in-flight sync under the
/// workers' compute); collect blocks per lane in worker-index order
/// and re-indexes reports by replica id, so the reduction order — and
/// every downstream bit — is transport-independent.
///
/// `fail_on_death` picks the policy for a lane that vanishes: the
/// in-proc pool fails the run (a vanished thread is a bug), remote
/// mode records the lane's replicas in `lost` and keeps going — the
/// drive loop turns them into journaled `Crash` membership.
struct LaneExec<L: Lane> {
    slots: Vec<LaneSlot<L>>,
    m: usize,
    fail_on_death: bool,
    lost: Vec<usize>,
}

impl<L: Lane> LaneExec<L> {
    fn new(lanes: Vec<(L, Vec<usize>)>, m: usize, fail_on_death: bool) -> LaneExec<L> {
        LaneExec {
            slots: lanes
                .into_iter()
                .map(|(lane, rids)| LaneSlot {
                    lane,
                    rids,
                    alive: true,
                })
                .collect(),
            m,
            fail_on_death,
            lost: Vec::new(),
        }
    }

    /// Ship the final broadcast to every surviving lane. Send failures
    /// are ignored — a lane dead at shutdown already had its replicas
    /// crashed out (remote) or failed the run (in-proc).
    fn finish(&mut self, broadcast: &Broadcast) {
        for slot in self.slots.iter_mut().filter(|s| s.alive) {
            let _ = slot.lane.send(Cmd::Finish {
                broadcast: broadcast.clone(),
            });
        }
    }

    fn lane_died(slot: &mut LaneSlot<L>, lost: &mut Vec<usize>) {
        slot.alive = false;
        lost.extend(slot.rids.iter().copied());
    }
}

impl<L: Lane> SegmentExec for LaneExec<L> {
    /// Fire the segment at every worker and return immediately — the
    /// coordinator reduces the in-flight sync while workers compute.
    fn dispatch(
        &mut self,
        from: usize,
        to: usize,
        broadcast: &Broadcast,
        payload: &PayloadSpec,
        churn: &SegmentChurn,
    ) -> Result<()> {
        for slot in self.slots.iter_mut().filter(|s| s.alive) {
            let cmd = Cmd::Run {
                from,
                to,
                broadcast: broadcast.clone(),
                payload: payload.clone(),
                churn: churn.clone(),
            };
            if slot.lane.send(cmd).is_err() {
                if self.fail_on_death {
                    bail!("worker hung up before segment ({from}, {to}]");
                }
                Self::lane_died(slot, &mut self.lost);
            }
        }
        Ok(())
    }

    fn collect(&mut self, from: usize, to: usize) -> Result<SegmentData> {
        let mut losses: Vec<Vec<f64>> = vec![Vec::new(); self.m];
        let mut payloads: Vec<Option<SyncPayload>> = (0..self.m).map(|_| None).collect();
        for slot in self.slots.iter().filter(|s| !s.alive) {
            // a dead lane's replicas are segment-dead: empty losses
            // and no payload, exactly how a frozen replica reports —
            // the coordinator flips their membership via take_lost
            // before validating
            for &r in &slot.rids {
                payloads[r] = Some(SyncPayload::Skipped);
            }
        }
        // Service lanes by readiness when the transport can poll: a
        // stalled worker 0 no longer blocks consuming (and decoding)
        // reports that already arrived from workers 1..N. Consumption
        // order cannot move any bit — reports land in rid-indexed
        // slots and the reduce order is fixed downstream.
        let mut pending: Vec<usize> = (0..self.slots.len())
            .filter(|&w| self.slots[w].alive)
            .collect();
        let poll = !pending.is_empty()
            && pending.iter().all(|&w| self.slots[w].lane.can_poll());
        while !pending.is_empty() {
            let mut progressed = false;
            let mut died: Result<()> = Ok(());
            pending.retain(|&w| {
                if died.is_err() {
                    return true;
                }
                let slot = &mut self.slots[w];
                let got = if poll {
                    match slot.lane.try_recv() {
                        Ok(None) => return true, // nothing yet
                        Ok(Some(rep)) => Ok(rep),
                        Err(e) => Err(e),
                    }
                } else {
                    slot.lane.recv()
                };
                progressed = true;
                match got {
                    // a worker-reported engine error fails the run on
                    // every transport — a broken engine is never churn
                    Ok(report) => match report {
                        Ok(report) => {
                            for (rid, l, p) in report.reps {
                                losses[rid] = l;
                                payloads[rid] = Some(p);
                            }
                        }
                        Err(e) => died = Err(e),
                    },
                    Err(_) if !self.fail_on_death => {
                        Self::lane_died(slot, &mut self.lost);
                        for &r in &slot.rids {
                            losses[r] = Vec::new();
                            payloads[r] = Some(SyncPayload::Skipped);
                        }
                    }
                    Err(_) => {
                        died = Err(anyhow!("worker {w} died during segment ({from}, {to}]"))
                    }
                }
                false
            });
            died?;
            if poll && !progressed && !pending.is_empty() {
                // nothing ready on any lane: workers are mid-segment —
                // yield the core to them rather than burn it spinning
                std::thread::sleep(std::time::Duration::from_micros(100));
            }
        }
        // step-count validation lives in coordinate(), which knows the
        // segment's live set (dead replicas legitimately report empty)
        let mut out = Vec::with_capacity(self.m);
        for (r, p) in payloads.into_iter().enumerate() {
            out.push(p.ok_or_else(|| anyhow!("replica {r}: missing segment payload"))?);
        }
        Ok((losses, out))
    }

    /// Deal the spent buffers round-robin across the surviving lanes.
    /// Send failures are ignored: spares are droppable by design (and
    /// the TCP lane drops them unconditionally — shipping empty
    /// buffers across a socket would cost more than it saves).
    fn recycle_wires(&mut self, bufs: Vec<WireBuf>) {
        let n = self.slots.iter().filter(|s| s.alive).count();
        if n == 0 {
            return;
        }
        let mut per_lane: Vec<Vec<WireBuf>> = (0..n).map(|_| Vec::new()).collect();
        for (i, b) in bufs.into_iter().enumerate() {
            per_lane[i % n].push(b);
        }
        for (slot, batch) in self.slots.iter_mut().filter(|s| s.alive).zip(per_lane) {
            if !batch.is_empty() {
                let _ = slot.lane.send(Cmd::Spares(batch));
            }
        }
    }

    fn take_lost(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.lost)
    }
}

/// Drive a run over pre-connected transport lanes — the remote
/// (`diloco coordinate`) twin of [`drive_ctl`]'s threaded path. Each
/// lane owns a fixed replica set; together they must cover the
/// universe exactly. Lane deaths are tolerated as journaled `Crash`
/// membership (survivors complete the run); worker-reported engine
/// errors still fail it. The final broadcast ships to survivors as
/// `Finish` before returning.
///
/// Remote workers rebuild their replicas and comm state from the
/// handshake config, so unlike [`drive_ctl`] there are no replica
/// states on this side to validate or return — the coordinator's own
/// copy of the trained parameters is the sync engine's global.
pub fn drive_lanes<E: InnerEngine, L: Lane>(
    engine: &E,
    lanes: Vec<(L, Vec<usize>)>,
    mut sync: Option<&mut OuterSync>,
    plan: &DrivePlan,
    ctl: &mut DriveCtl,
) -> Result<DriveOutcome> {
    let rids: Vec<&[usize]> = lanes.iter().map(|(_, r)| &r[..]).collect();
    let m = validate_remote_plan(&rids, sync.is_some(), plan, ctl)?;
    let mut exec = LaneExec::new(lanes, m, /* fail_on_death */ false);
    let res = coordinate(engine, &mut exec, sync.as_deref_mut(), plan, m, ctl);
    let pending = match &res {
        Ok((_, p)) => p.clone(),
        Err(_) => Broadcast::empty(),
    };
    exec.finish(&pending);
    let (out, _) = res?;
    Ok(out)
}

/// Shared entry checks for the socket-side drivers ([`drive_lanes`]
/// and [`drive_reactor`]): the lanes must cover the replica universe
/// exactly, and the plan must be self-consistent. Returns the universe
/// size.
fn validate_remote_plan(
    lane_rids: &[&[usize]],
    have_sync: bool,
    plan: &DrivePlan,
    ctl: &mut DriveCtl,
) -> Result<usize> {
    let m = ctl.live.len();
    if m == 0 {
        bail!("drive_lanes: empty replica universe");
    }
    if !ctl.live.iter().any(|&l| l) {
        bail!("drive_lanes: no live replicas at start");
    }
    let mut owner = vec![false; m];
    for rids in lane_rids {
        if rids.is_empty() {
            bail!("drive_lanes: a lane owns no replicas");
        }
        for &r in *rids {
            if r >= m {
                bail!("drive_lanes: replica {r} is outside the universe of {m}");
            }
            if owner[r] {
                bail!("drive_lanes: replica {r} is owned by two lanes");
            }
            owner[r] = true;
        }
    }
    if let Some(r) = owner.iter().position(|&o| !o) {
        bail!("drive_lanes: replica {r} is owned by no lane");
    }
    if plan.n_params == 0 {
        bail!("drive_lanes: n_params must be >= 1");
    }
    if plan.log_every == 0 {
        bail!("drive_lanes: log_every must be >= 1");
    }
    if plan.eval_every == Some(0) {
        bail!("drive_lanes: eval_every must be >= 1");
    }
    if have_sync && plan.sync_interval == 0 {
        bail!("drive_lanes: sync_interval must be >= 1");
    }
    if plan.overlap_tau > 0 && (!have_sync || plan.overlap_tau >= plan.sync_interval) {
        bail!(
            "drive_lanes: overlap_tau ({}) needs an outer sync and must stay below \
             the sync interval (one sync in flight at a time)",
            plan.overlap_tau
        );
    }
    if ctl.start_step >= plan.total_steps {
        bail!(
            "drive_lanes: start_step ({}) must be below total_steps ({})",
            ctl.start_step,
            plan.total_steps
        );
    }
    if !ctl.events.is_empty() && !have_sync {
        bail!("drive_lanes: fault events without an outer sync");
    }
    if ctl.residuals.len() != m {
        ctl.residuals = vec![Vec::new(); m];
    }
    Ok(m)
}

/// The reactor-backed segment executor: every TCP lane is one socket
/// inside a single [`LaneReactor`] poll loop, so dispatch fans a
/// once-serialized command onto every lane, collect drains reports as
/// lanes produce them (heartbeats consumed in-loop, patience clocks
/// ticking), and a lossy broadcast streams onto the wire while it
/// encodes. One coordinator thread, however many workers.
struct ReactorExec<'r> {
    reactor: &'r mut LaneReactor,
    m: usize,
}

impl ReactorExec<'_> {
    /// Ship the final broadcast to every surviving lane (errors
    /// ignored — a lane dead at shutdown already crashed out).
    fn finish(&mut self, broadcast: &Broadcast) {
        self.reactor.send_finish(broadcast);
    }

    /// Re-index collected reports by replica id and backfill dead
    /// lanes' replicas as segment-dead (shared by the one-shot and
    /// streamed collects — the reduction order downstream is fixed
    /// either way).
    fn finish_collect(&mut self, reports: Vec<WorkerReport>) -> Result<SegmentData> {
        let mut losses: Vec<Vec<f64>> = vec![Vec::new(); self.m];
        let mut payloads: Vec<Option<SyncPayload>> = (0..self.m).map(|_| None).collect();
        for report in reports {
            for (rid, l, p) in report.reps {
                if rid >= self.m {
                    bail!("drive: worker reported unknown replica {rid}");
                }
                losses[rid] = l;
                payloads[rid] = Some(p);
            }
        }
        // replicas on dead lanes (now or earlier) report nothing:
        // segment-dead, exactly how a frozen replica looks — the
        // coordinator flips their membership via take_lost
        for r in self.reactor.dead_rids() {
            payloads[r].get_or_insert(SyncPayload::Skipped);
        }
        let mut out = Vec::with_capacity(self.m);
        for (r, p) in payloads.into_iter().enumerate() {
            out.push(p.ok_or_else(|| anyhow!("replica {r}: missing segment payload"))?);
        }
        Ok((losses, out))
    }
}

impl SegmentExec for ReactorExec<'_> {
    fn dispatch(
        &mut self,
        from: usize,
        to: usize,
        broadcast: &Broadcast,
        payload: &PayloadSpec,
        churn: &SegmentChurn,
    ) -> Result<()> {
        let cmd = Cmd::Run {
            from,
            to,
            broadcast: broadcast.clone(),
            payload: payload.clone(),
            churn: churn.clone(),
        };
        self.reactor.send_cmd(&cmd)
    }

    fn collect(&mut self, _from: usize, _to: usize) -> Result<SegmentData> {
        let reports = self.reactor.collect_reports()?;
        self.finish_collect(reports)
    }

    fn stream_up(&self) -> bool {
        true
    }

    fn collect_streamed(
        &mut self,
        _from: usize,
        _to: usize,
        sync_index: u64,
        frag: Option<usize>,
        sink: &mut dyn FnMut(usize, usize, WireSlice) -> Result<()>,
    ) -> Result<SegmentData> {
        let reports = self
            .reactor
            .collect_reports_streamed(sync_index, frag, sink)?;
        self.finish_collect(reports)
    }

    fn recycle_wires(&mut self, bufs: Vec<WireBuf>) {
        self.reactor.recycle(bufs);
    }

    fn stream_down(&self) -> bool {
        true
    }

    fn bcast_begin(
        &mut self,
        frag: Option<usize>,
        sync_index: u64,
        payload_len: u64,
    ) -> Result<()> {
        self.reactor.bcast_begin(frag, sync_index, payload_len)
    }

    fn bcast_chunk(&mut self, chunk: &[u8]) -> Result<()> {
        self.reactor.bcast_chunk(chunk)
    }

    fn take_lost(&mut self) -> Vec<usize> {
        self.reactor.take_lost()
    }
}

/// Drive a run over a [`LaneReactor`] — the multiplexed successor of
/// [`drive_lanes`]'s thread-per-lane TCP path. Semantics are
/// identical (lane deaths become journaled `Crash` membership,
/// worker-reported engine errors fail the run, the final broadcast
/// ships as `Finish`), but the coordinator costs one poll loop instead
/// of one reader thread per worker, and lossy broadcasts stream onto
/// the lanes while they encode. On return the reactor's heartbeat
/// traffic has been folded into the sync engine's control-bytes
/// bucket (never the framed totals — those stay transport-invariant).
pub fn drive_reactor<E: InnerEngine>(
    engine: &E,
    reactor: &mut LaneReactor,
    mut sync: Option<&mut OuterSync>,
    plan: &DrivePlan,
    ctl: &mut DriveCtl,
) -> Result<DriveOutcome> {
    let rids = reactor.lane_rids();
    let rids: Vec<&[usize]> = rids.iter().map(|r| &r[..]).collect();
    let m = validate_remote_plan(&rids, sync.is_some(), plan, ctl)?;
    let res = {
        let mut exec = ReactorExec { reactor, m };
        let res = coordinate(engine, &mut exec, sync.as_deref_mut(), plan, m, ctl);
        let pending = match &res {
            Ok((_, p)) => p.clone(),
            Err(_) => Broadcast::empty(),
        };
        exec.finish(&pending);
        res
    };
    if let Some(bus) = sync.as_deref_mut() {
        bus.add_control_bytes(reactor.take_control_bytes());
    }
    let (out, _) = res?;
    Ok(out)
}

/// Compile-time pin: everything that crosses a worker-channel is Send.
#[allow(dead_code)]
fn _assert_send() {
    fn ok<T: Send>() {}
    ok::<ReplicaState>();
    ok::<WorkerComm>();
    ok::<ReplicaComm>();
    ok::<CommLink>();
    ok::<Broadcast>();
    ok::<SyncPayload>();
    ok::<PayloadSpec>();
    ok::<SegmentChurn>();
    ok::<OwnedReplica>();
    ok::<Cmd>();
    ok::<WorkerReport>();
    ok::<Result<WorkerReport>>();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(total: usize) -> DrivePlan {
        DrivePlan {
            total_steps: total,
            sync_interval: usize::MAX,
            fragments: 1,
            n_params: 1,
            eval_every: None,
            log_every: 1000,
            workers: 1,
            overlap_tau: 0,
        }
    }

    #[test]
    fn boundaries_follow_sync_cadence() {
        let mut p = plan(20);
        p.sync_interval = 6;
        assert_eq!(next_boundary(0, &p, true, None), 6);
        assert_eq!(next_boundary(6, &p, true, None), 12);
        assert_eq!(next_boundary(18, &p, true, None), 20); // clipped to T
        // DP with eval cadence
        let mut q = plan(10);
        q.eval_every = Some(4);
        assert_eq!(next_boundary(0, &q, false, None), 4);
        assert_eq!(next_boundary(8, &q, false, None), 10);
        // DP without evals: one segment
        assert_eq!(next_boundary(0, &plan(10), false, None), 10);
        // H larger than T never overflows
        let mut r = plan(7);
        r.sync_interval = usize::MAX;
        assert_eq!(next_boundary(0, &r, true, None), 7);
    }

    #[test]
    fn boundaries_include_pending_merge_points() {
        // a sync in flight splits the segment at its merge point
        let mut p = plan(20);
        p.sync_interval = 6;
        assert_eq!(next_boundary(6, &p, true, Some(8)), 8, "merge before next send");
        assert_eq!(next_boundary(8, &p, true, None), 12, "after the merge");
        // merge clamped to the end of training
        assert_eq!(next_boundary(18, &p, true, Some(20)), 20);
        // merges never matter for Data-Parallel
        let mut q = plan(10);
        q.eval_every = Some(4);
        assert_eq!(next_boundary(0, &q, false, None), 4);
    }

    #[test]
    fn due_fragments_round_robin_with_final_flush() {
        let mut p = plan(20);
        p.sync_interval = 5;
        p.fragments = 2;
        assert_eq!(due_fragment(5, &p), Some(0));
        assert_eq!(due_fragment(10, &p), Some(1));
        assert_eq!(due_fragment(15, &p), Some(0));
        assert_eq!(due_fragment(20, &p), None, "final boundary is a full flush");
        p.fragments = 1;
        assert_eq!(due_fragment(5, &p), None, "vanilla DiLoCo always full");
    }

    /// A scripted lane for the readiness-collection tests: `try_recv`
    /// stalls for `stall` polls before yielding the report, and every
    /// consumed report appends its lane id to the shared order log.
    struct ScriptedLane {
        id: usize,
        stall: usize,
        report: Option<WorkerReport>,
        order: Arc<std::sync::Mutex<Vec<usize>>>,
        pollable: bool,
    }

    impl ScriptedLane {
        fn try_take(&mut self) -> Result<Option<Result<WorkerReport>>> {
            if self.stall > 0 {
                self.stall -= 1;
                return Ok(None);
            }
            match self.report.take() {
                Some(r) => {
                    self.order.lock().unwrap().push(self.id);
                    Ok(Some(Ok(r)))
                }
                None => Ok(None),
            }
        }
    }

    impl Lane for ScriptedLane {
        fn send(&mut self, _cmd: Cmd) -> Result<()> {
            Ok(())
        }
        fn recv(&mut self) -> Result<Result<WorkerReport>> {
            loop {
                if let Some(r) = self.try_take()? {
                    return Ok(r);
                }
            }
        }
        fn try_recv(&mut self) -> Result<Option<Result<WorkerReport>>> {
            self.try_take()
        }
        fn can_poll(&self) -> bool {
            self.pollable
        }
    }

    #[test]
    fn readiness_collect_bypasses_a_stalled_lane() {
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        let rep = |rid: usize| WorkerReport {
            reps: vec![(rid, Vec::new(), SyncPayload::Skipped)],
        };
        let mk = |id: usize, stall: usize| ScriptedLane {
            id,
            stall,
            report: Some(rep(id)),
            order: Arc::clone(&order),
            pollable: true,
        };
        // lane 0 sits on its report for many polls; lane 1 is ready —
        // its report must be consumed without waiting on lane 0
        let mut exec =
            LaneExec::new(vec![(mk(0, 64), vec![0]), (mk(1, 0), vec![1])], 2, true);
        let (losses, payloads) = exec.collect(0, 0).unwrap();
        assert_eq!((losses.len(), payloads.len()), (2, 2));
        assert_eq!(
            order.lock().unwrap().clone(),
            vec![1, 0],
            "the arrived report is consumed before the stalled lane yields"
        );

        // a lane that can't poll drops the whole collect to the
        // blocking path — consumption follows slot order again
        order.lock().unwrap().clear();
        let mut slow = mk(0, 64);
        slow.pollable = false;
        let mut exec = LaneExec::new(vec![(slow, vec![0]), (mk(1, 0), vec![1])], 2, true);
        exec.collect(0, 0).unwrap();
        assert_eq!(order.lock().unwrap().clone(), vec![0, 1]);
    }

    struct NoopEngine;
    impl InnerEngine for NoopEngine {
        fn inner_step(&self, _r: usize, _s: &mut ReplicaState, t: usize) -> Result<f64> {
            Ok(t as f64)
        }
        fn eval(&self, _p: &[Arc<xla::Literal>]) -> Result<f64> {
            Ok(0.0)
        }
    }

    #[test]
    fn rejects_degenerate_plans() {
        let mut none: Vec<ReplicaState> = Vec::new();
        assert!(drive(&NoopEngine, &mut none, None, &plan(5)).is_err());

        let mk = || ReplicaState {
            state: vec![Arc::new(xla::Literal::vec1(&[0.0f32]))],
            shard: TokenStream::new(crate::data::synthetic::CorpusSpec::default(), 0, 0),
        };
        let mut reps = vec![mk()];
        let mut p = plan(5);
        p.n_params = 2; // more sync leaves than state
        assert!(drive(&NoopEngine, &mut reps, None, &p).is_err());
        let mut p = plan(5);
        p.eval_every = Some(0);
        assert!(drive(&NoopEngine, &mut reps, None, &p).is_err());
    }

    #[test]
    fn overlap_guards_fail_loud() {
        let mk = || ReplicaState {
            state: vec![Arc::new(xla::Literal::vec1(&[0.0f32]))],
            shard: TokenStream::new(crate::data::synthetic::CorpusSpec::default(), 0, 0),
        };
        // τ without a sync engine: nothing exists to delay
        let mut reps = vec![mk()];
        let mut p = plan(6);
        p.overlap_tau = 1;
        let err = drive(&NoopEngine, &mut reps, None, &p).expect_err("tau without sync");
        assert!(format!("{err:#}").contains("overlap_tau"), "{err:#}");
        // τ >= sync interval: two syncs would be in flight at once
        let l = Arc::new(crate::runtime::FlatLayout::new(vec![vec![1]]));
        let host = vec![crate::runtime::HostTensor::from_vec(&[1], vec![0.0])];
        let lits = vec![Arc::new(xla::Literal::vec1(&[0.0f32]))];
        let mut sync = OuterSync::new(Arc::clone(&l), &host, lits, 0.5, 0.0, 1).unwrap();
        let mut p = plan(6);
        p.sync_interval = 3;
        p.overlap_tau = 3;
        let err = drive(&NoopEngine, &mut reps, Some(&mut sync), &p)
            .expect_err("tau >= interval");
        assert!(format!("{err:#}").contains("in flight"), "{err:#}");
    }

    #[test]
    fn step_losses_cover_every_step() {
        let mk = |id: u64| ReplicaState {
            state: vec![Arc::new(xla::Literal::vec1(&[0.0f32]))],
            shard: TokenStream::new(crate::data::synthetic::CorpusSpec::default(), 0, id),
        };
        for workers in [1usize, 3] {
            let mut reps = vec![mk(0), mk(1), mk(2)];
            let mut p = plan(9);
            p.workers = workers;
            let out = drive(&NoopEngine, &mut reps, None, &p).unwrap();
            assert_eq!(out.step_losses.len(), 9);
            // loss is t averaged over replicas = t
            assert_eq!(out.step_losses[4], 5.0);
            assert_eq!(reps.len(), 3, "replica ownership must return");
            assert_eq!(out.outer_syncs, 0);
            // no comm wire => no comm arenas, whatever the worker count
            assert_eq!(out.comm_arena_bytes, 0);
        }
    }
}
