//! Replica-parallel inner loop: the worker pool that makes Algorithm
//! 1's "parallel for over replicas" actually parallel.
//!
//! # Concurrency model
//!
//! Training runs as a sequence of **segments** — the step ranges
//! between consecutive outer-sync boundaries (plus eval boundaries for
//! Data-Parallel). Each worker thread *owns* a fixed subset of
//! replicas for the whole run (`replica r -> worker r % workers`): the
//! replica's literal-handle state, its `TokenStream` shard, and its
//! comm-side state (global snapshot + error-feedback residual, see
//! `crate::comm`) live inside the worker, so all RNG/data/residual
//! consumption is per-replica sequential no matter how segments are
//! scheduled. The coordinator sends each worker a `Run` command for
//! the segment; workers execute their replicas' H inner steps
//! concurrently and hand back per-step losses plus each replica's
//! **sync payload** over a channel: under a *lossy* wire codec
//! (`--outer-bits` below 32) that payload is the replica's encoded
//! wire contribution — error-compensated quantized outer deltas, the
//! quantize stage running on the worker, where the replica lives.
//! Uncompressed runs (the identity codec) and Data-Parallel keep the
//! zero-copy `Arc` literal handoff from PR 2 — no serialization on
//! the default path; `OuterSync::sync` counts the identity wire
//! bytes itself.
//!
//! The **outer step is the barrier**: the coordinator blocks until
//! every worker reports, assembles the payloads in replica-index
//! order, runs the zero-alloc flat-bus outer step
//! ([`OuterSync::sync_encoded`]), and broadcasts by attaching the
//! deduplicated global literals to the *next* `Run` command (workers
//! adopt them — state handles and comm snapshot both — before
//! stepping). Only the coordinator ever touches the flat arenas;
//! workers only ever read literals — ownership never crosses the
//! barrier in both directions at once.
//!
//! # Why determinism holds
//!
//! Bit-identical results for any worker count follow from three
//! invariants, each pinned by `tests/worker_pool.rs` and (per bit
//! width) `tests/comm_codec.rs`:
//!
//! 1. replica state, data shard, and comm residual are owned by
//!    exactly one worker and advance in step/sync order — scheduling
//!    cannot reorder a replica's own computation, and encode seeds
//!    derive from (run seed, sync index, replica), never the schedule;
//! 2. cross-replica reduction (the per-step mean loss and the outer
//!    gradient accumulation) happens on the coordinator in replica
//!    index order, identical to the sequential loop's summation order;
//! 3. evaluation reads immutable literal sets that only change at
//!    barriers, so its placement relative to worker execution is
//!    irrelevant.
//!
//! `workers == 1` (the default, and `--workers 1` on the CLI) runs the
//! whole schedule inline on the caller's thread with the classic
//! step-major/replica-minor loop — the sequential oracle the parallel
//! path is tested against.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::comm::{CommState, SyncEncoder};
use crate::coordinator::sync::OuterSync;
use crate::data::synthetic::TokenStream;

/// One replica as the pool owns it: params ++ m ++ v literal handles
/// (manifest leaf order; only the first `n_params` leaves take part in
/// outer syncs) plus the replica's private data shard.
pub struct ReplicaState {
    pub state: Vec<Arc<xla::Literal>>,
    pub shard: TokenStream,
}

impl ReplicaState {
    /// Apply a broadcast: adopt the shared literal for each synced
    /// leaf (every replica ends up pointing at the same upload).
    fn adopt(&mut self, adopt: &Adopt) {
        for (leaf, lit) in adopt {
            self.state[*leaf] = Arc::clone(lit);
        }
    }
}

/// The inner computation the pool schedules. Implementations must be
/// `Sync` (shared by reference across workers) and deterministic per
/// `(rep, replica state, t)` — the PJRT path satisfies both, and tests
/// substitute host-math engines.
pub trait InnerEngine: Sync {
    /// One inner optimizer step for replica `rep` at 1-based global
    /// step `t`; replaces `replica.state` handles and returns the
    /// replica's mean loss for the step.
    fn inner_step(&self, rep: usize, replica: &mut ReplicaState, t: usize) -> Result<f64>;

    /// Eval loss of a parameter literal set (first `n_params` leaves).
    fn eval(&self, params: &[Arc<xla::Literal>]) -> Result<f64>;

    /// Effective inner learning rate at step `t`, for log lines only
    /// (None when the engine has no schedule — e.g. test surrogates).
    fn inner_lr(&self, _t: usize) -> Option<f64> {
        None
    }
}

/// Schedule parameters for one training run.
#[derive(Debug, Clone)]
pub struct DrivePlan {
    pub total_steps: usize,
    /// Steps between outer-sync events (H, or H/P with streaming
    /// fragments). Ignored when no `OuterSync` is supplied.
    pub sync_interval: usize,
    /// Streaming fragment count P (1 = vanilla DiLoCo).
    pub fragments: usize,
    /// Number of parameter leaves (the prefix of `state` that syncs).
    pub n_params: usize,
    /// Evaluate every k steps (None = final only).
    pub eval_every: Option<usize>,
    pub log_every: usize,
    /// Worker threads for the inner loop; clamped to [1, M]. 1 =
    /// sequential oracle (no threads spawned).
    pub workers: usize,
}

/// Everything the drive loop measures (the caller owns final-eval and
/// metric assembly).
#[derive(Debug, Default)]
pub struct DriveOutcome {
    /// Mean loss across replicas for every step, in step order.
    pub step_losses: Vec<f64>,
    /// Sampled (step, loss) points (log_every cadence, as before).
    pub loss_curve: Vec<(usize, f64)>,
    /// Intermediate (step, eval loss) points (eval_every cadence).
    pub eval_curve: Vec<(usize, f64)>,
    pub outer_syncs: usize,
}

/// Broadcast payload: (leaf index, shared literal) pairs every replica
/// adopts before its next inner step.
type Adopt = Vec<(usize, Arc<xla::Literal>)>;

/// What the coordinator told the workers to produce at segment end.
#[derive(Debug, Clone)]
struct EncodeSpec {
    /// Streaming fragment due at the boundary (None = full sync).
    frag: Option<usize>,
    /// 0-based outer-sync index (stochastic-rounding seed component).
    sync_index: u64,
}

/// One replica's contribution at a segment boundary.
enum SyncPayload {
    /// Data-Parallel: current parameter literal handles (for the
    /// boundary eval; nothing crosses a wire).
    Params(Vec<Arc<xla::Literal>>),
    /// DiLoCo: the encoded wire contribution for the due fragment.
    Encoded(Vec<u8>),
}

/// Per-segment result: `losses[r]` / `payloads[r]` for replica r.
type SegmentData = (Vec<Vec<f64>>, Vec<SyncPayload>);

/// Run one training schedule over the replicas, parallelizing the
/// inner loop across `plan.workers` threads. On return `replicas`
/// holds the final states (broadcasts applied), whatever the worker
/// count; `sync`, when supplied, has performed every due outer step.
///
/// When `sync` carries a lossy codec, replicas must enter with state
/// equal to the sync'd global for the synced leaves (Algorithm 1
/// line 2 guarantees this) — the comm snapshot is captured here,
/// before the first inner step.
pub fn drive<E: InnerEngine>(
    engine: &E,
    replicas: &mut Vec<ReplicaState>,
    sync: Option<&mut OuterSync>,
    plan: &DrivePlan,
) -> Result<DriveOutcome> {
    let m = replicas.len();
    if m == 0 {
        bail!("drive: zero replicas");
    }
    if plan.n_params == 0 {
        bail!("drive: n_params must be >= 1");
    }
    if plan.log_every == 0 {
        bail!("drive: log_every must be >= 1");
    }
    if plan.eval_every == Some(0) {
        bail!("drive: eval_every must be >= 1");
    }
    if sync.is_some() && plan.sync_interval == 0 {
        bail!("drive: sync_interval must be >= 1");
    }
    for (r, rep) in replicas.iter().enumerate() {
        if rep.state.len() < plan.n_params {
            bail!(
                "drive: replica {r} has {} state leaves, need >= {}",
                rep.state.len(),
                plan.n_params
            );
        }
    }
    let workers = plan.workers.clamp(1, m);

    // Comm-side state: the shared encoder recipe plus one CommState
    // per replica (snapshot of the global + error-feedback residual),
    // captured before any step moves the state off the init. Identity
    // codecs take none of this: they keep the PR 2 zero-copy literal
    // handoff (OuterSync::sync counts their wire bytes itself), so the
    // encode detour — and its arenas — exist only for lossy codecs.
    let encoder: Option<SyncEncoder> = match sync.as_deref() {
        Some(s) if !s.codec().is_identity() => Some(s.encoder()),
        _ => None,
    };
    let mut comm: Vec<CommState> = (0..m).map(|_| CommState::default()).collect();
    if let Some(enc) = &encoder {
        for (rep, cm) in replicas.iter().zip(comm.iter_mut()) {
            enc.init_snapshot(cm, &rep.state)?;
        }
    }

    if workers == 1 {
        let mut exec = InlineExec {
            engine,
            replicas: &mut replicas[..],
            n_params: plan.n_params,
            encoder: encoder.as_ref(),
            comm: &mut comm,
        };
        let (outcome, pending) = coordinate(engine, &mut exec, sync, plan, m)?;
        // final broadcast (the full flush at t = total_steps)
        for rep in replicas.iter_mut() {
            rep.adopt(&pending);
        }
        return Ok(outcome);
    }

    let n_params = plan.n_params;
    std::thread::scope(|scope| -> Result<DriveOutcome> {
        // Partition ownership: replica r lives on worker r % workers
        // for the whole run (its TokenStream and comm residual advance
        // only there).
        let mut owned: Vec<Vec<(usize, ReplicaState, CommState)>> =
            (0..workers).map(|_| Vec::new()).collect();
        for (r, (rep, cm)) in replicas.drain(..).zip(comm).enumerate() {
            owned[r % workers].push((r, rep, cm));
        }
        let mut txs = Vec::with_capacity(workers);
        let mut rxs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for set in owned {
            let (cmd_tx, cmd_rx) = channel::<Cmd>();
            let (res_tx, res_rx) = channel::<Result<WorkerReport>>();
            txs.push(cmd_tx);
            rxs.push(res_rx);
            let enc = encoder.clone();
            handles.push(
                scope.spawn(move || worker_loop(engine, n_params, enc, set, cmd_rx, res_tx)),
            );
        }

        let mut exec = PoolExec { txs, rxs, m };
        let res = coordinate(engine, &mut exec, sync, plan, m);

        // Shut down and reclaim replica states whether or not the run
        // succeeded; workers apply the final broadcast before exiting.
        let pending = match &res {
            Ok((_, p)) => p.clone(),
            Err(_) => Vec::new(),
        };
        for tx in &exec.txs {
            let _ = tx.send(Cmd::Finish {
                adopt: pending.clone(),
            });
        }
        drop(exec); // closes the command channels
        let mut returned: Vec<(usize, ReplicaState)> = Vec::with_capacity(m);
        let mut panicked = false;
        for h in handles {
            match h.join() {
                Ok(set) => returned.extend(set),
                Err(_) => panicked = true,
            }
        }
        returned.sort_by_key(|(r, _)| *r);
        replicas.extend(returned.into_iter().map(|(_, rep)| rep));
        let (outcome, _) = res?;
        if panicked || replicas.len() != m {
            bail!("drive: a worker panicked; replica states were lost");
        }
        Ok(outcome)
    })
}

// ---- the coordinator loop (shared by inline and threaded paths) ------

/// Executes one segment of inner steps across all replicas and reports
/// per-replica per-step losses + boundary sync payloads.
trait SegmentExec {
    fn run_segment(
        &mut self,
        from: usize,
        to: usize,
        adopt: &Adopt,
        encode: Option<&EncodeSpec>,
    ) -> Result<SegmentData>;
}

/// End of the segment starting after `t0`: the next outer-sync
/// boundary (DiLoCo), the next eval point (Data-Parallel, whose eval
/// reads per-step replica state), or the end of training.
fn next_boundary(t0: usize, plan: &DrivePlan, diloco: bool) -> usize {
    let mut b = plan.total_steps;
    if diloco {
        b = b.min((t0 / plan.sync_interval + 1).saturating_mul(plan.sync_interval));
    } else if let Some(k) = plan.eval_every {
        b = b.min((t0 / k + 1).saturating_mul(k));
    }
    b
}

/// The streaming fragment due at boundary `t1` (None = full sync —
/// vanilla DiLoCo, or the final full flush so nothing stays stale).
fn due_fragment(t1: usize, plan: &DrivePlan) -> Option<usize> {
    if plan.fragments > 1 && t1 != plan.total_steps {
        Some(((t1 / plan.sync_interval).wrapping_sub(1)) % plan.fragments)
    } else {
        None
    }
}

fn coordinate<E: InnerEngine, X: SegmentExec>(
    engine: &E,
    exec: &mut X,
    mut sync: Option<&mut OuterSync>,
    plan: &DrivePlan,
    m: usize,
) -> Result<(DriveOutcome, Adopt)> {
    let diloco = sync.is_some();
    // Lossy codecs route through the encoded wire; identity runs keep
    // the zero-copy literal handoff into OuterSync::sync.
    let wire_codec = sync
        .as_deref()
        .map(|b| !b.codec().is_identity())
        .unwrap_or(false);
    let mut out = DriveOutcome::default();
    let mut pending: Adopt = Vec::new();
    let mut t0 = 0usize;
    while t0 < plan.total_steps {
        let t1 = next_boundary(t0, plan, diloco);
        // A DiLoCo boundary is always a sync boundary, so the workers
        // know before stepping what they will encode at segment end.
        let frag = if diloco { due_fragment(t1, plan) } else { None };
        let spec = if wire_codec {
            Some(EncodeSpec {
                frag,
                sync_index: out.outer_syncs as u64,
            })
        } else {
            None
        };
        let (losses, payloads) = exec.run_segment(t0, t1, &pending, spec.as_ref())?;
        pending.clear();

        // Per-step mean loss, summed in replica index order — the same
        // order as the sequential loop, so results are bit-identical.
        for t in t0 + 1..=t1 {
            let mut step_loss = 0.0f64;
            for rep_losses in &losses {
                step_loss += rep_losses[t - t0 - 1] / m as f64;
            }
            out.step_losses.push(step_loss);
            if t % plan.log_every == 0 || t == 1 || t == plan.total_steps {
                out.loss_curve.push((t, step_loss));
                match engine.inner_lr(t) {
                    Some(lr) => log::info!(
                        "  step {t}/{} loss={step_loss:.4} lr={lr:.2e}",
                        plan.total_steps
                    ),
                    None => log::info!("  step {t}/{} loss={step_loss:.4}", plan.total_steps),
                }
            }
        }

        // DiLoCo evals strictly inside the segment read the global
        // model from the *previous* sync — by construction no fresher
        // global exists at those steps, so evaluating at the barrier
        // reproduces the sequential schedule exactly.
        if let (Some(bus), Some(k)) = (sync.as_deref(), plan.eval_every) {
            for t in t0 + 1..t1 {
                if t % k == 0 && t != plan.total_steps {
                    let e = engine.eval(bus.global_literals())?;
                    out.eval_curve.push((t, e));
                    log::info!("  step {t} eval_loss={e:.4}");
                }
            }
        }

        // Outer synchronization at the boundary (Algorithm 1 lines
        // 8-12): barrier already passed, payloads in hand — encoded
        // wire frames under a lossy codec, literal handles otherwise.
        if let Some(bus) = sync.as_deref_mut() {
            if wire_codec {
                let frames: Vec<&[u8]> = payloads
                    .iter()
                    .map(|p| match p {
                        SyncPayload::Encoded(bytes) => Ok(&bytes[..]),
                        SyncPayload::Params(_) => {
                            Err(anyhow!("drive: wire-codec segment returned unencoded payload"))
                        }
                    })
                    .collect::<Result<_>>()?;
                bus.sync_encoded(&frames, frag)?;
            } else {
                let parts: Vec<&[Arc<xla::Literal>]> = payloads
                    .iter()
                    .map(|p| match p {
                        SyncPayload::Params(v) => Ok(&v[..]),
                        SyncPayload::Encoded(_) => {
                            Err(anyhow!("drive: identity segment returned encoded payload"))
                        }
                    })
                    .collect::<Result<_>>()?;
                bus.sync(&parts, frag)?;
            }
            out.outer_syncs += 1;
            // Broadcast = the next segment's adopt list: every
            // replica gets the same freshly-uploaded literal per
            // synced leaf (N uploads, never M×N).
            let lits = bus.global_literals();
            pending = bus
                .synced_leaves(frag)
                .map(|leaf| (leaf, Arc::clone(&lits[leaf])))
                .collect();
        }

        // Eval due exactly at the boundary sees the post-sync model
        // (DiLoCo) or the boundary-step replica state (Data-Parallel).
        if let Some(k) = plan.eval_every {
            if t1 % k == 0 && t1 != plan.total_steps {
                let e = match sync.as_deref() {
                    Some(bus) => engine.eval(bus.global_literals())?,
                    None => match &payloads[0] {
                        SyncPayload::Params(p) => engine.eval(p)?,
                        SyncPayload::Encoded(_) => {
                            bail!("drive: Data-Parallel segment returned encoded payload")
                        }
                    },
                };
                out.eval_curve.push((t1, e));
                log::info!("  step {t1} eval_loss={e:.4}");
            }
        }
        t0 = t1;
    }
    Ok((out, pending))
}

// ---- sequential oracle ------------------------------------------------

struct InlineExec<'a, E: InnerEngine> {
    engine: &'a E,
    replicas: &'a mut [ReplicaState],
    n_params: usize,
    encoder: Option<&'a SyncEncoder>,
    comm: &'a mut Vec<CommState>,
}

impl<E: InnerEngine> SegmentExec for InlineExec<'_, E> {
    fn run_segment(
        &mut self,
        from: usize,
        to: usize,
        adopt: &Adopt,
        encode: Option<&EncodeSpec>,
    ) -> Result<SegmentData> {
        for (rep, cm) in self.replicas.iter_mut().zip(self.comm.iter_mut()) {
            rep.adopt(adopt);
            if let Some(enc) = self.encoder {
                enc.adopt(cm, adopt)?;
            }
        }
        let m = self.replicas.len();
        let mut losses = vec![Vec::with_capacity(to - from); m];
        // the classic sequential shape: step-major, replica-minor
        for t in from + 1..=to {
            for (r, rep) in self.replicas.iter_mut().enumerate() {
                losses[r].push(self.engine.inner_step(r, rep, t)?);
            }
        }
        let payloads: Vec<SyncPayload> = match encode {
            Some(spec) => {
                let enc = self.encoder.ok_or_else(|| {
                    anyhow!("drive: encode requested without a sync encoder")
                })?;
                self.replicas
                    .iter()
                    .zip(self.comm.iter_mut())
                    .enumerate()
                    .map(|(r, (rep, cm))| {
                        Ok(SyncPayload::Encoded(enc.encode_replica(
                            r,
                            &rep.state,
                            cm,
                            spec.frag,
                            spec.sync_index,
                        )?))
                    })
                    .collect::<Result<_>>()?
            }
            None => self
                .replicas
                .iter()
                .map(|r| SyncPayload::Params(r.state[..self.n_params].to_vec()))
                .collect(),
        };
        Ok((losses, payloads))
    }
}

// ---- worker pool ------------------------------------------------------

enum Cmd {
    /// Adopt the broadcast literals, run steps (from, to], then build
    /// the boundary payload (encoded when `encode` is set).
    Run {
        from: usize,
        to: usize,
        adopt: Adopt,
        encode: Option<EncodeSpec>,
    },
    /// Adopt the final broadcast and exit, returning replica ownership.
    Finish { adopt: Adopt },
}

struct WorkerReport {
    /// (replica id, per-step losses, boundary sync payload).
    reps: Vec<(usize, Vec<f64>, SyncPayload)>,
}

fn worker_loop<E: InnerEngine>(
    engine: &E,
    n_params: usize,
    encoder: Option<SyncEncoder>,
    mut owned: Vec<(usize, ReplicaState, CommState)>,
    rx: Receiver<Cmd>,
    tx: Sender<Result<WorkerReport>>,
) -> Vec<(usize, ReplicaState)> {
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Run {
                from,
                to,
                adopt,
                encode,
            } => {
                let mut report = WorkerReport {
                    reps: Vec::with_capacity(owned.len()),
                };
                let mut err: Option<anyhow::Error> = None;
                'replicas: for (rid, rep, cm) in owned.iter_mut() {
                    rep.adopt(&adopt);
                    if let Some(enc) = &encoder {
                        if let Err(e) = enc.adopt(cm, &adopt) {
                            err = Some(e);
                            break 'replicas;
                        }
                    }
                    let mut losses = Vec::with_capacity(to - from);
                    for t in from + 1..=to {
                        match engine.inner_step(*rid, rep, t) {
                            Ok(l) => losses.push(l),
                            Err(e) => {
                                err = Some(e);
                                break 'replicas;
                            }
                        }
                    }
                    let payload = match (&encode, &encoder) {
                        (Some(spec), Some(enc)) => {
                            match enc.encode_replica(*rid, &rep.state, cm, spec.frag, spec.sync_index)
                            {
                                Ok(bytes) => SyncPayload::Encoded(bytes),
                                Err(e) => {
                                    err = Some(e);
                                    break 'replicas;
                                }
                            }
                        }
                        (Some(_), None) => {
                            err = Some(anyhow!("worker: encode requested without an encoder"));
                            break 'replicas;
                        }
                        (None, _) => SyncPayload::Params(rep.state[..n_params].to_vec()),
                    };
                    report.reps.push((*rid, losses, payload));
                }
                let msg = match err {
                    Some(e) => Err(e),
                    None => Ok(report),
                };
                let failed = msg.is_err();
                if tx.send(msg).is_err() || failed {
                    break;
                }
            }
            Cmd::Finish { adopt } => {
                for (_, rep, _) in owned.iter_mut() {
                    rep.adopt(&adopt);
                }
                break;
            }
        }
    }
    owned.into_iter().map(|(r, rep, _)| (r, rep)).collect()
}

struct PoolExec {
    txs: Vec<Sender<Cmd>>,
    rxs: Vec<Receiver<Result<WorkerReport>>>,
    m: usize,
}

impl SegmentExec for PoolExec {
    fn run_segment(
        &mut self,
        from: usize,
        to: usize,
        adopt: &Adopt,
        encode: Option<&EncodeSpec>,
    ) -> Result<SegmentData> {
        for tx in &self.txs {
            tx.send(Cmd::Run {
                from,
                to,
                adopt: adopt.clone(),
                encode: encode.cloned(),
            })
            .map_err(|_| anyhow!("worker hung up before segment ({from}, {to}]"))?;
        }
        let mut losses: Vec<Vec<f64>> = vec![Vec::new(); self.m];
        let mut payloads: Vec<Option<SyncPayload>> = (0..self.m).map(|_| None).collect();
        for (w, rx) in self.rxs.iter().enumerate() {
            let report = rx
                .recv()
                .map_err(|_| anyhow!("worker {w} died during segment ({from}, {to}]"))??;
            for (rid, l, p) in report.reps {
                losses[rid] = l;
                payloads[rid] = Some(p);
            }
        }
        let mut out = Vec::with_capacity(self.m);
        for (r, p) in payloads.into_iter().enumerate() {
            if losses[r].len() != to - from {
                bail!(
                    "replica {r}: incomplete segment report ({} of {} steps)",
                    losses[r].len(),
                    to - from
                );
            }
            out.push(p.ok_or_else(|| anyhow!("replica {r}: missing segment payload"))?);
        }
        Ok((losses, out))
    }
}

/// Compile-time pin: everything that crosses a worker-channel is Send.
#[allow(dead_code)]
fn _assert_send() {
    fn ok<T: Send>() {}
    ok::<ReplicaState>();
    ok::<CommState>();
    ok::<SyncEncoder>();
    ok::<SyncPayload>();
    ok::<Cmd>();
    ok::<WorkerReport>();
    ok::<Result<WorkerReport>>();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(total: usize) -> DrivePlan {
        DrivePlan {
            total_steps: total,
            sync_interval: usize::MAX,
            fragments: 1,
            n_params: 1,
            eval_every: None,
            log_every: 1000,
            workers: 1,
        }
    }

    #[test]
    fn boundaries_follow_sync_cadence() {
        let mut p = plan(20);
        p.sync_interval = 6;
        assert_eq!(next_boundary(0, &p, true), 6);
        assert_eq!(next_boundary(6, &p, true), 12);
        assert_eq!(next_boundary(18, &p, true), 20); // clipped to T
        // DP with eval cadence
        let mut q = plan(10);
        q.eval_every = Some(4);
        assert_eq!(next_boundary(0, &q, false), 4);
        assert_eq!(next_boundary(8, &q, false), 10);
        // DP without evals: one segment
        assert_eq!(next_boundary(0, &plan(10), false), 10);
        // H larger than T never overflows
        let mut r = plan(7);
        r.sync_interval = usize::MAX;
        assert_eq!(next_boundary(0, &r, true), 7);
    }

    #[test]
    fn due_fragments_round_robin_with_final_flush() {
        let mut p = plan(20);
        p.sync_interval = 5;
        p.fragments = 2;
        assert_eq!(due_fragment(5, &p), Some(0));
        assert_eq!(due_fragment(10, &p), Some(1));
        assert_eq!(due_fragment(15, &p), Some(0));
        assert_eq!(due_fragment(20, &p), None, "final boundary is a full flush");
        p.fragments = 1;
        assert_eq!(due_fragment(5, &p), None, "vanilla DiLoCo always full");
    }

    struct NoopEngine;
    impl InnerEngine for NoopEngine {
        fn inner_step(&self, _r: usize, _s: &mut ReplicaState, t: usize) -> Result<f64> {
            Ok(t as f64)
        }
        fn eval(&self, _p: &[Arc<xla::Literal>]) -> Result<f64> {
            Ok(0.0)
        }
    }

    #[test]
    fn rejects_degenerate_plans() {
        let mut none: Vec<ReplicaState> = Vec::new();
        assert!(drive(&NoopEngine, &mut none, None, &plan(5)).is_err());

        let mk = || ReplicaState {
            state: vec![Arc::new(xla::Literal::vec1(&[0.0f32]))],
            shard: TokenStream::new(crate::data::synthetic::CorpusSpec::default(), 0, 0),
        };
        let mut reps = vec![mk()];
        let mut p = plan(5);
        p.n_params = 2; // more sync leaves than state
        assert!(drive(&NoopEngine, &mut reps, None, &p).is_err());
        let mut p = plan(5);
        p.eval_every = Some(0);
        assert!(drive(&NoopEngine, &mut reps, None, &p).is_err());
    }

    #[test]
    fn step_losses_cover_every_step() {
        let mk = |id: u64| ReplicaState {
            state: vec![Arc::new(xla::Literal::vec1(&[0.0f32]))],
            shard: TokenStream::new(crate::data::synthetic::CorpusSpec::default(), 0, id),
        };
        for workers in [1usize, 3] {
            let mut reps = vec![mk(0), mk(1), mk(2)];
            let mut p = plan(9);
            p.workers = workers;
            let out = drive(&NoopEngine, &mut reps, None, &p).unwrap();
            assert_eq!(out.step_losses.len(), 9);
            // loss is t averaged over replicas = t
            assert_eq!(out.step_losses[4], 5.0);
            assert_eq!(reps.len(), 3, "replica ownership must return");
            assert_eq!(out.outer_syncs, 0);
        }
    }
}
