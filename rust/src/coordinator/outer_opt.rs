//! Outer optimizer: SGD with Nesterov momentum over outer gradients
//! (paper Algorithm 1, line 11; Douillard et al. 2023's recommended
//! OuterOpt), vectorized over the flat parameter bus. The outer
//! gradient is the parameter-space delta
//! Delta = theta_global - mean_m theta_m; this module applies
//!
//!   v   <- mu * v + Delta
//!   theta <- theta - eta * (Delta + mu * v)
//!
//! (the standard "Nesterov-as-lookahead-momentum" form, matching
//! optax/PyTorch `nesterov=True`). With eta=1, mu=0 the update reduces
//! to theta <- mean_m theta_m, i.e. plain parameter averaging
//! (FedAvg/Local SGD) — a property the tests pin down.
//!
//! State and scratch are contiguous [`FlatParams`]-shaped arenas
//! allocated once and reused every round; the update itself is a
//! branch-free element-wise loop over offset ranges that the compiler
//! auto-vectorizes. The element-wise operation order is identical to
//! the retired per-leaf scalar implementation, so results are
//! bit-for-bit unchanged — `tests/flat_bus.rs` keeps that scalar
//! version alive as the oracle and pins the equivalence.

use std::ops::Range;

use crate::runtime::{FlatLayout, FlatParams};
use crate::util::par::{self, Piece};

#[derive(Debug, Clone)]
pub struct OuterOpt {
    pub lr: f64,
    pub momentum: f64,
    /// Velocity arena (same layout as the params); sized lazily on the
    /// first step and reused — streaming fragments each own their
    /// slices of it, untouched ranges keep their momentum as-is.
    velocity: Vec<f32>,
}

impl OuterOpt {
    pub fn new(lr: f64, momentum: f64) -> OuterOpt {
        OuterOpt {
            lr,
            momentum,
            velocity: Vec::new(),
        }
    }

    /// Apply one outer step in place on the whole global arena.
    /// `outer_grad` is Delta (already averaged across replicas).
    pub fn step(&mut self, global: &mut FlatParams, outer_grad: &FlatParams) {
        let ranges = global.layout().full_range();
        self.step_ranges(global, outer_grad, &ranges);
    }

    /// Streaming DiLoCo (Douillard et al. 2025; paper section 8 /
    /// Appendix A): apply the outer step only to the element ranges of
    /// the due fragment (see [`FlatLayout::fragment_ranges`]). Elements
    /// outside `ranges` — params and velocity both — are left exactly
    /// as-is.
    pub fn step_ranges(
        &mut self,
        global: &mut FlatParams,
        outer_grad: &FlatParams,
        ranges: &[Range<usize>],
    ) {
        let total = global.layout().total();
        assert_eq!(total, outer_grad.layout().total());
        if self.velocity.len() != total {
            assert!(self.velocity.is_empty(), "velocity arena size drifted");
            self.velocity = vec![0.0; total];
        }
        let mu = self.momentum as f32;
        let lr = self.lr as f32;
        let theta = global.data_mut();
        let grad = outer_grad.data();
        for r in ranges {
            nesterov_chunk(
                &mut theta[r.clone()],
                &grad[r.clone()],
                &mut self.velocity[r.clone()],
                lr,
                mu,
            );
        }
    }

    /// [`OuterOpt::step_ranges`] over a pre-computed shard partition
    /// (`util::par::shard_ranges` of the due ranges), one scoped
    /// thread per shard. Each element's Nesterov update runs exactly
    /// once on exactly one thread — the kernel is element-wise, so
    /// the result is bit-identical to the sequential step at any
    /// shard count.
    pub fn step_pieces(
        &mut self,
        global: &mut FlatParams,
        outer_grad: &FlatParams,
        shards: &[Vec<Piece>],
    ) {
        let total = global.layout().total();
        assert_eq!(total, outer_grad.layout().total());
        if self.velocity.len() != total {
            assert!(self.velocity.is_empty(), "velocity arena size drifted");
            self.velocity = vec![0.0; total];
        }
        let mu = self.momentum as f32;
        let lr = self.lr as f32;
        let thetas = par::split_pieces(global.data_mut(), shards);
        let vels = par::split_pieces(&mut self.velocity, shards);
        let grad = outer_grad.data();
        let items: Vec<_> = shards.iter().zip(thetas).zip(vels).collect();
        par::map_shards(items, |_, ((pieces, thetas), vels)| {
            for ((p, theta), vel) in pieces.iter().zip(thetas).zip(vels) {
                nesterov_chunk(theta, &grad[p.range.clone()], vel, lr, mu);
            }
        });
    }

    /// The velocity arena (empty until the first step).
    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Restore the velocity arena from a checkpoint. Only legal before
    /// the first step of this instance (a sized arena would mean state
    /// is being overwritten mid-run); an empty restore is a no-op — an
    /// optimizer that never stepped has nothing to carry.
    pub fn restore_velocity(&mut self, velocity: Vec<f32>) {
        assert!(
            self.velocity.is_empty(),
            "restore_velocity after the optimizer has stepped"
        );
        self.velocity = velocity;
    }
}

/// The vectorizable inner kernel: element-wise, no cross-lane
/// dependencies, identical operation order to the scalar oracle.
#[inline]
fn nesterov_chunk(theta: &mut [f32], grad: &[f32], vel: &mut [f32], lr: f32, mu: f32) {
    assert_eq!(theta.len(), grad.len());
    assert_eq!(theta.len(), vel.len());
    for ((t, g), v) in theta.iter_mut().zip(grad).zip(vel.iter_mut()) {
        *v = mu * *v + *g;
        *t -= lr * (*g + mu * *v);
    }
}

/// acc += x, element-wise (one replica's contribution to the mean).
#[inline]
pub fn acc_add(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len());
    for (a, b) in acc.iter_mut().zip(x) {
        *a += *b;
    }
}

/// Finish the outer gradient in place: acc_i <- global_i - acc_i / m
/// (acc arrives holding sum_m theta_m; leaves holding Delta).
#[inline]
pub fn acc_finish(acc: &mut [f32], global: &[f32], m: f32) {
    assert_eq!(acc.len(), global.len());
    for (a, g) in acc.iter_mut().zip(global) {
        *a = *g - *a / m;
    }
}

/// Finish a delta-coded outer gradient in place: acc_i <- acc_i / m
/// (acc arrives holding sum_m dq(delta_m), which already IS the outer
/// gradient up to the mean — the lossy-codec counterpart of
/// [`acc_finish`]).
#[inline]
pub fn acc_scale(acc: &mut [f32], m: f32) {
    for a in acc.iter_mut() {
        *a /= m;
    }
}

/// Compute the outer gradient Delta = global - mean(replicas)
/// (Algorithm 1 lines 9-10: Delta_m = theta^(t-H) - theta_m, averaged).
/// Allocates a fresh arena — convenience for tests and benches; the
/// coordinator's hot path accumulates into a reused arena via
/// [`acc_add`]/[`acc_finish`] instead.
pub fn outer_gradient(global: &FlatParams, replicas: &[FlatParams]) -> FlatParams {
    assert!(!replicas.is_empty());
    let mut acc = FlatParams::zeros(global.layout());
    for r in replicas {
        acc_add(acc.data_mut(), r.data());
    }
    let m = replicas.len() as f32;
    acc_finish(acc.data_mut(), global.data(), m);
    acc
}

/// The retired per-leaf scalar implementation, frozen verbatim.
///
/// This is the reference the flat bus is pinned against — the oracle
/// in `tests/flat_bus.rs` (bit-for-bit equivalence) and the baseline
/// in `benches/bench_hot_path.rs` (the ≥2× speedup measurement). ONE
/// canonical copy lives here so the two cannot drift. Do NOT optimize
/// or reorder it: its element-wise operation order IS the contract.
#[doc(hidden)]
pub mod scalar_ref {
    /// Delta = global - mean(replicas), one fresh `Vec` per leaf (the
    /// allocation profile the flat bus eliminated).
    pub fn outer_gradient(global: &[Vec<f32>], replicas: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
        assert!(!replicas.is_empty());
        let m = replicas.len() as f32;
        global
            .iter()
            .enumerate()
            .map(|(leaf, g)| {
                let mut out = vec![0.0f32; g.len()];
                for r in replicas {
                    let rt = &r[leaf];
                    assert_eq!(rt.len(), g.len());
                    for i in 0..out.len() {
                        out[i] += rt[i];
                    }
                }
                for i in 0..out.len() {
                    out[i] = g[i] - out[i] / m;
                }
                out
            })
            .collect()
    }

    pub struct ScalarOuterOpt {
        pub lr: f32,
        pub mu: f32,
        velocity: Option<Vec<Vec<f32>>>,
    }

    impl ScalarOuterOpt {
        pub fn new(lr: f32, mu: f32) -> ScalarOuterOpt {
            ScalarOuterOpt {
                lr,
                mu,
                velocity: None,
            }
        }

        /// Nesterov step on the leaves selected by `in_fragment`
        /// (per-leaf closure — the selection mechanism the flat bus
        /// replaced with offset ranges).
        pub fn step_subset(
            &mut self,
            global: &mut [Vec<f32>],
            grad: &[Vec<f32>],
            in_fragment: impl Fn(usize) -> bool,
        ) {
            assert_eq!(global.len(), grad.len());
            let velocity = self
                .velocity
                .get_or_insert_with(|| grad.iter().map(|g| vec![0.0f32; g.len()]).collect());
            for (leaf, ((theta, g), v)) in
                global.iter_mut().zip(grad).zip(velocity.iter_mut()).enumerate()
            {
                if !in_fragment(leaf) {
                    continue;
                }
                for i in 0..theta.len() {
                    v[i] = self.mu * v[i] + g[i];
                    theta[i] -= self.lr * (g[i] + self.mu * v[i]);
                }
            }
        }

        pub fn velocity(&self) -> Option<&[Vec<f32>]> {
            self.velocity.as_deref()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn flat1(data: Vec<f32>) -> FlatParams {
        let layout = Arc::new(FlatLayout::new(vec![vec![data.len()]]));
        let mut fp = FlatParams::zeros(&layout);
        fp.data_mut().copy_from_slice(&data);
        fp
    }

    #[test]
    fn plain_averaging_when_lr1_mu0() {
        // eta=1, mu=0 => global becomes the replica average (FedAvg).
        let mut global = flat1(vec![1.0, 2.0]);
        let replicas = vec![flat1(vec![0.0, 0.0]), flat1(vec![2.0, 6.0])];
        let delta = outer_gradient(&global, &replicas);
        let mut opt = OuterOpt::new(1.0, 0.0);
        opt.step(&mut global, &delta);
        assert_eq!(global.data(), &[1.0, 3.0]);
    }

    #[test]
    fn single_replica_identity_when_lr1_mu0() {
        // M=1, eta=1, mu=0: outer step sets global = replica params, so
        // DiLoCo degenerates to the inner optimizer alone.
        let mut global = flat1(vec![5.0, -1.0, 0.5]);
        let replica = flat1(vec![4.0, 3.0, 0.25]);
        let delta = outer_gradient(&global, std::slice::from_ref(&replica));
        let mut opt = OuterOpt::new(1.0, 0.0);
        opt.step(&mut global, &delta);
        for (a, b) in global.data().iter().zip(replica.data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_accumulates_nesterov_style() {
        // Constant outer grad g with mu, lr: first step = lr*(1+mu)*g,
        // second = lr*(1 + mu + mu^2)*g... cumulative matches closed form.
        let g = flat1(vec![1.0]);
        let mut global = flat1(vec![0.0]);
        let mut opt = OuterOpt::new(0.5, 0.9);
        opt.step(&mut global, &g);
        // v=1, update=0.5*(1+0.9*1)=0.95 -> theta=-0.95
        assert!((global.data()[0] + 0.95).abs() < 1e-6);
        opt.step(&mut global, &g);
        // v=1.9, update=0.5*(1+0.9*1.9)=1.355 -> theta=-2.305
        assert!((global.data()[0] + 2.305).abs() < 1e-5);
    }

    #[test]
    fn outer_gradient_zero_when_replicas_equal_global() {
        let global = flat1(vec![1.0, 2.0, 3.0]);
        let replicas = vec![global.clone(), global.clone()];
        let delta = outer_gradient(&global, &replicas);
        assert!(delta.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn step_ranges_leaves_other_elements_untouched() {
        // A fragment step must not move params or velocity outside its
        // ranges (streaming fragments own disjoint momentum slices).
        let layout = Arc::new(FlatLayout::new(vec![vec![2], vec![3], vec![2]]));
        let mut global = FlatParams::zeros(&layout);
        global.data_mut().copy_from_slice(&[1.0; 7]);
        let mut delta = FlatParams::zeros(&layout);
        delta.data_mut().copy_from_slice(&[0.5; 7]);
        let mut opt = OuterOpt::new(0.7, 0.9);
        let ranges = layout.fragment_ranges(2, 1); // leaf 1 only -> [2..5]
        opt.step_ranges(&mut global, &delta, &ranges);
        assert_eq!(global.leaf(0), &[1.0, 1.0]);
        assert_eq!(global.leaf(2), &[1.0, 1.0]);
        assert!(global.leaf(1).iter().all(|&x| x != 1.0));
        assert!(opt.velocity()[..2].iter().all(|&v| v == 0.0));
        assert!(opt.velocity()[5..].iter().all(|&v| v == 0.0));
        assert!(opt.velocity()[2..5].iter().all(|&v| v == 0.5));
    }

    #[test]
    fn step_pieces_matches_step_ranges_at_any_shard_count() {
        let layout = Arc::new(FlatLayout::new(vec![vec![700], vec![300], vec![513]]));
        let mk = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut fp = FlatParams::zeros(&layout);
            for x in fp.data_mut() {
                *x = rng.normal() as f32;
            }
            fp
        };
        let ranges = layout.fragment_ranges(2, 0);
        let delta = mk(7);
        let mut want = mk(1);
        let mut opt_seq = OuterOpt::new(0.7, 0.9);
        opt_seq.step_ranges(&mut want, &delta, &ranges);
        opt_seq.step_ranges(&mut want, &delta, &ranges); // momentum carries
        for threads in [1, 2, 3, 16] {
            let shards = par::shard_ranges(&ranges, threads, 256);
            let mut got = mk(1);
            let mut opt = OuterOpt::new(0.7, 0.9);
            opt.step_pieces(&mut got, &delta, &shards);
            opt.step_pieces(&mut got, &delta, &shards);
            for i in 0..layout.total() {
                assert_eq!(
                    got.data()[i].to_bits(),
                    want.data()[i].to_bits(),
                    "threads={threads} theta[{i}]"
                );
                assert_eq!(
                    opt.velocity().get(i).copied().unwrap_or(0.0).to_bits(),
                    opt_seq.velocity()[i].to_bits(),
                    "threads={threads} velocity[{i}]"
                );
            }
        }
    }

    #[test]
    fn prop_average_invariant() {
        // Property: for random replicas, eta=1/mu=0 recovers the mean to
        // float tolerance, for any M in 1..8 and leaf size in 1..64.
        prop::check(
            0xA11CE,
            64,
            |rng: &mut Rng| {
                let m = 1 + rng.below(8) as usize;
                let n = 1 + rng.below(64) as usize;
                let global: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                let replicas: Vec<Vec<f32>> = (0..m)
                    .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
                    .collect();
                (global, replicas)
            },
            |(g, rs)| {
                let mut global = flat1(g.clone());
                let reps: Vec<FlatParams> = rs.iter().map(|r| flat1(r.clone())).collect();
                let delta = outer_gradient(&global, &reps);
                OuterOpt::new(1.0, 0.0).step(&mut global, &delta);
                let n = g.len();
                for i in 0..n {
                    let mean: f32 =
                        rs.iter().map(|r| r[i]).sum::<f32>() / rs.len() as f32;
                    prop::close(global.data()[i] as f64, mean as f64, 1e-5)?;
                }
                Ok(())
            },
        );
    }
}
