//! Outer optimizer: SGD with Nesterov momentum over outer gradients
//! (paper Algorithm 1, line 11; Douillard et al. 2023's recommended
//! OuterOpt). The outer gradient is the parameter-space delta
//! Delta = theta_global - mean_m theta_m; this module applies
//!
//!   v   <- mu * v + Delta
//!   theta <- theta - eta * (Delta + mu * v)
//!
//! (the standard "Nesterov-as-lookahead-momentum" form, matching
//! optax/PyTorch `nesterov=True`). With eta=1, mu=0 the update reduces
//! to theta <- mean_m theta_m, i.e. plain parameter averaging
//! (FedAvg/Local SGD) — a property the tests pin down.

use crate::runtime::HostTensor;

#[derive(Debug, Clone)]
pub struct OuterOpt {
    pub lr: f64,
    pub momentum: f64,
    velocity: Option<Vec<HostTensor>>,
}

impl OuterOpt {
    pub fn new(lr: f64, momentum: f64) -> OuterOpt {
        OuterOpt {
            lr,
            momentum,
            velocity: None,
        }
    }

    /// Apply one outer step in place on the global params.
    /// `outer_grad` is Delta (already averaged across replicas).
    pub fn step(&mut self, global: &mut [HostTensor], outer_grad: &[HostTensor]) {
        self.step_subset(global, outer_grad, |_| true)
    }

    /// Streaming DiLoCo (Douillard et al. 2025; paper section 8 /
    /// Appendix A): apply the outer step only to the parameter leaves
    /// selected by `in_fragment` — each fragment keeps its own slice of
    /// the momentum state, untouched leaves are left exactly as-is.
    pub fn step_subset(
        &mut self,
        global: &mut [HostTensor],
        outer_grad: &[HostTensor],
        in_fragment: impl Fn(usize) -> bool,
    ) {
        assert_eq!(global.len(), outer_grad.len());
        let velocity = self.velocity.get_or_insert_with(|| {
            outer_grad
                .iter()
                .map(|g| HostTensor::zeros(&g.shape))
                .collect()
        });
        assert_eq!(velocity.len(), outer_grad.len());
        let mu = self.momentum as f32;
        let lr = self.lr as f32;
        for (leaf, ((theta, g), v)) in global
            .iter_mut()
            .zip(outer_grad)
            .zip(velocity.iter_mut())
            .enumerate()
        {
            if !in_fragment(leaf) {
                continue;
            }
            assert_eq!(theta.shape, g.shape);
            for i in 0..theta.data.len() {
                v.data[i] = mu * v.data[i] + g.data[i];
                theta.data[i] -= lr * (g.data[i] + mu * v.data[i]);
            }
        }
    }

    pub fn velocity(&self) -> Option<&[HostTensor]> {
        self.velocity.as_deref()
    }
}

/// Compute the outer gradient Delta = global - mean(replicas)
/// (Algorithm 1 lines 9-10: Delta_m = theta^(t-H) - theta_m, averaged).
pub fn outer_gradient(global: &[HostTensor], replicas: &[Vec<HostTensor>]) -> Vec<HostTensor> {
    assert!(!replicas.is_empty());
    let m = replicas.len() as f32;
    global
        .iter()
        .enumerate()
        .map(|(leaf, g)| {
            let mut out = HostTensor::zeros(&g.shape);
            for r in replicas {
                let rt = &r[leaf];
                assert_eq!(rt.shape, g.shape);
                for i in 0..out.data.len() {
                    out.data[i] += rt.data[i];
                }
            }
            for i in 0..out.data.len() {
                out.data[i] = g.data[i] - out.data[i] / m;
            }
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn t(data: Vec<f32>) -> HostTensor {
        let n = data.len();
        HostTensor::from_vec(&[n], data)
    }

    #[test]
    fn plain_averaging_when_lr1_mu0() {
        // eta=1, mu=0 => global becomes the replica average (FedAvg).
        let mut global = vec![t(vec![1.0, 2.0])];
        let replicas = vec![
            vec![t(vec![0.0, 0.0])],
            vec![t(vec![2.0, 6.0])],
        ];
        let delta = outer_gradient(&global, &replicas);
        let mut opt = OuterOpt::new(1.0, 0.0);
        opt.step(&mut global, &delta);
        assert_eq!(global[0].data, vec![1.0, 3.0]);
    }

    #[test]
    fn single_replica_identity_when_lr1_mu0() {
        // M=1, eta=1, mu=0: outer step sets global = replica params, so
        // DiLoCo degenerates to the inner optimizer alone.
        let mut global = vec![t(vec![5.0, -1.0, 0.5])];
        let replica = vec![t(vec![4.0, 3.0, 0.25])];
        let delta = outer_gradient(&global, std::slice::from_ref(&replica));
        let mut opt = OuterOpt::new(1.0, 0.0);
        opt.step(&mut global, &delta);
        for (a, b) in global[0].data.iter().zip(&replica[0].data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_accumulates_nesterov_style() {
        // Constant outer grad g with mu, lr: first step = lr*(1+mu)*g,
        // second = lr*(1 + mu + mu^2)*g... cumulative matches closed form.
        let g = vec![t(vec![1.0])];
        let mut global = vec![t(vec![0.0])];
        let mut opt = OuterOpt::new(0.5, 0.9);
        opt.step(&mut global, &g);
        // v=1, update=0.5*(1+0.9*1)=0.95 -> theta=-0.95
        assert!((global[0].data[0] + 0.95).abs() < 1e-6);
        opt.step(&mut global, &g);
        // v=1.9, update=0.5*(1+0.9*1.9)=1.355 -> theta=-2.305
        assert!((global[0].data[0] + 2.305).abs() < 1e-5);
    }

    #[test]
    fn outer_gradient_zero_when_replicas_equal_global() {
        let global = vec![t(vec![1.0, 2.0, 3.0])];
        let replicas = vec![global.clone(), global.clone()];
        let delta = outer_gradient(&global, &replicas);
        assert!(delta[0].data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn prop_average_invariant() {
        // Property: for random replicas, eta=1/mu=0 recovers the mean to
        // float tolerance, for any M in 1..8 and leaf size in 1..64.
        prop::check(
            0xA11CE,
            64,
            |rng: &mut Rng| {
                let m = 1 + rng.below(8) as usize;
                let n = 1 + rng.below(64) as usize;
                let global: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                let replicas: Vec<Vec<f32>> = (0..m)
                    .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
                    .collect();
                (global, replicas)
            },
            |(g, rs)| {
                let mut global = vec![t(g.clone())];
                let reps: Vec<Vec<HostTensor>> =
                    rs.iter().map(|r| vec![t(r.clone())]).collect();
                let delta = outer_gradient(&global, &reps);
                OuterOpt::new(1.0, 0.0).step(&mut global, &delta);
                let n = g.len();
                for i in 0..n {
                    let mean: f32 =
                        rs.iter().map(|r| r[i]).sum::<f32>() / rs.len() as f32;
                    prop::close(global[0].data[i] as f64, mean as f64, 1e-5)?;
                }
                Ok(())
            },
        );
    }
}
