//! Elastic membership: the fault plan and the live set.
//!
//! DiLoCo's premise is training over islands of compute that are
//! individually unreliable; this module gives the coordinator a
//! *deterministic* model of that unreliability. A [`FaultPlan`] is a
//! schedule of membership events — joins, graceful leaves, crashes,
//! straggler notes — keyed to `(outer sync index, replica id)`. It is
//! parsed from the `--churn` CLI spec and resolved against the run's
//! shape (replica count, total outer syncs) into a concrete event
//! list. Seed-derived `rate=` events use splitmix64 chains off the
//! run seed, so a churn scenario replays bit-identically on any
//! machine and any worker count, and never touches the data or
//! encode-seed RNG streams.
//!
//! Event timing semantics (all keyed to outer sync index `K`, counted
//! absolutely across checkpoint/resume):
//! - `crash@K:rR` — replica R is dead for the whole segment that ends
//!   at send K: it takes no inner steps and is dropped from that
//!   reduce onward (mean over survivors).
//! - `leave@K:rR` — replica R contributes to send K, then leaves.
//! - `join@K:rR` — replica R goes live at the first segment after the
//!   merge of sync K, initialized from the current broadcast view.
//! - `straggle@K:rR` — journal/walltime note only; the math is
//!   unaffected (stragglers are a netsim concern, `netsim::walltime`).
//!
//! The live set itself is a [`Membership`] — a universe-sized bitmap.
//! The universe (initial replicas plus every planned joiner) is fixed
//! at startup so replica ids, shard streams, and encode seeds never
//! shift when membership changes; liveness is the only mutable part.

use anyhow::{bail, Context, Result};

use crate::util::rng::splitmix64;

/// Salt for rate-derived crash draws, chained with the run seed so
/// churn draws are independent of data and wire-codec streams.
const CHURN_SALT: u64 = 0xC4A5_41F7_BAD5_EED5;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Join,
    Leave,
    Crash,
    Straggle,
}

impl FaultKind {
    pub fn label(self) -> &'static str {
        match self {
            FaultKind::Join => "join",
            FaultKind::Leave => "leave",
            FaultKind::Crash => "crash",
            FaultKind::Straggle => "straggle",
        }
    }

    fn parse(s: &str) -> Result<FaultKind> {
        Ok(match s {
            "join" => FaultKind::Join,
            "leave" => FaultKind::Leave,
            "crash" => FaultKind::Crash,
            "straggle" => FaultKind::Straggle,
            other => bail!(
                "churn: unknown event kind {other:?} (expected join|leave|crash|straggle)"
            ),
        })
    }
}

/// One scheduled membership event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub at_sync: u64,
    pub replica: usize,
    pub kind: FaultKind,
}

/// A parsed `--churn` spec: explicit events plus an optional
/// seed-derived crash rate. The plan is pure data — resolution against
/// a concrete run shape happens in [`FaultPlan::resolve`].
///
/// Grammar (comma-separated, no spaces required):
/// `crash@K:rR`, `leave@K:rR`, `join@K:rR`, `straggle@K:rR`,
/// `rate=P` (at most once, `0 <= P < 1`).
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    spec: String,
    seed: u64,
    explicit: Vec<FaultEvent>,
    rate: f64,
}

impl FaultPlan {
    /// Parse a spec. The empty spec is the empty plan (no churn).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut plan = FaultPlan {
            spec: spec.to_string(),
            seed,
            explicit: Vec::new(),
            rate: 0.0,
        };
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            if let Some(rate) = item.strip_prefix("rate=") {
                if plan.rate != 0.0 {
                    bail!("churn: `rate=` given more than once in {spec:?}");
                }
                let r: f64 = rate
                    .parse()
                    .with_context(|| format!("churn: bad rate {rate:?}"))?;
                if !(0.0..1.0).contains(&r) {
                    bail!("churn: rate must be in [0, 1), got {r}");
                }
                plan.rate = r;
                continue;
            }
            let (kind, rest) = item
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("churn: bad event {item:?} (want kind@K:rR)"))?;
            let kind = FaultKind::parse(kind)?;
            let (sync, rep) = rest
                .split_once(":r")
                .ok_or_else(|| anyhow::anyhow!("churn: bad event {item:?} (want kind@K:rR)"))?;
            let at_sync: u64 = sync
                .parse()
                .with_context(|| format!("churn: bad sync index in {item:?}"))?;
            let replica: usize = rep
                .parse()
                .with_context(|| format!("churn: bad replica id in {item:?}"))?;
            plan.explicit.push(FaultEvent {
                at_sync,
                replica,
                kind,
            });
        }
        // deterministic order regardless of how the spec was written
        plan.explicit
            .sort_by_key(|e| (e.at_sync, e.replica, e.kind.label()));
        Ok(plan)
    }

    /// True when the plan schedules nothing (empty spec or rate 0 with
    /// no explicit events) — the coordinator takes the churn-free path.
    pub fn is_empty(&self) -> bool {
        self.explicit.is_empty() && self.rate == 0.0
    }

    pub fn spec(&self) -> &str {
        &self.spec
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// The replica universe for a run starting with `m` replicas:
    /// initial ids plus room for every explicitly planned joiner.
    /// Fixed at startup so ids, shards, and encode seeds never shift.
    pub fn universe(&self, m: usize) -> usize {
        self.explicit
            .iter()
            .filter(|e| e.kind == FaultKind::Join)
            .map(|e| e.replica + 1)
            .fold(m, usize::max)
    }

    /// Resolve the plan against a run shape into a concrete, sorted
    /// event list: explicit events plus seed-derived crashes at
    /// `rate` per (sync, replica) cell. Replica 0 is the anchor and is
    /// never auto-crashed (a plan must not be able to kill the whole
    /// run by chance), and a rate-crashed replica draws no further
    /// events. Explicit events are the author's responsibility — the
    /// coordinator still refuses, loudly, to kill the last survivor.
    pub fn resolve(&self, m: usize, n_syncs: u64) -> Vec<FaultEvent> {
        let mut events = self.explicit.clone();
        if self.rate > 0.0 {
            let mut dead = vec![false; m];
            for k in 0..n_syncs {
                for (r, gone) in dead.iter_mut().enumerate().skip(1) {
                    if *gone {
                        continue;
                    }
                    let mut s = self.seed ^ CHURN_SALT;
                    let mut chain = splitmix64(&mut s) ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15);
                    let mut chain2 =
                        splitmix64(&mut chain) ^ (r as u64).wrapping_mul(0xA24B_AED4_963E_E407);
                    let draw = splitmix64(&mut chain2);
                    if (draw >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < self.rate {
                        *gone = true;
                        events.push(FaultEvent {
                            at_sync: k,
                            replica: r,
                            kind: FaultKind::Crash,
                        });
                    }
                }
            }
        }
        events.sort_by_key(|e| (e.at_sync, e.replica, e.kind.label()));
        events
    }

    /// Fraction of (sync, replica) contribution slots lost to crashes
    /// and leaves — the x-axis of the churn report table.
    pub fn dropout_rate(&self, m: usize, n_syncs: u64) -> f64 {
        if m == 0 || n_syncs == 0 {
            return 0.0;
        }
        let universe = self.universe(m);
        let mut live = vec![false; universe];
        for flag in live.iter_mut().take(m) {
            *flag = true;
        }
        let mut lost = 0u64;
        let mut events = self.resolve(m, n_syncs);
        events.sort_by_key(|e| e.at_sync);
        let mut idx = 0;
        for k in 0..n_syncs {
            while idx < events.len() && events[idx].at_sync == k {
                let e = events[idx];
                idx += 1;
                match e.kind {
                    // dead for the segment ending at send k
                    FaultKind::Crash if live[e.replica] => {
                        live[e.replica] = false;
                        lost += n_syncs - k;
                    }
                    // contributes to send k, gone after
                    FaultKind::Leave if live[e.replica] => {
                        live[e.replica] = false;
                        lost += n_syncs.saturating_sub(k + 1);
                    }
                    FaultKind::Join if !live[e.replica] => live[e.replica] = true,
                    _ => {}
                }
            }
        }
        lost as f64 / (m as f64 * n_syncs as f64)
    }
}

/// The live set over the replica universe. Replica ids are stable for
/// the whole run; only liveness flips.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Membership {
    live: Vec<bool>,
}

impl Membership {
    /// All of the first `m` replicas live, the rest (planned joiners)
    /// dark.
    pub fn initial(universe: usize, m: usize) -> Membership {
        let mut live = vec![false; universe];
        for flag in live.iter_mut().take(m) {
            *flag = true;
        }
        Membership { live }
    }

    /// Restore from checkpointed flags.
    pub fn from_flags(live: Vec<bool>) -> Membership {
        Membership { live }
    }

    pub fn universe(&self) -> usize {
        self.live.len()
    }

    pub fn is_live(&self, r: usize) -> bool {
        self.live.get(r).copied().unwrap_or(false)
    }

    pub fn live_count(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    pub fn flags(&self) -> &[bool] {
        &self.live
    }

    pub fn live_ids(&self) -> Vec<usize> {
        (0..self.live.len()).filter(|&r| self.live[r]).collect()
    }

    pub fn set_live(&mut self, r: usize, live: bool) {
        self.live[r] = live;
    }
}

/// Parse a replica-claim spec: a comma list of single ids (`K`) and
/// half-open ranges (`A..B`), e.g. `0..2,5` = replicas 0, 1, 5. Used
/// by `diloco worker --replicas` to claim ownership at the handshake.
/// Duplicates within one spec are rejected here; overlap *between*
/// workers is the coordinator's handshake check.
pub fn parse_replica_set(spec: &str) -> Result<Vec<usize>> {
    let mut out: Vec<usize> = Vec::new();
    for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        if let Some((a, b)) = item.split_once("..") {
            let a: usize = a
                .trim()
                .parse()
                .with_context(|| format!("replicas: bad range start in {item:?}"))?;
            let b: usize = b
                .trim()
                .parse()
                .with_context(|| format!("replicas: bad range end in {item:?}"))?;
            if a >= b {
                bail!("replicas: empty range {item:?} (want A..B with A < B)");
            }
            out.extend(a..b);
        } else {
            out.push(
                item.parse()
                    .with_context(|| format!("replicas: bad id {item:?}"))?,
            );
        }
    }
    if out.is_empty() {
        bail!("replicas: empty spec {spec:?}");
    }
    let mut seen = out.clone();
    seen.sort_unstable();
    seen.dedup();
    if seen.len() != out.len() {
        bail!("replicas: duplicate id in {spec:?}");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_explicit_events_in_stable_order() {
        let plan = FaultPlan::parse("leave@2:r1, crash@1:r2, join@1:r3", 42).unwrap();
        assert!(!plan.is_empty());
        let events = plan.resolve(3, 4);
        assert_eq!(
            events,
            vec![
                FaultEvent {
                    at_sync: 1,
                    replica: 2,
                    kind: FaultKind::Crash
                },
                FaultEvent {
                    at_sync: 1,
                    replica: 3,
                    kind: FaultKind::Join
                },
                FaultEvent {
                    at_sync: 2,
                    replica: 1,
                    kind: FaultKind::Leave
                },
            ]
        );
        assert_eq!(plan.universe(3), 4, "join r3 widens the universe");
        assert_eq!(plan.universe(8), 8);
    }

    #[test]
    fn empty_spec_is_empty_plan() {
        let plan = FaultPlan::parse("", 1).unwrap();
        assert!(plan.is_empty());
        assert!(plan.resolve(4, 10).is_empty());
        assert_eq!(plan.dropout_rate(4, 10), 0.0);
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in [
            "explode@1:r0",
            "crash@x:r0",
            "crash@1:rx",
            "crash@1",
            "rate=1.5",
            "rate=0.1,rate=0.2",
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn rate_draws_are_deterministic_and_spare_the_anchor() {
        let plan = FaultPlan::parse("rate=0.4", 7).unwrap();
        let a = plan.resolve(4, 8);
        let b = plan.resolve(4, 8);
        assert_eq!(a, b, "same seed, same events");
        assert!(!a.is_empty(), "rate=0.4 over 24 cells should fire");
        assert!(a.iter().all(|e| e.kind == FaultKind::Crash));
        assert!(a.iter().all(|e| e.replica != 0), "replica 0 is the anchor");
        // one crash per replica at most
        let mut seen = vec![0usize; 4];
        for e in &a {
            seen[e.replica] += 1;
        }
        assert!(seen.iter().all(|&c| c <= 1));

        let other = FaultPlan::parse("rate=0.4", 8).unwrap().resolve(4, 8);
        assert_ne!(a, other, "different seed, different schedule");
    }

    #[test]
    fn dropout_rate_counts_lost_contribution_slots() {
        // m=2, 4 syncs: crash@2:r1 loses r1's sends 2 and 3 -> 2/8
        let plan = FaultPlan::parse("crash@2:r1", 0).unwrap();
        assert!((plan.dropout_rate(2, 4) - 0.25).abs() < 1e-12);
        // leave@2:r1 contributes to send 2, loses only send 3 -> 1/8
        let plan = FaultPlan::parse("leave@2:r1", 0).unwrap();
        assert!((plan.dropout_rate(2, 4) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn membership_tracks_the_live_set() {
        let mut ms = Membership::initial(4, 3);
        assert_eq!(ms.live_count(), 3);
        assert!(!ms.is_live(3));
        ms.set_live(3, true);
        ms.set_live(1, false);
        assert_eq!(ms.live_ids(), vec![0, 2, 3]);
        let back = Membership::from_flags(ms.flags().to_vec());
        assert_eq!(back, ms);
    }
}
