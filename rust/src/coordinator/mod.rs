//! L3 coordination: the paper's system contribution. DiLoCo driver
//! (Algorithm 1), outer SGD-Nesterov optimizer over the flat parameter
//! bus, the H-cadence sync engine, replica management.

pub mod diloco;
pub mod outer_opt;
pub mod sync;

pub use diloco::{run, Algo, RunConfig, RunMetrics};
pub use outer_opt::{outer_gradient, OuterOpt};
pub use sync::OuterSync;
