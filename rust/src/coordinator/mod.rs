//! L3 coordination: the paper's system contribution. DiLoCo driver
//! (Algorithm 1), outer SGD-Nesterov optimizer over the flat parameter
//! bus, the H-cadence sync engine, and the replica-parallel worker
//! pool that runs the M inner loops concurrently between outer syncs.

pub mod diloco;
pub mod outer_opt;
pub mod pool;
pub mod sync;

pub use diloco::{run, Algo, RunConfig, RunMetrics};
pub use outer_opt::{outer_gradient, OuterOpt};
pub use pool::{drive, DriveOutcome, DrivePlan, InnerEngine, ReplicaState};
pub use sync::OuterSync;
