//! L3 coordination: the paper's system contribution. DiLoCo driver
//! (Algorithm 1), outer SGD-Nesterov optimizer over the flat parameter
//! bus, the H-cadence sync engine, and the replica-parallel worker
//! pool that runs the M inner loops concurrently between outer syncs.

pub mod checkpoint;
pub mod diloco;
pub mod fsm;
pub mod journal;
pub mod membership;
pub mod outer_opt;
pub mod pool;
pub mod sync;

pub use checkpoint::{Checkpoint, OutcomeCkpt, ReplicaCkpt};
pub use diloco::{run, run_checkpoint, run_resume, Algo, RunConfig, RunMetrics};
pub use fsm::{CoordinatorFsm, Phase};
pub use journal::{EventKind, Journal, JournalEvent};
pub use membership::{parse_replica_set, FaultEvent, FaultKind, FaultPlan, Membership};
pub use outer_opt::{outer_gradient, OuterOpt};
pub use pool::{
    drive, drive_ctl, drive_lanes, drive_reactor, worker_session, DriveCtl, DriveOutcome,
    DrivePlan, InnerEngine, OwnedReplica, ReplicaState,
};
pub use sync::{OuterSync, SyncState};
