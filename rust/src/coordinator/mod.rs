//! L3 coordination: the paper's system contribution. DiLoCo driver
//! (Algorithm 1), outer SGD-Nesterov optimizer, replica management.

pub mod diloco;
pub mod outer_opt;

pub use diloco::{run, Algo, RunConfig, RunMetrics};
pub use outer_opt::{outer_gradient, OuterOpt};
