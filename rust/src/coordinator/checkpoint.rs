//! Outer-boundary checkpoints with bit-identical resume.
//!
//! A [`Checkpoint`] captures everything the drive loop carries across
//! an outer boundary: per-replica state literals + data-shard
//! positions + up-wire EF residuals, the live membership set, the
//! outer engine's [`SyncState`] (global, velocity, down-wire
//! view/residual, wire records), the partial [`DriveOutcome`] curves,
//! and the event journal. Resume rebuilds a run from this and
//! continues it such that losses, evals, wire bytes, and final params
//! are bit-identical to the uninterrupted run (`tests/churn_resume.rs`
//! pins this for identity and lossy codec pairs).
//!
//! Serialization is JSON through `util::json` (the repo's substrate),
//! with two exactness rules:
//! - **f32 arenas** serialize as hex strings of little-endian bytes —
//!   exact round-trip, no decimal-float detour, and the encoder is a
//!   straight byte loop cheap enough to sit on the hot path
//!   (`bench_hot_path` measures serialize cost per sync);
//! - **f64 curves** (losses, evals) serialize as their IEEE-754 bit
//!   patterns in exact [`Json::Int`]s.
//!
//! Checkpoints are legal only at outer boundaries (post-merge, no
//! fragment in flight, no unshipped broadcast) — `OuterSync`
//! enforces the broadcast half, the drive loop the pipeline half.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::comm::SyncWireRecord;
use crate::runtime::HostTensor;
use crate::util::json::Json;

use super::journal::Journal;
use super::pool::{DriveOutcome, ReplicaState};
use super::sync::{OuterSync, SyncState};

pub const CHECKPOINT_VERSION: u64 = 1;

// ---- exact scalar encodings ------------------------------------------------

const HEX: &[u8; 16] = b"0123456789abcdef";

/// f32 slice -> hex of little-endian bytes (exact, allocation-lean).
pub fn hex_of_f32(v: &[f32]) -> String {
    let mut s = String::with_capacity(v.len() * 8);
    for x in v {
        for b in x.to_le_bytes() {
            s.push(HEX[(b >> 4) as usize] as char);
            s.push(HEX[(b & 15) as usize] as char);
        }
    }
    s
}

pub fn f32_of_hex(s: &str) -> Result<Vec<f32>> {
    let bytes = s.as_bytes();
    if bytes.len() % 8 != 0 {
        bail!("hex f32 arena: length {} is not a multiple of 8", bytes.len());
    }
    fn nib(b: u8) -> Result<u8> {
        match b {
            b'0'..=b'9' => Ok(b - b'0'),
            b'a'..=b'f' => Ok(b - b'a' + 10),
            b'A'..=b'F' => Ok(b - b'A' + 10),
            other => bail!("hex f32 arena: bad digit {:?}", other as char),
        }
    }
    let mut out = Vec::with_capacity(bytes.len() / 8);
    for chunk in bytes.chunks_exact(8) {
        let mut le = [0u8; 4];
        for (i, pair) in chunk.chunks_exact(2).enumerate() {
            le[i] = (nib(pair[0])? << 4) | nib(pair[1])?;
        }
        out.push(f32::from_le_bytes(le));
    }
    Ok(out)
}

fn json_of_f64_bits(v: f64) -> Json {
    Json::int(v.to_bits())
}

fn f64_of_json_bits(j: &Json) -> Result<f64> {
    let bits = j
        .as_u64()
        .ok_or_else(|| anyhow::anyhow!("expected an f64 bit pattern, got {j}"))?;
    Ok(f64::from_bits(bits))
}

// ---- checkpoint pieces -----------------------------------------------------

/// One replica's full restorable state.
#[derive(Debug, Clone)]
pub struct ReplicaCkpt {
    /// Every state leaf (params + optimizer moments), shape + values.
    pub leaves: Vec<(Vec<usize>, Vec<f32>)>,
    /// Up-wire EF residual (empty for identity up-wires).
    pub residual: Vec<f32>,
    /// Tokens the replica's shard has consumed (replayed on resume).
    pub consumed: u64,
}

impl ReplicaCkpt {
    /// Rebuild the state literal list.
    pub fn literals(&self) -> Result<Vec<Arc<xla::Literal>>> {
        self.leaves
            .iter()
            .map(|(shape, data)| {
                Ok(Arc::new(
                    HostTensor::from_vec(shape, data.clone())
                        .to_literal()
                        .map_err(|e| anyhow::anyhow!("checkpoint leaf rebuild: {e}"))?,
                ))
            })
            .collect()
    }
}

/// The partial run curves at checkpoint time, stitched onto the
/// resumed segment's curves by [`Checkpoint::stitch`].
#[derive(Debug, Clone, Default)]
pub struct OutcomeCkpt {
    pub step_losses: Vec<f64>,
    pub loss_curve: Vec<(usize, f64)>,
    pub eval_curve: Vec<(usize, f64)>,
    pub outer_syncs: usize,
}

impl OutcomeCkpt {
    pub fn of(out: &DriveOutcome) -> OutcomeCkpt {
        OutcomeCkpt {
            step_losses: out.step_losses.clone(),
            loss_curve: out.loss_curve.clone(),
            eval_curve: out.eval_curve.clone(),
            outer_syncs: out.outer_syncs,
        }
    }
}

/// A full outer-boundary checkpoint.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub version: u64,
    /// Inner step the run had completed.
    pub step: usize,
    /// Live membership flags over the replica universe.
    pub live: Vec<bool>,
    pub replicas: Vec<ReplicaCkpt>,
    /// Outer engine state (None for data-parallel runs, which have no
    /// outer sync — checkpointing them is not supported today).
    pub sync: Option<SyncState>,
    pub outcome: OutcomeCkpt,
    pub journal: Journal,
    /// The originating `RunConfig` as JSON, when captured through the
    /// CLI path (`diloco checkpoint`); drive-level captures leave it
    /// None and the caller re-supplies the config.
    pub config: Option<Json>,
}

impl Checkpoint {
    /// Capture at an outer boundary. `residuals[r]` is replica r's
    /// up-wire EF residual (empty slices for identity up-wires or
    /// never-initialized replicas).
    pub fn capture(
        step: usize,
        replicas: &[ReplicaState],
        residuals: &[Vec<f32>],
        live: &[bool],
        sync: Option<&OuterSync>,
        outcome: &DriveOutcome,
        journal: &Journal,
    ) -> Result<Checkpoint> {
        if replicas.len() != live.len() {
            bail!(
                "checkpoint: {} replicas but {} live flags",
                replicas.len(),
                live.len()
            );
        }
        let mut reps = Vec::with_capacity(replicas.len());
        for (r, rep) in replicas.iter().enumerate() {
            let mut leaves = Vec::with_capacity(rep.state.len());
            for (leaf, lit) in rep.state.iter().enumerate() {
                let shape: Vec<usize> = lit
                    .array_shape()
                    .map_err(|e| anyhow::anyhow!("checkpoint: replica {r} leaf {leaf}: {e}"))?
                    .dims()
                    .iter()
                    .map(|&d| d as usize)
                    .collect();
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("checkpoint: replica {r} leaf {leaf}: {e}"))?;
                leaves.push((shape, data));
            }
            reps.push(ReplicaCkpt {
                leaves,
                residual: residuals.get(r).cloned().unwrap_or_default(),
                consumed: rep.shard.consumed(),
            });
        }
        Ok(Checkpoint {
            version: CHECKPOINT_VERSION,
            step,
            live: live.to_vec(),
            replicas: reps,
            sync: sync.map(|s| s.export_state()).transpose()?,
            outcome: OutcomeCkpt::of(outcome),
            journal: journal.clone(),
            config: None,
        })
    }

    /// Stitch the resumed segment's outcome onto the checkpointed
    /// curves: the result is what the uninterrupted run would have
    /// produced (resumed curves start after `self.step`).
    pub fn stitch(&self, resumed: &DriveOutcome) -> DriveOutcome {
        DriveOutcome {
            step_losses: [&self.outcome.step_losses[..], &resumed.step_losses[..]].concat(),
            loss_curve: [&self.outcome.loss_curve[..], &resumed.loss_curve[..]].concat(),
            eval_curve: [&self.outcome.eval_curve[..], &resumed.eval_curve[..]].concat(),
            outer_syncs: self.outcome.outer_syncs + resumed.outer_syncs,
            comm_arena_bytes: resumed.comm_arena_bytes,
            down_wire_arena_bytes: resumed.down_wire_arena_bytes,
        }
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        let replicas = Json::arr(self.replicas.iter().map(|r| {
            Json::obj(vec![
                (
                    "leaves",
                    Json::arr(r.leaves.iter().map(|(shape, data)| {
                        Json::obj(vec![
                            (
                                "shape",
                                Json::arr(shape.iter().map(|&d| Json::int(d as u64))),
                            ),
                            ("data", Json::str(&hex_of_f32(data))),
                        ])
                    })),
                ),
                ("residual", Json::str(&hex_of_f32(&r.residual))),
                ("consumed", Json::int(r.consumed)),
            ])
        }));
        let sync = match &self.sync {
            Some(st) => {
                let wire = Json::arr(st.wire_records.iter().map(|w| {
                    let mut pairs = vec![
                        ("sync_index", Json::int(w.sync_index)),
                        ("replicas", Json::int(w.replicas as u64)),
                        ("bytes_per_replica", Json::int(w.bytes_per_replica)),
                        ("bytes_down", Json::int(w.bytes_down)),
                    ];
                    if let Some(f) = w.frag {
                        pairs.push(("frag", Json::int(f as u64)));
                    }
                    Json::obj(pairs)
                }));
                let mut pairs = vec![
                    ("global", Json::str(&hex_of_f32(&st.global))),
                    ("velocity", Json::str(&hex_of_f32(&st.velocity))),
                    ("wire", wire),
                ];
                if let Some(view) = &st.down_view {
                    pairs.push(("down_view", Json::str(&hex_of_f32(view))));
                }
                if let Some(res) = &st.down_residual {
                    pairs.push(("down_residual", Json::str(&hex_of_f32(res))));
                }
                Json::obj(pairs)
            }
            None => Json::Null,
        };
        let curve = |c: &[(usize, f64)]| {
            Json::arr(c.iter().map(|&(t, v)| {
                Json::arr([Json::int(t as u64), json_of_f64_bits(v)])
            }))
        };
        let outcome = Json::obj(vec![
            (
                "step_losses",
                Json::arr(self.outcome.step_losses.iter().map(|&v| json_of_f64_bits(v))),
            ),
            ("loss_curve", curve(&self.outcome.loss_curve)),
            ("eval_curve", curve(&self.outcome.eval_curve)),
            ("outer_syncs", Json::int(self.outcome.outer_syncs as u64)),
        ]);
        let mut pairs = vec![
            ("version", Json::int(self.version)),
            ("step", Json::int(self.step as u64)),
            (
                "live",
                Json::arr(self.live.iter().map(|&l| Json::Bool(l))),
            ),
            ("replicas", replicas),
            ("sync", sync),
            ("outcome", outcome),
            ("journal", self.journal.to_json()),
        ];
        if let Some(cfg) = &self.config {
            pairs.push(("config", cfg.clone()));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Checkpoint> {
        let version = j.u64_of("version")?;
        if version != CHECKPOINT_VERSION {
            bail!("checkpoint version {version} (this build reads {CHECKPOINT_VERSION})");
        }
        let live = j
            .arr_of("live")?
            .iter()
            .map(|v| {
                v.as_bool()
                    .ok_or_else(|| anyhow::anyhow!("checkpoint: live flag is not a bool"))
            })
            .collect::<Result<Vec<bool>>>()?;
        let mut replicas = Vec::new();
        for (r, item) in j.arr_of("replicas")?.iter().enumerate() {
            let mut leaves = Vec::new();
            for leaf in item.arr_of("leaves")? {
                let shape = leaf
                    .arr_of("shape")?
                    .iter()
                    .map(|d| {
                        d.as_usize()
                            .ok_or_else(|| anyhow::anyhow!("checkpoint: bad shape dim"))
                    })
                    .collect::<Result<Vec<usize>>>()?;
                let data = f32_of_hex(&leaf.str_of("data")?)
                    .with_context(|| format!("checkpoint: replica {r} leaf data"))?;
                if shape.iter().product::<usize>() != data.len() {
                    bail!(
                        "checkpoint: replica {r}: shape {:?} does not fit {} elements",
                        shape,
                        data.len()
                    );
                }
                leaves.push((shape, data));
            }
            replicas.push(ReplicaCkpt {
                leaves,
                residual: f32_of_hex(&item.str_of("residual")?)?,
                consumed: item.u64_of("consumed")?,
            });
        }
        let sync = match j.req("sync")? {
            Json::Null => None,
            st => {
                let mut wire_records = Vec::new();
                for w in st.arr_of("wire")? {
                    wire_records.push(SyncWireRecord {
                        sync_index: w.u64_of("sync_index")?,
                        frag: w.get("frag").and_then(|v| v.as_usize()),
                        replicas: w.usize_of("replicas")?,
                        bytes_per_replica: w.u64_of("bytes_per_replica")?,
                        bytes_down: w.u64_of("bytes_down")?,
                    });
                }
                Some(SyncState {
                    global: f32_of_hex(&st.str_of("global")?)?,
                    velocity: f32_of_hex(&st.str_of("velocity")?)?,
                    down_view: st
                        .get("down_view")
                        .map(|v| {
                            f32_of_hex(v.as_str().ok_or_else(|| {
                                anyhow::anyhow!("checkpoint: down_view is not a string")
                            })?)
                        })
                        .transpose()?,
                    down_residual: st
                        .get("down_residual")
                        .map(|v| {
                            f32_of_hex(v.as_str().ok_or_else(|| {
                                anyhow::anyhow!("checkpoint: down_residual is not a string")
                            })?)
                        })
                        .transpose()?,
                    wire_records,
                })
            }
        };
        let out = j.req("outcome")?;
        let curve = |key: &str| -> Result<Vec<(usize, f64)>> {
            out.arr_of(key)?
                .iter()
                .map(|pt| {
                    let pair = pt
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("checkpoint: curve point not a pair"))?;
                    if pair.len() != 2 {
                        bail!("checkpoint: curve point not a pair");
                    }
                    let t = pair[0]
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("checkpoint: bad curve step"))?;
                    Ok((t, f64_of_json_bits(&pair[1])?))
                })
                .collect()
        };
        let outcome = OutcomeCkpt {
            step_losses: out
                .arr_of("step_losses")?
                .iter()
                .map(f64_of_json_bits)
                .collect::<Result<Vec<f64>>>()?,
            loss_curve: curve("loss_curve")?,
            eval_curve: curve("eval_curve")?,
            outer_syncs: out.usize_of("outer_syncs")?,
        };
        Ok(Checkpoint {
            version,
            step: j.usize_of("step")?,
            live,
            replicas,
            sync,
            outcome,
            journal: Journal::from_json(j.req("journal")?)?,
            config: j.get("config").cloned(),
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        self.to_json().write_file(path)
    }

    pub fn load(path: &std::path::Path) -> Result<Checkpoint> {
        Checkpoint::from_json(&Json::parse_file(path)?)
            .with_context(|| format!("loading checkpoint {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_f32_roundtrips_exactly() {
        let v: Vec<f32> = vec![
            0.0,
            -0.0,
            1.5,
            f32::MIN_POSITIVE,
            f32::MAX,
            -1e-30,
            std::f32::consts::PI,
        ];
        let hex = hex_of_f32(&v);
        assert_eq!(hex.len(), v.len() * 8);
        let back = f32_of_hex(&hex).unwrap();
        assert_eq!(
            back.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert!(f32_of_hex("abc").is_err(), "odd length rejected");
        assert!(f32_of_hex("zzzzzzzz").is_err(), "bad digit rejected");
    }

    #[test]
    fn f64_bits_roundtrip_through_json_text() {
        for v in [0.1, -3.25e-17, f64::MAX, 1.0 / 3.0] {
            let j = json_of_f64_bits(v);
            let text = j.to_string_compact();
            let back = f64_of_json_bits(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn checkpoint_json_roundtrips() {
        let mut journal = Journal::new();
        journal.append(4, 1, super::super::journal::EventKind::SyncMerge, None, "");
        let ck = Checkpoint {
            version: CHECKPOINT_VERSION,
            step: 12,
            live: vec![true, false, true],
            replicas: vec![ReplicaCkpt {
                leaves: vec![(vec![2, 2], vec![1.0, -2.5, 0.25, 9.0]), (vec![1], vec![7.0])],
                residual: vec![0.125, -0.5],
                consumed: 4096,
            }],
            sync: Some(SyncState {
                global: vec![1.0, 2.0],
                velocity: vec![],
                down_view: Some(vec![0.5, 0.5]),
                down_residual: Some(vec![0.0, -0.25]),
                wire_records: vec![SyncWireRecord {
                    sync_index: 0,
                    frag: Some(1),
                    replicas: 2,
                    bytes_per_replica: 40,
                    bytes_down: 20,
                }],
            }),
            outcome: OutcomeCkpt {
                step_losses: vec![0.5, 0.25],
                loss_curve: vec![(1, 0.5)],
                eval_curve: vec![(2, 0.75)],
                outer_syncs: 1,
            },
            journal,
            config: Some(Json::obj(vec![("seed", Json::int(7u64))])),
        };
        let text = ck.to_json().to_string_pretty();
        let back = Checkpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.step, 12);
        assert_eq!(back.live, ck.live);
        assert_eq!(back.replicas[0].leaves, ck.replicas[0].leaves);
        assert_eq!(back.replicas[0].residual, ck.replicas[0].residual);
        assert_eq!(back.replicas[0].consumed, 4096);
        assert_eq!(back.sync, ck.sync);
        assert_eq!(back.outcome.step_losses, ck.outcome.step_losses);
        assert_eq!(back.outcome.eval_curve, ck.outcome.eval_curve);
        assert_eq!(back.journal.events(), ck.journal.events());
        assert_eq!(back.config.unwrap().u64_of("seed").unwrap(), 7);

        // literals rebuild with the right shapes
        let lits = back.replicas[0].literals().unwrap();
        assert_eq!(lits.len(), 2);
        assert_eq!(lits[0].to_vec::<f32>().unwrap(), vec![1.0, -2.5, 0.25, 9.0]);
    }

    #[test]
    fn version_mismatch_fails_loud() {
        let j = Json::parse(r#"{"version": 999}"#).unwrap();
        assert!(Checkpoint::from_json(&j).is_err());
    }
}
