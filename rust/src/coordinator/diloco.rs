//! The DiLoCo training coordinator — paper Algorithm 1, verbatim.
//!
//! M replica states (params + AdamW moments, which *persist across
//! rounds* — the key difference from FedOpt) take H inner AdamW steps
//! on their own data shards; every H steps the coordinator forms the
//! outer gradient Delta = theta_global - mean_m(theta_m), applies an
//! outer SGD-Nesterov step, and broadcasts the new global params back.
//! Data-Parallel is the degenerate configuration (M=1, no outer step).
//!
//! Replica state lives as shared `Arc<xla::Literal>`s between steps
//! (no host copies on the inner path); host round-trips happen only at
//! the H-cadence sync and for scalar metrics. The sync itself runs on
//! the flat parameter bus (`runtime::bus` + `coordinator::sync`):
//! pulls touch only the due fragment's leaves, the outer step is a
//! zero-alloc vectorized pass over offset ranges, and the broadcast
//! uploads each synced leaf once, sharing the immutable literal across
//! all M replicas and the eval path.
//!
//! The "parallel for" over replicas is real concurrency: the worker
//! pool (`coordinator::pool`) gives each replica a persistent owner
//! thread that runs its H inner steps between outer syncs. The outer
//! step is no longer a hard barrier: with `--overlap-tau` > 0 the
//! drive loop emits **send** and **merge** events instead of
//! barrier-bounded segments — workers ship their sync contribution
//! and keep stepping, the coordinator reduces under their compute,
//! and the broadcast merges τ inner steps after the send (Streaming
//! DiLoCo's delayed application; τ=0 reproduces the barrier bit for
//! bit). `RunConfig::workers` picks the thread count; 1 (the default)
//! is the sequential oracle, and any worker count produces
//! bit-identical results at every τ (per-replica RNG streams and
//! coordinator-side reductions are scheduling-independent — see the
//! pool module docs). The analytic `netsim` wall-clock model (paper
//! Appendix A, with the overlap term `max(0, t_comm − τ·t_step)`) is
//! cross-checked against measured pool concurrency in
//! `benches/bench_hot_path.rs`.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::comm::{codec_for, OuterBits};
use crate::config::OptimizerPolicy;
use crate::data::downstream::{scoring_input, McTaskSpec};
use crate::data::synthetic::{CorpusSpec, TokenStream};
use crate::runtime::{
    decompose_micro, f32_scalar, i32_literal, scalar_f32, u32_scalar, Executable, FlatLayout,
    HostTensor, ModelRuntime,
};
use crate::train::schedule::{weight_decay, LrSchedule};
use crate::util::json::Json;

use super::checkpoint::Checkpoint;
use super::membership::{FaultEvent, FaultPlan};
use super::pool::{drive_ctl, DriveCtl, DriveOutcome, DrivePlan, InnerEngine, ReplicaState};
use super::sync::OuterSync;

/// Stream-id namespace: replicas use 0..M, eval uses the high range.
const EVAL_STREAM: u64 = 0xF000_0001;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    DataParallel,
    DiLoCo { replicas: usize },
}

impl Algo {
    pub fn replicas(&self) -> usize {
        match self {
            Algo::DataParallel => 1,
            Algo::DiLoCo { replicas } => *replicas,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Algo::DataParallel => "dp".into(),
            Algo::DiLoCo { replicas } => format!("diloco-m{replicas}"),
        }
    }

    pub fn parse(s: &str) -> Result<Algo> {
        if s == "dp" || s == "data-parallel" {
            return Ok(Algo::DataParallel);
        }
        if let Some(m) = s.strip_prefix("diloco-m").or_else(|| s.strip_prefix("m")) {
            return Ok(Algo::DiLoCo {
                replicas: m.parse().context("replica count")?,
            });
        }
        bail!("unknown algorithm {s:?} (want dp | diloco-mK)")
    }
}

/// Full specification of one training run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub algo: Algo,
    /// Synchronization cadence H (ignored for Data-Parallel).
    pub sync_every: usize,
    /// Global batch size B in sequences (tokens = B * seq_len). Evenly
    /// partitioned across replicas (Algorithm 1 line 4).
    pub global_batch_seqs: usize,
    pub inner_lr: f64,
    pub outer_lr: f64,
    /// Token budget override; None = Chinchilla 20N from the manifest.
    pub token_budget: Option<usize>,
    /// Overtraining multiplier lambda (paper section 5.2): D = 20N*lambda.
    pub overtrain: f64,
    pub seed: u64,
    /// Held-out tokens for eval loss.
    pub eval_tokens: usize,
    /// Evaluate every k steps (None = final only).
    pub eval_every: Option<usize>,
    pub downstream: bool,
    pub log_every: usize,
    /// Perf instrumentation: disable the fused train_step fast path and
    /// force the grad_step/grad_acc/apply_update decomposition even when
    /// the local batch matches the fused artifact (EXPERIMENTS.md §Perf).
    pub force_accumulate: bool,
    /// Streaming DiLoCo (paper section 8, Appendix A): split the outer
    /// sync into P parameter fragments, one fragment synchronized every
    /// H/P steps (offset round-robin). 1 = vanilla DiLoCo. Requires
    /// H % P == 0. Total communication is unchanged; peak per-sync
    /// traffic drops by P.
    pub streaming_fragments: usize,
    /// Overlapped outer sync (`--overlap-tau`, Streaming DiLoCo's
    /// delayed application): a fragment's contributions are sent at
    /// the sync boundary, the workers keep stepping, and the reduced
    /// broadcast merges into live replica params exactly τ inner
    /// steps later — the coordinator's reduce + outer step + encode
    /// hide under compute, and `netsim` charges the outer leg
    /// `max(0, t_comm − τ·t_step)`. 0 (the default) is the exact
    /// barrier schedule. Must be < H/P; changes training results for
    /// τ > 0, so it IS part of the sweep-store run id (`_tau{τ}`).
    pub overlap_tau: usize,
    /// Worker threads for the replica-parallel inner loop (clamped to
    /// [1, M]). 1 = sequential execution, the deterministic oracle the
    /// parallel path is pinned against; any value yields bit-identical
    /// training results, so this is a pure wall-clock knob and is
    /// deliberately excluded from sweep-store run ids.
    pub workers: usize,
    /// Coordinator-side sync parallelism (`--sync-threads`): how many
    /// threads shard the fused decode→reduce and the flat-bus outer
    /// step. 0 (the default) means "match `workers`". The sharding is
    /// block-aligned with deterministic range ownership, so any value
    /// yields bit-identical training results — like `workers`, a pure
    /// wall-clock knob, deliberately excluded from sweep-store run ids.
    pub sync_threads: usize,
    /// Up-wire bit width (`--outer-bits`, paper section 7): the wire
    /// codec replicas encode their sync contribution with. Fp32 is the
    /// identity oracle (bit-identical to the uncompressed path); lower
    /// widths quantize the outer gradients with per-block scales,
    /// stochastic rounding, and error feedback (see `crate::comm`).
    /// Changes training results, so it IS part of the sweep-store run
    /// id.
    pub outer_bits: OuterBits,
    /// Down-wire bit width (`--outer-bits-down`): the broadcast codec
    /// the coordinator pushes the refreshed global back out with. Fp32
    /// keeps the zero-copy deduplicated literal handoff; lower widths
    /// quantize the broadcast with a coordinator-owned error-feedback
    /// stream (Streaming DiLoCo compresses the merged-model push the
    /// same way). Changes training results, so it too is part of the
    /// run id.
    pub outer_bits_down: OuterBits,
    /// Deterministic membership-churn spec (`--churn`, see
    /// `membership::FaultPlan` for the grammar): explicit
    /// `crash|leave|join|straggle@K:rR` events plus an optional
    /// seed-derived `rate=P` crash rate, keyed to absolute outer-sync
    /// indices. The empty spec (the default) is the churn-free path,
    /// bit-identical to a build without membership support. Changes
    /// training results, so a non-empty spec IS part of the sweep-store
    /// run id (`_ch{spec}`). Inert for Data-Parallel.
    pub churn: String,
    /// Print a per-sync stage-latency breakdown (`sync:` lines on
    /// stderr: encode / wire-wait / decode+reduce / outer-step /
    /// broadcast). Pure observability — deliberately excluded from
    /// `to_json` and therefore from the handshake fingerprint, so a
    /// verbose coordinator still accepts quiet workers and resumed
    /// checkpoints are unaffected.
    pub verbose: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "m0".into(),
            algo: Algo::DataParallel,
            sync_every: 30,
            global_batch_seqs: 16,
            inner_lr: 6e-3,
            outer_lr: 0.8,
            token_budget: None,
            overtrain: 1.0,
            seed: 17,
            eval_tokens: 32 * 1024,
            eval_every: None,
            downstream: false,
            log_every: 200,
            force_accumulate: false,
            streaming_fragments: 1,
            overlap_tau: 0,
            workers: 1,
            sync_threads: 0,
            outer_bits: OuterBits::Fp32,
            outer_bits_down: OuterBits::Fp32,
            churn: String::new(),
            verbose: false,
        }
    }
}

impl RunConfig {
    /// Serialize for checkpoint embedding: `diloco checkpoint` stores
    /// the originating config inside the checkpoint file so `diloco
    /// resume` rebuilds the identical run without re-supplied flags.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("algo", Json::str(&self.algo.label())),
            ("sync_every", Json::int(self.sync_every as u64)),
            ("global_batch_seqs", Json::int(self.global_batch_seqs as u64)),
            ("inner_lr", Json::num(self.inner_lr)),
            ("outer_lr", Json::num(self.outer_lr)),
            (
                "token_budget",
                match self.token_budget {
                    Some(b) => Json::int(b as u64),
                    None => Json::Null,
                },
            ),
            ("overtrain", Json::num(self.overtrain)),
            ("seed", Json::int(self.seed)),
            ("eval_tokens", Json::int(self.eval_tokens as u64)),
            (
                "eval_every",
                match self.eval_every {
                    Some(k) => Json::int(k as u64),
                    None => Json::Null,
                },
            ),
            ("downstream", Json::Bool(self.downstream)),
            ("log_every", Json::int(self.log_every as u64)),
            ("force_accumulate", Json::Bool(self.force_accumulate)),
            ("streaming_fragments", Json::int(self.streaming_fragments as u64)),
            ("overlap_tau", Json::int(self.overlap_tau as u64)),
            ("workers", Json::int(self.workers as u64)),
            ("sync_threads", Json::int(self.sync_threads as u64)),
            ("outer_bits", Json::str(self.outer_bits.label())),
            ("outer_bits_down", Json::str(self.outer_bits_down.label())),
            ("churn", Json::str(&self.churn)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        Ok(RunConfig {
            model: j.str_of("model")?,
            algo: Algo::parse(&j.str_of("algo")?)?,
            sync_every: j.usize_of("sync_every")?,
            global_batch_seqs: j.usize_of("global_batch_seqs")?,
            inner_lr: j.f64_of("inner_lr")?,
            outer_lr: j.f64_of("outer_lr")?,
            token_budget: j.get("token_budget").and_then(|v| v.as_usize()),
            overtrain: j.f64_of("overtrain")?,
            seed: j.u64_of("seed")?,
            eval_tokens: j.usize_of("eval_tokens")?,
            eval_every: j.get("eval_every").and_then(|v| v.as_usize()),
            downstream: j.req("downstream")?.as_bool().unwrap_or(false),
            log_every: j.usize_of("log_every")?,
            force_accumulate: j.req("force_accumulate")?.as_bool().unwrap_or(false),
            streaming_fragments: j.usize_of("streaming_fragments")?,
            overlap_tau: j.usize_of("overlap_tau")?,
            workers: j.usize_of("workers")?,
            // tolerant: checkpoints from before the knob default to auto
            sync_threads: j.get("sync_threads").and_then(|v| v.as_usize()).unwrap_or(0),
            outer_bits: OuterBits::parse(&j.str_of("outer_bits")?)?,
            outer_bits_down: OuterBits::parse(&j.str_of("outer_bits_down")?)?,
            churn: j
                .get("churn")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            // observability knob, never serialized: quiet on resume
            verbose: false,
        })
    }

    /// FNV-1a fingerprint of the canonical config JSON — what the TCP
    /// handshake pins, so a worker launched with a different model,
    /// seed, schedule, or codec pair is rejected before it can corrupt
    /// a run. Serialization is deterministic (ordered keys, exact
    /// integer carriage), so equal configs always fingerprint equal.
    pub fn fingerprint(&self) -> u64 {
        crate::transport::frame::fnv1a64(self.to_json().to_string().as_bytes())
    }
}

/// Everything measured during a run (serialized into the sweep store).
#[derive(Debug, Clone)]
pub struct RunMetrics {
    pub model: String,
    pub algo: String,
    pub replicas: usize,
    pub sync_every: usize,
    pub global_batch_tokens: usize,
    pub inner_lr: f64,
    pub outer_lr: f64,
    pub overtrain: f64,
    pub seed: u64,
    pub param_count: usize,
    pub steps: usize,
    pub tokens: usize,
    pub final_eval_loss: f64,
    pub final_train_loss: f64,
    pub eval_curve: Vec<(usize, f64)>,
    pub loss_curve: Vec<(usize, f64)>,
    pub downstream: Vec<(String, f64)>,
    pub outer_syncs: usize,
    pub wall_secs: f64,
    /// Streaming fragment count P the run used (1 = vanilla).
    pub fragments: usize,
    /// Delayed-application window τ the run used (0 = barrier).
    pub overlap_tau: usize,
    /// Up-wire bit width the run used (32 = uncompressed).
    pub outer_bits: u32,
    /// Down-wire (broadcast) bit width the run used (32 = literal
    /// handoff).
    pub outer_bits_down: u32,
    /// Exact replica→coordinator wire bytes across all outer syncs
    /// (encoded payload sizes, counted on the bus; 0 for DP).
    pub wire_up_bytes: u64,
    /// Exact coordinator→replica broadcast bytes across all outer
    /// syncs — the down codec's encoded payload sizes, counted once
    /// per sync (0 for DP).
    pub wire_down_bytes: u64,
    /// Wire bytes as framed on a real socket: payloads plus one
    /// length-prefixed transport header per contribution/broadcast
    /// stream (`transport::frame::FRAME_OVERHEAD` each). The payload
    /// counts above stay the paper-facing numbers; this is what the
    /// TCP transport actually moves.
    pub wire_framed_bytes: u64,
    /// The membership-churn spec the run used ("" = churn-free).
    pub churn: String,
    /// Fraction of (sync, replica) contribution slots the churn plan
    /// cost the run (crashes + leaves over m × n_syncs) — the x-axis
    /// of `diloco report --exp churn`.
    pub dropout_rate: f64,
    /// Mean per-sync stage latencies in milliseconds, from the outer
    /// bus's stage log (0.0 when the run had no outer syncs or no
    /// codec). `sync_wire_wait_ms` is the collect wall time *minus*
    /// any decode→reduce work that ran inside the collect — under the
    /// arrival-pipelined up-leg that subtraction is exactly the
    /// overlap won, so streamed runs show it shrinking while
    /// `sync_reduce_ms` holds steady.
    pub sync_encode_ms: f64,
    pub sync_wire_wait_ms: f64,
    pub sync_reduce_ms: f64,
    pub sync_step_ms: f64,
    pub sync_bcast_ms: f64,
}

impl RunMetrics {
    pub fn to_json(&self) -> Json {
        let curve = |c: &[(usize, f64)]| {
            Json::arr(c.iter().map(|&(s, l)| {
                Json::arr([Json::num(s as f64), Json::num(l)])
            }))
        };
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("algo", Json::str(&self.algo)),
            ("replicas", Json::num(self.replicas as f64)),
            ("sync_every", Json::num(self.sync_every as f64)),
            ("global_batch_tokens", Json::num(self.global_batch_tokens as f64)),
            ("inner_lr", Json::num(self.inner_lr)),
            ("outer_lr", Json::num(self.outer_lr)),
            ("overtrain", Json::num(self.overtrain)),
            // seeds are u64 and must round-trip exactly (2^53-safe);
            // Json::int carries integers without an f64 detour.
            ("seed", Json::int(self.seed)),
            ("param_count", Json::num(self.param_count as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("final_eval_loss", Json::num(self.final_eval_loss)),
            ("final_train_loss", Json::num(self.final_train_loss)),
            ("eval_curve", curve(&self.eval_curve)),
            ("loss_curve", curve(&self.loss_curve)),
            (
                "downstream",
                Json::obj(
                    self.downstream
                        .iter()
                        .map(|(k, v)| (k.as_str(), Json::num(*v)))
                        .collect(),
                ),
            ),
            ("outer_syncs", Json::num(self.outer_syncs as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("fragments", Json::num(self.fragments as f64)),
            ("overlap_tau", Json::num(self.overlap_tau as f64)),
            ("outer_bits", Json::int(self.outer_bits)),
            ("outer_bits_down", Json::int(self.outer_bits_down)),
            // wire bytes are u64 exact counts; Json::int avoids f64
            ("wire_up_bytes", Json::int(self.wire_up_bytes)),
            ("wire_down_bytes", Json::int(self.wire_down_bytes)),
            ("wire_framed_bytes", Json::int(self.wire_framed_bytes)),
            ("churn", Json::str(&self.churn)),
            ("dropout_rate", Json::num(self.dropout_rate)),
            ("sync_encode_ms", Json::num(self.sync_encode_ms)),
            ("sync_wire_wait_ms", Json::num(self.sync_wire_wait_ms)),
            ("sync_reduce_ms", Json::num(self.sync_reduce_ms)),
            ("sync_step_ms", Json::num(self.sync_step_ms)),
            ("sync_bcast_ms", Json::num(self.sync_bcast_ms)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RunMetrics> {
        let curve = |key: &str| -> Result<Vec<(usize, f64)>> {
            j.arr_of(key)?
                .iter()
                .map(|p| {
                    let a = p.as_arr().context("curve point")?;
                    Ok((
                        a[0].as_usize().context("step")?,
                        a[1].as_f64().context("loss")?,
                    ))
                })
                .collect()
        };
        let mut downstream = Vec::new();
        if let Some(Json::Obj(map)) = j.get("downstream") {
            for (k, v) in map {
                downstream.push((k.clone(), v.as_f64().unwrap_or(f64::NAN)));
            }
        }
        Ok(RunMetrics {
            model: j.str_of("model")?,
            algo: j.str_of("algo")?,
            replicas: j.usize_of("replicas")?,
            sync_every: j.usize_of("sync_every")?,
            global_batch_tokens: j.usize_of("global_batch_tokens")?,
            inner_lr: j.f64_of("inner_lr")?,
            outer_lr: j.f64_of("outer_lr")?,
            overtrain: j.f64_of("overtrain")?,
            seed: j.u64_of("seed")?,
            param_count: j.usize_of("param_count")?,
            steps: j.usize_of("steps")?,
            tokens: j.usize_of("tokens")?,
            final_eval_loss: j.f64_of("final_eval_loss")?,
            final_train_loss: j.f64_of("final_train_loss")?,
            eval_curve: curve("eval_curve")?,
            loss_curve: curve("loss_curve")?,
            downstream,
            outer_syncs: j.usize_of("outer_syncs")?,
            wall_secs: j.f64_of("wall_secs")?,
            // absent in pre-overlap records: the fragment count was
            // not recorded then and τ did not exist — all old sweep
            // grids ran P=1 barrier schedules
            fragments: j
                .get("fragments")
                .and_then(|v| v.as_u64())
                .unwrap_or(1) as usize,
            overlap_tau: j
                .get("overlap_tau")
                .and_then(|v| v.as_u64())
                .unwrap_or(0) as usize,
            // absent in pre-comm-subsystem records: those ran the
            // uncompressed path and counted no wire bytes
            outer_bits: j
                .get("outer_bits")
                .and_then(|v| v.as_u64())
                .unwrap_or(32) as u32,
            // absent before the down-wire landed: those runs broadcast
            // f32 literals
            outer_bits_down: j
                .get("outer_bits_down")
                .and_then(|v| v.as_u64())
                .unwrap_or(32) as u32,
            wire_up_bytes: j.get("wire_up_bytes").and_then(|v| v.as_u64()).unwrap_or(0),
            wire_down_bytes: j
                .get("wire_down_bytes")
                .and_then(|v| v.as_u64())
                .unwrap_or(0),
            // absent in pre-transport records: approximate with the
            // payload totals (headers unknowable after the fact)
            wire_framed_bytes: j.get("wire_framed_bytes").and_then(|v| v.as_u64()).unwrap_or(
                j.get("wire_up_bytes").and_then(|v| v.as_u64()).unwrap_or(0)
                    + j.get("wire_down_bytes")
                        .and_then(|v| v.as_u64())
                        .unwrap_or(0),
            ),
            // absent in pre-membership records: those ran churn-free
            churn: j
                .get("churn")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            dropout_rate: j.get("dropout_rate").and_then(|v| v.as_f64()).unwrap_or(0.0),
            // absent in pre-pipelined-sync records: no stage log then
            sync_encode_ms: j.get("sync_encode_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
            sync_wire_wait_ms: j
                .get("sync_wire_wait_ms")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            sync_reduce_ms: j.get("sync_reduce_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
            sync_step_ms: j.get("sync_step_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
            sync_bcast_ms: j.get("sync_bcast_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
        })
    }
}

/// The PJRT-backed inner engine the worker pool schedules: one AdamW
/// step per call on either the fused `train_step` artifact or the
/// grad/accumulate/apply decomposition (chosen once per run), plus the
/// held-out eval path. Shared by `&self` across worker threads — the
/// executables are `Arc`s into the process-wide compile cache and PJRT
/// CPU execution is thread-safe per client; each call's mutable state
/// (literal handles, token shard) is owned by exactly one worker.
struct PjrtEngine {
    n: usize,
    seq: usize,
    local_seqs: usize,
    sched: LrSchedule,
    wd: f64,
    train_step: Option<Arc<Executable>>,
    micro_plan: Option<Vec<usize>>,
    grad_steps: BTreeMap<usize, Arc<Executable>>,
    grad_acc: Option<Arc<Executable>>,
    apply_update: Option<Arc<Executable>>,
    eval_step: Arc<Executable>,
    eval_batch: usize,
    eval_tokens: usize,
    corpus: CorpusSpec,
    seed: u64,
}

impl InnerEngine for PjrtEngine {
    fn inner_step(&self, _rep: usize, replica: &mut ReplicaState, t: usize) -> Result<f64> {
        let n = self.n;
        let seq = self.seq;
        let lr = self.sched.lr(t);
        let step_lit = f32_scalar(t as f32);
        let lr_lit = f32_scalar(lr as f32);
        let wd_lit = f32_scalar(self.wd as f32);
        match &self.micro_plan {
            None => {
                // fused path: one dispatch
                let toks = replica.shard.next_batch(self.local_seqs, seq);
                let tok_lit = i32_literal(&[self.local_seqs, seq], &toks)?;
                let mut args: Vec<&xla::Literal> =
                    replica.state.iter().map(|l| &**l).collect();
                args.push(&tok_lit);
                args.push(&step_lit);
                args.push(&lr_lit);
                args.push(&wd_lit);
                let out = self.train_step.as_ref().expect("fused path").call(&args)?;
                let loss = scalar_f32(&out[3 * n])? as f64;
                replica.state = out.into_iter().take(3 * n).map(Arc::new).collect();
                Ok(loss)
            }
            Some(plan) => {
                // micro-batch accumulation path
                let mut acc: Option<Vec<xla::Literal>> = None;
                let mut loss_sum = 0.0f64;
                for &mb in plan {
                    let toks = replica.shard.next_batch(mb, seq);
                    let tok_lit = i32_literal(&[mb, seq], &toks)?;
                    let gs = &self.grad_steps[&mb];
                    let mut args: Vec<&xla::Literal> =
                        replica.state[..n].iter().map(|l| &**l).collect();
                    args.push(&tok_lit);
                    let out = gs.call(&args)?;
                    loss_sum +=
                        scalar_f32(&out[n])? as f64 * mb as f64 / self.local_seqs as f64;
                    let w = mb as f32 / self.local_seqs as f32;
                    let g: Vec<xla::Literal> = out.into_iter().take(n).collect();
                    acc = Some(match acc {
                        None => {
                            // scale the first micro grad by its weight
                            let wa = f32_scalar(w);
                            let wb = f32_scalar(0.0);
                            let mut args: Vec<&xla::Literal> =
                                g.iter().chain(g.iter()).collect();
                            args.push(&wa);
                            args.push(&wb);
                            self.grad_acc.as_ref().expect("accum path").call(&args)?
                        }
                        Some(prev) => {
                            let wa = f32_scalar(1.0);
                            let wb = f32_scalar(w);
                            let mut args: Vec<&xla::Literal> =
                                prev.iter().chain(g.iter()).collect();
                            args.push(&wa);
                            args.push(&wb);
                            self.grad_acc.as_ref().expect("accum path").call(&args)?
                        }
                    });
                }
                let grads = acc.unwrap();
                let mut args: Vec<&xla::Literal> = replica
                    .state
                    .iter()
                    .map(|l| &**l)
                    .chain(grads.iter())
                    .collect();
                args.push(&step_lit);
                args.push(&lr_lit);
                args.push(&wd_lit);
                let out = self.apply_update.as_ref().expect("accum path").call(&args)?;
                replica.state = out.into_iter().take(3 * n).map(Arc::new).collect();
                Ok(loss_sum)
            }
        }
    }

    /// Evaluation takes literals directly — the DiLoCo path hands the
    /// cached global literal set over without any host->device copies.
    /// The eval stream is rebuilt per call (stateless), so eval results
    /// do not depend on when the pool schedules them.
    fn eval(&self, params: &[Arc<xla::Literal>]) -> Result<f64> {
        let eb = self.eval_batch;
        let mut stream = TokenStream::new(self.corpus.clone(), self.seed, EVAL_STREAM);
        let n_batches = (self.eval_tokens / (eb * self.seq)).max(1);
        let mut sum = 0.0f64;
        let mut count = 0.0f64;
        for _ in 0..n_batches {
            let toks = stream.next_batch(eb, self.seq);
            let t = i32_literal(&[eb, self.seq], &toks)?;
            let mut args: Vec<&xla::Literal> = params.iter().map(|l| &**l).collect();
            args.push(&t);
            let out = self.eval_step.call(&args)?;
            sum += scalar_f32(&out[0])? as f64;
            count += scalar_f32(&out[1])? as f64;
        }
        Ok(sum / count)
    }

    fn inner_lr(&self, t: usize) -> Option<f64> {
        Some(self.sched.lr(t))
    }
}

/// Everything a drive needs, built once from a [`RunConfig`] — shared
/// by [`run`], [`run_checkpoint`], and [`run_resume`], so a resumed run
/// reconstructs the identical engine, schedule, and fault plan that the
/// interrupted run was using (bit-identical continuation depends on it).
struct Prepared {
    engine: PjrtEngine,
    plan: DrivePlan,
    sync: Option<OuterSync>,
    /// The replica universe (initial replicas + planned-joiner slots),
    /// fresh-initialized; resume overwrites states from the checkpoint.
    replicas: Vec<ReplicaState>,
    /// Resolved fault events (empty = churn-free).
    events: Vec<FaultEvent>,
    corpus: CorpusSpec,
    m_replicas: usize,
    universe: usize,
    tokens_per_step: usize,
    h: usize,
    is_diloco: bool,
    outer_bits: OuterBits,
    outer_bits_down: OuterBits,
    n: usize,
    /// Normalized churn spec ("" for DP, where churn is inert).
    churn_spec: String,
    dropout_rate: f64,
}

fn prepare(mr: &ModelRuntime, policy: &OptimizerPolicy, cfg: &RunConfig) -> Result<Prepared> {
    let n = mr.n_leaves();
    let seq = mr.manifest.model.seq_len;
    let m_replicas = cfg.algo.replicas();
    if m_replicas == 0 {
        bail!("replicas must be >= 1");
    }
    if cfg.global_batch_seqs % m_replicas != 0 {
        bail!(
            "global batch ({} seqs) must divide evenly across {m_replicas} replicas",
            cfg.global_batch_seqs
        );
    }
    let local_seqs = cfg.global_batch_seqs / m_replicas;
    let budget = cfg
        .token_budget
        .unwrap_or(mr.manifest.model.token_budget);
    let budget = (budget as f64 * cfg.overtrain) as usize;
    let tokens_per_step = cfg.global_batch_seqs * seq;
    let total_steps = (budget + tokens_per_step - 1) / tokens_per_step;
    if total_steps == 0 {
        bail!("token budget {budget} smaller than one batch");
    }
    let sched = LrSchedule::new(
        cfg.inner_lr,
        total_steps,
        policy.warmup_frac,
        policy.warmup_cap,
        policy.final_lr_frac,
    );
    let wd = weight_decay(total_steps);
    let is_diloco = matches!(cfg.algo, Algo::DiLoCo { .. });
    let h = if is_diloco { cfg.sync_every.max(1) } else { usize::MAX };
    let fragments = cfg.streaming_fragments.max(1);
    if is_diloco && fragments > 1 && h % fragments != 0 {
        bail!("streaming_fragments ({fragments}) must divide H ({h})");
    }
    // streaming: one fragment syncs every H/P steps, round-robin.
    let frag_interval = if fragments > 1 { h / fragments } else { h };
    // overlap: the broadcast merges τ inner steps after the send; DP
    // has no broadcast to delay, so the knob is inert there
    let overlap_tau = if is_diloco { cfg.overlap_tau } else { 0 };
    if is_diloco && overlap_tau >= frag_interval {
        bail!(
            "overlap_tau ({overlap_tau}) must be smaller than the per-fragment \
             sync interval H/P ({frag_interval}) so at most one fragment is in \
             flight"
        );
    }
    if !is_diloco && cfg.overlap_tau != 0 {
        log::warn!(
            "--overlap-tau {} has no effect for Data-Parallel (no outer sync); recording 0",
            cfg.overlap_tau
        );
    }
    // DP has no outer wire: --outer-bits / --outer-bits-down are inert
    // there, so normalize both to fp32 (metrics + run ids must not
    // pretend a codec ran)
    let outer_bits = if is_diloco { cfg.outer_bits } else { OuterBits::Fp32 };
    let outer_bits_down = if is_diloco { cfg.outer_bits_down } else { OuterBits::Fp32 };
    if !is_diloco && cfg.outer_bits != OuterBits::Fp32 {
        log::warn!(
            "--outer-bits {} has no effect for Data-Parallel (no outer sync); recording 32",
            cfg.outer_bits.label()
        );
    }
    if !is_diloco && cfg.outer_bits_down != OuterBits::Fp32 {
        log::warn!(
            "--outer-bits-down {} has no effect for Data-Parallel (no broadcast); recording 32",
            cfg.outer_bits_down.label()
        );
    }

    // ---- membership / churn ---------------------------------------------
    // The fault plan resolves against the run shape (replica count,
    // total sends) into a concrete event list; the universe is fixed
    // here so replica ids, shards, and encode seeds never shift when
    // membership changes mid-run.
    let fault_plan = if is_diloco {
        FaultPlan::parse(&cfg.churn, cfg.seed)?
    } else {
        if !cfg.churn.is_empty() {
            log::warn!(
                "--churn {:?} has no effect for Data-Parallel (no membership); recording none",
                cfg.churn
            );
        }
        FaultPlan::default()
    };
    let universe = fault_plan.universe(m_replicas);
    // Send boundaries: every frag_interval steps, plus the final flush.
    let n_sends = ((total_steps - 1) / frag_interval + 1) as u64;
    let events = fault_plan.resolve(m_replicas, n_sends);
    for ev in &events {
        if ev.replica >= universe {
            bail!(
                "churn: {}@{}:r{} references a replica outside the universe of \
                 {universe} slots (only join events widen it)",
                ev.kind.label(),
                ev.at_sync,
                ev.replica
            );
        }
    }
    let dropout_rate = fault_plan.dropout_rate(m_replicas, n_sends);
    if !events.is_empty() {
        log::info!(
            "churn: {} events over {n_sends} sends (dropout rate {dropout_rate:.3})",
            events.len()
        );
    }

    log::info!(
        "run {} {} B={} tok/step, T={total_steps}, lr={}, H={}, wd={wd:.2e}, outer_bits={}/{} (up/down), tau={overlap_tau}",
        cfg.model,
        cfg.algo.label(),
        tokens_per_step,
        cfg.inner_lr,
        if is_diloco { h } else { 0 },
        outer_bits.label(),
        outer_bits_down.label(),
    );

    // ---- artifacts ------------------------------------------------------
    // Path choice (EXPERIMENTS.md §Perf): the fused train_step is ~9%
    // faster per step but costs 15-48s of XLA compilation; the split
    // grad/apply artifacts compile in <3s. Use the fused path only when
    // its compile cost amortizes: it is already compiled in this
    // process (sweeps re-use executables across runs) or the run is
    // long enough (M replicas each step the executable).
    let fused_batch = mr.manifest.train_step_batch();
    let use_fused = local_seqs == fused_batch
        && !cfg.force_accumulate
        && (mr.is_compiled("train_step") || total_steps * m_replicas >= 4000);
    let init = mr.artifact("init")?;
    let train_step = if use_fused {
        Some(mr.artifact("train_step")?)
    } else {
        None
    };
    let eval_step = mr.artifact("eval_step")?;
    let micro_sizes = mr.manifest.micro_batches_desc();
    let micro_plan = if use_fused {
        None // fused fast path
    } else {
        Some(decompose_micro(local_seqs, &micro_sizes)?)
    };
    // Compile only what this run's plan actually dispatches — XLA CPU
    // compilation is seconds per artifact (EXPERIMENTS.md §Perf).
    let (apply_update, grad_acc) = if micro_plan.is_some() {
        (Some(mr.artifact("apply_update")?), Some(mr.artifact("grad_acc")?))
    } else {
        (None, None)
    };
    let grad_steps: std::collections::BTreeMap<usize, _> = micro_plan
        .as_deref()
        .unwrap_or(&[])
        .iter()
        .copied()
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .map(|mb| Ok((mb, mr.artifact(&format!("grad_step_mb{mb}"))?)))
        .collect::<Result<_>>()?;

    // ---- state ----------------------------------------------------------
    let params0: Vec<Arc<xla::Literal>> = init
        .call(&[&u32_scalar(cfg.seed as u32)])?
        .into_iter()
        .map(Arc::new)
        .collect();
    let host_params0: Vec<HostTensor> = params0
        .iter()
        .map(|l| HostTensor::from_literal(l))
        .collect::<Result<_>>()?;
    // AdamW moments start at zero; build each leaf's zero literal once
    // and share it across every replica and both moment slots —
    // literals are immutable, and the inner step replaces (never
    // mutates) state, so init uploads 2N literals instead of 3N*M.
    let zero_moments: Vec<Arc<xla::Literal>> = host_params0
        .iter()
        .map(|p| Ok(Arc::new(HostTensor::zeros(&p.shape).to_literal()?)))
        .collect::<Result<_>>()?;
    let make_state = || -> Vec<Arc<xla::Literal>> {
        params0
            .iter()
            .chain(zero_moments.iter())
            .chain(zero_moments.iter())
            .cloned()
            .collect()
    };
    let corpus = CorpusSpec {
        vocab: mr.manifest.model.vocab,
        ..CorpusSpec::default()
    };
    // Per-replica state and data shard, owned by one pool worker each
    // for the whole run (paper Algorithm 1 line 4: shard D_m). The
    // universe includes planned-joiner slots beyond m_replicas; they
    // start dark (frozen at params0, shard unconsumed) until their
    // join event revives them from the then-current broadcast view.
    let replicas: Vec<ReplicaState> = (0..universe)
        .map(|r| ReplicaState {
            state: make_state(),
            shard: TokenStream::new(corpus.clone(), cfg.seed, r as u64),
        })
        .collect();
    // The H-cadence sync engine: flat-bus global model + outer
    // optimizer arenas + per-leaf literal cache (DiLoCo only).
    let sync: Option<OuterSync> = if is_diloco {
        let layout = Arc::new(FlatLayout::from_specs(&mr.manifest.params));
        Some(
            OuterSync::new(
                layout,
                &host_params0,
                params0.clone(),
                cfg.outer_lr,
                policy.outer_momentum,
                fragments,
            )?
            // the comm plane: workers encode their up-wire sync
            // contribution with the up codec, the coordinator decodes
            // + reduces, then pushes the broadcast back out through
            // the down codec — and every byte on both legs is counted
            // (crate::comm)
            .with_codec(codec_for(outer_bits), cfg.seed)
            .with_down_codec(codec_for(outer_bits_down))
            // 0 = auto: match the worker pool so the reduce uses the
            // same cores the segment compute just vacated
            .with_sync_threads(if cfg.sync_threads == 0 {
                cfg.workers.max(1)
            } else {
                cfg.sync_threads
            })
            .with_verbose(cfg.verbose),
        )
    } else {
        None
    };

    let engine = PjrtEngine {
        n,
        seq,
        local_seqs,
        sched,
        wd,
        train_step,
        micro_plan,
        grad_steps,
        grad_acc,
        apply_update,
        eval_step,
        eval_batch: mr.manifest.eval_batch,
        eval_tokens: cfg.eval_tokens,
        corpus: corpus.clone(),
        seed: cfg.seed,
    };

    let plan = DrivePlan {
        total_steps,
        sync_interval: frag_interval,
        fragments,
        n_params: n,
        eval_every: cfg.eval_every,
        log_every: cfg.log_every,
        workers: cfg.workers,
        overlap_tau,
    };
    Ok(Prepared {
        engine,
        plan,
        sync,
        replicas,
        events,
        corpus,
        m_replicas,
        universe,
        tokens_per_step,
        h,
        is_diloco,
        outer_bits,
        outer_bits_down,
        n,
        churn_spec: if is_diloco { cfg.churn.clone() } else { String::new() },
        dropout_rate,
    })
}

/// The drive controls a fresh (non-resumed) run starts with: initial
/// replicas live, planned-joiner slots dark, the resolved fault
/// schedule attached.
fn initial_ctl(pre: &Prepared) -> DriveCtl {
    let mut ctl = DriveCtl::fresh(pre.universe);
    for flag in ctl.live.iter_mut().skip(pre.m_replicas) {
        *flag = false;
    }
    ctl.events = pre.events.clone();
    ctl
}

/// Execute one training run end to end.
pub fn run(mr: &ModelRuntime, policy: &OptimizerPolicy, cfg: &RunConfig) -> Result<RunMetrics> {
    let t_start = std::time::Instant::now();
    let mut pre = prepare(mr, policy, cfg)?;
    let mut sync = pre.sync.take();
    let mut replicas = std::mem::take(&mut pre.replicas);
    let mut ctl = initial_ctl(&pre);
    let outcome = drive_ctl(&pre.engine, &mut replicas, sync.as_mut(), &pre.plan, &mut ctl)?;
    finish(mr, cfg, &pre, sync, &replicas, outcome, t_start)
}

/// Run until `after_sync` outer syncs have merged, then capture a
/// [`Checkpoint`] (with the originating config embedded) to `out`.
/// Returns the inner step the run stopped at. `run_resume` continues
/// such a checkpoint bit-identically to the uninterrupted run.
pub fn run_checkpoint(
    mr: &ModelRuntime,
    policy: &OptimizerPolicy,
    cfg: &RunConfig,
    after_sync: u64,
    out: &std::path::Path,
) -> Result<usize> {
    let mut pre = prepare(mr, policy, cfg)?;
    if !pre.is_diloco {
        bail!("checkpoint: Data-Parallel has no outer syncs to stop at (use DiLoCo)");
    }
    let mut sync = pre.sync.take();
    let mut replicas = std::mem::take(&mut pre.replicas);
    let mut ctl = initial_ctl(&pre);
    ctl.stop_after_sync = Some(after_sync);
    let outcome = drive_ctl(&pre.engine, &mut replicas, sync.as_mut(), &pre.plan, &mut ctl)?;
    let Some(step) = ctl.stopped_at else {
        bail!(
            "checkpoint: the run finished (T={}) before {after_sync} outer syncs \
             completed with steps to spare — nothing left to resume",
            pre.plan.total_steps
        );
    };
    let mut ck = Checkpoint::capture(
        step,
        &replicas,
        &ctl.residuals,
        &ctl.live,
        sync.as_ref(),
        &outcome,
        &ctl.journal,
    )?;
    ck.config = Some(cfg.to_json());
    ck.save(out)?;
    log::info!(
        "checkpoint: stopped at step {step}/{} after {after_sync} outer syncs -> {}",
        pre.plan.total_steps,
        out.display()
    );
    Ok(step)
}

/// Resume a [`run_checkpoint`] capture and run to completion. The
/// config is read back out of the checkpoint, so the continuation uses
/// exactly the schedule, codecs, and fault plan of the original run —
/// losses, evals, wire bytes, and final params are bit-identical to
/// the run that was never interrupted (`tests/churn_resume.rs`).
pub fn run_resume(
    mr: &ModelRuntime,
    policy: &OptimizerPolicy,
    path: &std::path::Path,
) -> Result<RunMetrics> {
    let t_start = std::time::Instant::now();
    let ck = Checkpoint::load(path)?;
    let cfg_json = ck
        .config
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("checkpoint {} carries no config", path.display()))?;
    let cfg = RunConfig::from_json(cfg_json)
        .with_context(|| format!("checkpoint {} config", path.display()))?;
    let mut pre = prepare(mr, policy, &cfg)?;
    if ck.replicas.len() != pre.universe || ck.live.len() != pre.universe {
        bail!(
            "checkpoint has {} replicas / {} live flags, the config's universe is {}",
            ck.replicas.len(),
            ck.live.len(),
            pre.universe
        );
    }
    let mut replicas = std::mem::take(&mut pre.replicas);
    let mut residuals = Vec::with_capacity(pre.universe);
    for (r, (rep, rck)) in replicas.iter_mut().zip(ck.replicas.iter()).enumerate() {
        let lits = rck
            .literals()
            .with_context(|| format!("checkpoint replica {r}"))?;
        if lits.len() != rep.state.len() {
            bail!(
                "checkpoint replica {r} has {} leaves, the model wants {}",
                lits.len(),
                rep.state.len()
            );
        }
        rep.state = lits;
        // re-seat the shard by replaying its consumed prefix — exact,
        // because the stream is pure in (corpus seed, stream id)
        rep.shard = TokenStream::new(pre.corpus.clone(), cfg.seed, r as u64);
        rep.shard.skip(rck.consumed);
        residuals.push(rck.residual.clone());
    }
    let mut sync = pre.sync.take();
    let snap_init = match (&mut sync, &ck.sync) {
        (Some(bus), Some(st)) => {
            bus.restore_state(st)?;
            Some(bus.broadcast_view().to_vec())
        }
        (None, None) => None,
        (have, _) => bail!(
            "checkpoint and config disagree on the outer sync (config {}, checkpoint {})",
            if have.is_some() { "diloco" } else { "dp" },
            if ck.sync.is_some() { "diloco" } else { "dp" },
        ),
    };
    let mut ctl = DriveCtl {
        events: pre.events.clone(),
        live: ck.live.clone(),
        stop_after_sync: None,
        start_step: ck.step,
        resume: true,
        journal: ck.journal.clone(),
        residuals,
        snap_init,
        stopped_at: None,
    };
    let resumed = drive_ctl(&pre.engine, &mut replicas, sync.as_mut(), &pre.plan, &mut ctl)?;
    let outcome = ck.stitch(&resumed);
    finish(mr, &cfg, &pre, sync, &replicas, outcome, t_start)
}

/// Final eval + downstream scoring + metric assembly, shared by the
/// fresh and resumed paths (`outcome` is the full-run outcome — the
/// resumed path stitches before calling).
fn finish(
    mr: &ModelRuntime,
    cfg: &RunConfig,
    pre: &Prepared,
    mut sync: Option<OuterSync>,
    replicas: &[ReplicaState],
    outcome: DriveOutcome,
    t_start: std::time::Instant,
) -> Result<RunMetrics> {
    let n = pre.n;
    let seq = pre.engine.seq;
    let total_steps = pre.plan.total_steps;
    let last_train_loss = outcome.step_losses.last().copied().unwrap_or(f64::NAN);
    let mut eval_curve = outcome.eval_curve;

    // DP's "global" model is simply the replica's current params;
    // DiLoCo's is the literal cache, fresh after the final full-flush
    // sync. Either way no re-upload happens here (paper section 2.2:
    // DiLoCo evaluates the most recent global model).
    let final_lits: Vec<Arc<xla::Literal>> = match sync.as_mut() {
        Some(bus) => bus.global_literals()?.to_vec(),
        None => replicas[0].state[..n].to_vec(),
    };
    let final_eval = pre.engine.eval(&final_lits)?;
    eval_curve.push((total_steps, final_eval));

    // ---- downstream zero-shot scoring --------------------------------------
    let mut downstream = Vec::new();
    if cfg.downstream {
        let seq_nll = mr.artifact("seq_nll")?;
        for task in McTaskSpec::standard_suite(cfg.seed ^ 0xDD) {
            let instances = task.generate(cfg.seed);
            let mut correct = 0usize;
            for inst in &instances {
                let mut best = (f64::INFINITY, 0usize);
                for c in 0..inst.candidates.len() {
                    let (toks, mask) = scoring_input(inst, c, seq);
                    let t = i32_literal(&[1, seq], &toks)?;
                    let m = HostTensor::from_vec(&[1, seq], mask).to_literal()?;
                    let mut args: Vec<&xla::Literal> =
                        final_lits.iter().map(|l| &**l).collect();
                    args.push(&t);
                    args.push(&m);
                    let nll = scalar_f32(&seq_nll.call(&args)?[0])? as f64;
                    if nll < best.0 {
                        best = (nll, c);
                    }
                }
                if best.1 == inst.answer {
                    correct += 1;
                }
            }
            let acc = correct as f64 / instances.len() as f64;
            log::info!("  downstream {}: {acc:.3}", task.name);
            downstream.push((task.name.clone(), acc));
        }
    }

    let (wire_up_bytes, wire_down_bytes, wire_framed_bytes) = match &sync {
        Some(bus) => (
            bus.wire_stats().total_up(),
            bus.wire_stats().total_down(),
            bus.wire_stats().total_framed(),
        ),
        None => (0, 0, 0),
    };
    let stage_ms = match &sync {
        Some(bus) if !bus.stage_log().is_empty() => {
            let log = bus.stage_log();
            let mean = |f: fn(&crate::coordinator::sync::SyncStages) -> f64| {
                1e3 * log.iter().map(f).sum::<f64>() / log.len() as f64
            };
            [
                mean(|s| s.encode_s),
                mean(|s| s.wire_wait_s),
                mean(|s| s.reduce_s),
                mean(|s| s.step_s),
                mean(|s| s.bcast_s),
            ]
        }
        _ => [0.0; 5],
    };

    Ok(RunMetrics {
        model: cfg.model.clone(),
        algo: cfg.algo.label(),
        replicas: pre.m_replicas,
        sync_every: if pre.is_diloco { pre.h } else { 0 },
        global_batch_tokens: pre.tokens_per_step,
        inner_lr: cfg.inner_lr,
        outer_lr: if pre.is_diloco { cfg.outer_lr } else { 0.0 },
        overtrain: cfg.overtrain,
        seed: cfg.seed,
        param_count: mr.manifest.model.param_count,
        steps: total_steps,
        tokens: total_steps * pre.tokens_per_step,
        final_eval_loss: final_eval,
        final_train_loss: last_train_loss,
        eval_curve,
        loss_curve: outcome.loss_curve,
        downstream,
        outer_syncs: outcome.outer_syncs,
        wall_secs: t_start.elapsed().as_secs_f64(),
        fragments: if pre.is_diloco { pre.plan.fragments } else { 1 },
        overlap_tau: pre.plan.overlap_tau,
        outer_bits: pre.outer_bits.bits(),
        outer_bits_down: pre.outer_bits_down.bits(),
        wire_up_bytes,
        wire_down_bytes,
        wire_framed_bytes,
        churn: pre.churn_spec.clone(),
        dropout_rate: pre.dropout_rate,
        sync_encode_ms: stage_ms[0],
        sync_wire_wait_ms: stage_ms[1],
        sync_reduce_ms: stage_ms[2],
        sync_step_ms: stage_ms[3],
        sync_bcast_ms: stage_ms[4],
    })
}
