//! The outer-synchronization engine: everything that happens at the
//! H-cadence (Algorithm 1 lines 8-12), running on the flat parameter
//! bus with state allocated once per run.
//!
//! Per sync event [`OuterSync::sync`]:
//!
//! 1. **pull** — only the due leaves of each replica's params come back
//!    to host, into a scratch arena reused across rounds (streaming
//!    fragments no longer round-trip the whole model every H/P steps);
//! 2. **outer step** — accumulate the replica sum, finish
//!    Delta = global - sum/M, and apply the Nesterov step, all as
//!    element-wise loops over the fragment's precomputed offset ranges
//!    (zero allocation in coordinator code);
//! 3. **publish** — each synced leaf is uploaded to a literal exactly
//!    **once** and cached; the coordinator broadcasts by handing every
//!    replica the same immutable `Arc<xla::Literal>`, cutting
//!    host→device traffic from M×N to N literals per full sync. The
//!    cache doubles as the global model's literal form for the eval and
//!    downstream paths (which previously re-uploaded all N leaves per
//!    eval); a sync invalidates only the fragment it touched.
//!
//! Literals are never mutated after construction (PJRT treats inputs
//! as immutable and copies to device), so sharing one literal across
//! replicas and the eval path is safe.

use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::{FlatLayout, FlatParams, HostTensor};

use super::outer_opt::{acc_add, acc_finish, OuterOpt};

pub struct OuterSync {
    fragments: usize,
    opt: OuterOpt,
    /// The global model theta (host side of the bus).
    global: FlatParams,
    /// Replica-sum / outer-gradient arena (reused every round).
    acc: FlatParams,
    /// Device→host pull arena (reused every round).
    scratch: FlatParams,
    /// Precomputed element ranges per fragment (index = fragment id).
    frag_ranges: Vec<Vec<Range<usize>>>,
    /// The whole arena as one range (full syncs / final flush).
    full: Vec<Range<usize>>,
    /// Cached literal per leaf — the global model as the device sees
    /// it. Every entry is shared (never rebuilt) until its leaf syncs.
    lits: Vec<Arc<xla::Literal>>,
}

impl OuterSync {
    /// `init` and `init_lits` are the same initial global params in
    /// host and literal form (the init artifact's outputs), so setup
    /// costs zero extra uploads.
    pub fn new(
        layout: Arc<FlatLayout>,
        init: &[HostTensor],
        init_lits: Vec<Arc<xla::Literal>>,
        outer_lr: f64,
        outer_momentum: f64,
        fragments: usize,
    ) -> Result<OuterSync> {
        let fragments = fragments.max(1);
        if init_lits.len() != layout.n_leaves() {
            bail!(
                "outer sync: {} cached literals for a {}-leaf layout",
                init_lits.len(),
                layout.n_leaves()
            );
        }
        let global = FlatParams::from_host(&layout, init)?;
        let acc = FlatParams::zeros(&layout);
        let scratch = FlatParams::zeros(&layout);
        let frag_ranges = (0..fragments)
            .map(|f| layout.fragment_ranges(fragments, f))
            .collect();
        let full = layout.full_range();
        Ok(OuterSync {
            fragments,
            opt: OuterOpt::new(outer_lr, outer_momentum),
            global,
            acc,
            scratch,
            frag_ranges,
            full,
            lits: init_lits,
        })
    }

    pub fn global(&self) -> &FlatParams {
        &self.global
    }

    /// The global model's cached literal form (manifest leaf order) —
    /// valid at every step, freshened leaf-by-leaf as syncs land.
    pub fn global_literals(&self) -> &[Arc<xla::Literal>] {
        &self.lits
    }

    /// Host→device uploads performed through the bus so far.
    pub fn uploads(&self) -> u64 {
        self.global.uploads()
    }

    /// Leaves a sync event touches: all for `frag = None`, the
    /// round-robin subset for a streaming fragment.
    pub fn synced_leaves(&self, frag: Option<usize>) -> std::iter::StepBy<Range<usize>> {
        self.global.layout().leaves(self.fragments, frag)
    }

    /// One outer synchronization. `replica_params[r]` is replica r's
    /// current parameter literals (manifest leaf order, length
    /// n_leaves). After this returns, `global_literals()` holds the
    /// refreshed leaves; the caller broadcasts by cloning those `Arc`s
    /// into each replica's state.
    pub fn sync(
        &mut self,
        replica_params: &[&[Arc<xla::Literal>]],
        frag: Option<usize>,
    ) -> Result<()> {
        if replica_params.is_empty() {
            bail!("outer sync with zero replicas");
        }
        if let Some(f) = frag {
            if f >= self.fragments {
                bail!("fragment {f} out of range (P={})", self.fragments);
            }
        }
        let layout = Arc::clone(self.global.layout());
        let n = layout.n_leaves();
        for rp in replica_params {
            if rp.len() != n {
                bail!("outer sync: replica with {} leaves, expected {n}", rp.len());
            }
        }
        let ranges: &[Range<usize>] = match frag {
            Some(f) => &self.frag_ranges[f],
            None => &self.full,
        };

        // 1. pull + accumulate: acc <- sum_m theta_m over the due ranges.
        for r in ranges {
            self.acc.data_mut()[r.clone()].fill(0.0);
        }
        for rp in replica_params {
            for leaf in layout.leaves(self.fragments, frag) {
                self.scratch.read_leaf_literal(leaf, &rp[leaf])?;
            }
            for r in ranges {
                acc_add(
                    &mut self.acc.data_mut()[r.clone()],
                    &self.scratch.data()[r.clone()],
                );
            }
        }

        // 2. finish Delta = global - acc/M and take the Nesterov step.
        let m = replica_params.len() as f32;
        for r in ranges {
            acc_finish(
                &mut self.acc.data_mut()[r.clone()],
                &self.global.data()[r.clone()],
                m,
            );
        }
        self.opt.step_ranges(&mut self.global, &self.acc, ranges);

        // 3. publish: one upload per synced leaf, shared by all readers.
        for leaf in layout.leaves(self.fragments, frag) {
            self.lits[leaf] = Arc::new(self.global.leaf_literal(leaf)?);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> Arc<FlatLayout> {
        Arc::new(FlatLayout::new(vec![vec![2], vec![3], vec![1], vec![2]]))
    }

    fn host(layout: &FlatLayout, fill: f32) -> Vec<HostTensor> {
        (0..layout.n_leaves())
            .map(|l| {
                HostTensor::from_vec(
                    layout.shape(l),
                    vec![fill; layout.len(l)],
                )
            })
            .collect()
    }

    fn lits_of(tensors: &[HostTensor]) -> Vec<Arc<xla::Literal>> {
        tensors
            .iter()
            .map(|t| Arc::new(t.to_literal().unwrap()))
            .collect()
    }

    #[test]
    fn full_sync_with_eta1_mu0_averages_replicas() {
        let l = layout();
        let init = host(&l, 1.0);
        let mut sync =
            OuterSync::new(Arc::clone(&l), &init, lits_of(&init), 1.0, 0.0, 1).unwrap();
        let r0 = lits_of(&host(&l, 0.0));
        let r1 = lits_of(&host(&l, 4.0));
        sync.sync(&[&r0[..], &r1[..]], None).unwrap();
        assert!(sync.global().data().iter().all(|&x| x == 2.0));
        // one upload per leaf, not per (replica, leaf)
        assert_eq!(sync.uploads(), l.n_leaves() as u64);
        // the cache matches the new global
        for leaf in 0..l.n_leaves() {
            let v = sync.global_literals()[leaf].to_vec::<f32>().unwrap();
            assert!(v.iter().all(|&x| x == 2.0));
        }
    }

    #[test]
    fn fragment_sync_touches_only_due_leaves() {
        let l = layout();
        let init = host(&l, 1.0);
        let init_lits = lits_of(&init);
        let mut sync =
            OuterSync::new(Arc::clone(&l), &init, init_lits.clone(), 1.0, 0.0, 2).unwrap();
        let r = lits_of(&host(&l, 5.0));
        sync.sync(&[&r[..]], Some(1)).unwrap(); // leaves {1, 3}
        assert_eq!(sync.uploads(), 2);
        assert_eq!(sync.global().leaf(0), &[1.0, 1.0]);
        assert!(sync.global().leaf(1).iter().all(|&x| x == 5.0));
        assert_eq!(sync.global().leaf(2), &[1.0]);
        assert!(sync.global().leaf(3).iter().all(|&x| x == 5.0));
        // untouched leaves still share the ORIGINAL literal allocation
        assert!(Arc::ptr_eq(&sync.global_literals()[0], &init_lits[0]));
        assert!(Arc::ptr_eq(&sync.global_literals()[2], &init_lits[2]));
        assert!(!Arc::ptr_eq(&sync.global_literals()[1], &init_lits[1]));
    }

    #[test]
    fn rejects_malformed_inputs() {
        let l = layout();
        let init = host(&l, 0.0);
        let mut sync =
            OuterSync::new(Arc::clone(&l), &init, lits_of(&init), 0.8, 0.9, 2).unwrap();
        assert!(sync.sync(&[], None).is_err());
        let short = lits_of(&host(&l, 1.0)[..3]);
        assert!(sync.sync(&[&short[..]], None).is_err());
        let ok = lits_of(&host(&l, 1.0));
        assert!(sync.sync(&[&ok[..]], Some(2)).is_err()); // fragment id out of range
    }
}
