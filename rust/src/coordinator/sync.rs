//! The outer-synchronization engine: everything that happens at the
//! H-cadence (Algorithm 1 lines 8-12), running on the flat parameter
//! bus with state allocated once per run.
//!
//! Per sync event [`OuterSync::sync`]:
//!
//! 1. **pull** — only the due leaves of each replica's params come back
//!    to host, into a scratch arena reused across rounds (streaming
//!    fragments no longer round-trip the whole model every H/P steps);
//! 2. **outer step** — accumulate the replica sum, finish
//!    Delta = global - sum/M, and apply the Nesterov step, all as
//!    element-wise loops over the fragment's precomputed offset ranges
//!    (zero allocation in coordinator code);
//! 3. **publish** — each synced leaf is uploaded to a literal at most
//!    **once** and cached; the cache is the global model's literal
//!    form for the eval and downstream paths (which previously
//!    re-uploaded all N leaves per eval); a sync invalidates only the
//!    fragment it touched. Under an identity down-wire the coordinator
//!    broadcasts by handing every replica the same immutable
//!    `Arc<xla::Literal>`, cutting host→device traffic from M×N to N
//!    literals per full sync — those leaves are rebuilt eagerly, the
//!    broadcast needs them anyway. Under a lossy down-wire
//!    (`--outer-bits-down` below 32) the broadcast is instead encoded
//!    **once** through the coordinator-owned [`DownWire`] — quantized,
//!    error-compensated against the replicas' running view — and the
//!    single byte payload is what crosses the wire; workers decode it
//!    into their shared snapshot and rebuild their own literals (see
//!    `crate::comm`), so the coordinator's cache is **dirty-flag
//!    lazy**: a sync only marks the touched leaves stale, and the
//!    literal is materialized when eval/downstream actually reads the
//!    cache through [`OuterSync::global_literals`]. A run that never
//!    evaluates mid-stream pays zero coordinator uploads per sync.
//!
//! Literals are never mutated after construction (PJRT treats inputs
//! as immutable and copies to device), so sharing one literal across
//! replicas and the eval path is safe.
//!
//! Since the comm subsystem landed, the pull stage has two entry
//! points: [`OuterSync::sync`] ingests replica literal handles — the
//! live path for uncompressed runs (zero-copy, unchanged from PR 2)
//! and the oracle the encoded path is pinned against — while
//! [`OuterSync::sync_encoded`] ingests the wire payloads the pool
//! workers encode with the run's lossy [`Codec`] — the reduce half of
//! the quantize→reduce→dequantize contract (see `crate::comm`). Both
//! count exact wire bytes into [`WireStats`], in both directions.

use std::ops::Range;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::comm::codec::{codec_for, Codec, OuterBits, BLOCK};
use crate::comm::{Channel, CommLink, Direction, DownWire, SyncWireRecord, WireStats};
use crate::runtime::{FlatLayout, FlatParams, HostTensor};
use crate::transport::frame::{WireBuf, WireSlice};
use crate::util::par::{self, Piece};

use super::outer_opt::{acc_add, acc_finish, acc_scale, OuterOpt};

/// Everything mutable the outer-sync engine carries between syncs, in
/// checkpointable form: the global arena, the outer optimizer's
/// velocity, the down-wire's broadcast view + EF residual (lossy
/// broadcasts only), and the per-sync wire records (whose length is
/// the absolute sync counter every encode seed derives from). A fresh
/// `OuterSync` built with the same config and `restore_state`d from
/// this continues the run bit-identically — pinned by
/// `tests/churn_resume.rs`.
#[derive(Debug, Clone, PartialEq)]
pub struct SyncState {
    pub global: Vec<f32>,
    pub velocity: Vec<f32>,
    pub down_view: Option<Vec<f32>>,
    pub down_residual: Option<Vec<f32>>,
    pub wire_records: Vec<SyncWireRecord>,
}

/// One sync event's stage latency breakdown, in seconds. `encode_s`
/// and `wire_wait_s` are driver-observed (the engine cannot see the
/// workers' clocks): on remote transports the up-leg encode happens on
/// the far side and is attributed to the wire wait. `reduce_s` sums
/// every fused decode→reduce shard — for an arrival-pipelined sync
/// that work runs *inside* the collect, which is exactly the overlap
/// the `wire_wait_s` subtraction makes visible.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SyncStages {
    pub encode_s: f64,
    pub wire_wait_s: f64,
    pub reduce_s: f64,
    pub step_s: f64,
    pub bcast_s: f64,
}

/// The arrival half of one in-flight sync: per-contributor chunk
/// cursors over the streamed up-leg, plus the block-range readiness
/// tracker that lets [`OuterSync::arrival_chunk`] fire each fused
/// decode→reduce shard the moment **all live contributors'** bytes for
/// it are in — while later chunks are still on the wire. Built by
/// [`OuterSync::arrival_begin`] at dispatch, fed by the transport as
/// `ContribChunk` frames land, resolved by [`OuterSync::sync_arrival`]
/// at merge time.
///
/// Bit-identity discipline: the shard partition is the exact
/// `shard_ranges(ranges, sync_threads, BLOCK)` cut the one-shot
/// [`OuterSync::sync_encoded`] uses, shards fire strictly in payload
/// order, and within a shard every piece accumulates its contributors
/// in replica-index order — so the fp summation order, and therefore
/// the bits, are unchanged from the one-shot path no matter how the
/// chunks interleave on the wire (pinned by `tests/streamed_sync.rs`).
pub struct ArrivalReduce {
    frag: Option<usize>,
    /// The due element ranges (coordinator geometry — same layout and
    /// fragment math as the workers').
    ranges: Vec<Range<usize>>,
    /// Cumulative wire-byte offset of each source range.
    range_off: Vec<usize>,
    /// Exact per-contributor payload size.
    expected: usize,
    /// The reduce shard partition (identical to the one-shot cut).
    shards: Vec<Vec<Piece>>,
    /// Wire-byte end of each shard (max over its pieces) — the
    /// watermark every contributor must reach before it fires.
    wire_end: Vec<usize>,
    /// Live contributor replica ids, strictly ascending — the fp
    /// accumulation order.
    ranks: Vec<usize>,
    /// Per contributor (parallel to `ranks`): received chunks as
    /// `(wire offset, zero-copy frame view)`, contiguous from 0.
    chunks: Vec<Vec<(usize, WireSlice)>>,
    /// Per contributor: total contiguous bytes received.
    watermark: Vec<usize>,
    /// Next shard to fire (shards fire strictly in order).
    next: usize,
    /// Shards whose reduce fired before every contributor's full
    /// payload had arrived — the pipeline-overlap evidence.
    fired_early: usize,
}

impl ArrivalReduce {
    pub fn frag(&self) -> Option<usize> {
        self.frag
    }

    /// Live contributor replica ids (ascending).
    pub fn contributors(&self) -> &[usize] {
        &self.ranks
    }

    /// Exact per-contributor payload size in bytes.
    pub fn payload_bytes(&self) -> usize {
        self.expected
    }

    /// Whether every live contributor's full payload has arrived.
    pub fn complete(&self) -> bool {
        self.watermark.iter().all(|&w| w == self.expected)
    }

    /// Reduce shards fired so far / total.
    pub fn fired(&self) -> (usize, usize) {
        (self.next, self.shards.len())
    }

    /// Shards whose reduce fired while at least one contributor's
    /// payload was still incomplete — proof the reduce overlapped
    /// arrival rather than waiting for the last byte.
    pub fn fired_early(&self) -> usize {
        self.fired_early
    }
}

pub struct OuterSync {
    fragments: usize,
    opt: OuterOpt,
    /// The global model theta (host side of the bus).
    global: FlatParams,
    /// Replica-sum / outer-gradient arena (reused every round).
    acc: FlatParams,
    /// Device→host pull arena (reused every round).
    scratch: FlatParams,
    /// Precomputed element ranges per fragment (index = fragment id).
    frag_ranges: Vec<Vec<Range<usize>>>,
    /// The whole arena as one range (full syncs / final flush).
    full: Vec<Range<usize>>,
    /// Cached literal per leaf — the global model as the device sees
    /// it. Every entry is shared (never rebuilt) until its leaf syncs.
    lits: Vec<Arc<xla::Literal>>,
    /// Per-leaf staleness for `lits`, set by syncs under a lossy
    /// down-wire (whose broadcast ships bytes, not these literals) and
    /// cleared by [`OuterSync::global_literals`] when the cache is
    /// actually read — the ROADMAP "dirty-flag lazy" cleanup.
    lits_stale: Vec<bool>,
    /// Up-wire codec for encoded syncs (identity f32 unless the run
    /// compresses outer communication — `--outer-bits`).
    codec: Arc<dyn Codec>,
    /// Down-wire codec for the broadcast (`--outer-bits-down`).
    down_codec: Arc<dyn Codec>,
    /// Coordinator-owned down-wire state: the replicas' running view
    /// of the global + the broadcast's error-feedback residual. None
    /// for identity down-wires (zero-copy literal handoff).
    down: Option<DownWire>,
    /// The last sync's encoded broadcast, awaiting pickup by the
    /// driver (lossy down-wires only; one recycled buffer,
    /// `Arc`-shared by every worker). None when the payload was
    /// streamed to a transport sink at encode time.
    pending_down: Option<WireSlice>,
    /// Seed both channels derive stochastic rounding from.
    run_seed: u64,
    /// Exact bytes moved per sync/fragment/replica.
    wire: WireStats,
    /// Shard width for the coordinator-side sync kernels (fused
    /// decode→reduce, outer step, broadcast encode). Results are
    /// bit-identical at any value; 1 = the sequential path.
    sync_threads: usize,
    /// Recycled wire buffers (spent broadcasts returned by the driver
    /// via [`OuterSync::recycle_wire`]), so steady-state syncs
    /// allocate nothing for the down-wire payload.
    wire_pool: Vec<WireBuf>,
    /// Print a `sync:` stage-breakdown stderr line per sync event
    /// (`--verbose`).
    verbose: bool,
    /// Per-sync stage latency records (one per completed sync event).
    stages: Vec<SyncStages>,
    /// Stage accumulator for the sync currently in flight; finalized
    /// and pushed by `publish_and_record`.
    cur: SyncStages,
}

impl OuterSync {
    /// `init` and `init_lits` are the same initial global params in
    /// host and literal form (the init artifact's outputs), so setup
    /// costs zero extra uploads.
    pub fn new(
        layout: Arc<FlatLayout>,
        init: &[HostTensor],
        init_lits: Vec<Arc<xla::Literal>>,
        outer_lr: f64,
        outer_momentum: f64,
        fragments: usize,
    ) -> Result<OuterSync> {
        let fragments = fragments.max(1);
        if init_lits.len() != layout.n_leaves() {
            bail!(
                "outer sync: {} cached literals for a {}-leaf layout",
                init_lits.len(),
                layout.n_leaves()
            );
        }
        let global = FlatParams::from_host(&layout, init)?;
        let acc = FlatParams::zeros(&layout);
        let scratch = FlatParams::zeros(&layout);
        let frag_ranges = (0..fragments)
            .map(|f| layout.fragment_ranges(fragments, f))
            .collect();
        let full = layout.full_range();
        let lits_stale = vec![false; layout.n_leaves()];
        Ok(OuterSync {
            fragments,
            opt: OuterOpt::new(outer_lr, outer_momentum),
            global,
            acc,
            scratch,
            frag_ranges,
            full,
            lits: init_lits,
            lits_stale,
            codec: codec_for(OuterBits::Fp32),
            down_codec: codec_for(OuterBits::Fp32),
            down: None,
            pending_down: None,
            run_seed: 0,
            wire: WireStats::default(),
            sync_threads: 1,
            wire_pool: Vec::new(),
            verbose: false,
            stages: Vec::new(),
            cur: SyncStages::default(),
        })
    }

    /// Emit a `sync:` stderr line with the stage latency breakdown
    /// after every sync event (`--verbose`).
    pub fn with_verbose(mut self, v: bool) -> OuterSync {
        self.verbose = v;
        self
    }

    /// Per-sync stage latency records so far (one per sync event, in
    /// sync order) — the aggregate means land in `RunMetrics`.
    pub fn stage_log(&self) -> &[SyncStages] {
        &self.stages
    }

    /// Credit driver-observed up-leg encode time to the in-flight
    /// sync's stage record (inline transports only — remote workers'
    /// encode clocks are invisible and fold into the wire wait).
    pub fn note_encode_time(&mut self, s: f64) {
        self.cur.encode_s += s;
    }

    /// Credit driver-observed wire wait (collect wall time minus any
    /// reduce work that ran inside the collect) to the in-flight
    /// sync's stage record.
    pub fn note_wire_wait(&mut self, s: f64) {
        self.cur.wire_wait_s += s;
    }

    /// Reduce seconds accumulated by the in-flight sync so far — the
    /// driver samples this around a collect to subtract in-collect
    /// reduce time out of the wire wait.
    pub fn reduce_time_so_far(&self) -> f64 {
        self.cur.reduce_s
    }

    /// Shard the coordinator-side sync kernels over up to `n` scoped
    /// threads (`--sync-threads`). Deterministic per-range ownership
    /// keeps every element's operation order unchanged, so results are
    /// bit-identical at any value (pinned by `tests/comm_codec.rs`).
    pub fn with_sync_threads(mut self, n: usize) -> OuterSync {
        self.sync_threads = n.max(1);
        self
    }

    /// Return a spent wire buffer (a shipped broadcast or a consumed
    /// up-wire payload) for reuse by the next broadcast encode.
    /// Capacity is retained; every byte is rewritten on reuse.
    pub fn recycle_wire(&mut self, mut buf: WireBuf) {
        if self.wire_pool.len() < 16 {
            buf.reset();
            self.wire_pool.push(buf);
        }
    }

    /// Attach the up-wire codec (and the run seed both channels derive
    /// stochastic rounding from). Default is the identity f32 codec.
    pub fn with_codec(mut self, codec: Arc<dyn Codec>, run_seed: u64) -> OuterSync {
        self.codec = codec;
        self.run_seed = run_seed;
        self.rebuild_down();
        self
    }

    /// Attach the down-wire (broadcast) codec. Identity keeps the
    /// zero-copy literal handoff; lossy codecs build the coordinator's
    /// [`DownWire`] with the view initialized to the current global
    /// (call at setup, before any sync moves the global off the init).
    pub fn with_down_codec(mut self, codec: Arc<dyn Codec>) -> OuterSync {
        self.down_codec = codec;
        self.rebuild_down();
        self
    }

    fn rebuild_down(&mut self) {
        self.down = if self.down_codec.is_identity() {
            None
        } else {
            Some(DownWire::new(
                Channel::new(
                    Arc::clone(self.global.layout()),
                    Arc::clone(&self.down_codec),
                    self.fragments,
                    self.run_seed,
                    Direction::Down,
                ),
                self.global.data(),
            ))
        };
    }

    pub fn codec(&self) -> &Arc<dyn Codec> {
        &self.codec
    }

    pub fn down_codec(&self) -> &Arc<dyn Codec> {
        &self.down_codec
    }

    /// The coordinator-side down-wire state (None while the broadcast
    /// is identity) — exposed for tests.
    pub fn down(&self) -> Option<&DownWire> {
        self.down.as_ref()
    }

    /// Both legs of the comm plane as the pool's workers see them
    /// (same layout, codecs, fragment count, and seed).
    pub fn link(&self) -> CommLink {
        let layout = Arc::clone(self.global.layout());
        CommLink::new(
            Channel::new(
                Arc::clone(&layout),
                Arc::clone(&self.codec),
                self.fragments,
                self.run_seed,
                Direction::Up,
            ),
            Channel::new(
                layout,
                Arc::clone(&self.down_codec),
                self.fragments,
                self.run_seed,
                Direction::Down,
            ),
        )
    }

    /// Take the last sync's encoded broadcast payload (lossy
    /// down-wires only; the driver attaches it to the next segment's
    /// command, one buffer shared by every worker). Empty when the
    /// payload was streamed onto the transport at encode time.
    pub fn take_broadcast_bytes(&mut self) -> Option<WireSlice> {
        self.pending_down.take()
    }

    /// Exact encoded payload size of the next broadcast for `frag`
    /// under a lossy down-wire, `None` at the identity width. A
    /// streaming transport stamps this into the `Bcast` frame header
    /// before the encode starts, so shards can hit the socket as they
    /// finish.
    pub fn down_payload_bytes(&self, frag: Option<usize>) -> Option<u64> {
        self.down.as_ref()?;
        let ranges: &[Range<usize>] = match frag {
            Some(f) => self.frag_ranges.get(f)?,
            None => &self.full,
        };
        Some(
            ranges
                .iter()
                .map(|r| self.down_codec.wire_bytes(r.len()) as u64)
                .sum(),
        )
    }

    /// Exact wire traffic so far (one record per sync event).
    pub fn wire_stats(&self) -> &WireStats {
        &self.wire
    }

    /// Fold transport control traffic (heartbeats, handshakes) measured
    /// by a socket transport into the wire accounting's control bucket
    /// — reported separately, never part of the framed totals (those
    /// stay schedule-derived and transport-invariant).
    pub fn add_control_bytes(&mut self, bytes: u64) {
        self.wire.add_control_bytes(bytes);
    }

    /// The flat arena the replicas' broadcast view currently holds:
    /// the [`DownWire`]'s view under a lossy broadcast, the exact
    /// global otherwise. This is what a resumed worker's snapshot (and
    /// a joining replica's initial params) must be seeded from — NOT
    /// the raw global, which a lossy view legitimately lags.
    pub fn broadcast_view(&self) -> &[f32] {
        match &self.down {
            Some(dw) => dw.view(),
            None => self.global.data(),
        }
    }

    /// Snapshot the engine's mutable state at an outer boundary.
    /// Refuses mid-broadcast (an un-taken lossy payload means the
    /// replicas have not adopted the last sync — not a clean boundary).
    pub fn export_state(&self) -> Result<SyncState> {
        if self.pending_down.is_some() {
            bail!(
                "outer sync: cannot checkpoint with an unshipped broadcast \
                 payload pending"
            );
        }
        Ok(SyncState {
            global: self.global.data().to_vec(),
            velocity: self.opt.velocity().to_vec(),
            down_view: self.down.as_ref().map(|dw| dw.view().to_vec()),
            down_residual: self.down.as_ref().map(|dw| dw.residual().to_vec()),
            wire_records: self.wire.records().to_vec(),
        })
    }

    /// Restore a freshly built engine (same layout, codecs, fragment
    /// count, seed, and outer hypers) to a checkpointed state. The
    /// literal cache is marked all-stale and rebuilt lazily on the
    /// first read, so restore itself performs zero uploads.
    pub fn restore_state(&mut self, st: &SyncState) -> Result<()> {
        let total = self.global.layout().total();
        if st.global.len() != total {
            bail!(
                "sync restore: global has {} elements, layout wants {total}",
                st.global.len()
            );
        }
        if !st.velocity.is_empty() && st.velocity.len() != total {
            bail!(
                "sync restore: velocity has {} elements, expected 0 or {total}",
                st.velocity.len()
            );
        }
        if st.down_view.is_some() != self.down.is_some() {
            bail!(
                "sync restore: checkpoint and engine disagree on the down-wire \
                 (checkpoint lossy-down: {}, engine: {}) — rebuild with the \
                 run's own --outer-bits-down",
                st.down_view.is_some(),
                self.down.is_some()
            );
        }
        self.global.data_mut().copy_from_slice(&st.global);
        self.opt.restore_velocity(st.velocity.clone());
        if let Some(dw) = &mut self.down {
            let (Some(view), Some(residual)) = (&st.down_view, &st.down_residual) else {
                bail!("sync restore: down-wire view without residual");
            };
            dw.restore(view, residual)?;
        }
        self.wire = WireStats::from_records(st.wire_records.clone());
        self.pending_down = None;
        for s in self.lits_stale.iter_mut() {
            *s = true;
        }
        Ok(())
    }

    pub fn global(&self) -> &FlatParams {
        &self.global
    }

    /// The global model's cached literal form (manifest leaf order) —
    /// valid at every step. Under an identity down-wire the cache is
    /// freshened eagerly as syncs land (the broadcast shares those
    /// exact literals); under a lossy down-wire a sync only marks its
    /// leaves stale, and this read materializes them — so uploads
    /// happen when eval/downstream actually consumes the cache, never
    /// per sync.
    pub fn global_literals(&mut self) -> Result<&[Arc<xla::Literal>]> {
        if self.lits_stale.iter().any(|&s| s) {
            for leaf in 0..self.lits_stale.len() {
                if self.lits_stale[leaf] {
                    self.lits[leaf] = Arc::new(self.global.leaf_literal(leaf)?);
                    self.lits_stale[leaf] = false;
                }
            }
        }
        Ok(&self.lits)
    }

    /// How many cached leaves are currently stale (lossy down-wire
    /// syncs not yet read back) — exposed so tests can pin laziness.
    pub fn stale_literals(&self) -> usize {
        self.lits_stale.iter().filter(|&&s| s).count()
    }

    /// Host→device uploads performed through the bus so far.
    pub fn uploads(&self) -> u64 {
        self.global.uploads()
    }

    /// Leaves a sync event touches: all for `frag = None`, the
    /// round-robin subset for a streaming fragment.
    pub fn synced_leaves(&self, frag: Option<usize>) -> std::iter::StepBy<Range<usize>> {
        self.global.layout().leaves(self.fragments, frag)
    }

    /// One outer synchronization. `replica_params[r]` is replica r's
    /// current parameter literals (manifest leaf order, length
    /// n_leaves). After this returns, `global_literals()` holds the
    /// refreshed leaves. How the caller must broadcast depends on the
    /// down-wire: at the identity width, clone those `Arc`s into each
    /// replica's state (the zero-copy handoff); under a lossy
    /// `with_down_codec`, the replicas must instead receive this
    /// sync's [`OuterSync::take_broadcast_bytes`] payload and decode
    /// it (`CommLink::adopt_encoded`) — adopting the exact global
    /// literals would desynchronize the replicas from the
    /// [`DownWire`]'s view, which is the reference the next outer
    /// gradient is measured against.
    pub fn sync(
        &mut self,
        replica_params: &[&[Arc<xla::Literal>]],
        frag: Option<usize>,
    ) -> Result<()> {
        if replica_params.is_empty() {
            bail!("outer sync with zero replicas");
        }
        if let Some(f) = frag {
            if f >= self.fragments {
                bail!("fragment {f} out of range (P={})", self.fragments);
            }
        }
        let layout = Arc::clone(self.global.layout());
        let n = layout.n_leaves();
        for rp in replica_params {
            if rp.len() != n {
                bail!("outer sync: replica with {} leaves, expected {n}", rp.len());
            }
        }
        let ranges: &[Range<usize>] = match frag {
            Some(f) => &self.frag_ranges[f],
            None => &self.full,
        };

        // 1. pull + accumulate: acc <- sum_m theta_m over the due ranges.
        for r in ranges {
            self.acc.data_mut()[r.clone()].fill(0.0);
        }
        for rp in replica_params {
            for leaf in layout.leaves(self.fragments, frag) {
                self.scratch.read_leaf_literal(leaf, &rp[leaf])?;
            }
            for r in ranges {
                acc_add(
                    &mut self.acc.data_mut()[r.clone()],
                    &self.scratch.data()[r.clone()],
                );
            }
        }

        // 2. finish Delta = reference - acc/M and take the Nesterov
        // step. The reference is what the replicas actually started
        // this round from: the broadcast view under a lossy down-wire
        // (the outer gradient must measure replica movement only —
        // the global-vs-view lag is carried by the down-wire's error
        // feedback and re-broadcast, never double-counted into the
        // outer step), the exact global otherwise (identical values
        // when the broadcast is exact). The lossy up-wire path agrees:
        // its deltas are formed against the worker snapshot, which
        // tracks the same view.
        let m = replica_params.len() as f32;
        let shards = par::shard_ranges(ranges, self.sync_threads, BLOCK);
        let reference: &[f32] = match &self.down {
            Some(dw) => dw.view(),
            None => self.global.data(),
        };
        let accs = par::split_pieces(self.acc.data_mut(), &shards);
        let items: Vec<_> = shards.iter().zip(accs).collect();
        par::map_shards(items, |_, (pieces, accs)| {
            for (p, acc) in pieces.iter().zip(accs) {
                acc_finish(acc, &reference[p.range.clone()], m);
            }
        });
        self.opt.step_pieces(&mut self.global, &self.acc, &shards);

        // 3. publish + wire accounting (this path ships raw f32 up).
        self.publish_and_record(frag, replica_params.len(), None, None)
    }

    /// Shared tail of both sync entry points: refresh the literal
    /// cache (eagerly under an identity down-wire, whose broadcast
    /// Arc-shares those exact literals with every replica; lazily —
    /// stale marks only — under a lossy one, whose replicas rebuild
    /// their own from the broadcast bytes), drive the down-wire, and
    /// record the sync's wire traffic. `bytes_per_replica` is the
    /// encoded up payload size, or `None` for the raw-f32 literal path
    /// (4 bytes/element). The broadcast is counted **once** per sync —
    /// a bandwidth-optimal broadcast costs ~one payload regardless of
    /// the fan-out — at the down-wire codec's exact encoded size: the
    /// measured bytes of the [`DownWire`] payload when the broadcast
    /// is lossy, `4 * elems` under the identity f32 codec.
    ///
    /// With a `sink`, a lossy broadcast is **streamed**: encode shards
    /// are flushed through the sink in payload order as each finishes
    /// (overlapping encode with the transport write), the spent buffer
    /// is recycled immediately, and nothing is stashed for
    /// [`OuterSync::take_broadcast_bytes`] — the transport already
    /// shipped the exact one-shot bytes.
    fn publish_and_record(
        &mut self,
        frag: Option<usize>,
        replicas: usize,
        bytes_per_replica: Option<u64>,
        sink: Option<&mut dyn FnMut(&[u8]) -> Result<()>>,
    ) -> Result<()> {
        let layout = Arc::clone(self.global.layout());
        if self.down.is_some() {
            // lossy broadcast: nothing consumes these literals at sync
            // time — defer the uploads to the next cache read
            for leaf in layout.leaves(self.fragments, frag) {
                self.lits_stale[leaf] = true;
            }
        } else {
            for leaf in layout.leaves(self.fragments, frag) {
                self.lits[leaf] = Arc::new(self.global.leaf_literal(leaf)?);
            }
        }
        let ranges: &[Range<usize>] = match frag {
            Some(f) => &self.frag_ranges[f],
            None => &self.full,
        };
        let sync_index = self.wire.syncs();
        let t_bcast = Instant::now();
        let bytes_down = match &mut self.down {
            Some(dw) => {
                // the view advances with every encode, so a dropped
                // payload would silently desynchronize the replicas
                // from the reference the outer gradient is measured
                // against — refuse instead
                if self.pending_down.is_some() {
                    bail!(
                        "outer sync: the previous broadcast payload was never \
                         taken — lossy down-wire callers must ship \
                         take_broadcast_bytes() to the replicas before the \
                         next sync"
                    );
                }
                // encode the broadcast fragment once for all replicas
                // — into a recycled buffer, sharded over the sync
                // threads; the driver ships these bytes to every
                // worker (streamed shard-by-shard when a sink is
                // attached, stashed whole otherwise)
                let mut buf = self.wire_pool.pop().unwrap_or_default();
                let n;
                match sink {
                    Some(flush) => {
                        dw.encode_broadcast_chunked(
                            self.global.data(),
                            frag,
                            sync_index,
                            self.sync_threads,
                            &mut buf,
                            flush,
                        )?;
                        n = buf.payload_len() as u64;
                        // already on the wire — recycle right away
                        if self.wire_pool.len() < 16 {
                            buf.reset();
                            self.wire_pool.push(buf);
                        }
                    }
                    None => {
                        dw.encode_broadcast_into(
                            self.global.data(),
                            frag,
                            sync_index,
                            self.sync_threads,
                            &mut buf,
                        )?;
                        n = buf.payload_len() as u64;
                        self.pending_down = Some(WireSlice::whole(Arc::new(buf)));
                    }
                }
                n
            }
            None => ranges
                .iter()
                .map(|r| self.down_codec.wire_bytes(r.len()) as u64)
                .sum(),
        };
        self.cur.bcast_s += t_bcast.elapsed().as_secs_f64();
        let elems: u64 = ranges.iter().map(|r| r.len() as u64).sum();
        self.wire.record(
            frag,
            replicas,
            bytes_per_replica.unwrap_or(elems * 4),
            bytes_down,
        );
        // finalize this sync's stage record (encode / wire-wait were
        // credited by the driver as the collect ran)
        let st = std::mem::take(&mut self.cur);
        if self.verbose {
            let frag_s = frag.map_or_else(|| "-".to_string(), |f| f.to_string());
            eprintln!(
                "sync: idx={sync_index} frag={frag_s} enc={:.2}ms wire={:.2}ms \
                 reduce={:.2}ms step={:.2}ms bcast={:.2}ms",
                st.encode_s * 1e3,
                st.wire_wait_s * 1e3,
                st.reduce_s * 1e3,
                st.step_s * 1e3,
                st.bcast_s * 1e3,
            );
        }
        self.stages.push(st);
        Ok(())
    }

    /// One outer synchronization from **encoded wire payloads** — the
    /// reduce half of the quantize→reduce→dequantize contract (see
    /// `crate::comm`). `payloads[r]` is replica r's contribution for
    /// the due fragment, produced by this engine's [`CommLink`]:
    /// raw f32 parameters under the identity codec (making this
    /// bit-identical to [`OuterSync::sync`] on the same values), or
    /// error-compensated quantized outer deltas under a lossy codec.
    /// Payloads accumulate block-by-block straight into the delta
    /// arena in replica-index order (fused decode→reduce, sharded
    /// over `--sync-threads`); the Nesterov step and the deduplicated
    /// literal publish are exactly the legacy path's, bit for bit.
    pub fn sync_encoded(&mut self, payloads: &[&[u8]], frag: Option<usize>) -> Result<()> {
        self.sync_encoded_inner(payloads, frag, None)
    }

    /// [`OuterSync::sync_encoded`] with the lossy broadcast **streamed**
    /// through `sink` as encode shards finish, instead of stashed for
    /// [`OuterSync::take_broadcast_bytes`] — a socket transport writes
    /// each shard onto its lanes while the next is still encoding,
    /// overlapping broadcast encode with the wire inside the overlap
    /// window. The concatenation of sink calls is byte-identical to
    /// the one-shot payload (pinned by `chunked` tests in
    /// `comm::channel`), and the global/view/residual state advances
    /// identically. Callers must check [`OuterSync::down_payload_bytes`]
    /// first: at the identity width there is no byte payload to
    /// stream, and this refuses rather than silently skipping the
    /// literal handoff.
    pub fn sync_encoded_streamed(
        &mut self,
        payloads: &[&[u8]],
        frag: Option<usize>,
        sink: &mut dyn FnMut(&[u8]) -> Result<()>,
    ) -> Result<()> {
        if self.down.is_none() {
            bail!(
                "outer sync: streamed broadcast requested under an identity \
                 down-wire (the broadcast is a literal handoff, not bytes)"
            );
        }
        self.sync_encoded_inner(payloads, frag, Some(sink))
    }

    fn sync_encoded_inner(
        &mut self,
        payloads: &[&[u8]],
        frag: Option<usize>,
        sink: Option<&mut dyn FnMut(&[u8]) -> Result<()>>,
    ) -> Result<()> {
        if payloads.is_empty() {
            bail!("outer sync with zero replicas");
        }
        if let Some(f) = frag {
            if f >= self.fragments {
                bail!("fragment {f} out of range (P={})", self.fragments);
            }
        }
        let ranges: &[Range<usize>] = match frag {
            Some(f) => &self.frag_ranges[f],
            None => &self.full,
        };
        let expected: usize = ranges.iter().map(|r| self.codec.wire_bytes(r.len())).sum();
        for (r, p) in payloads.iter().enumerate() {
            if p.len() != expected {
                bail!(
                    "outer sync: replica {r} wire payload is {} bytes, expected {expected}",
                    p.len()
                );
            }
        }

        // 1+2. fused decode→reduce→finish, sharded with deterministic
        // per-piece ownership: each shard zeros its pieces of the
        // delta arena, accumulates every payload's dequantized blocks
        // directly into them (`Codec::decode_add` — no per-replica
        // f32 scratch) in replica-index order, then finishes the
        // outer gradient in place. Every element's operation sequence
        // is exactly the retired scratch-buffer path's, so the result
        // is bit-identical at any thread count. Identity payloads
        // hold theta: Delta = reference - acc/M, where the reference
        // is the broadcast view under a lossy down-wire and the exact
        // global otherwise (see `sync` for why the view). Lossy
        // payloads hold dq(delta): Delta = acc/M directly.
        let mut range_off = Vec::with_capacity(ranges.len());
        let mut off = 0usize;
        for r in ranges {
            range_off.push(off);
            off += self.codec.wire_bytes(r.len());
        }
        let m = payloads.len() as f32;
        let identity = self.codec.is_identity();
        let shards = par::shard_ranges(ranges, self.sync_threads, BLOCK);
        let reference: &[f32] = match &self.down {
            Some(dw) => dw.view(),
            None => self.global.data(),
        };
        let codec = Arc::clone(&self.codec);
        let t0 = Instant::now();
        let accs = par::split_pieces(self.acc.data_mut(), &shards);
        let items: Vec<_> = shards.iter().zip(accs).collect();
        par::map_shards(items, |_, (pieces, accs)| -> Result<()> {
            for (p, acc) in pieces.iter().zip(accs) {
                let src = &ranges[p.src];
                let woff = range_off[p.src] + codec.wire_bytes(p.range.start - src.start);
                let wlen = codec.wire_bytes(p.len());
                acc.fill(0.0);
                for payload in payloads {
                    codec.decode_add(&payload[woff..woff + wlen], &mut acc[..])?;
                }
                if identity {
                    acc_finish(acc, &reference[p.range.clone()], m);
                } else {
                    acc_scale(acc, m);
                }
            }
            Ok(())
        })
        .into_iter()
        .collect::<Result<()>>()?;
        self.cur.reduce_s += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        self.opt.step_pieces(&mut self.global, &self.acc, &shards);
        self.cur.step_s += t0.elapsed().as_secs_f64();

        // 3. publish + wire accounting (exact encoded bytes up).
        self.publish_and_record(frag, payloads.len(), Some(expected as u64), sink)
    }

    /// Open the arrival half of a streamed sync: fix the contributor
    /// set (the replicas live at dispatch, strictly ascending — the fp
    /// accumulation order) and precompute the reduce shard partition
    /// and its per-shard wire watermarks. The transport then feeds
    /// [`OuterSync::arrival_chunk`] as `ContribChunk` frames land.
    pub fn arrival_begin(
        &self,
        contributors: &[usize],
        frag: Option<usize>,
    ) -> Result<ArrivalReduce> {
        if contributors.is_empty() {
            bail!("outer sync: arrival with zero contributors");
        }
        if !contributors.windows(2).all(|w| w[0] < w[1]) {
            bail!("outer sync: arrival contributors must be strictly ascending replica ids");
        }
        if let Some(f) = frag {
            if f >= self.fragments {
                bail!("fragment {f} out of range (P={})", self.fragments);
            }
        }
        let ranges: Vec<Range<usize>> = match frag {
            Some(f) => self.frag_ranges[f].clone(),
            None => self.full.clone(),
        };
        let mut range_off = Vec::with_capacity(ranges.len());
        let mut off = 0usize;
        for r in &ranges {
            range_off.push(off);
            off += self.codec.wire_bytes(r.len());
        }
        let expected = off;
        let shards = par::shard_ranges(&ranges, self.sync_threads, BLOCK);
        let wire_end = shards
            .iter()
            .map(|pieces| {
                pieces
                    .iter()
                    .map(|p| {
                        let src = &ranges[p.src];
                        range_off[p.src]
                            + self.codec.wire_bytes(p.range.start - src.start)
                            + self.codec.wire_bytes(p.len())
                    })
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let n = contributors.len();
        Ok(ArrivalReduce {
            frag,
            ranges,
            range_off,
            expected,
            shards,
            wire_end,
            ranks: contributors.to_vec(),
            chunks: (0..n).map(|_| Vec::new()).collect(),
            watermark: vec![0; n],
            next: 0,
            fired_early: 0,
        })
    }

    /// Ingest one streamed contribution chunk and fire every reduce
    /// shard that just became ready. Chunks must arrive per replica
    /// contiguously in payload order (`offset` == that replica's
    /// watermark) — out-of-order, duplicate, or overrunning chunks
    /// fail loud, since a silent drop here would corrupt the reduce.
    /// The chunk is parked as a zero-copy frame view; bytes are only
    /// read when a shard containing them fires.
    pub fn arrival_chunk(
        &mut self,
        ar: &mut ArrivalReduce,
        rid: usize,
        offset: usize,
        chunk: WireSlice,
    ) -> Result<()> {
        let Ok(idx) = ar.ranks.binary_search(&rid) else {
            bail!(
                "outer sync: contribution chunk from replica {rid}, which is not a \
                 live contributor of this sync"
            );
        };
        if chunk.is_empty() {
            bail!("outer sync: empty contribution chunk from replica {rid}");
        }
        if offset != ar.watermark[idx] {
            bail!(
                "outer sync: replica {rid} chunk at wire offset {offset}, expected \
                 {} — chunks must arrive contiguously in payload order",
                ar.watermark[idx]
            );
        }
        let end = offset + chunk.len();
        if end > ar.expected {
            bail!(
                "outer sync: replica {rid} contribution overruns its payload \
                 ({end} of {} bytes)",
                ar.expected
            );
        }
        ar.watermark[idx] = end;
        ar.chunks[idx].push((offset, chunk));
        self.arrival_fire(ar)
    }

    /// Drop contributors whose lanes died mid-stream (the existing
    /// crash-membership path decided they will never complete) and
    /// re-fire every shard over the survivors' buffered bytes. The
    /// refire is cheap and rare: each shard zeroes its delta pieces
    /// before accumulating, so firing twice is idempotent up to the
    /// contributor set, and the survivors' bits land exactly as if
    /// the dead replicas had never been in the set.
    pub fn arrival_drop(&mut self, ar: &mut ArrivalReduce, dead: &[usize]) -> Result<()> {
        let mut changed = false;
        for &rid in dead {
            if let Ok(idx) = ar.ranks.binary_search(&rid) {
                ar.ranks.remove(idx);
                ar.chunks.remove(idx);
                ar.watermark.remove(idx);
                changed = true;
            }
        }
        if !changed {
            return Ok(());
        }
        ar.next = 0;
        self.arrival_fire(ar)
    }

    /// Fire every reduce shard whose bytes are in from all live
    /// contributors. The per-piece arithmetic is exactly
    /// `sync_encoded`'s fused decode→reduce — same shard partition,
    /// same zero-fill, same replica-index accumulation order, same
    /// finish — just cut per chunk overlap at block-aligned seams
    /// (where `decode_add` splits bit-exactly, because codec blocks
    /// are self-contained).
    fn arrival_fire(&mut self, ar: &mut ArrivalReduce) -> Result<()> {
        if ar.ranks.is_empty() || ar.next >= ar.shards.len() {
            return Ok(());
        }
        let t0 = Instant::now();
        let m = ar.ranks.len() as f32;
        let identity = self.codec.is_identity();
        let wb_block = self.codec.wire_bytes(BLOCK);
        let reference: &[f32] = match &self.down {
            Some(dw) => dw.view(),
            None => self.global.data(),
        };
        let acc = self.acc.data_mut();
        while ar.next < ar.shards.len() {
            let end = ar.wire_end[ar.next];
            if !ar.watermark.iter().all(|&w| w >= end) {
                break;
            }
            if ar.watermark.iter().any(|&w| w < ar.expected) {
                ar.fired_early += 1;
            }
            for p in &ar.shards[ar.next] {
                let src = &ar.ranges[p.src];
                let woff = ar.range_off[p.src] + self.codec.wire_bytes(p.range.start - src.start);
                let wlen = self.codec.wire_bytes(p.len());
                let dst = &mut acc[p.range.clone()];
                dst.fill(0.0);
                for chunks in &ar.chunks {
                    for (coff, cs) in chunks {
                        let a = woff.max(*coff);
                        let b = (woff + wlen).min(coff + cs.len());
                        if a >= b {
                            continue;
                        }
                        // chunk and piece cuts sit on the same BLOCK
                        // grid relative to the source range start, so
                        // the overlap maps to whole codec blocks
                        let e0 = ((a - woff) / wb_block) * BLOCK;
                        let e1 = if b == woff + wlen {
                            p.len()
                        } else {
                            ((b - woff) / wb_block) * BLOCK
                        };
                        self.codec
                            .decode_add(&cs.as_slice()[a - coff..b - coff], &mut dst[e0..e1])?;
                    }
                }
                if identity {
                    acc_finish(dst, &reference[p.range.clone()], m);
                } else {
                    acc_scale(dst, m);
                }
            }
            ar.next += 1;
        }
        self.cur.reduce_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Resolve a streamed sync at merge time: verify the arrival state
    /// is complete and matches the merge's contributor set, fire any
    /// straggler shards, then run the exact one-shot tail — Nesterov
    /// step over the same shard partition, publish, wire accounting,
    /// optional streamed broadcast. Returns the spent chunk views for
    /// the driver to reclaim into the transport's buffer pool.
    pub fn sync_arrival(
        &mut self,
        mut ar: ArrivalReduce,
        contributors: &[usize],
        sink: Option<&mut dyn FnMut(&[u8]) -> Result<()>>,
    ) -> Result<Vec<WireSlice>> {
        if ar.ranks.is_empty() {
            bail!("outer sync with zero replicas");
        }
        if ar.ranks != contributors {
            bail!(
                "outer sync: arrival contributors {:?} do not match the merge set {:?}",
                ar.ranks,
                contributors
            );
        }
        for (i, &w) in ar.watermark.iter().enumerate() {
            if w != ar.expected {
                bail!(
                    "outer sync: replica {} contribution truncated at {w} of {} bytes",
                    ar.ranks[i],
                    ar.expected
                );
            }
        }
        self.arrival_fire(&mut ar)?;
        let (fired, total) = ar.fired();
        if fired != total {
            bail!("outer sync: {} of {total} reduce shards never became ready", total - fired);
        }
        let t0 = Instant::now();
        self.opt.step_pieces(&mut self.global, &self.acc, &ar.shards);
        self.cur.step_s += t0.elapsed().as_secs_f64();
        self.publish_and_record(ar.frag, ar.ranks.len(), Some(ar.expected as u64), sink)?;
        Ok(ar.chunks.into_iter().flatten().map(|(_, ws)| ws).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> Arc<FlatLayout> {
        Arc::new(FlatLayout::new(vec![vec![2], vec![3], vec![1], vec![2]]))
    }

    fn host(layout: &FlatLayout, fill: f32) -> Vec<HostTensor> {
        (0..layout.n_leaves())
            .map(|l| {
                HostTensor::from_vec(
                    layout.shape(l),
                    vec![fill; layout.len(l)],
                )
            })
            .collect()
    }

    fn lits_of(tensors: &[HostTensor]) -> Vec<Arc<xla::Literal>> {
        tensors
            .iter()
            .map(|t| Arc::new(t.to_literal().unwrap()))
            .collect()
    }

    #[test]
    fn full_sync_with_eta1_mu0_averages_replicas() {
        let l = layout();
        let init = host(&l, 1.0);
        let mut sync =
            OuterSync::new(Arc::clone(&l), &init, lits_of(&init), 1.0, 0.0, 1).unwrap();
        let r0 = lits_of(&host(&l, 0.0));
        let r1 = lits_of(&host(&l, 4.0));
        sync.sync(&[&r0[..], &r1[..]], None).unwrap();
        assert!(sync.global().data().iter().all(|&x| x == 2.0));
        // one upload per leaf, not per (replica, leaf)
        assert_eq!(sync.uploads(), l.n_leaves() as u64);
        // the cache matches the new global
        for leaf in 0..l.n_leaves() {
            let v = sync.global_literals().unwrap()[leaf].to_vec::<f32>().unwrap();
            assert!(v.iter().all(|&x| x == 2.0));
        }
    }

    #[test]
    fn fragment_sync_touches_only_due_leaves() {
        let l = layout();
        let init = host(&l, 1.0);
        let init_lits = lits_of(&init);
        let mut sync =
            OuterSync::new(Arc::clone(&l), &init, init_lits.clone(), 1.0, 0.0, 2).unwrap();
        let r = lits_of(&host(&l, 5.0));
        sync.sync(&[&r[..]], Some(1)).unwrap(); // leaves {1, 3}
        assert_eq!(sync.uploads(), 2);
        assert_eq!(sync.global().leaf(0), &[1.0, 1.0]);
        assert!(sync.global().leaf(1).iter().all(|&x| x == 5.0));
        assert_eq!(sync.global().leaf(2), &[1.0]);
        assert!(sync.global().leaf(3).iter().all(|&x| x == 5.0));
        // untouched leaves still share the ORIGINAL literal allocation
        assert!(Arc::ptr_eq(&sync.global_literals().unwrap()[0], &init_lits[0]));
        assert!(Arc::ptr_eq(&sync.global_literals().unwrap()[2], &init_lits[2]));
        assert!(!Arc::ptr_eq(&sync.global_literals().unwrap()[1], &init_lits[1]));
    }

    #[test]
    fn wire_stats_count_exact_bytes_per_sync() {
        let l = layout(); // 8 elements total; P=2 frag 1 = leaves {1,3} = 5 elems
        let init = host(&l, 1.0);
        let mut sync =
            OuterSync::new(Arc::clone(&l), &init, lits_of(&init), 1.0, 0.0, 2).unwrap();
        let r = lits_of(&host(&l, 5.0));
        sync.sync(&[&r[..], &r[..]], Some(1)).unwrap();
        sync.sync(&[&r[..], &r[..]], None).unwrap();
        let w = sync.wire_stats();
        assert_eq!(w.syncs(), 2);
        assert_eq!(w.records()[0].frag, Some(1));
        assert_eq!(w.records()[0].bytes_per_replica, 5 * 4);
        assert_eq!(w.records()[0].bytes_up(), 2 * 5 * 4);
        assert_eq!(w.records()[0].bytes_down, 5 * 4);
        assert_eq!(w.records()[1].bytes_per_replica, 8 * 4);
        assert_eq!(w.total_up(), 2 * 5 * 4 + 2 * 8 * 4);
        assert_eq!(w.total_down(), 5 * 4 + 8 * 4);
    }

    #[test]
    fn encoded_fp32_sync_matches_literal_sync() {
        use crate::comm::{ReplicaComm, WorkerComm};
        let l = layout();
        let init = host(&l, 1.0);
        let mut legacy =
            OuterSync::new(Arc::clone(&l), &init, lits_of(&init), 0.8, 0.9, 1).unwrap();
        let mut coded =
            OuterSync::new(Arc::clone(&l), &init, lits_of(&init), 0.8, 0.9, 1).unwrap();
        let r0 = lits_of(&host(&l, 0.25));
        let r1 = lits_of(&host(&l, 4.5));
        legacy.sync(&[&r0[..], &r1[..]], None).unwrap();

        let link = coded.link();
        let mut wc = WorkerComm::default();
        let mut payloads = Vec::new();
        for (r, lits) in [&r0, &r1].into_iter().enumerate() {
            let mut rc = ReplicaComm::default();
            payloads.push(
                link.encode_replica(r, lits, &mut wc, &mut rc, None, 0).unwrap(),
            );
        }
        let frames: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        coded.sync_encoded(&frames, None).unwrap();

        let a: Vec<u32> = legacy.global().data().iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = coded.global().data().iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "fp32 encoded sync must be bit-identical");
        assert_eq!(
            legacy.wire_stats().total(),
            coded.wire_stats().total(),
            "identity wire bytes must agree between the two entry points"
        );
        // short payloads are rejected
        assert!(coded.sync_encoded(&[&frames[0][1..]], None).is_err());
        assert!(coded.sync_encoded(&[], None).is_err());
    }

    #[test]
    fn lossy_down_wire_records_encoded_broadcast_bytes() {
        use crate::comm::{codec_for, OuterBits};
        let l = layout(); // 8 elements total
        let init = host(&l, 1.0);
        let mut sync = OuterSync::new(Arc::clone(&l), &init, lits_of(&init), 1.0, 0.0, 2)
            .unwrap()
            .with_codec(codec_for(OuterBits::Fp32), 7)
            .with_down_codec(codec_for(OuterBits::Int4));
        assert!(sync.down().is_some());
        assert!(sync.take_broadcast_bytes().is_none(), "no sync yet");
        let r = lits_of(&host(&l, 5.0));
        sync.sync(&[&r[..], &r[..]], Some(1)).unwrap(); // leaves {1,3}: 5 elems
        let bytes = sync.take_broadcast_bytes().expect("lossy down must stash bytes");
        let w = sync.wire_stats();
        // down counted at the exact encoded size, not 4 B/elem
        assert_eq!(w.records()[0].bytes_down, bytes.len() as u64);
        assert!(w.records()[0].bytes_down < 5 * 4, "int4 < f32 broadcast");
        // up stays the raw f32 literal path
        assert_eq!(w.records()[0].bytes_per_replica, 5 * 4);
        // the view tracks the refreshed global over the synced ranges
        let dw = sync.down().unwrap();
        let step_bound = 5.0 / 7.0 * 1.0001; // max|delta| / qmax
        for range in [l.range(1), l.range(3)] {
            for i in range {
                let g = sync.global().data()[i];
                assert!(
                    (dw.view()[i] - g).abs() <= step_bound,
                    "view[{i}] {} vs global {g}",
                    dw.view()[i]
                );
            }
        }
        // eval cache still holds the exact global, not the lossy view
        // (materialized lazily at this read)
        for leaf in [1usize, 3] {
            let v = sync.global_literals().unwrap()[leaf].to_vec::<f32>().unwrap();
            let r = l.range(leaf);
            for (x, i) in v.iter().zip(r) {
                assert_eq!(x.to_bits(), sync.global().data()[i].to_bits());
            }
        }
        // taking twice yields nothing until the next sync
        assert!(sync.take_broadcast_bytes().is_none());
        // a sync whose payload is never shipped must fail loud rather
        // than silently desynchronize replicas from the down view
        sync.sync(&[&r[..], &r[..]], Some(0)).unwrap();
        assert!(
            sync.sync(&[&r[..], &r[..]], Some(0)).is_err(),
            "un-taken broadcast payload must refuse the next sync"
        );
    }

    #[test]
    fn lossy_down_wire_defers_literal_uploads_until_read() {
        use crate::comm::{codec_for, OuterBits};
        let l = layout(); // 4 leaves; P=2 frag 1 = leaves {1, 3}
        let init = host(&l, 1.0);
        let init_lits = lits_of(&init);
        let mut sync = OuterSync::new(Arc::clone(&l), &init, init_lits.clone(), 1.0, 0.0, 2)
            .unwrap()
            .with_codec(codec_for(OuterBits::Fp32), 3)
            .with_down_codec(codec_for(OuterBits::Int8));
        let r = lits_of(&host(&l, 5.0));
        sync.sync(&[&r[..]], Some(1)).unwrap();
        let _ = sync.take_broadcast_bytes().unwrap();
        // the sync itself built no literals: workers rebuild their own
        // from the broadcast, so the coordinator cache only marks
        assert_eq!(sync.uploads(), 0, "lossy-down sync must not upload");
        assert_eq!(sync.stale_literals(), 2);
        // the first cache read materializes exactly the stale leaves
        sync.global_literals().unwrap();
        assert_eq!(sync.uploads(), 2);
        assert_eq!(sync.stale_literals(), 0);
        // repeated reads are free, untouched leaves keep the original
        sync.global_literals().unwrap();
        assert_eq!(sync.uploads(), 2);
        assert!(Arc::ptr_eq(&sync.global_literals().unwrap()[0], &init_lits[0]));
        // a second sync re-marks only its fragment
        sync.sync(&[&r[..]], Some(0)).unwrap();
        let _ = sync.take_broadcast_bytes().unwrap();
        assert_eq!(sync.uploads(), 2);
        assert_eq!(sync.stale_literals(), 2, "leaves {{0, 2}} stale");
        sync.global_literals().unwrap();
        assert_eq!(sync.uploads(), 4);
    }

    #[test]
    fn streamed_broadcast_matches_the_stashed_payload() {
        use crate::comm::{codec_for, OuterBits, ReplicaComm, WorkerComm};
        let l = layout(); // 8 elements, P=2
        let init = host(&l, 1.0);
        let build = || {
            OuterSync::new(Arc::clone(&l), &init, lits_of(&init), 0.8, 0.9, 2)
                .unwrap()
                .with_codec(codec_for(OuterBits::Fp32), 7)
                .with_down_codec(codec_for(OuterBits::Int4))
                .with_sync_threads(3)
        };
        let mut oracle = build();
        let mut streamed = build();
        let r0 = lits_of(&host(&l, 0.25));
        let r1 = lits_of(&host(&l, 4.5));
        for (round, frag) in [(0u64, Some(0)), (1, Some(1)), (2, None)] {
            let mut payloads = Vec::new();
            for sync in [&oracle, &streamed] {
                let link = sync.link();
                let mut per_sync = Vec::new();
                for (r, lits) in [&r0, &r1].into_iter().enumerate() {
                    let mut wc = WorkerComm::default();
                    let mut rc = ReplicaComm::default();
                    per_sync.push(
                        link.encode_replica(r, lits, &mut wc, &mut rc, frag, round).unwrap(),
                    );
                }
                payloads.push(per_sync);
            }
            let frames: Vec<&[u8]> = payloads[0].iter().map(|p| p.as_slice()).collect();
            oracle.sync_encoded(&frames, frag).unwrap();
            let want = oracle.take_broadcast_bytes().unwrap();
            assert_eq!(
                oracle.down_payload_bytes(frag),
                Some(want.len() as u64),
                "down_payload_bytes must predict the exact encoded size"
            );

            let frames: Vec<&[u8]> = payloads[1].iter().map(|p| p.as_slice()).collect();
            let mut got = Vec::new();
            streamed
                .sync_encoded_streamed(&frames, frag, &mut |chunk| {
                    got.extend_from_slice(chunk);
                    Ok(())
                })
                .unwrap();
            assert_eq!(got, want.as_slice(), "streamed bytes == stashed payload");
            // nothing stashed — the sink already shipped it
            assert!(streamed.take_broadcast_bytes().is_none());
            // and the engines stay bit-identical
            assert_eq!(
                oracle.global().data(), streamed.global().data(),
                "round {round}: globals diverged"
            );
        }
        // identity down-wire refuses to stream (nothing to stream)
        let mut ident =
            OuterSync::new(Arc::clone(&l), &init, lits_of(&init), 1.0, 0.0, 1).unwrap();
        assert!(ident.down_payload_bytes(None).is_none());
        let link = ident.link();
        let mut wc = WorkerComm::default();
        let mut rc = ReplicaComm::default();
        let p = link.encode_replica(0, &lits_of(&host(&l, 2.0)), &mut wc, &mut rc, None, 0)
            .unwrap();
        assert!(ident
            .sync_encoded_streamed(&[p.as_slice()], None, &mut |_| Ok(()))
            .is_err());
    }

    fn host_fn(layout: &FlatLayout, f: impl Fn(usize) -> f32) -> Vec<HostTensor> {
        (0..layout.n_leaves())
            .map(|l| {
                let r = layout.range(l);
                HostTensor::from_vec(layout.shape(l), r.map(&f).collect())
            })
            .collect()
    }

    /// Encode replica `r`'s contribution both ways (one-shot and
    /// streamed chunks) from identical fresh comm state.
    fn encode_both(
        link: &crate::comm::CommLink,
        init: &[Arc<xla::Literal>],
        state: &[Arc<xla::Literal>],
        r: usize,
        frag: Option<usize>,
        chunks: usize,
    ) -> (Vec<u8>, Vec<(usize, Vec<u8>)>) {
        use crate::comm::{ReplicaComm, WorkerComm};
        let mut wc = WorkerComm::default();
        let mut rc = ReplicaComm::default();
        link.init_snapshot(&mut wc, init).unwrap();
        link.init_replica(&mut rc);
        let one = link
            .encode_replica(r, state, &mut wc, &mut rc, frag, 0)
            .unwrap()
            .as_slice()
            .to_vec();
        let mut wc = WorkerComm::default();
        let mut rc = ReplicaComm::default();
        link.init_snapshot(&mut wc, init).unwrap();
        link.init_replica(&mut rc);
        let mut parts = Vec::new();
        link.encode_replica_streamed(r, state, &mut wc, &mut rc, frag, 0, chunks, &mut |off, b| {
            parts.push((off, b.to_vec()));
            Ok(())
        })
        .unwrap();
        (one, parts)
    }

    #[test]
    fn arrival_pipelined_sync_matches_one_shot() {
        use crate::comm::{codec_for, OuterBits};
        // multi-block leaves with an odd tail so chunk cuts are real
        let l = Arc::new(FlatLayout::new(vec![vec![700], vec![300, 2], vec![513]]));
        let init = host_fn(&l, |i| (i as f32 * 0.01).cos());
        let init_lits = lits_of(&init);
        let build = || {
            OuterSync::new(Arc::clone(&l), &init, init_lits.clone(), 0.8, 0.9, 2)
                .unwrap()
                .with_codec(codec_for(OuterBits::Int4), 7)
                .with_down_codec(codec_for(OuterBits::Int4))
                .with_sync_threads(3)
        };
        let mut oracle = build();
        let mut arrival = build();
        let states: Vec<_> = (0..3)
            .map(|r| lits_of(&host_fn(&l, |i| ((i + 31 * r) as f32 * 0.03).sin())))
            .collect();
        let frag = Some(1);
        let link = oracle.link();
        let mut one_shots = Vec::new();
        let mut streamed = Vec::new();
        for (r, st) in states.iter().enumerate() {
            let (one, parts) = encode_both(&link, &init_lits, st, r, frag, 4);
            let cat: Vec<u8> = parts.iter().flat_map(|(_, b)| b.clone()).collect();
            assert_eq!(cat, one, "replica {r}: chunks must concatenate to the one-shot");
            one_shots.push(one);
            streamed.push(parts);
        }
        let frames: Vec<&[u8]> = one_shots.iter().map(|p| p.as_slice()).collect();
        oracle.sync_encoded(&frames, frag).unwrap();
        let want_bcast = oracle.take_broadcast_bytes().unwrap();

        // feed chunks round-robin across replicas — shards must fire
        // as ranges complete, before the last replica's tail arrives
        let mut ar = arrival.arrival_begin(&[0, 1, 2], frag).unwrap();
        let max_chunks = streamed.iter().map(|p| p.len()).max().unwrap();
        assert!(max_chunks > 1, "test needs real chunking");
        for j in 0..max_chunks {
            for (r, parts) in streamed.iter().enumerate() {
                if let Some((off, b)) = parts.get(j) {
                    arrival
                        .arrival_chunk(&mut ar, r, *off, WireSlice::copied_from(b))
                        .unwrap();
                }
            }
        }
        assert!(ar.complete());
        let (fired, total) = ar.fired();
        assert_eq!(fired, total, "all shards fire once the bytes are in");
        assert!(ar.fired_early() > 0, "reduce must start before the last chunk");
        let spent = arrival.sync_arrival(ar, &[0, 1, 2], None).unwrap();
        assert!(!spent.is_empty());
        let got_bcast = arrival.take_broadcast_bytes().unwrap();
        assert_eq!(got_bcast.as_slice(), want_bcast.as_slice());
        let a: Vec<u32> = oracle.global().data().iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = arrival.global().data().iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "arrival-pipelined sync must be bit-identical");
        assert_eq!(oracle.wire_stats().total(), arrival.wire_stats().total());
        // stage log recorded reduce time on both engines
        assert_eq!(arrival.stage_log().len(), 1);
    }

    #[test]
    fn arrival_rejects_bad_chunks_and_resolves_drops() {
        use crate::comm::{codec_for, OuterBits};
        let l = Arc::new(FlatLayout::new(vec![vec![700], vec![300, 2], vec![513]]));
        let init = host_fn(&l, |i| (i as f32 * 0.01).cos());
        let init_lits = lits_of(&init);
        let build = || {
            OuterSync::new(Arc::clone(&l), &init, init_lits.clone(), 0.8, 0.9, 1)
                .unwrap()
                .with_codec(codec_for(OuterBits::Int8), 11)
                .with_down_codec(codec_for(OuterBits::Int8))
                .with_sync_threads(2)
        };
        let mut oracle = build();
        let mut arrival = build();
        let states: Vec<_> = (0..3)
            .map(|r| lits_of(&host_fn(&l, |i| ((i + 7 * r) as f32 * 0.05).sin())))
            .collect();
        let link = oracle.link();
        let mut one_shots = Vec::new();
        let mut streamed = Vec::new();
        for (r, st) in states.iter().enumerate() {
            let (one, parts) = encode_both(&link, &init_lits, st, r, None, 3);
            one_shots.push(one);
            streamed.push(parts);
        }
        // the oracle merges only the survivors
        let frames: Vec<&[u8]> = one_shots[..2].iter().map(|p| p.as_slice()).collect();
        oracle.sync_encoded(&frames, None).unwrap();
        let _ = oracle.take_broadcast_bytes().unwrap();

        let mut ar = arrival.arrival_begin(&[0, 1, 2], None).unwrap();
        // unknown replica fails loud
        assert!(arrival
            .arrival_chunk(&mut ar, 9, 0, WireSlice::copied_from(&streamed[0][0].1))
            .is_err());
        // out-of-order (non-watermark) offset fails loud
        let (off1, b1) = &streamed[0][1];
        assert!(arrival
            .arrival_chunk(&mut ar, 0, *off1, WireSlice::copied_from(b1))
            .is_err());
        // feed survivors fully, replica 2 only partially
        for r in 0..2 {
            for (off, b) in &streamed[r] {
                arrival
                    .arrival_chunk(&mut ar, r, *off, WireSlice::copied_from(b))
                    .unwrap();
            }
        }
        let (off, b) = &streamed[2][0];
        arrival
            .arrival_chunk(&mut ar, 2, *off, WireSlice::copied_from(b))
            .unwrap();
        // merging with a truncated live contributor fails loud
        assert!(!ar.complete());
        // replica 2's lane died: drop it and re-fire over survivors
        arrival.arrival_drop(&mut ar, &[2]).unwrap();
        assert_eq!(ar.contributors(), &[0, 1]);
        assert!(ar.complete());
        arrival.sync_arrival(ar, &[0, 1], None).unwrap();
        let _ = arrival.take_broadcast_bytes().unwrap();
        let a: Vec<u32> = oracle.global().data().iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = arrival.global().data().iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "post-drop refire must match the survivor-only one-shot");
    }

    #[test]
    fn rejects_malformed_inputs() {
        let l = layout();
        let init = host(&l, 0.0);
        let mut sync =
            OuterSync::new(Arc::clone(&l), &init, lits_of(&init), 0.8, 0.9, 2).unwrap();
        assert!(sync.sync(&[], None).is_err());
        let short = lits_of(&host(&l, 1.0)[..3]);
        assert!(sync.sync(&[&short[..]], None).is_err());
        let ok = lits_of(&host(&l, 1.0));
        assert!(sync.sync(&[&ok[..]], Some(2)).is_err()); // fragment id out of range
    }
}
