//! Append-only event journal for coordinated runs.
//!
//! Every membership and sync event in a run — phase transitions of the
//! coordinator FSM, outer-sync sends and merges, joins, leaves,
//! crashes, straggler notes, checkpoint stops and resumes — appends
//! one [`JournalEvent`] here. The journal is the run's flight
//! recorder: it serializes into the checkpoint (so a resumed run
//! carries its full history) and it is what `diloco resume` replays to
//! know where the interrupted run stood. Events are keyed by the step
//! and the *absolute* outer-sync count at the time of the event, so
//! entries written before and after a resume stitch into one coherent
//! timeline.
//!
//! The journal never drives control flow — the fault plan and the FSM
//! do that. It only records, which keeps the append path cheap enough
//! to sit on the hot sync path (measured by `bench_hot_path`).

use anyhow::{bail, Result};

use crate::util::json::Json;

/// What happened. `label()`/`parse()` round-trip through the
/// checkpoint's JSON form.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Coordinator FSM entered a phase (detail = phase label).
    PhaseEnter,
    /// An outer-sync payload was captured and handed to the reducer.
    SyncSend,
    /// An outer sync was reduced and its broadcast built.
    SyncMerge,
    /// A replica joined the run at an outer boundary.
    Join,
    /// A replica left gracefully (contributed to its last sync).
    Leave,
    /// A replica died mid-segment (dropped from that reduce).
    Crash,
    /// A replica straggled (walltime-model note; math unaffected).
    Straggle,
    /// A checkpoint was captured at an outer boundary.
    Checkpoint,
    /// The run resumed from a checkpoint.
    Resume,
}

impl EventKind {
    pub fn label(self) -> &'static str {
        match self {
            EventKind::PhaseEnter => "phase",
            EventKind::SyncSend => "sync-send",
            EventKind::SyncMerge => "sync-merge",
            EventKind::Join => "join",
            EventKind::Leave => "leave",
            EventKind::Crash => "crash",
            EventKind::Straggle => "straggle",
            EventKind::Checkpoint => "checkpoint",
            EventKind::Resume => "resume",
        }
    }

    pub fn parse(s: &str) -> Result<EventKind> {
        Ok(match s {
            "phase" => EventKind::PhaseEnter,
            "sync-send" => EventKind::SyncSend,
            "sync-merge" => EventKind::SyncMerge,
            "join" => EventKind::Join,
            "leave" => EventKind::Leave,
            "crash" => EventKind::Crash,
            "straggle" => EventKind::Straggle,
            "checkpoint" => EventKind::Checkpoint,
            "resume" => EventKind::Resume,
            other => bail!("journal: unknown event kind {other:?}"),
        })
    }
}

/// One journal entry. `sync` is the absolute outer-sync count at the
/// time of the event (merges completed so far, including any before a
/// resume), `step` the inner step the coordinator had reached.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalEvent {
    pub step: usize,
    pub sync: u64,
    pub kind: EventKind,
    pub replica: Option<usize>,
    pub detail: String,
}

/// The append-only log. Cloned wholesale into checkpoints; `extend`
/// stitches a resumed run's new events onto the restored history.
#[derive(Debug, Clone, Default)]
pub struct Journal {
    events: Vec<JournalEvent>,
}

impl Journal {
    pub fn new() -> Journal {
        Journal::default()
    }

    pub fn append(
        &mut self,
        step: usize,
        sync: u64,
        kind: EventKind,
        replica: Option<usize>,
        detail: impl Into<String>,
    ) {
        self.events.push(JournalEvent {
            step,
            sync,
            kind,
            replica,
            detail: detail.into(),
        });
    }

    pub fn events(&self) -> &[JournalEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn count(&self, kind: EventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// Append all of `later`'s events after this journal's (resume
    /// stitching: restored history first, new run's events after).
    pub fn extend(&mut self, later: Journal) {
        self.events.extend(later.events);
    }

    pub fn to_json(&self) -> Json {
        Json::arr(self.events.iter().map(|e| {
            let mut pairs = vec![
                ("step", Json::int(e.step as u64)),
                ("sync", Json::int(e.sync)),
                ("kind", Json::str(e.kind.label())),
                ("detail", Json::str(&e.detail)),
            ];
            if let Some(r) = e.replica {
                pairs.push(("replica", Json::int(r as u64)));
            }
            Json::obj(pairs)
        }))
    }

    pub fn from_json(j: &Json) -> Result<Journal> {
        let Some(items) = j.as_arr() else {
            bail!("journal: expected a JSON array, got {j}");
        };
        let mut journal = Journal::new();
        for item in items {
            journal.events.push(JournalEvent {
                step: item.usize_of("step")?,
                sync: item.u64_of("sync")?,
                kind: EventKind::parse(&item.str_of("kind")?)?,
                replica: item.get("replica").and_then(|v| v.as_usize()),
                detail: item.str_of("detail")?,
            });
        }
        Ok(journal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appends_counts_and_roundtrips() {
        let mut j = Journal::new();
        j.append(0, 0, EventKind::PhaseEnter, None, "warmup");
        j.append(6, 1, EventKind::SyncMerge, None, "frag 0");
        j.append(9, 1, EventKind::Crash, Some(2), "fault plan");
        assert_eq!(j.len(), 3);
        assert_eq!(j.count(EventKind::Crash), 1);
        assert_eq!(j.count(EventKind::SyncSend), 0);

        let back = Journal::from_json(&j.to_json()).unwrap();
        assert_eq!(back.events(), j.events());

        // stitching keeps order: history first, new events after
        let mut newer = Journal::new();
        newer.append(12, 2, EventKind::Resume, None, "from ckpt");
        let mut stitched = back;
        stitched.extend(newer);
        assert_eq!(stitched.len(), 4);
        assert_eq!(stitched.events()[3].kind, EventKind::Resume);
    }

    #[test]
    fn rejects_unknown_kinds() {
        assert!(EventKind::parse("nope").is_err());
        let j = Json::parse(r#"[{"step":1,"sync":0,"kind":"nope","detail":""}]"#).unwrap();
        assert!(Journal::from_json(&j).is_err());
    }

    #[test]
    fn every_kind_label_roundtrips() {
        for k in [
            EventKind::PhaseEnter,
            EventKind::SyncSend,
            EventKind::SyncMerge,
            EventKind::Join,
            EventKind::Leave,
            EventKind::Crash,
            EventKind::Straggle,
            EventKind::Checkpoint,
            EventKind::Resume,
        ] {
            assert_eq!(EventKind::parse(k.label()).unwrap(), k);
        }
    }
}
