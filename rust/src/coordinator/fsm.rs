//! The coordinator's ticked run-phase state machine.
//!
//! The drive loop has always had implicit phases — validate the
//! membership, capture comm snapshots, run segments, drain the
//! pipeline, apply the final broadcast — but they lived as positions
//! in a function body, invisible to the journal and impossible to
//! assert on. This module makes them an explicit FSM:
//!
//! ```text
//! WaitingForMembers -> Warmup -> Train -> Cooldown -> Done
//! ```
//!
//! - **WaitingForMembers** — entry: the universe of replicas exists
//!   but the live set has not been validated yet (elastic runs start
//!   with joiners dark).
//! - **Warmup** — at least one live replica; comm arenas and
//!   snapshots are being captured, no inner step has run.
//! - **Train** — segments are being dispatched; membership events
//!   (join/leave/crash) apply at their keyed outer boundaries.
//! - **Cooldown** — the step loop has exited (end of training or a
//!   checkpoint stop); the pipeline is drained, the final broadcast
//!   is pending application.
//! - **Done** — the final broadcast is built; replica states are
//!   final.
//!
//! Transitions are validated fail-loud: the drive loop *ticks* the
//! machine at fixed points, and an illegal edge (a bug in the loop's
//! sequencing, e.g. dispatching before membership validation) is an
//! error, not a silent relabel. Every successful transition is
//! recorded in the run's event journal (`coordinator::journal`), so a
//! run's phase history is replayable from the checkpoint.

use anyhow::{bail, Result};

/// One phase of a coordinated run. Ordering is the legal chain; the
/// only skip allowed is `Warmup -> Cooldown` (a zero-step schedule
/// never dispatches a segment).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    WaitingForMembers,
    Warmup,
    Train,
    Cooldown,
    Done,
}

impl Phase {
    pub fn label(self) -> &'static str {
        match self {
            Phase::WaitingForMembers => "waiting-for-members",
            Phase::Warmup => "warmup",
            Phase::Train => "train",
            Phase::Cooldown => "cooldown",
            Phase::Done => "done",
        }
    }

    fn can_advance_to(self, to: Phase) -> bool {
        matches!(
            (self, to),
            (Phase::WaitingForMembers, Phase::Warmup)
                | (Phase::Warmup, Phase::Train)
                | (Phase::Warmup, Phase::Cooldown)
                | (Phase::Train, Phase::Cooldown)
                | (Phase::Cooldown, Phase::Done)
        )
    }
}

/// The ticked machine: current phase + how many ticks it has taken.
/// Owned by the drive loop; one instance per `drive_ctl` invocation
/// (a resumed run re-walks the chain — the phases describe *this*
/// process's lifecycle, the journal carries history across restarts).
#[derive(Debug)]
pub struct CoordinatorFsm {
    phase: Phase,
    ticks: u64,
}

impl CoordinatorFsm {
    pub fn new() -> CoordinatorFsm {
        CoordinatorFsm {
            phase: Phase::WaitingForMembers,
            ticks: 0,
        }
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Tick the machine to `to`. Illegal edges fail loud — they mean
    /// the drive loop's sequencing is broken, and relabeling silently
    /// would let a mis-ordered journal masquerade as a clean run.
    pub fn advance(&mut self, to: Phase) -> Result<Phase> {
        if !self.phase.can_advance_to(to) {
            bail!(
                "coordinator fsm: illegal transition {} -> {}",
                self.phase.label(),
                to.label()
            );
        }
        self.phase = to;
        self.ticks += 1;
        Ok(to)
    }
}

impl Default for CoordinatorFsm {
    fn default() -> Self {
        CoordinatorFsm::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn the_legal_chain_walks_end_to_end() {
        let mut fsm = CoordinatorFsm::new();
        assert_eq!(fsm.phase(), Phase::WaitingForMembers);
        for to in [Phase::Warmup, Phase::Train, Phase::Cooldown, Phase::Done] {
            fsm.advance(to).unwrap();
            assert_eq!(fsm.phase(), to);
        }
        assert_eq!(fsm.ticks(), 4);
    }

    #[test]
    fn zero_step_runs_may_skip_train() {
        let mut fsm = CoordinatorFsm::new();
        fsm.advance(Phase::Warmup).unwrap();
        fsm.advance(Phase::Cooldown).unwrap();
        fsm.advance(Phase::Done).unwrap();
    }

    #[test]
    fn illegal_edges_fail_loud() {
        let mut fsm = CoordinatorFsm::new();
        // skipping membership validation is a sequencing bug
        assert!(fsm.advance(Phase::Train).is_err());
        assert!(fsm.advance(Phase::Done).is_err());
        fsm.advance(Phase::Warmup).unwrap();
        // no going back
        assert!(fsm.advance(Phase::WaitingForMembers).is_err());
        fsm.advance(Phase::Train).unwrap();
        // self-loops are not ticks
        assert!(fsm.advance(Phase::Train).is_err());
        fsm.advance(Phase::Cooldown).unwrap();
        fsm.advance(Phase::Done).unwrap();
        assert!(fsm.advance(Phase::Cooldown).is_err());
        assert_eq!(fsm.ticks(), 4);
    }
}
