//! Nelder-Mead simplex minimizer.
//!
//! The paper minimizes the Huber objective with L-BFGS from 256 random
//! inits (section 6.5). L-BFGS needs gradients; for these 3-7 parameter
//! objectives a derivative-free simplex with random restarts is an
//! equivalent (and more robust) choice — DESIGN.md section 7 records
//! the substitution.

/// Minimize `f` starting from `x0`. Returns (argmin, min).
pub fn minimize(
    f: &dyn Fn(&[f64]) -> f64,
    x0: &[f64],
    scale: f64,
    max_iter: usize,
) -> (Vec<f64>, f64) {
    let n = x0.len();
    let (alpha, gamma, rho, sigma) = (1.0, 2.0, 0.5, 0.5);

    // initial simplex: x0 plus per-coordinate offsets
    let mut simplex: Vec<Vec<f64>> = vec![x0.to_vec()];
    for i in 0..n {
        let mut p = x0.to_vec();
        p[i] += if p[i].abs() > 1e-8 {
            scale * p[i].abs()
        } else {
            scale
        };
        simplex.push(p);
    }
    let mut values: Vec<f64> = simplex.iter().map(|p| f(p)).collect();

    for _ in 0..max_iter {
        // sort simplex by value
        let mut idx: Vec<usize> = (0..=n).collect();
        idx.sort_by(|&a, &b| values[a].partial_cmp(&values[b]).unwrap_or(std::cmp::Ordering::Equal));
        simplex = idx.iter().map(|&i| simplex[i].clone()).collect();
        values = idx.iter().map(|&i| values[i]).collect();

        if (values[n] - values[0]).abs() < 1e-12 * (1.0 + values[0].abs()) {
            break;
        }

        // centroid of all but worst
        let mut centroid = vec![0.0; n];
        for p in &simplex[..n] {
            for (c, &v) in centroid.iter_mut().zip(p) {
                *c += v / n as f64;
            }
        }
        let worst = simplex[n].clone();
        let reflect: Vec<f64> = centroid
            .iter()
            .zip(&worst)
            .map(|(&c, &w)| c + alpha * (c - w))
            .collect();
        let fr = f(&reflect);
        if fr < values[0] {
            // expansion
            let expand: Vec<f64> = centroid
                .iter()
                .zip(&worst)
                .map(|(&c, &w)| c + gamma * (c - w))
                .collect();
            let fe = f(&expand);
            if fe < fr {
                simplex[n] = expand;
                values[n] = fe;
            } else {
                simplex[n] = reflect;
                values[n] = fr;
            }
        } else if fr < values[n - 1] {
            simplex[n] = reflect;
            values[n] = fr;
        } else {
            // contraction
            let contract: Vec<f64> = centroid
                .iter()
                .zip(&worst)
                .map(|(&c, &w)| c + rho * (w - c))
                .collect();
            let fc = f(&contract);
            if fc < values[n] {
                simplex[n] = contract;
                values[n] = fc;
            } else {
                // shrink toward best
                let best = simplex[0].clone();
                for i in 1..=n {
                    for j in 0..n {
                        simplex[i][j] = best[j] + sigma * (simplex[i][j] - best[j]);
                    }
                    values[i] = f(&simplex[i]);
                }
            }
        }
    }
    let mut best = 0;
    for i in 1..=n {
        if values[i] < values[best] {
            best = i;
        }
    }
    (simplex[best].clone(), values[best])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic_bowl() {
        let f = |x: &[f64]| (x[0] - 3.0).powi(2) + (x[1] + 1.0).powi(2) + 5.0;
        let (x, v) = minimize(&f, &[0.0, 0.0], 1.0, 500);
        assert!((x[0] - 3.0).abs() < 1e-4, "{x:?}");
        assert!((x[1] + 1.0).abs() < 1e-4);
        assert!((v - 5.0).abs() < 1e-7);
    }

    #[test]
    fn minimizes_rosenbrock_2d() {
        let f = |x: &[f64]| {
            100.0 * (x[1] - x[0] * x[0]).powi(2) + (1.0 - x[0]).powi(2)
        };
        let (x, _) = minimize(&f, &[-1.2, 1.0], 0.5, 5000);
        assert!((x[0] - 1.0).abs() < 1e-2, "{x:?}");
        assert!((x[1] - 1.0).abs() < 2e-2, "{x:?}");
    }

    #[test]
    fn handles_higher_dimensions() {
        let f = |x: &[f64]| x.iter().map(|v| (v - 2.0) * (v - 2.0)).sum::<f64>();
        let (x, v) = minimize(&f, &[0.0; 5], 1.0, 3000);
        for xi in &x {
            assert!((xi - 2.0).abs() < 1e-3, "{x:?}");
        }
        assert!(v < 1e-5);
    }
}
