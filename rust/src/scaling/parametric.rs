//! Parametric function fitting — paper section 6.5, Table 13.
//!
//! Four candidate forms for the joint loss surface L(N, M):
//!   1. A*N^alpha*M^beta                  (joint power law)
//!   2. A*N^alpha*M^beta + C
//!   3. A*N^(alpha + beta*M) + C
//!   4. A*N^alpha + B*M^beta + C          (Chinchilla-style additive)
//!
//! Fit protocol (exactly the paper's): minimize
//!   sum Huber_delta( log f_Q(N,M) - log L(N,M) )
//! over the training rungs, from 256 random initializations, and select
//! the parameter vector that best fits the held-out top-rung data
//! measured by the mean |log f - log L| residual.

use anyhow::{bail, Result};

use crate::util::rng::Rng;
use crate::util::stats::huber;

use super::neldermead;
use super::residuals::log_residual;

pub const HUBER_DELTA: f64 = 1e-3;
pub const N_RESTARTS: usize = 256;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParametricForm {
    PowerLaw,          // A N^a M^b
    PowerLawPlusC,     // A N^a M^b + C
    ExponentShift,     // A N^(a + b M) + C
    Additive,          // A N^a + B M^b + C
}

impl ParametricForm {
    pub fn all() -> [ParametricForm; 4] {
        [
            ParametricForm::PowerLaw,
            ParametricForm::PowerLawPlusC,
            ParametricForm::ExponentShift,
            ParametricForm::Additive,
        ]
    }

    pub fn label(&self) -> &'static str {
        match self {
            ParametricForm::PowerLaw => "A*N^a*M^b",
            ParametricForm::PowerLawPlusC => "A*N^a*M^b + C",
            ParametricForm::ExponentShift => "A*N^(a+b*M) + C",
            ParametricForm::Additive => "A*N^a + B*M^b + C",
        }
    }

    pub fn n_params(&self) -> usize {
        match self {
            ParametricForm::PowerLaw => 3,
            ParametricForm::PowerLawPlusC => 4,
            ParametricForm::ExponentShift => 4,
            ParametricForm::Additive => 5,
        }
    }

    /// Evaluate with raw (unconstrained) parameter vector q.
    /// Positivity of A/B/C is enforced by exp() transforms.
    pub fn eval(&self, q: &[f64], n: f64, m: f64) -> f64 {
        match self {
            ParametricForm::PowerLaw => q[0].exp() * n.powf(q[1]) * m.powf(q[2]),
            ParametricForm::PowerLawPlusC => {
                q[0].exp() * n.powf(q[1]) * m.powf(q[2]) + q[3].exp()
            }
            ParametricForm::ExponentShift => {
                q[0].exp() * n.powf(q[1] + q[2] * m) + q[3].exp()
            }
            ParametricForm::Additive => {
                q[0].exp() * n.powf(q[1]) + q[2].exp() * m.powf(q[3]) + q[4].exp()
            }
        }
    }

    fn random_init(&self, rng: &mut Rng) -> Vec<f64> {
        // log A ~ U(-1, 4); exponents ~ U(-0.5, 0.2); log C ~ U(-4, 1)
        match self {
            ParametricForm::PowerLaw => vec![
                rng.range_f64(-1.0, 4.0),
                rng.range_f64(-0.5, 0.1),
                rng.range_f64(-0.2, 0.2),
            ],
            ParametricForm::PowerLawPlusC => vec![
                rng.range_f64(-1.0, 4.0),
                rng.range_f64(-0.5, 0.1),
                rng.range_f64(-0.2, 0.2),
                rng.range_f64(-4.0, 1.0),
            ],
            ParametricForm::ExponentShift => vec![
                rng.range_f64(-1.0, 4.0),
                rng.range_f64(-0.5, 0.1),
                rng.range_f64(-0.05, 0.05),
                rng.range_f64(-4.0, 1.0),
            ],
            ParametricForm::Additive => vec![
                rng.range_f64(-1.0, 4.0),
                rng.range_f64(-0.5, 0.1),
                rng.range_f64(-4.0, 1.0),
                rng.range_f64(-0.5, 0.5),
                rng.range_f64(-4.0, 1.0),
            ],
        }
    }
}

/// One (N, M, loss) observation.
#[derive(Debug, Clone, Copy)]
pub struct Obs {
    pub n: f64,
    pub m: f64,
    pub loss: f64,
}

#[derive(Debug, Clone)]
pub struct ParametricFit {
    pub form: ParametricForm,
    pub params: Vec<f64>,
    /// Mean |log f - log L| on the held-out set (Table 13's metric).
    pub holdout_residual: f64,
}

impl ParametricFit {
    pub fn predict(&self, n: f64, m: f64) -> f64 {
        self.form.eval(&self.params, n, m)
    }
}

/// Fit one form on `train`, select the restart by `holdout` residual.
pub fn fit_parametric(
    form: ParametricForm,
    train: &[Obs],
    holdout: &[Obs],
    seed: u64,
    restarts: usize,
) -> Result<ParametricFit> {
    if train.is_empty() || holdout.is_empty() {
        bail!("parametric fit needs train and holdout data");
    }
    let objective = |q: &[f64]| -> f64 {
        let mut total = 0.0;
        for o in train {
            let f = form.eval(q, o.n, o.m);
            if f <= 0.0 || !f.is_finite() {
                return 1e18;
            }
            total += huber(HUBER_DELTA, f.ln() - o.loss.ln());
        }
        total
    };
    let mut rng = Rng::new(seed);
    let mut best: Option<ParametricFit> = None;
    for _ in 0..restarts {
        let q0 = form.random_init(&mut rng);
        let (q, _v) = neldermead::minimize(&objective, &q0, 0.3, 800);
        // holdout selection (the paper holds out the largest rung)
        let mut resid = 0.0;
        let mut ok = true;
        for o in holdout {
            let f = form.eval(&q, o.n, o.m);
            if f <= 0.0 || !f.is_finite() {
                ok = false;
                break;
            }
            resid += log_residual(o.loss, f);
        }
        if !ok {
            continue;
        }
        resid /= holdout.len() as f64;
        if best.as_ref().is_none_or(|b| resid < b.holdout_residual) {
            best = Some(ParametricFit {
                form,
                params: q,
                holdout_residual: resid,
            });
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("no restart produced a finite fit"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(form: ParametricForm, q: &[f64]) -> (Vec<Obs>, Vec<Obs>) {
        let mut train = Vec::new();
        let mut holdout = Vec::new();
        for (i, &n) in [4.6e4, 1.1e5, 2.4e5, 7.2e5].iter().enumerate() {
            for m in [1.0, 2.0, 4.0, 8.0] {
                let loss = form.eval(q, n, m);
                let o = Obs { n, m, loss };
                if i == 3 {
                    holdout.push(o);
                } else {
                    train.push(o);
                }
            }
        }
        (train, holdout)
    }

    #[test]
    fn recovers_pure_power_law() {
        let truth = [19.226f64.ln(), -0.0985, 0.0116];
        let (train, holdout) = synth(ParametricForm::PowerLaw, &truth);
        let fit = fit_parametric(ParametricForm::PowerLaw, &train, &holdout, 1, 64)
            .unwrap();
        assert!(fit.holdout_residual < 1e-3, "resid {}", fit.holdout_residual);
    }

    #[test]
    fn plus_c_form_fits_shifted_data() {
        let truth = [2.0f64.ln(), -0.15, 0.02, 1.5f64.ln()];
        let (train, holdout) = synth(ParametricForm::PowerLawPlusC, &truth);
        let fit =
            fit_parametric(ParametricForm::PowerLawPlusC, &train, &holdout, 2, 128)
                .unwrap();
        assert!(fit.holdout_residual < 5e-3, "resid {}", fit.holdout_residual);
    }

    #[test]
    fn wrong_form_has_larger_residual_than_right_form() {
        // Data generated from the exponent-shift form: the pure power
        // law should extrapolate worse (Table 13's qualitative result).
        let truth = [3.0f64.ln(), -0.12, -0.004, 0.9f64.ln()];
        let (train, holdout) = synth(ParametricForm::ExponentShift, &truth);
        let right =
            fit_parametric(ParametricForm::ExponentShift, &train, &holdout, 3, 128)
                .unwrap();
        let wrong =
            fit_parametric(ParametricForm::PowerLaw, &train, &holdout, 3, 128).unwrap();
        assert!(
            right.holdout_residual < wrong.holdout_residual,
            "{} vs {}",
            right.holdout_residual,
            wrong.holdout_residual
        );
    }

    #[test]
    fn all_forms_have_labels_and_arities() {
        for f in ParametricForm::all() {
            assert!(!f.label().is_empty());
            let mut rng = Rng::new(1);
            assert_eq!(f.random_init(&mut rng).len(), f.n_params());
        }
    }
}
