//! Scaling-law fitting stack — paper section 6.
//!
//! - [`powerlaw`]: independent fits L(N) ~ A*N^alpha (Tables 7-9),
//! - [`joint`]: joint fits f(N,M) ~ A*N^alpha*M^beta (Table 10),
//! - [`batchopt`]: quadratic-in-log2(B) interpolation of the optimal
//!   batch size (section 6.1's batch-size refinement),
//! - [`neldermead`]: derivative-free minimizer (stands in for L-BFGS,
//!   which would need a gradient; the objective is 3-7 dimensional),
//! - [`parametric`]: the four candidate functional forms fit with a
//!   Huber loss and 256 random restarts, selected on held-out top-rung
//!   data (Table 13, section 6.5),
//! - [`residuals`]: the paper's log-residual metric and leave-one-out
//!   validation (Table 11).

pub mod batchopt;
pub mod joint;
pub mod neldermead;
pub mod parametric;
pub mod powerlaw;
pub mod residuals;

pub use batchopt::optimal_batch_log2;
pub use joint::JointFit;
pub use parametric::{fit_parametric, ParametricForm};
pub use powerlaw::PowerLaw;
pub use residuals::log_residual;
