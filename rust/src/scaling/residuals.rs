//! The paper's goodness-of-fit metric and leave-one-out validation
//! (section 6.3, Table 11): res(y, y~) = |log(y) - log(y~)| — chosen
//! because it works uniformly across loss, learning rate, and batch
//! size despite their very different scales.

/// |log(actual) - log(predicted)|.
pub fn log_residual(actual: f64, predicted: f64) -> f64 {
    (actual.ln() - predicted.ln()).abs()
}

/// Summary of one leave-one-out comparison row (one M value).
#[derive(Debug, Clone)]
pub struct LooRow {
    pub m: usize,
    pub loss_residual: f64,
    pub lr_residual: f64,
    pub batch_residual: f64,
}

/// Average residuals across M (the paper's "Average over M" row).
pub fn average_rows(rows: &[LooRow]) -> (f64, f64, f64) {
    let n = rows.len().max(1) as f64;
    (
        rows.iter().map(|r| r.loss_residual).sum::<f64>() / n,
        rows.iter().map(|r| r.lr_residual).sum::<f64>() / n,
        rows.iter().map(|r| r.batch_residual).sum::<f64>() / n,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_is_symmetric_in_ratio() {
        assert!((log_residual(2.0, 4.0) - log_residual(4.0, 2.0)).abs() < 1e-12);
        assert_eq!(log_residual(3.0, 3.0), 0.0);
    }

    #[test]
    fn residual_scale_free() {
        // res depends only on the ratio: key for mixed-scale comparisons.
        let a = log_residual(1e-3, 2e-3);
        let b = log_residual(1e6, 2e6);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn averages() {
        let rows = vec![
            LooRow { m: 1, loss_residual: 0.01, lr_residual: 0.3, batch_residual: 0.1 },
            LooRow { m: 2, loss_residual: 0.03, lr_residual: 0.1, batch_residual: 0.3 },
        ];
        let (l, g, b) = average_rows(&rows);
        assert!((l - 0.02).abs() < 1e-12);
        assert!((g - 0.2).abs() < 1e-12);
        assert!((b - 0.2).abs() < 1e-12);
    }
}
