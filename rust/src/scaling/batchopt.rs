//! Optimal-batch-size interpolation (paper section 6.1, Table 9 prep):
//! sweeps use powers of 2, so the true optimum may fall between grid
//! points; fit a quadratic to loss as a function of log2(B) (using the
//! best loss at each B) and take the parabola's minimum.

use anyhow::{bail, Result};

use crate::util::stats;

/// Given (batch_tokens, best_loss_at_that_batch) pairs, return the
/// interpolated optimal log2(batch). Falls back to the argmin grid
/// point when the quadratic is degenerate or non-convex.
pub fn optimal_batch_log2(points: &[(f64, f64)]) -> Result<f64> {
    if points.len() < 2 {
        bail!("need >= 2 batch points");
    }
    let x: Vec<f64> = points.iter().map(|p| p.0.log2()).collect();
    let y: Vec<f64> = points.iter().map(|p| p.1).collect();
    let argmin = x[y
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0];
    if points.len() == 2 {
        return Ok(argmin);
    }
    match stats::quadfit(&x, &y) {
        Some(c) if c[2] > 1e-12 => {
            let xmin = -c[1] / (2.0 * c[2]);
            // Clamp to the swept range: extrapolating a parabola beyond
            // the grid is meaningless.
            let lo = x.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            Ok(xmin.clamp(lo, hi))
        }
        _ => Ok(argmin),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_parabola_minimum() {
        // loss = (log2 B - 11.5)^2 + 3 -> optimum 11.5 (between grid pts)
        let pts: Vec<(f64, f64)> = [9.0f64, 10.0, 11.0, 12.0, 13.0]
            .iter()
            .map(|&l| (2f64.powf(l), (l - 11.5) * (l - 11.5) + 3.0))
            .collect();
        let b = optimal_batch_log2(&pts).unwrap();
        assert!((b - 11.5).abs() < 1e-9);
    }

    #[test]
    fn clamps_to_grid_range() {
        // Monotone decreasing loss: parabola vertex beyond the grid.
        let pts: Vec<(f64, f64)> = [8.0f64, 9.0, 10.0]
            .iter()
            .map(|&l| (2f64.powf(l), 10.0 - l))
            .collect();
        let b = optimal_batch_log2(&pts).unwrap();
        assert!(b <= 10.0 + 1e-9);
    }

    #[test]
    fn two_points_uses_argmin() {
        let pts = vec![(512.0, 3.0), (1024.0, 2.5)];
        assert_eq!(optimal_batch_log2(&pts).unwrap(), 10.0);
    }

    #[test]
    fn concave_falls_back_to_argmin() {
        let pts: Vec<(f64, f64)> = [8.0f64, 9.0, 10.0]
            .iter()
            .map(|&l| (2f64.powf(l), -(l - 9.0) * (l - 9.0)))
            .collect();
        let b = optimal_batch_log2(&pts).unwrap();
        assert!(b == 8.0 || b == 10.0);
    }
}
