//! Power-law fits y ~ A * N^alpha via log-log linear regression —
//! exactly the paper's independent-fit methodology ("can easily be done
//! via applying linear fit techniques to log(L), and is not sensitive
//! to initial values", section 6.1).

use anyhow::{bail, Result};

use crate::util::stats;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerLaw {
    pub a: f64,
    pub alpha: f64,
}

impl PowerLaw {
    /// Fit to (n_i, y_i) pairs; all values must be positive.
    pub fn fit(n: &[f64], y: &[f64]) -> Result<PowerLaw> {
        if n.len() != y.len() || n.len() < 2 {
            bail!("power law fit needs >= 2 points");
        }
        if n.iter().chain(y).any(|&v| v <= 0.0) {
            bail!("power law fit requires positive data");
        }
        let lx: Vec<f64> = n.iter().map(|v| v.ln()).collect();
        let ly: Vec<f64> = y.iter().map(|v| v.ln()).collect();
        let (intercept, slope) =
            stats::linreg(&lx, &ly).ok_or_else(|| anyhow::anyhow!("degenerate fit"))?;
        Ok(PowerLaw {
            a: intercept.exp(),
            alpha: slope,
        })
    }

    pub fn predict(&self, n: f64) -> f64 {
        self.a * n.powf(self.alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn recovers_exact_law() {
        let n: Vec<f64> = vec![1e5, 1e6, 1e7, 1e8];
        let y: Vec<f64> = n.iter().map(|&x| 18.0 * x.powf(-0.095)).collect();
        let p = PowerLaw::fit(&n, &y).unwrap();
        assert!((p.a - 18.0).abs() < 1e-6, "A={}", p.a);
        assert!((p.alpha + 0.095).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(PowerLaw::fit(&[1.0], &[1.0]).is_err());
        assert!(PowerLaw::fit(&[1.0, -2.0], &[1.0, 2.0]).is_err());
    }

    #[test]
    fn prop_recovery_under_noise() {
        // Property: with small multiplicative noise, recovered exponent
        // is close to truth for random laws.
        prop::check(
            7,
            48,
            |rng: &mut Rng| {
                let a = rng.range_f64(0.5, 30.0);
                let alpha = rng.range_f64(-1.2, -0.02);
                (a, alpha, rng.next_u64())
            },
            |&(a, alpha, seed)| {
                let mut noise = Rng::new(seed);
                let n: Vec<f64> = (0..8).map(|i| 1e4 * 4f64.powi(i)).collect();
                let y: Vec<f64> = n
                    .iter()
                    .map(|&x| a * x.powf(alpha) * (1.0 + 0.002 * noise.normal()))
                    .collect();
                let p = PowerLaw::fit(&n, &y).map_err(|e| e.to_string())?;
                prop::close(p.alpha, alpha, 0.02)?;
                Ok(())
            },
        );
    }

    #[test]
    fn predict_interpolates() {
        let p = PowerLaw {
            a: 2.0,
            alpha: 0.5,
        };
        assert!((p.predict(4.0) - 4.0).abs() < 1e-12);
    }
}
