//! Joint two-variable power laws f(N, M) ~ A * N^alpha * M^beta
//! (paper section 6.2, Table 10), fit by linear regression in
//! log-space: ln f = ln A + alpha ln N + beta ln M.

use anyhow::{bail, Result};

use crate::util::stats;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JointFit {
    pub a: f64,
    pub alpha: f64,
    pub beta: f64,
}

impl JointFit {
    pub fn fit(n: &[f64], m: &[f64], y: &[f64]) -> Result<JointFit> {
        if n.len() != m.len() || n.len() != y.len() || n.len() < 3 {
            bail!("joint fit needs >= 3 aligned points");
        }
        if n.iter().chain(m).chain(y).any(|&v| v <= 0.0) {
            bail!("joint fit requires positive data");
        }
        let rows: Vec<Vec<f64>> = n
            .iter()
            .zip(m)
            .map(|(&ni, &mi)| vec![1.0, ni.ln(), mi.ln()])
            .collect();
        let ly: Vec<f64> = y.iter().map(|v| v.ln()).collect();
        let beta = stats::least_squares(&rows, &ly)
            .ok_or_else(|| anyhow::anyhow!("degenerate joint fit"))?;
        Ok(JointFit {
            a: beta[0].exp(),
            alpha: beta[1],
            beta: beta[2],
        })
    }

    pub fn predict(&self, n: f64, m: f64) -> f64 {
        self.a * n.powf(self.alpha) * m.powf(self.beta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> (Vec<f64>, Vec<f64>) {
        let mut ns = Vec::new();
        let mut ms = Vec::new();
        for n in [1e5, 1e6, 1e7] {
            for m in [1.0, 2.0, 4.0, 8.0] {
                ns.push(n);
                ms.push(m);
            }
        }
        (ns, ms)
    }

    #[test]
    fn recovers_exact_joint_law() {
        let (ns, ms) = grid();
        let y: Vec<f64> = ns
            .iter()
            .zip(&ms)
            .map(|(&n, &m)| 19.226 * n.powf(-0.0985) * m.powf(0.0116))
            .collect();
        let f = JointFit::fit(&ns, &ms, &y).unwrap();
        assert!((f.a - 19.226).abs() < 1e-3);
        assert!((f.alpha + 0.0985).abs() < 1e-9);
        assert!((f.beta - 0.0116).abs() < 1e-9);
    }

    #[test]
    fn degenerate_single_m_rejected() {
        // All M equal -> beta unidentifiable -> singular system.
        let ns = vec![1e5, 1e6, 1e7];
        let ms = vec![2.0, 2.0, 2.0];
        let y = vec![3.0, 2.5, 2.1];
        assert!(JointFit::fit(&ns, &ms, &y).is_err());
    }

    #[test]
    fn predict_matches_formula() {
        let f = JointFit {
            a: 2.0,
            alpha: -0.1,
            beta: 0.3,
        };
        let v = f.predict(1e6, 4.0);
        assert!((v - 2.0 * 1e6f64.powf(-0.1) * 4f64.powf(0.3)).abs() < 1e-12);
    }
}
