//! Runtime layer: loads AOT HLO-text artifacts and executes them via
//! the PJRT C API (`xla` crate). Python never runs here — the rust
//! binary is self-contained once `make artifacts` has produced the
//! HLO text + manifests.

pub mod artifact;
pub mod bus;
pub mod executor;
pub mod tensor;

pub use artifact::{decompose_micro, ArtifactDef, Manifest, ModelInfo};
pub use bus::{FlatLayout, FlatParams};
pub use executor::{Executable, ModelRuntime, Runtime};
pub use tensor::{f32_scalar, i32_literal, scalar_f32, u32_scalar, Dtype, HostTensor, TensorSpec};
