//! Artifact manifests: the contract between the AOT pipeline (python)
//! and the runtime (rust).
//!
//! `artifacts/<model>/manifest.json` pins the canonical parameter
//! flatten order and every lowered function's input/output signature;
//! this module parses and validates it. Any drift between the python
//! lowering and the rust caller is caught here, at load time, instead
//! of as garbage numerics.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::{Dtype, TensorSpec};
use crate::util::json::Json;

/// One lowered function: file + typed signature.
#[derive(Debug, Clone)]
pub struct ArtifactDef {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model metadata recorded by aot.py.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub layers: usize,
    pub d_model: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub param_count: usize,
    pub token_budget: usize,
}

/// Parsed manifest for one model directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub params: Vec<TensorSpec>,
    pub micro_batches: Vec<usize>,
    pub eval_batch: usize,
    pub artifacts: BTreeMap<String, ArtifactDef>,
}

fn parse_specs(arr: &[Json]) -> Result<Vec<TensorSpec>> {
    arr.iter()
        .map(|e| {
            Ok(TensorSpec {
                name: e.str_of("name")?,
                shape: e
                    .arr_of("shape")?
                    .iter()
                    .map(|d| d.as_usize().context("shape dim"))
                    .collect::<Result<_>>()?,
                dtype: Dtype::parse(&e.str_of("dtype")?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(model_dir: &Path) -> Result<Manifest> {
        let manifest_path = model_dir.join("manifest.json");
        let j = Json::parse_file(&manifest_path)?;
        let m = j.req("model")?;
        let model = ModelInfo {
            name: m.str_of("name")?,
            layers: m.usize_of("layers")?,
            d_model: m.usize_of("d_model")?,
            heads: m.usize_of("heads")?,
            head_dim: m.usize_of("head_dim")?,
            d_ff: m.usize_of("d_ff")?,
            vocab: m.usize_of("vocab")?,
            seq_len: m.usize_of("seq_len")?,
            param_count: m.usize_of("param_count")?,
            token_budget: m.usize_of("token_budget")?,
        };
        let params = parse_specs(j.arr_of("params")?)?;
        let micro_batches = j
            .arr_of("micro_batches")?
            .iter()
            .map(|v| v.as_usize().context("micro_batch"))
            .collect::<Result<Vec<_>>>()?;
        let eval_batch = j.usize_of("eval_batch")?;
        let mut artifacts = BTreeMap::new();
        let arts = j
            .req("artifacts")?
            .as_obj()
            .context("artifacts must be an object")?;
        for (name, a) in arts {
            let def = ArtifactDef {
                name: name.clone(),
                file: model_dir.join(a.str_of("file")?),
                inputs: parse_specs(a.arr_of("inputs")?)?,
                outputs: parse_specs(a.arr_of("outputs")?)?,
            };
            if !def.file.is_file() {
                bail!("artifact file missing: {}", def.file.display());
            }
            artifacts.insert(name.clone(), def);
        }
        let manifest = Manifest {
            dir: model_dir.to_path_buf(),
            model,
            params,
            micro_batches,
            eval_batch,
            artifacts,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    /// Structural invariants the rust side relies on.
    pub fn validate(&self) -> Result<()> {
        let n = self.params.len();
        if n != 10 * self.model.layers + 2 {
            bail!("param leaf count {n} != 10*layers+2");
        }
        let total: usize = self.params.iter().map(|p| p.numel()).sum();
        if total != self.model.param_count {
            bail!("param_count {} != sum of leaves {total}", self.model.param_count);
        }
        for req in ["init", "apply_update", "train_step", "grad_acc", "eval_step", "seq_nll"] {
            if !self.artifacts.contains_key(req) {
                bail!("manifest missing required artifact {req:?}");
            }
        }
        for mb in &self.micro_batches {
            let key = format!("grad_step_mb{mb}");
            let a = self
                .artifacts
                .get(&key)
                .with_context(|| format!("missing {key}"))?;
            if a.inputs.len() != n + 1 || a.outputs.len() != n + 2 {
                bail!("{key}: bad arity");
            }
        }
        let ts = &self.artifacts["train_step"];
        if ts.inputs.len() != 3 * n + 4 || ts.outputs.len() != 3 * n + 2 {
            bail!("train_step: bad arity");
        }
        let au = &self.artifacts["apply_update"];
        if au.inputs.len() != 4 * n + 3 || au.outputs.len() != 3 * n + 1 {
            bail!("apply_update: bad arity");
        }
        Ok(())
    }

    /// The micro-batch sizes available for grad_step, largest first.
    pub fn micro_batches_desc(&self) -> Vec<usize> {
        let mut v = self.micro_batches.clone();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }

    /// Batch (sequence count) of the fused train_step artifact.
    pub fn train_step_batch(&self) -> usize {
        self.artifacts["train_step"]
            .inputs
            .iter()
            .find(|s| s.name == "tokens")
            .map(|s| s.shape[0])
            .unwrap_or(0)
    }
}

/// Decompose a sequence-count into available micro-batch sizes,
/// largest-first greedy. E.g. 21 with {8,1} -> [8,8,1,1,1,1,1].
pub fn decompose_micro(total: usize, sizes_desc: &[usize]) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    let mut rem = total;
    for &s in sizes_desc {
        while rem >= s {
            out.push(s);
            rem -= s;
        }
    }
    if rem != 0 {
        bail!("cannot decompose batch of {total} into micro sizes {sizes_desc:?}");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompose_greedy() {
        assert_eq!(decompose_micro(21, &[8, 1]).unwrap(),
                   vec![8, 8, 1, 1, 1, 1, 1]);
        assert_eq!(decompose_micro(8, &[8, 1]).unwrap(), vec![8]);
        assert_eq!(decompose_micro(0, &[8, 1]).unwrap(), Vec::<usize>::new());
        assert!(decompose_micro(3, &[8, 2]).is_err());
    }
}
