//! PJRT execution: load HLO text artifacts, compile once, call many.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.
//! Artifacts are lowered with `return_tuple=True`, so every call
//! returns a single tuple literal which is decomposed into the typed
//! outputs declared by the manifest. Arity and scalar/shape mismatches
//! fail loudly here rather than corrupting training state.
//!
//! Everything here is `Send + Sync`: the client and its compiled
//! executables are shared across the coordinator's replica-parallel
//! workers as `Arc`s (PJRT CPU execution is thread-safe per client),
//! and the artifact cache is behind a `Mutex` so lazy compilation is
//! race-free.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::{ArtifactDef, Manifest};

/// Shared PJRT client (CPU). One per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Arc<Runtime>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Runtime { client }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, def: &ArtifactDef) -> Result<Executable> {
        let path_str = def
            .file
            .to_str()
            .with_context(|| format!("non-utf8 path {}", def.file.display()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {}", def.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", def.name))?;
        Ok(Executable {
            def: def.clone(),
            exe,
        })
    }
}

/// A compiled artifact with its manifest signature.
pub struct Executable {
    pub def: ArtifactDef,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed tuple outputs
    /// in manifest order.
    pub fn call(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.def.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest declares {}",
                self.def.name,
                inputs.len(),
                self.def.inputs.len()
            );
        }
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.def.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.def.name))?;
        let outs = tuple
            .to_tuple()
            .with_context(|| format!("decomposing result tuple of {}", self.def.name))?;
        if outs.len() != self.def.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest declares {}",
                self.def.name,
                outs.len(),
                self.def.outputs.len()
            );
        }
        Ok(outs)
    }
}

/// All compiled executables for one model, lazily loaded from its
/// manifest. This is what the coordinator holds per model variant.
pub struct ModelRuntime {
    pub manifest: Manifest,
    rt: Arc<Runtime>,
    cache: Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl ModelRuntime {
    pub fn load(rt: Arc<Runtime>, model_dir: &Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(model_dir)?;
        Ok(ModelRuntime {
            manifest,
            rt,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    /// True if the artifact is already compiled in this process.
    pub fn is_compiled(&self, name: &str) -> bool {
        self.cache.lock().expect("artifact cache poisoned").contains_key(name)
    }

    /// Get (compiling on first use) a named artifact. The cache lock is
    /// held across compilation so concurrent workers never compile the
    /// same artifact twice.
    pub fn artifact(&self, name: &str) -> Result<Arc<Executable>> {
        let mut cache = self.cache.lock().expect("artifact cache poisoned");
        if let Some(e) = cache.get(name) {
            return Ok(e.clone());
        }
        let def = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("model {} has no artifact {name:?}", self.manifest.model.name))?;
        log::debug!("compiling artifact {}/{}", self.manifest.model.name, name);
        let exe = Arc::new(self.rt.load(def)?);
        cache.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn n_leaves(&self) -> usize {
        self.manifest.params.len()
    }
}

/// Compile-time pin: the runtime layer is shareable across the worker
/// pool's threads (see `coordinator::pool`).
#[allow(dead_code)]
fn _assert_send_sync() {
    fn ok<T: Send + Sync>() {}
    ok::<Runtime>();
    ok::<Executable>();
    ok::<ModelRuntime>();
}
