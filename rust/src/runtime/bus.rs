//! Flat parameter bus: the contiguous-arena representation the outer
//! sync hot path runs on.
//!
//! The coordinator's H-cadence path used to materialize one `Vec<f32>`
//! per leaf per replica per round (delta, velocity, scratch, and the
//! broadcast re-upload all allocated fresh). [`FlatParams`] instead
//! holds the whole leaf set in one contiguous `Vec<f32>` with an offset
//! table ([`FlatLayout`]) derived from the manifest's canonical flatten
//! order. Per-leaf views are plain subslices, fragment selection is a
//! precomputed list of element-offset ranges (no per-leaf closure), and
//! the outer optimizer's state lives in arenas of the same layout that
//! are reused across rounds — after the first sync the coordinator's
//! own code allocates nothing. (The `xla::Literal` bridge still copies
//! at the FFI boundary, as the PJRT C API requires.)
//!
//! Host→device traffic through the bus is counted per literal built
//! (`uploads()`), which is what lets tests pin the deduplicated
//! broadcast to exactly N uploads per full sync instead of M×N.
//!
//! Arenas and layouts are `Send + Sync` (layout shared via `Arc`, the
//! upload counter is atomic) so the replica-parallel coordinator can
//! hand literal handles across worker threads; the arenas themselves
//! stay coordinator-owned — only one thread mutates them.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::tensor::{HostTensor, TensorSpec};

/// Offset table mapping leaf index -> element range in the flat arena.
/// Derived once (from the manifest or raw shapes) and shared by every
/// arena of the model via `Arc`.
#[derive(Debug, Clone, PartialEq)]
pub struct FlatLayout {
    shapes: Vec<Vec<usize>>,
    /// `n_leaves + 1` entries; `offsets[i]..offsets[i+1]` is leaf i.
    offsets: Vec<usize>,
}

impl FlatLayout {
    pub fn new(shapes: Vec<Vec<usize>>) -> FlatLayout {
        let mut offsets = Vec::with_capacity(shapes.len() + 1);
        let mut off = 0usize;
        offsets.push(0);
        for s in &shapes {
            off += s.iter().product::<usize>();
            offsets.push(off);
        }
        FlatLayout { shapes, offsets }
    }

    /// Layout of a manifest's parameter leaf set (canonical order).
    pub fn from_specs(specs: &[TensorSpec]) -> FlatLayout {
        FlatLayout::new(specs.iter().map(|s| s.shape.clone()).collect())
    }

    pub fn n_leaves(&self) -> usize {
        self.shapes.len()
    }

    /// Total element count across all leaves.
    pub fn total(&self) -> usize {
        *self.offsets.last().expect("offsets is never empty")
    }

    pub fn shape(&self, leaf: usize) -> &[usize] {
        &self.shapes[leaf]
    }

    pub fn len(&self, leaf: usize) -> usize {
        self.offsets[leaf + 1] - self.offsets[leaf]
    }

    pub fn is_empty(&self) -> bool {
        self.shapes.is_empty()
    }

    /// Element-offset range of one leaf in the flat arena.
    pub fn range(&self, leaf: usize) -> Range<usize> {
        self.offsets[leaf]..self.offsets[leaf + 1]
    }

    /// Leaf indices synchronized by a sync event: all leaves for a full
    /// sync (`frag = None`), the round-robin subset `leaf % fragments
    /// == f` for a streaming-fragment sync.
    pub fn leaves(
        &self,
        fragments: usize,
        frag: Option<usize>,
    ) -> std::iter::StepBy<Range<usize>> {
        match frag {
            None => (0..self.n_leaves()).step_by(1),
            Some(f) => (f..self.n_leaves()).step_by(fragments.max(1)),
        }
    }

    /// Element-offset ranges of one fragment's leaves, with adjacent
    /// leaves merged into maximal contiguous runs. Precomputed once per
    /// run; the hot path then iterates ranges instead of consulting a
    /// per-leaf predicate.
    pub fn fragment_ranges(&self, fragments: usize, frag: usize) -> Vec<Range<usize>> {
        let mut out: Vec<Range<usize>> = Vec::new();
        for leaf in self.leaves(fragments.max(1), Some(frag)) {
            let r = self.range(leaf);
            if r.is_empty() {
                continue;
            }
            match out.last_mut() {
                Some(last) if last.end == r.start => last.end = r.end,
                _ => out.push(r),
            }
        }
        out
    }

    /// The whole arena as a single range (full-sync fast path).
    pub fn full_range(&self) -> Vec<Range<usize>> {
        if self.total() == 0 {
            Vec::new()
        } else {
            vec![0..self.total()]
        }
    }
}

/// One contiguous f32 arena over a [`FlatLayout`]: global params, outer
/// gradient, velocity, and pull scratch are all instances of this.
#[derive(Debug)]
pub struct FlatParams {
    layout: Arc<FlatLayout>,
    data: Vec<f32>,
    /// Literals built from this arena (host→device uploads through the
    /// bus). Monotonic; readers diff across events. Atomic so the arena
    /// is `Sync` (counting stays accurate even under shared readers).
    uploads: AtomicU64,
}

impl Clone for FlatParams {
    fn clone(&self) -> FlatParams {
        FlatParams {
            layout: Arc::clone(&self.layout),
            data: self.data.clone(),
            uploads: AtomicU64::new(self.uploads.load(Ordering::Relaxed)),
        }
    }
}

impl FlatParams {
    pub fn zeros(layout: &Arc<FlatLayout>) -> FlatParams {
        FlatParams {
            layout: Arc::clone(layout),
            data: vec![0.0; layout.total()],
            uploads: AtomicU64::new(0),
        }
    }

    /// Pack host tensors (manifest leaf order) into a fresh arena.
    pub fn from_host(layout: &Arc<FlatLayout>, tensors: &[HostTensor]) -> Result<FlatParams> {
        if tensors.len() != layout.n_leaves() {
            bail!(
                "flat bus: {} tensors for a {}-leaf layout",
                tensors.len(),
                layout.n_leaves()
            );
        }
        let mut fp = FlatParams::zeros(layout);
        for (leaf, t) in tensors.iter().enumerate() {
            if t.shape != layout.shape(leaf) {
                bail!(
                    "flat bus: leaf {leaf} shape {:?} != layout {:?}",
                    t.shape,
                    layout.shape(leaf)
                );
            }
            fp.leaf_mut(leaf).copy_from_slice(&t.data);
        }
        Ok(fp)
    }

    pub fn layout(&self) -> &Arc<FlatLayout> {
        &self.layout
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Per-leaf view (contiguous subslice of the arena).
    pub fn leaf(&self, leaf: usize) -> &[f32] {
        &self.data[self.layout.range(leaf)]
    }

    pub fn leaf_mut(&mut self, leaf: usize) -> &mut [f32] {
        let r = self.layout.range(leaf);
        &mut self.data[r]
    }

    /// Unpack to per-leaf host tensors (reports, tests; not hot).
    pub fn to_host(&self) -> Vec<HostTensor> {
        (0..self.layout.n_leaves())
            .map(|leaf| HostTensor::from_vec(self.layout.shape(leaf), self.leaf(leaf).to_vec()))
            .collect()
    }

    /// Device→host: read one leaf's literal straight into the arena
    /// slot — zero allocation, the arena is reused across rounds.
    pub fn read_leaf_literal(&mut self, leaf: usize, lit: &xla::Literal) -> Result<()> {
        lit.to_slice::<f32>(self.leaf_mut(leaf))
            .map_err(|e| anyhow::anyhow!("flat bus: reading leaf {leaf}: {e}"))
    }

    /// Host→device: build one leaf's literal straight from the arena
    /// slice (no intermediate host tensor). Counts one bus upload.
    pub fn leaf_literal(&self, leaf: usize) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.layout.shape(leaf).iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(self.leaf(leaf)).reshape(&dims)?;
        self.uploads.fetch_add(1, Ordering::Relaxed);
        Ok(lit)
    }

    /// Host→device uploads built from this arena so far (monotonic).
    pub fn uploads(&self) -> u64 {
        self.uploads.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout3() -> Arc<FlatLayout> {
        // leaves: 2x3, 4, 3x1 -> offsets [0, 6, 10, 13]
        Arc::new(FlatLayout::new(vec![vec![2, 3], vec![4], vec![3, 1]]))
    }

    #[test]
    fn offsets_and_ranges() {
        let l = layout3();
        assert_eq!(l.n_leaves(), 3);
        assert_eq!(l.total(), 13);
        assert_eq!(l.range(0), 0..6);
        assert_eq!(l.range(1), 6..10);
        assert_eq!(l.range(2), 10..13);
        assert_eq!(l.len(1), 4);
        assert_eq!(l.shape(2), &[3, 1]);
    }

    #[test]
    fn fragment_selection_round_robin() {
        let l = layout3();
        // P=2: fragment 0 = leaves {0, 2}, fragment 1 = leaf {1}
        assert_eq!(l.leaves(2, Some(0)).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(l.leaves(2, Some(1)).collect::<Vec<_>>(), vec![1]);
        assert_eq!(l.leaves(2, None).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(l.fragment_ranges(2, 0), vec![0..6, 10..13]);
        assert_eq!(l.fragment_ranges(2, 1), vec![6..10]);
        // P=1 merges everything into the full range.
        assert_eq!(l.fragment_ranges(1, 0), vec![0..13]);
        assert_eq!(l.full_range(), vec![0..13]);
    }

    #[test]
    fn fragment_ranges_cover_exactly_once() {
        let l = Arc::new(FlatLayout::new(
            (0..11).map(|i| vec![i + 1]).collect::<Vec<_>>(),
        ));
        for p in 1..=4usize {
            let mut covered = vec![0u8; l.total()];
            for f in 0..p {
                for r in l.fragment_ranges(p, f) {
                    for c in &mut covered[r] {
                        *c += 1;
                    }
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "P={p}: {covered:?}");
        }
    }

    #[test]
    fn host_roundtrip_through_arena() {
        let l = layout3();
        let tensors = vec![
            HostTensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect()),
            HostTensor::from_vec(&[4], vec![9.0, 8.0, 7.0, 6.0]),
            HostTensor::from_vec(&[3, 1], vec![1.5, 2.5, 3.5]),
        ];
        let fp = FlatParams::from_host(&l, &tensors).unwrap();
        assert_eq!(fp.leaf(1), &[9.0, 8.0, 7.0, 6.0]);
        assert_eq!(fp.to_host(), tensors);
    }

    #[test]
    fn from_host_rejects_shape_drift() {
        let l = layout3();
        let bad = vec![
            HostTensor::zeros(&[3, 2]), // transposed
            HostTensor::zeros(&[4]),
            HostTensor::zeros(&[3, 1]),
        ];
        assert!(FlatParams::from_host(&l, &bad).is_err());
        assert!(FlatParams::from_host(&l, &bad[..2]).is_err());
    }

    #[test]
    fn literal_bridge_and_upload_count() {
        let l = layout3();
        let mut fp = FlatParams::zeros(&l);
        fp.leaf_mut(1).copy_from_slice(&[4.0, 3.0, 2.0, 1.0]);
        assert_eq!(fp.uploads(), 0);
        let lit = fp.leaf_literal(1).unwrap();
        assert_eq!(fp.uploads(), 1);
        assert_eq!(lit.array_shape().unwrap().dims(), &[4]);

        let mut other = FlatParams::zeros(&l);
        other.read_leaf_literal(1, &lit).unwrap();
        assert_eq!(other.leaf(1), fp.leaf(1));
        assert_eq!(other.uploads(), 0); // reads are not uploads

        // wrong-leaf literal is rejected (size mismatch)
        assert!(other.read_leaf_literal(0, &lit).is_err());
    }
}
