//! Host tensors and the Literal bridge.
//!
//! Replica state stays as `xla::Literal`s between steps (zero extra
//! copies on the hot path); `HostTensor` is the host-side view used by
//! the outer optimizer, data pipeline, and metrics.
//!
//! Literals are immutable after construction and `Send + Sync`, so the
//! replica-parallel coordinator shares them across worker threads as
//! `Arc<xla::Literal>` handles — the broadcast dedup (one upload shared
//! by all replicas) and the worker pool both hinge on that immutability.

use anyhow::{anyhow, bail, Result};

/// Element type of a tensor (subset used by our artifacts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "u32" => Ok(Dtype::U32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
            Dtype::U32 => "u32",
        }
    }
}

/// Shape + dtype + name of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A host-side tensor. The payload is always f32 — model params,
/// moments, and metrics are all f32 in this system. Integer tensors
/// (token batches, masks) never pass through `HostTensor`; they are
/// built directly as i32 literals via [`i32_literal`].
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Convert to an XLA literal with this shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Read an f32 literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(HostTensor { shape: dims, data })
    }
}

/// Build an i32 literal (token batches) with the given shape.
pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("i32 literal: shape {shape:?} != {} elements", data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn u32_scalar(v: u32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read a scalar f32 out of a literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("reading f32 scalar: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrip() {
        for d in [Dtype::F32, Dtype::I32, Dtype::U32] {
            assert_eq!(Dtype::parse(d.name()).unwrap(), d);
        }
        assert!(Dtype::parse("bf16").is_err());
    }

    #[test]
    fn host_tensor_shapes() {
        let t = HostTensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        let u = HostTensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        assert_eq!(u.data[2], 3.0);
    }

    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literals() {
        assert_eq!(scalar_f32(&f32_scalar(2.5)).unwrap(), 2.5);
    }
}
