//! Report generation: regenerates every table and figure of the paper
//! (DESIGN.md section 5 experiment index) from the sweep store, the
//! analytic simulators, and the embedded paper data.

pub mod figures;
pub mod paperdata;
pub mod tables;

use anyhow::{Context, Result};

use crate::cli::args::Args;
use crate::config::RepoConfig;
use crate::sweep::SweepStore;

/// Every experiment id and its generator.
pub fn experiment_ids() -> Vec<&'static str> {
    vec![
        "table4", "table5", "table6", "table7", "table8_9", "table10", "table11",
        "table13", "comm", "stream", "churn", "fig2", "fig_batch", "fig6_12", "fig7_8", "fig9",
        "fig10", "fig11", "fig13",
    ]
}

pub fn generate(
    id: &str,
    store: &SweepStore,
    repo: &RepoConfig,
    restarts: usize,
) -> Result<String> {
    Ok(match id {
        "table4" => tables::table4(store),
        "table5" => tables::table5_12(store, repo),
        "table6" => tables::table6(),
        "table7" => tables::table7(store),
        "table8_9" => tables::table8_9(store),
        "table10" => tables::table10(store),
        "table11" => tables::table11(store),
        "table13" => tables::table13(store, restarts),
        "comm" => tables::table_comm(store),
        "stream" => tables::table_stream(store),
        "churn" => tables::table_churn(store),
        "fig2" => figures::fig2(store),
        "fig_batch" => figures::fig_batch(store),
        "fig6_12" => figures::fig6_12(store),
        "fig7_8" => figures::fig7_8(store),
        "fig9" => figures::fig9(store),
        "fig10" => figures::fig10(),
        "fig11" => figures::fig11(store),
        "fig13" => figures::fig13(store),
        other => anyhow::bail!("unknown experiment {other:?}; known: {:?}", experiment_ids()),
    })
}

pub fn cmd_report(args: &Args) -> Result<()> {
    let repo = RepoConfig::load_default()?;
    let store_path = repo.root.join(args.get_or("store", "runs/sweep.jsonl"));
    let store = SweepStore::open(&store_path)?;
    let out_dir = repo.root.join(args.get_or("out", "reports"));
    std::fs::create_dir_all(&out_dir)?;
    let restarts: usize = args
        .get_or("restarts", "64")
        .parse()
        .context("--restarts")?;
    let exp = args.get_or("exp", "all");
    let ids: Vec<&str> = if exp == "all" {
        experiment_ids()
    } else {
        experiment_ids()
            .into_iter()
            .filter(|i| *i == exp)
            .collect()
    };
    if ids.is_empty() {
        anyhow::bail!("unknown experiment {exp:?}; known: {:?}", experiment_ids());
    }
    for id in ids {
        let text = generate(id, &store, &repo, restarts)?;
        let path = out_dir.join(format!("{id}.md"));
        std::fs::write(&path, &text)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

pub fn cmd_simulate(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("utilization");
    match which {
        "utilization" => print!("{}", tables::table6()),
        "walltime" => {
            let repo = RepoConfig::load_default()?;
            let store = SweepStore::open(&repo.root.join(
                args.get_or("store", "runs/sweep.jsonl"),
            ))?;
            print!("{}", figures::fig6_12(&store));
        }
        other => anyhow::bail!("unknown simulator {other:?} (utilization|walltime)"),
    }
    Ok(())
}
