//! The paper's published numbers, transcribed for two purposes:
//! (1) [P]-mode validation — our fitting code is run ON the paper's
//!     measurements and must recover the paper's fitted coefficients
//!     (the strongest available check of methodological fidelity), and
//! (2) side-by-side columns in every generated report.

/// Paper ladder sizes (Table 3), aligned with the loss tables below.
pub const PAPER_N: [f64; 7] = [35e6, 90e6, 180e6, 335e6, 550e6, 1.3e9, 2.4e9];

pub const PAPER_N_NAMES: [&str; 7] = ["35M", "90M", "180M", "335M", "550M", "1.3B", "2.4B"];

/// Table 4: best evaluation loss per (N, algorithm).
/// Rows follow PAPER_N; columns: DP, DiLoCo M=1, M=2, M=4, M=8.
pub const TABLE4: [[f64; 5]; 7] = [
    [3.485, 3.482, 3.508, 3.554, 3.621],
    [3.167, 3.162, 3.182, 3.213, 3.265],
    [2.950, 2.943, 2.957, 2.981, 3.019],
    [2.784, 2.777, 2.788, 2.808, 2.841],
    [2.653, 2.645, 2.657, 2.673, 2.698],
    [2.460, 2.451, 2.464, 2.472, 2.493],
    [2.326, 2.317, 2.323, 2.332, 2.351],
];

pub const ALGO_LABELS: [&str; 5] = ["dp", "diloco-m1", "diloco-m2", "diloco-m4", "diloco-m8"];

/// Table 5: 4B / 10B evaluation losses with scaling-law-predicted
/// hyperparameters (best fit method per row, as in the paper's Table 5).
pub const TABLE5_4B: [(&str, f64); 4] = [
    ("dp", 2.224),
    ("diloco-m1", 2.219),
    ("diloco-m2", 2.220),
    ("diloco-m4", 2.230),
];
pub const TABLE5_10B: [(&str, f64); 4] = [
    ("dp", 2.090),
    ("diloco-m1", 2.086),
    ("diloco-m2", 2.086),
    ("diloco-m4", 2.096),
];

/// Table 7: loss power laws L(N) ~ A*N^alpha. (algo, A, alpha).
pub const TABLE7: [(&str, f64, f64); 5] = [
    ("dp", 18.129, -0.0953),
    ("diloco-m1", 18.363, -0.0961),
    ("diloco-m2", 18.768, -0.0969),
    ("diloco-m4", 19.762, -0.0992),
    ("diloco-m8", 21.051, -0.1018),
];

/// Table 8: inner-learning-rate power laws gamma(N) ~ A*N^alpha.
pub const TABLE8: [(&str, f64, f64); 5] = [
    ("dp", 16319.2, -0.819),
    ("diloco-m1", 74620.6, -0.945),
    ("diloco-m2", 3978.82, -0.780),
    ("diloco-m4", 4512.99, -0.789),
    ("diloco-m8", 618986.0, -1.102),
];

/// Table 9: global-batch-size power laws B(N) ~ A*N^alpha (tokens).
pub const TABLE9: [(&str, f64, f64); 5] = [
    ("dp", 0.22592, 0.281),
    ("diloco-m1", 0.01361, 0.435),
    ("diloco-m2", 0.00769, 0.479),
    ("diloco-m4", 0.00535, 0.510),
    ("diloco-m8", 0.01859, 0.455),
];

/// Table 10: joint laws f(N,M) = A*N^alpha*M^beta for DiLoCo.
/// (quantity, A, alpha, beta).
pub const TABLE10: [(&str, f64, f64, f64); 3] = [
    ("loss", 19.226, -0.0985, 0.0116),
    ("inner_lr", 22256.0, -0.8827, 0.2929),
    ("batch", 0.00709, 0.4695, 0.3399),
];

/// Table 6: required Gbit/s to reach CU targets {50,80,90,95,99}%.
/// (archetype, H (0 = Data-Parallel), five cells; None = "1000.0+").
pub const TABLE6: [(&str, usize, [Option<f64>; 5]); 18] = [
    ("Chinchilla-10B", 0, [Some(104.8), Some(184.2), Some(222.3), Some(222.3), Some(390.7)]),
    ("Chinchilla-10B", 1, [Some(104.8), Some(184.2), Some(222.3), Some(222.3), Some(390.7)]),
    ("Chinchilla-10B", 10, [Some(16.0), Some(49.4), Some(86.8), Some(152.6), Some(222.3)]),
    ("Chinchilla-10B", 50, [Some(3.0), Some(11.0), Some(23.3), Some(41.0), Some(126.5)]),
    ("Chinchilla-10B", 100, [Some(1.4), Some(6.2), Some(13.3), Some(23.3), Some(86.8)]),
    ("Chinchilla-10B", 300, [Some(0.5), Some(2.0), Some(4.3), Some(9.1), Some(41.0)]),
    ("Llama3-405B", 0, [Some(126.5), Some(222.3), Some(268.3), Some(323.8), Some(323.8)]),
    ("Llama3-405B", 1, [Some(126.5), Some(222.3), Some(268.3), Some(323.8), Some(323.8)]),
    ("Llama3-405B", 10, [Some(19.3), Some(72.0), Some(126.5), Some(184.2), Some(268.3)]),
    ("Llama3-405B", 50, [Some(3.6), Some(13.3), Some(28.1), Some(59.6), Some(184.2)]),
    ("Llama3-405B", 100, [Some(2.0), Some(7.5), Some(16.0), Some(33.9), Some(126.5)]),
    ("Llama3-405B", 300, [Some(0.7), Some(3.0), Some(6.2), Some(13.3), Some(59.6)]),
    ("DeepSeek-V3-671B", 0, [Some(323.8), Some(569.0), Some(686.6), Some(686.6), None]),
    ("DeepSeek-V3-671B", 1, [Some(323.8), Some(569.0), Some(686.6), Some(686.6), None]),
    ("DeepSeek-V3-671B", 10, [Some(49.4), Some(152.6), Some(268.3), Some(390.7), Some(686.6)]),
    ("DeepSeek-V3-671B", 50, [Some(7.5), Some(33.9), Some(72.0), Some(126.5), Some(390.7)]),
    ("DeepSeek-V3-671B", 100, [Some(4.3), Some(16.0), Some(41.0), Some(72.0), Some(268.3)]),
    ("DeepSeek-V3-671B", 300, [Some(1.7), Some(6.2), Some(13.3), Some(28.1), Some(126.5)]),
];

/// Column index of an algorithm label in TABLE4.
pub fn algo_column(label: &str) -> Option<usize> {
    ALGO_LABELS.iter().position(|&l| l == label)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_losses_decrease_with_n() {
        for col in 0..5 {
            for row in 1..7 {
                assert!(TABLE4[row][col] < TABLE4[row - 1][col]);
            }
        }
    }

    #[test]
    fn table4_m1_beats_dp_everywhere() {
        // Paper Finding 2: DiLoCo M=1 < DP at every scale.
        for row in 0..7 {
            assert!(TABLE4[row][1] < TABLE4[row][0]);
        }
    }

    #[test]
    fn table4_percent_gap_shrinks_with_scale() {
        // Paper Finding 1: DiLoCo's % gap vs DP decreases in N. The raw
        // table has sub-0.01pp upticks at 550M/1.3B (rounding in the
        // published losses), so assert the trend with that tolerance.
        for col in 2..5 {
            let gaps: Vec<f64> = (0..7)
                .map(|r| (TABLE4[r][col] - TABLE4[r][0]) / TABLE4[r][0])
                .collect();
            for w in gaps.windows(2) {
                assert!(w[1] < w[0] + 2e-4, "col {col}: {gaps:?}");
            }
            assert!(gaps[6] < gaps[0] * 0.5, "col {col}: no overall shrink");
        }
    }

    #[test]
    fn table5_diloco_m2_beats_dp() {
        let dp4 = TABLE5_4B[0].1;
        assert!(TABLE5_4B[2].1 < dp4);
        let dp10 = TABLE5_10B[0].1;
        assert!(TABLE5_10B[2].1 < dp10);
    }

    #[test]
    fn table6_row_structure() {
        assert_eq!(TABLE6.len(), 18);
        // DP row == DiLoCo H=1 row for each archetype.
        for arch in ["Chinchilla-10B", "Llama3-405B", "DeepSeek-V3-671B"] {
            let dp = TABLE6.iter().find(|r| r.0 == arch && r.1 == 0).unwrap();
            let h1 = TABLE6.iter().find(|r| r.0 == arch && r.1 == 1).unwrap();
            assert_eq!(dp.2, h1.2);
        }
        // bandwidth requirement decreases monotonically with H.
        for arch in ["Chinchilla-10B", "Llama3-405B", "DeepSeek-V3-671B"] {
            for cu in 0..5 {
                let vals: Vec<f64> = TABLE6
                    .iter()
                    .filter(|r| r.0 == arch && r.1 >= 1)
                    .filter_map(|r| r.2[cu])
                    .collect();
                for w in vals.windows(2) {
                    assert!(w[1] <= w[0], "{arch} cu{cu}: {vals:?}");
                }
            }
        }
    }
}
