//! Table generators: one function per paper table (DESIGN.md section 5
//! experiment index). Each returns markdown with our measured/fitted
//! values side-by-side with the paper's published numbers.

use std::fmt::Write as _;



use crate::config::RepoConfig;
use crate::netsim::utilization::{calibrate, SimModel, ARCHETYPES as LLM_ARCHS, CADENCES};
use crate::scaling::parametric::{fit_parametric, Obs, ParametricForm};
use crate::scaling::residuals::log_residual;
use crate::scaling::{optimal_batch_log2, JointFit, PowerLaw};
use crate::sweep::SweepStore;

use super::paperdata as paper;

pub const MINI_LADDER: [&str; 5] = ["m0", "m1", "m2", "m3", "m4"];
pub const SWEEP_LADDER: [&str; 3] = ["m0", "m1", "m2"];
pub const ALGOS: [&str; 5] = ["dp", "diloco-m1", "diloco-m2", "diloco-m4", "diloco-m8"];

/// Best run (lowest final eval loss) for (model, algo) at Chinchilla
/// budget (overtrain == 1, default seed space).
pub fn best_run<'a>(
    store: &'a SweepStore,
    model: &str,
    algo: &str,
) -> Option<&'a crate::coordinator::RunMetrics> {
    store.best(|r| {
        r.model == model && r.algo == algo && (r.overtrain - 1.0).abs() < 1e-9
            && r.sync_every <= 30
    })
}

fn param_count_of(store: &SweepStore, model: &str) -> Option<f64> {
    store
        .records()
        .find(|r| r.model == model)
        .map(|r| r.param_count as f64)
}

/// Our ladder of best losses: (model, N, [loss per algo]) — the
/// measured analogue of paper Table 4.
pub fn measured_ladder(store: &SweepStore) -> Vec<(String, f64, Vec<Option<f64>>)> {
    let mut out = Vec::new();
    for model in SWEEP_LADDER {
        let Some(n) = param_count_of(store, model) else {
            continue;
        };
        let losses: Vec<Option<f64>> = ALGOS
            .iter()
            .map(|algo| best_run(store, model, algo).map(|r| r.final_eval_loss))
            .collect();
        if losses.iter().any(|l| l.is_some()) {
            out.push((model.to_string(), n, losses));
        }
    }
    out
}

fn pct(new: f64, base: f64) -> String {
    format!("{:+.2}%", (new - base) / base * 100.0)
}

// ---------------------------------------------------------------------------
// Table 4 — eval loss ladder, DP vs DiLoCo M in {1,2,4,8}
// ---------------------------------------------------------------------------
pub fn table4(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(s, "# Table 4 — evaluation loss: Data-Parallel vs DiLoCo\n").unwrap();
    writeln!(s, "## Ours (mini ladder, synthetic corpus, Chinchilla D=20N)\n").unwrap();
    writeln!(s, "| N (model) | DP | M=1 | M=2 | M=4 | M=8 |").unwrap();
    writeln!(s, "|---|---|---|---|---|---|").unwrap();
    for (model, n, losses) in measured_ladder(store) {
        let dp = losses[0];
        let mut row = format!("| {n:.0} ({model}) ");
        for (i, l) in losses.iter().enumerate() {
            match (l, dp) {
                (Some(l), Some(dp)) if i > 0 => {
                    row.push_str(&format!("| {l:.4} ({}) ", pct(*l, dp)))
                }
                (Some(l), _) => row.push_str(&format!("| {l:.4} ")),
                _ => row.push_str("| — "),
            }
        }
        writeln!(s, "{row}|").unwrap();
    }
    writeln!(s, "\n## Paper (C4, 35M-2.4B)\n").unwrap();
    writeln!(s, "| N | DP | M=1 | M=2 | M=4 | M=8 |").unwrap();
    writeln!(s, "|---|---|---|---|---|---|").unwrap();
    for (row, name) in paper::TABLE4.iter().zip(paper::PAPER_N_NAMES) {
        let dp = row[0];
        write!(s, "| {name} | {dp:.3} ").unwrap();
        for l in &row[1..] {
            write!(s, "| {l:.3} ({}) ", pct(*l, dp)).unwrap();
        }
        writeln!(s, "|").unwrap();
    }
    writeln!(
        s,
        "\nShape check: the paper's Finding 1 is that the % gap of DiLoCo \
         (M>=2) vs DP shrinks as N grows, and M=1 beats DP throughout."
    )
    .unwrap();
    s
}

// ---------------------------------------------------------------------------
// Tables 7/8/9 — independent power laws (ours + paper-data validation)
// ---------------------------------------------------------------------------

/// Fit loss power laws to the PAPER's Table 4 data — must recover the
/// paper's Table 7 coefficients (the [P]-mode check).
pub fn fit_paper_loss_laws() -> Vec<(String, PowerLaw)> {
    paper::ALGO_LABELS
        .iter()
        .enumerate()
        .map(|(col, algo)| {
            let y: Vec<f64> = paper::TABLE4.iter().map(|r| r[col]).collect();
            (
                algo.to_string(),
                PowerLaw::fit(&paper::PAPER_N, &y).expect("paper data fits"),
            )
        })
        .collect()
}

/// Fit loss power laws to our measured ladder.
pub fn fit_our_loss_laws(store: &SweepStore) -> Vec<(String, Option<PowerLaw>)> {
    let ladder = measured_ladder(store);
    ALGOS
        .iter()
        .enumerate()
        .map(|(col, algo)| {
            let pts: Vec<(f64, f64)> = ladder
                .iter()
                .filter_map(|(_, n, losses)| losses[col].map(|l| (*n, l)))
                .collect();
            let fit = if pts.len() >= 2 {
                let (n, y): (Vec<f64>, Vec<f64>) = pts.into_iter().unzip();
                PowerLaw::fit(&n, &y).ok()
            } else {
                None
            };
            (algo.to_string(), fit)
        })
        .collect()
}

pub fn table7(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(s, "# Table 7 — loss power laws L(N) ~ A*N^alpha\n").unwrap();
    writeln!(s, "## Validation: our fitter on the paper's Table 4 data\n").unwrap();
    writeln!(s, "| algo | paper A | our A | paper alpha | our alpha |").unwrap();
    writeln!(s, "|---|---|---|---|---|").unwrap();
    for ((algo, fit), (_, pa, palpha)) in fit_paper_loss_laws().iter().zip(paper::TABLE7) {
        writeln!(
            s,
            "| {algo} | {pa:.3} | {:.3} | {palpha:.4} | {:.4} |",
            fit.a, fit.alpha
        )
        .unwrap();
    }
    writeln!(s, "\n## Ours (mini ladder)\n").unwrap();
    writeln!(s, "| algo | A | alpha |").unwrap();
    writeln!(s, "|---|---|---|").unwrap();
    for (algo, fit) in fit_our_loss_laws(store) {
        match fit {
            Some(f) => writeln!(s, "| {algo} | {:.3} | {:.4} |", f.a, f.alpha).unwrap(),
            None => writeln!(s, "| {algo} | — | — |").unwrap(),
        }
    }
    s
}

/// Best (lr, interpolated batch tokens) per (model, algo) from the store.
fn our_hyper_optima(
    store: &SweepStore,
    model: &str,
    algo: &str,
) -> Option<(f64, f64)> {
    let best = best_run(store, model, algo)?;
    // batch interpolation: best loss at each batch size (over lr/eta)
    let mut by_batch: std::collections::BTreeMap<usize, f64> = Default::default();
    for r in store.by_model_algo(model, algo) {
        if (r.overtrain - 1.0).abs() > 1e-9 || r.sync_every > 30 {
            continue;
        }
        let e = by_batch
            .entry(r.global_batch_tokens)
            .or_insert(f64::INFINITY);
        *e = e.min(r.final_eval_loss);
    }
    let pts: Vec<(f64, f64)> = by_batch
        .into_iter()
        .map(|(b, l)| (b as f64, l))
        .collect();
    let b_opt = if pts.len() >= 2 {
        2f64.powf(optimal_batch_log2(&pts).ok()?)
    } else {
        pts.first()?.0
    };
    Some((best.inner_lr, b_opt))
}

pub fn table8_9(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "# Tables 8 & 9 — hyperparameter power laws (inner LR, batch)\n"
    )
    .unwrap();
    writeln!(s, "## Ours (mini ladder; batch via quadratic-in-log2 interpolation)\n").unwrap();
    writeln!(s, "| algo | lr A | lr alpha | B A | B alpha |").unwrap();
    writeln!(s, "|---|---|---|---|---|").unwrap();
    for algo in ALGOS {
        let mut ns = Vec::new();
        let mut lrs = Vec::new();
        let mut bs = Vec::new();
        for model in SWEEP_LADDER {
            if let (Some(n), Some((lr, b))) = (
                param_count_of(store, model),
                our_hyper_optima(store, model, algo),
            ) {
                ns.push(n);
                lrs.push(lr);
                bs.push(b);
            }
        }
        if ns.len() >= 2 {
            let lr_fit = PowerLaw::fit(&ns, &lrs).ok();
            let b_fit = PowerLaw::fit(&ns, &bs).ok();
            writeln!(
                s,
                "| {algo} | {} | {} | {} | {} |",
                lr_fit.map_or("—".into(), |f| format!("{:.4}", f.a)),
                lr_fit.map_or("—".into(), |f| format!("{:.4}", f.alpha)),
                b_fit.map_or("—".into(), |f| format!("{:.4}", f.a)),
                b_fit.map_or("—".into(), |f| format!("{:.4}", f.alpha)),
            )
            .unwrap();
        } else {
            writeln!(s, "| {algo} | — | — | — | — |").unwrap();
        }
    }
    writeln!(s, "\n## Paper (Tables 8 & 9)\n").unwrap();
    writeln!(s, "| algo | lr A | lr alpha | B A | B alpha |").unwrap();
    writeln!(s, "|---|---|---|---|---|").unwrap();
    for ((a8, la, laa), (_, ba, balpha)) in paper::TABLE8.iter().zip(paper::TABLE9) {
        writeln!(s, "| {a8} | {la} | {laa} | {ba} | {balpha} |").unwrap();
    }
    writeln!(
        s,
        "\nShape check: optimal LR falls with N (alpha<0), optimal batch \
         grows with N (alpha>0) and with M (paper Finding 3)."
    )
    .unwrap();
    s
}

// ---------------------------------------------------------------------------
// Table 10 — joint fits f(N,M) = A*N^alpha*M^beta
// ---------------------------------------------------------------------------

/// Joint loss fit on the paper's Table 4 DiLoCo columns (M=1..8) —
/// validates against the paper's Table 10 "loss" row.
pub fn fit_paper_joint_loss() -> JointFit {
    let mut n = Vec::new();
    let mut m = Vec::new();
    let mut y = Vec::new();
    for (row, &nn) in paper::TABLE4.iter().zip(paper::PAPER_N.iter()) {
        for (col, mm) in [(1usize, 1.0f64), (2, 2.0), (3, 4.0), (4, 8.0)] {
            n.push(nn);
            m.push(mm);
            y.push(row[col]);
        }
    }
    JointFit::fit(&n, &m, &y).expect("paper joint fit")
}

pub fn our_joint_obs(store: &SweepStore) -> Vec<Obs> {
    let mut obs = Vec::new();
    for model in SWEEP_LADDER {
        let Some(n) = param_count_of(store, model) else {
            continue;
        };
        for (algo, m) in [
            ("diloco-m1", 1.0),
            ("diloco-m2", 2.0),
            ("diloco-m4", 4.0),
            ("diloco-m8", 8.0),
        ] {
            if let Some(r) = best_run(store, model, algo) {
                obs.push(Obs {
                    n,
                    m,
                    loss: r.final_eval_loss,
                });
            }
        }
    }
    obs
}

pub fn table10(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(s, "# Table 10 — joint power laws f(N,M) = A*N^alpha*M^beta\n").unwrap();
    let pf = fit_paper_joint_loss();
    writeln!(s, "## Validation on the paper's loss data\n").unwrap();
    writeln!(s, "| | A | alpha | beta |").unwrap();
    writeln!(s, "|---|---|---|---|").unwrap();
    let (label, a, al, be) = paper::TABLE10[0];
    writeln!(s, "| paper ({label}) | {a} | {al} | {be} |").unwrap();
    writeln!(
        s,
        "| ours-on-paper-data | {:.3} | {:.4} | {:.4} |",
        pf.a, pf.alpha, pf.beta
    )
    .unwrap();
    let obs = our_joint_obs(store);
    if obs.len() >= 4 {
        let n: Vec<f64> = obs.iter().map(|o| o.n).collect();
        let m: Vec<f64> = obs.iter().map(|o| o.m).collect();
        let y: Vec<f64> = obs.iter().map(|o| o.loss).collect();
        if let Ok(f) = JointFit::fit(&n, &m, &y) {
            writeln!(s, "\n## Ours (mini ladder loss)\n").unwrap();
            writeln!(
                s,
                "L(N,M) ~ {:.3} * N^{:.4} * M^{:.4}  ({} observations)",
                f.a,
                f.alpha,
                f.beta,
                obs.len()
            )
            .unwrap();
        }
    }
    s
}

// ---------------------------------------------------------------------------
// Table 11 — leave-one-out residuals, independent vs joint
// ---------------------------------------------------------------------------
pub fn table11(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "# Table 11 — leave-one-out residuals (hold out the top rung)\n"
    )
    .unwrap();
    // [P] validation on the paper's loss data: fit on N<=1.3B, predict 2.4B.
    writeln!(s, "## On the paper's Table 4 loss data (predict N=2.4B)\n").unwrap();
    writeln!(s, "| M | independent res(L) | joint res(L) | paper indep | paper joint |").unwrap();
    writeln!(s, "|---|---|---|---|---|").unwrap();
    let paper_indep = [0.011, 0.0099, 0.012, 0.014];
    let paper_joint = [0.019, 0.013, 0.0082, 0.0076];
    // joint fit on first 6 rungs
    let mut n = Vec::new();
    let mut m = Vec::new();
    let mut y = Vec::new();
    for (row, &nn) in paper::TABLE4.iter().take(6).zip(paper::PAPER_N.iter()) {
        for (col, mm) in [(1usize, 1.0f64), (2, 2.0), (3, 4.0), (4, 8.0)] {
            n.push(nn);
            m.push(mm);
            y.push(row[col]);
        }
    }
    let joint = JointFit::fit(&n, &m, &y).expect("joint LOO fit");
    for (i, (col, mm)) in [(1usize, 1.0f64), (2, 2.0), (3, 4.0), (4, 8.0)]
        .iter()
        .enumerate()
    {
        let ys: Vec<f64> = paper::TABLE4.iter().take(6).map(|r| r[*col]).collect();
        let ns = &paper::PAPER_N[..6];
        let indep = PowerLaw::fit(ns, &ys).expect("indep LOO fit");
        let actual = paper::TABLE4[6][*col];
        let r_i = log_residual(actual, indep.predict(2.4e9));
        let r_j = log_residual(actual, joint.predict(2.4e9, *mm));
        writeln!(
            s,
            "| {mm} | {r_i:.4} | {r_j:.4} | {} | {} |",
            paper_indep[i], paper_joint[i]
        )
        .unwrap();
    }
    // ours: hold out the largest measured rung
    let ladder = measured_ladder(store);
    if ladder.len() >= 3 {
        let (hold_model, hold_n, hold_losses) = ladder.last().unwrap().clone();
        writeln!(s, "\n## Ours (hold out {hold_model})\n").unwrap();
        writeln!(s, "| M | independent res(L) | joint res(L) |").unwrap();
        writeln!(s, "|---|---|---|").unwrap();
        let train = &ladder[..ladder.len() - 1];
        let mut n = Vec::new();
        let mut m = Vec::new();
        let mut y = Vec::new();
        for (_, nn, losses) in train {
            for (col, mm) in [(1usize, 1.0f64), (2, 2.0), (3, 4.0), (4, 8.0)] {
                if let Some(l) = losses[col] {
                    n.push(*nn);
                    m.push(mm);
                    y.push(l);
                }
            }
        }
        if let Ok(joint) = JointFit::fit(&n, &m, &y) {
            for (col, mm) in [(1usize, 1.0f64), (2, 2.0), (3, 4.0), (4, 8.0)] {
                let pts: Vec<(f64, f64)> = train
                    .iter()
                    .filter_map(|(_, nn, losses)| losses[col].map(|l| (*nn, l)))
                    .collect();
                let (Some(actual), true) = (hold_losses[col], pts.len() >= 2) else {
                    continue;
                };
                let (ns, ys): (Vec<f64>, Vec<f64>) = pts.into_iter().unzip();
                if let Ok(indep) = PowerLaw::fit(&ns, &ys) {
                    writeln!(
                        s,
                        "| {mm} | {:.4} | {:.4} |",
                        log_residual(actual, indep.predict(hold_n)),
                        log_residual(actual, joint.predict(hold_n, mm))
                    )
                    .unwrap();
                }
            }
        }
    }
    s
}

// ---------------------------------------------------------------------------
// Table 13 — parametric function fitting
// ---------------------------------------------------------------------------
pub fn table13(store: &SweepStore, restarts: usize) -> String {
    let mut s = String::new();
    writeln!(s, "# Table 13 — parametric forms for L(N,M), Huber fit, \
                 {restarts} restarts, top rung held out\n").unwrap();
    // [P] mode: paper's Table 4 DiLoCo losses, hold out N=2.4B.
    let mut train = Vec::new();
    let mut holdout = Vec::new();
    for (i, (row, &nn)) in paper::TABLE4.iter().zip(paper::PAPER_N.iter()).enumerate() {
        for (col, mm) in [(1usize, 1.0f64), (2, 2.0), (3, 4.0), (4, 8.0)] {
            let o = Obs {
                n: nn,
                m: mm,
                loss: row[col],
            };
            if i == 6 {
                holdout.push(o);
            } else {
                train.push(o);
            }
        }
    }
    writeln!(s, "## On the paper's loss data\n").unwrap();
    writeln!(s, "| parametric form | our residual | paper residual |").unwrap();
    writeln!(s, "|---|---|---|").unwrap();
    let paper_resid = [0.0044, 0.0035, 0.0025, 0.0043];
    for (form, pr) in ParametricForm::all().into_iter().zip(paper_resid) {
        match fit_parametric(form, &train, &holdout, 0x7AB13, restarts) {
            Ok(fit) => writeln!(
                s,
                "| {} | {:.4} | {pr} |",
                form.label(),
                fit.holdout_residual
            )
            .unwrap(),
            Err(e) => writeln!(s, "| {} | failed: {e} | {pr} |", form.label()).unwrap(),
        }
    }
    // ours
    let obs = our_joint_obs(store);
    let ladder = measured_ladder(store);
    if ladder.len() >= 3 && obs.len() >= 8 {
        let top_n = ladder.last().unwrap().1;
        let train: Vec<Obs> = obs.iter().filter(|o| o.n < top_n).cloned().collect();
        let hold: Vec<Obs> = obs.iter().filter(|o| o.n >= top_n).cloned().collect();
        if !train.is_empty() && !hold.is_empty() {
            writeln!(s, "\n## Ours (mini ladder)\n").unwrap();
            writeln!(s, "| parametric form | residual |").unwrap();
            writeln!(s, "|---|---|").unwrap();
            for form in ParametricForm::all() {
                match fit_parametric(form, &train, &hold, 0x7AB14, restarts) {
                    Ok(fit) => writeln!(s, "| {} | {:.4} |", form.label(), fit.holdout_residual)
                        .unwrap(),
                    Err(_) => writeln!(s, "| {} | failed |", form.label()).unwrap(),
                }
            }
        }
    }
    s
}

// ---------------------------------------------------------------------------
// Table 6 — compute utilization simulator
// ---------------------------------------------------------------------------
pub fn table6() -> String {
    let mut s = String::new();
    writeln!(s, "# Table 6 — bandwidth (Gbit/s) to reach compute utilization\n").unwrap();
    let (model, matched, total) = calibrate(&paper::TABLE6);
    writeln!(
        s,
        "Calibrated simulator: {:.1} bits/param DP traffic, {:.2}x outer \
         traffic, {:.0e}s latency — {matched}/{total} published cells matched \
         exactly on the logspace(0.1,1000,50) grid.\n",
        model.dp_bits_per_param, model.outer_traffic_ratio, model.latency_s
    )
    .unwrap();
    writeln!(s, "| architecture | method | CU=50% | 80% | 90% | 95% | 99% |").unwrap();
    writeln!(s, "|---|---|---|---|---|---|---|").unwrap();
    let fmt = |c: &Option<f64>| c.map_or("1000.0+".to_string(), |v| format!("{v}"));
    for arch in &LLM_ARCHS {
        for (label, cells) in model.table6_block(arch) {
            let row: Vec<String> = cells.iter().map(&fmt).collect();
            writeln!(s, "| {} | {label} | {} |", arch.name, row.join(" | ")).unwrap();
        }
        // paper rows for comparison
        for &(name, h, ref cells) in paper::TABLE6.iter() {
            if name == arch.name {
                let label = if h == 0 {
                    "paper: Data-Parallel".to_string()
                } else {
                    format!("paper: DiLoCo, H={h}")
                };
                let row: Vec<String> = cells.iter().map(&fmt).collect();
                writeln!(s, "| {} | {label} | {} |", arch.name, row.join(" | ")).unwrap();
            }
        }
    }
    let m = SimModel::default();
    let dp = m
        .required_bandwidth_gbps(
            &crate::netsim::utilization::CHINCHILLA_10B,
            crate::netsim::utilization::SimAlgo::DataParallel,
            0.5,
        )
        .unwrap_or(f64::NAN);
    let h300 = m
        .required_bandwidth_gbps(
            &crate::netsim::utilization::CHINCHILLA_10B,
            crate::netsim::utilization::SimAlgo::DiLoCo { sync_every: 300 },
            0.5,
        )
        .unwrap_or(f64::NAN);
    writeln!(
        s,
        "\nHeadline reproduction: DiLoCo H=300 needs {:.0}x less bandwidth \
         than Data-Parallel at CU=50% (paper: >100x).",
        dp / h300
    )
    .unwrap();
    writeln!(s, "\n`CADENCES` reproduced: {CADENCES:?}").unwrap();
    s
}

// ---------------------------------------------------------------------------
// Table 5 / 12 — extrapolation runs (filled once m3 runs exist)
// ---------------------------------------------------------------------------
pub fn table5_12(store: &SweepStore, repo: &RepoConfig) -> String {
    let _ = repo;
    let mut s = String::new();
    writeln!(
        s,
        "# Tables 5 & 12 — extrapolation rung with scaling-law-predicted \
         hyperparameters\n"
    )
    .unwrap();
    writeln!(s, "## Paper (4B / 10B)\n").unwrap();
    writeln!(s, "| algo | 4B loss | 10B loss |").unwrap();
    writeln!(s, "|---|---|---|").unwrap();
    for ((a, l4), (_, l10)) in paper::TABLE5_4B.iter().zip(paper::TABLE5_10B.iter()) {
        writeln!(s, "| {a} | {l4} | {l10} |").unwrap();
    }
    writeln!(s, "\n## Ours (extrapolation rung m3, hypers from fits on m0-m2)\n").unwrap();
    let mut any = false;
    writeln!(s, "| algo | eval loss | vs DP |").unwrap();
    writeln!(s, "|---|---|---|").unwrap();
    let dp = store.best(|r| r.model == "m3" && r.algo == "dp");
    for algo in ["dp", "diloco-m1", "diloco-m2", "diloco-m4"] {
        if let Some(r) = store.best(|x| x.model == "m3" && x.algo == algo) {
            any = true;
            let vs = dp
                .map(|d| pct(r.final_eval_loss, d.final_eval_loss))
                .unwrap_or_else(|| "—".into());
            writeln!(s, "| {algo} | {:.4} | {vs} |", r.final_eval_loss).unwrap();
        }
    }
    if !any {
        writeln!(
            s,
            "| (pending) | run `diloco sweep --grid extrapolate` | |"
        )
        .unwrap();
    }
    s
}

// ---------------------------------------------------------------------------
// Compression report — eval-loss delta vs wire bytes per outer bit width
// (ROADMAP "Compressed outer communication"; paper section 7 studies 4-bit
// outer gradients; generated by `diloco report --exp comm`)
// ---------------------------------------------------------------------------
pub fn table_comm(store: &SweepStore) -> String {
    use crate::netsim::walltime::{walltime, WalltimeAlgo, WalltimeInput};
    use crate::netsim::LOW;

    let mut s = String::new();
    writeln!(s, "# Compressed outer communication — loss delta vs wire bytes\n").unwrap();
    writeln!(
        s,
        "Per (model, M): the best run at each (up, down) wire-width pair \
         (`--outer-bits` / `--outer-bits-down`, sweep grid `comm`) — the \
         symmetric ladder plus the two asymmetric corners that narrow one \
         leg alone. Delta is measured against the 32/32 run of the same \
         (model, algo) family — the exact fp32 baseline, bit-identical to \
         the uncompressed path. Wire columns are **exact encoded bytes \
         counted on the bus** (up = replica → coordinator payloads, \
         counted per replica; down = the coordinator's single encoded \
         broadcast per sync — quantized and error-compensated below 32 \
         bits, a deduplicated f32 literal handoff at 32); framed adds \
         the TCP transport's length-prefixed header per contribution \
         and per broadcast (36 B each — what a real socket moves, see \
         EXPERIMENTS.md on calibration); netsim comm \
         time is the Appendix-A model on the LOW archetype at the run's \
         per-leg wire widths.\n"
    )
    .unwrap();
    writeln!(
        s,
        "| model | algo | bits up/down | eval loss | delta vs fp32 | wire up (MiB) | wire down (MiB) | framed (MiB) | netsim comm_s (low) |"
    )
    .unwrap();
    writeln!(s, "|---|---|---|---|---|---|---|---|---|").unwrap();
    let mib = |b: u64| b as f64 / (1024.0 * 1024.0);
    let mut rows = 0usize;
    // the row set IS the comm grid's coverage (baseline first for
    // display) — derived so grid and report can't drift apart
    let pairs: Vec<(u32, u32)> = crate::sweep::grids::COMM_PAIRS
        .iter()
        .map(|&(u, d)| (u.bits(), d.bits()))
        .collect();
    // narrowest compressed pair first for the baseline-anchor search
    let mut anchor_order: Vec<(u32, u32)> =
        pairs.iter().copied().filter(|&p| p != (32, 32)).collect();
    anchor_order.sort_by_key(|&(u, d)| u + d);
    for model in SWEEP_LADDER {
        for algo in &ALGOS[1..] {
            let family = |up: u32, down: u32| {
                store.best(|r| {
                    r.model == model
                        && r.algo == *algo
                        && r.outer_bits == up
                        && r.outer_bits_down == down
                        && (r.overtrain - 1.0).abs() < 1e-9
                })
            };
            let hypers_match = |a: &crate::coordinator::RunMetrics,
                                b: &crate::coordinator::RunMetrics| {
                a.sync_every == b.sync_every
                    && a.global_batch_tokens == b.global_batch_tokens
                    && a.inner_lr == b.inner_lr
                    && a.outer_lr == b.outer_lr
            };
            // The printed fp32 baseline must be the SAME run the lossy
            // deltas are measured against, and it must share the
            // compressed runs' hyperparameters exactly — otherwise the
            // delta conflates codec loss with tuning differences (the
            // comm grid varies ONLY the widths within a family). Anchor
            // on the narrowest compressed pair present; without any
            // compressed runs, fall back to the best fp32 run alone.
            let anchor = anchor_order.iter().filter_map(|&(u, d)| family(u, d)).next();
            let base = match anchor {
                Some(a) => store.best(|b| {
                    b.model == model
                        && b.algo == *algo
                        && b.outer_bits == 32
                        && b.outer_bits_down == 32
                        && (b.overtrain - 1.0).abs() < 1e-9
                        && hypers_match(a, b)
                }),
                None => family(32, 32),
            };
            for &(up, down) in &pairs {
                let is_base = (up, down) == (32, 32);
                let Some(r) = (if is_base { base } else { family(up, down) }) else {
                    continue;
                };
                rows += 1;
                let delta = if is_base {
                    "baseline".to_string()
                } else {
                    match base {
                        Some(b) if hypers_match(b, r) => {
                            pct(r.final_eval_loss, b.final_eval_loss)
                        }
                        _ => "— (no matched fp32 run)".to_string(),
                    }
                };
                let w = walltime(&WalltimeInput {
                    algo: WalltimeAlgo::DiLoCo {
                        replicas: r.replicas.max(1),
                        sync_every: r.sync_every.max(1),
                    },
                    params: r.param_count as f64,
                    tokens: r.tokens as f64,
                    batch_tokens: r.global_batch_tokens as f64,
                    cross_dc: LOW,
                    // THIS run's actual wire widths — fp32 legs model
                    // 32 bits, matching the measured wire columns.
                    // (fig6_12 instead models uncompressed runs at the
                    // paper's bf16, deliberately: it reproduces
                    // Appendix A.)
                    outer_bits: up as f64,
                    outer_bits_down: down as f64,
                    overlap_tau: r.overlap_tau as f64,
                    churn: None,
                });
                writeln!(
                    s,
                    "| {model} | {algo} | {up}/{down} | {:.4} | {delta} | {:.2} | {:.2} | {:.2} | {:.3e} |",
                    r.final_eval_loss,
                    mib(r.wire_up_bytes),
                    mib(r.wire_down_bytes),
                    mib(r.wire_framed_bytes),
                    w.comm_s
                )
                .unwrap();
            }
        }
    }
    if rows == 0 {
        writeln!(
            s,
            "| (pending) | run `diloco sweep --grid comm` | | | | | | | |"
        )
        .unwrap();
    }
    writeln!(
        s,
        "\nShape check (Streaming DiLoCo, arXiv:2501.18512 / paper section 7; \
         DiLoCoX, arXiv:2506.21263): 4-bit wires should cost a negligible \
         loss delta while cutting that leg's bytes ~8x vs fp32 (per-block \
         scales add 0.125 bits/param), with error feedback — per replica on \
         the up-wire, coordinator-owned on the down-wire — keeping repeated \
         quantized syncs unbiased in both directions. At 4/32 the f32 \
         broadcast dominates total sync bytes ~8:1, which is what the 4/4 \
         rows close."
    )
    .unwrap();
    s
}

// ---------------------------------------------------------------------------
// Overlap report — loss vs τ and walltime vs τ for the overlapped outer sync
// (ROADMAP "Overlapped outer sync"; Streaming DiLoCo's delayed application;
// generated by `diloco report --exp stream`)
// ---------------------------------------------------------------------------
pub fn table_stream(store: &SweepStore) -> String {
    use crate::netsim::walltime::{walltime, WalltimeAlgo, WalltimeInput, BITS_PER_PARAM};
    use crate::netsim::{ARCHETYPES, LOW};

    let mut s = String::new();
    writeln!(s, "# Overlapped outer sync — loss vs τ, walltime vs τ\n").unwrap();
    writeln!(
        s,
        "**The τ column** is `--overlap-tau`, Streaming DiLoCo's delayed \
         application: a fragment's sync contributions are sent at the \
         cadence boundary, the workers keep taking inner steps, and the \
         reduced broadcast merges into live replica params exactly τ steps \
         later — so the coordinator's reduce, outer step, and broadcast \
         encode all hide under compute. τ=0 is the barrier schedule, \
         bit-identical to the pre-overlap coordinator; τ>0 trades a \
         slightly stale merge for `netsim`'s \
         `max(0, t_comm − τ·t_step)` outer leg.\n"
    )
    .unwrap();

    // ---- loss vs τ, from the sweep store (grid `stream`) ----
    writeln!(s, "## Loss vs τ (sweep grid `stream`)\n").unwrap();
    writeln!(
        s,
        "Per (model, M): the best run at each (P, τ, bits) corner of \
         `sweep::grids::STREAM_CORNERS`. Delta is measured against the \
         (P=1, τ=0, 32/32) barrier run of the same family with the same \
         hyperparameters — the exact baseline, so the delta is \
         attributable to the schedule (and, on the quantized corner, the \
         codecs) alone.\n"
    )
    .unwrap();
    writeln!(
        s,
        "| model | algo | P | τ | bits up/down | eval loss | delta vs barrier | netsim outer_s τ=0 (low) | netsim outer_s at τ (low) |"
    )
    .unwrap();
    writeln!(s, "|---|---|---|---|---|---|---|---|---|").unwrap();
    let mut rows = 0usize;
    let corners: Vec<(usize, usize, u32, u32)> = crate::sweep::grids::STREAM_CORNERS
        .iter()
        .map(|&(p, tau, u, d)| (p, tau, u.bits(), d.bits()))
        .collect();
    for model in SWEEP_LADDER {
        for algo in &ALGOS[1..] {
            let family = |p: usize, tau: usize, up: u32, down: u32| {
                store.best(|r| {
                    r.model == model
                        && r.algo == *algo
                        && r.fragments == p
                        && r.overlap_tau == tau
                        && r.outer_bits == up
                        && r.outer_bits_down == down
                        && (r.overtrain - 1.0).abs() < 1e-9
                })
            };
            let hypers_match = |a: &crate::coordinator::RunMetrics,
                                b: &crate::coordinator::RunMetrics| {
                a.sync_every == b.sync_every
                    && a.global_batch_tokens == b.global_batch_tokens
                    && a.inner_lr == b.inner_lr
                    && a.outer_lr == b.outer_lr
            };
            // The printed barrier baseline must be the SAME run the
            // overlap deltas anchor on, sharing the corners' exact
            // hyperparameters (the stream grid varies only the
            // schedule/width knobs within a family) — same policy as
            // the comm report's anchor search. Without any overlap
            // runs, fall back to the best barrier run alone.
            let anchor = corners
                .iter()
                .filter(|&&c| c != (1, 0, 32, 32))
                .filter_map(|&(p, tau, up, down)| family(p, tau, up, down))
                .next();
            let base = match anchor {
                Some(a) => store.best(|b| {
                    b.model == model
                        && b.algo == *algo
                        && b.fragments == 1
                        && b.overlap_tau == 0
                        && b.outer_bits == 32
                        && b.outer_bits_down == 32
                        && (b.overtrain - 1.0).abs() < 1e-9
                        && hypers_match(a, b)
                }),
                None => family(1, 0, 32, 32),
            };
            for &(p, tau, up, down) in &corners {
                let is_base = (p, tau, up, down) == (1, 0, 32, 32);
                let Some(r) = (if is_base { base } else { family(p, tau, up, down) }) else {
                    continue;
                };
                rows += 1;
                let delta = if is_base {
                    "baseline".to_string()
                } else {
                    match base {
                        Some(b) if hypers_match(b, r) => {
                            pct(r.final_eval_loss, b.final_eval_loss)
                        }
                        _ => "— (no matched barrier run)".to_string(),
                    }
                };
                // the outer term in isolation: total comm minus the
                // H -> inf (inner-only) comm, at τ and at 0
                let outer_at = |t: f64| -> f64 {
                    let mk = |sync_every: usize, tau: f64| {
                        walltime(&WalltimeInput {
                            algo: WalltimeAlgo::DiLoCo {
                                replicas: r.replicas.max(1),
                                sync_every,
                            },
                            params: r.param_count as f64,
                            tokens: r.tokens as f64,
                            batch_tokens: r.global_batch_tokens as f64,
                            cross_dc: LOW,
                            outer_bits: up as f64,
                            outer_bits_down: down as f64,
                            overlap_tau: tau,
                            churn: None,
                        })
                        .comm_s
                    };
                    mk(r.sync_every.max(1), t) - mk(usize::MAX, 0.0)
                };
                writeln!(
                    s,
                    "| {model} | {algo} | {p} | {tau} | {up}/{down} | {:.4} | {delta} | {:.3e} | {:.3e} |",
                    r.final_eval_loss,
                    outer_at(0.0),
                    outer_at(tau as f64),
                )
                .unwrap();
            }
        }
    }
    if rows == 0 {
        writeln!(
            s,
            "| (pending) | run `diloco sweep --grid stream` | | | | | | | |"
        )
        .unwrap();
    }

    // ---- walltime vs τ, analytic (works before any runs land) ----
    writeln!(
        s,
        "\n## Walltime vs τ (netsim, paper-scale N=1e9, M=4, H=30, bf16 legs)\n"
    )
    .unwrap();
    writeln!(
        s,
        "Appendix-A model with the overlap term: per-sync outer cost \
         `max(0, t_comm − τ·t_step)`. The outer column hits zero once τ \
         covers the sync's communication — fully compute-hidden.\n"
    )
    .unwrap();
    writeln!(s, "| network | τ | comm_s | outer_s | outer hidden |").unwrap();
    writeln!(s, "|---|---|---|---|---|").unwrap();
    for net in ARCHETYPES {
        let mk = |sync_every: usize, tau: f64| {
            walltime(&WalltimeInput {
                algo: WalltimeAlgo::DiLoCo {
                    replicas: 4,
                    sync_every,
                },
                params: 1e9,
                tokens: 20e9,
                batch_tokens: 2f64.powi(20),
                cross_dc: net,
                outer_bits: BITS_PER_PARAM,
                outer_bits_down: BITS_PER_PARAM,
                overlap_tau: tau,
                churn: None,
            })
        };
        let inner_only = mk(usize::MAX, 0.0).comm_s;
        let outer0 = mk(30, 0.0).comm_s - inner_only;
        for tau in [0usize, 1, 2, 4, 8, 14] {
            let w = mk(30, tau as f64);
            let outer = w.comm_s - inner_only;
            writeln!(
                s,
                "| {} | {tau} | {:.3e} | {:.3e} | {:.0}% |",
                net.name,
                w.comm_s,
                outer,
                if outer0 > 0.0 { (1.0 - outer / outer0) * 100.0 } else { 0.0 }
            )
            .unwrap();
        }
    }
    s
}

// ---------------------------------------------------------------------------
// Churn report — eval loss vs replica dropout rate for elastic membership
// (ROADMAP "ticked coordinator state machine"; deterministic fault injection
// via `--churn`; generated by `diloco report --exp churn`)
// ---------------------------------------------------------------------------
pub fn table_churn(store: &SweepStore) -> String {
    use crate::netsim::walltime::{
        walltime, ChurnModel, WalltimeAlgo, WalltimeInput,
    };
    use crate::netsim::{ARCHETYPES, LOW};

    let mut s = String::new();
    writeln!(s, "# Elastic membership — eval loss vs replica dropout rate\n").unwrap();
    writeln!(
        s,
        "**The fault plan column** is `--churn`, the coordinator's \
         deterministic fault injection: crashes drop a replica from the \
         reduce mid-segment (the outer step means over survivors), leaves \
         freeze a replica after its last contribution, joins admit a fresh \
         replica at an outer boundary (initialized from the current \
         broadcast view), and stragglers only stretch the sync in the \
         walltime model — the loss trajectory is untouched. `rate=P` \
         derives a seed-keyed random crash per replica with probability P \
         per sync (replica 0 always survives). The empty plan is \
         bit-identical to the churn-free coordinator, which is what makes \
         the delta column attributable to churn alone.\n"
    )
    .unwrap();

    // ---- loss vs dropout, from the sweep store (grid `churn`) ----
    writeln!(s, "## Loss vs dropout rate (sweep grid `churn`)\n").unwrap();
    writeln!(
        s,
        "Per (model, M): the best run at each fault plan of \
         `sweep::grids::CHURN_CORNERS`. Delta is measured against the \
         churn-free run of the same family with the same hyperparameters \
         (the churn grid varies only the fault plan within a family).\n"
    )
    .unwrap();
    writeln!(
        s,
        "| model | algo | fault plan | dropout rate | eval loss | delta vs churn-free | netsim outer_s clean (low) | netsim outer_s churned (low) |"
    )
    .unwrap();
    writeln!(s, "|---|---|---|---|---|---|---|---|").unwrap();
    let mut rows = 0usize;
    let corners = crate::sweep::grids::CHURN_CORNERS;
    for model in SWEEP_LADDER {
        for algo in &ALGOS[1..] {
            let family = |spec: &str| {
                store.best(|r| {
                    r.model == model
                        && r.algo == *algo
                        && r.churn == spec
                        && (r.overtrain - 1.0).abs() < 1e-9
                })
            };
            let hypers_match = |a: &crate::coordinator::RunMetrics,
                                b: &crate::coordinator::RunMetrics| {
                a.sync_every == b.sync_every
                    && a.global_batch_tokens == b.global_batch_tokens
                    && a.inner_lr == b.inner_lr
                    && a.outer_lr == b.outer_lr
            };
            // The printed churn-free baseline must be the SAME run the
            // faulted deltas anchor on (same policy as the comm/stream
            // reports' anchor search). Without any faulted runs, fall
            // back to the best churn-free run alone.
            let anchor = corners
                .iter()
                .filter(|c| !c.is_empty())
                .filter_map(|&c| family(c))
                .next();
            let base = match anchor {
                Some(a) => store.best(|b| {
                    b.model == model
                        && b.algo == *algo
                        && b.churn.is_empty()
                        && (b.overtrain - 1.0).abs() < 1e-9
                        && hypers_match(a, b)
                }),
                None => family(""),
            };
            for &spec in &corners {
                let is_base = spec.is_empty();
                let Some(r) = (if is_base { base } else { family(spec) }) else {
                    continue;
                };
                rows += 1;
                let delta = if is_base {
                    "baseline".to_string()
                } else {
                    match base {
                        Some(b) if hypers_match(b, r) => {
                            pct(r.final_eval_loss, b.final_eval_loss)
                        }
                        _ => "— (no matched churn-free run)".to_string(),
                    }
                };
                // the outer term in isolation, clean vs churned: the
                // run's measured dropout rate thins the up leg; any
                // straggle events in the plan stretch their syncs 4x
                let straggle_syncs = spec.matches("straggle").count();
                let churn_model = ChurnModel {
                    dropout_rate: r.dropout_rate,
                    straggler_frac: if r.outer_syncs > 0 {
                        (straggle_syncs as f64 / r.outer_syncs as f64).min(1.0)
                    } else {
                        0.0
                    },
                    straggler_slowdown: 4.0,
                };
                let outer_with = |churn: Option<ChurnModel>| -> f64 {
                    let mk = |sync_every: usize, churn: Option<ChurnModel>| {
                        walltime(&WalltimeInput {
                            algo: WalltimeAlgo::DiLoCo {
                                replicas: r.replicas.max(1),
                                sync_every,
                            },
                            params: r.param_count as f64,
                            tokens: r.tokens as f64,
                            batch_tokens: r.global_batch_tokens as f64,
                            cross_dc: LOW,
                            outer_bits: r.outer_bits as f64,
                            outer_bits_down: r.outer_bits_down as f64,
                            overlap_tau: r.overlap_tau as f64,
                            churn,
                        })
                        .comm_s
                    };
                    mk(r.sync_every.max(1), churn) - mk(usize::MAX, None)
                };
                writeln!(
                    s,
                    "| {model} | {algo} | {} | {:.3} | {:.4} | {delta} | {:.3e} | {:.3e} |",
                    if is_base { "(none)" } else { spec },
                    r.dropout_rate,
                    r.final_eval_loss,
                    outer_with(None),
                    outer_with(Some(churn_model)),
                )
                .unwrap();
            }
        }
    }
    if rows == 0 {
        writeln!(
            s,
            "| (pending) | run `diloco sweep --grid churn` | | | | | | |"
        )
        .unwrap();
    }

    // ---- straggler cost, analytic (works before any runs land) ----
    writeln!(
        s,
        "\n## Straggler cost vs τ (netsim, paper-scale N=1e9, M=4, H=30, bf16 legs)\n"
    )
    .unwrap();
    writeln!(
        s,
        "Stragglers stretch a sync's outer leg **before** the τ window \
         hides any of it, so a straggling sync needs proportionally more \
         compute to disappear from the critical path; dropout only thins \
         the up leg (the coordinator never waits for the dead).\n"
    )
    .unwrap();
    writeln!(s, "| network | straggler frac x slowdown | τ | outer_s clean | outer_s churned |").unwrap();
    writeln!(s, "|---|---|---|---|---|").unwrap();
    for net in ARCHETYPES {
        let mk = |sync_every: usize, tau: f64, churn: Option<ChurnModel>| {
            walltime(&WalltimeInput {
                algo: WalltimeAlgo::DiLoCo {
                    replicas: 4,
                    sync_every,
                },
                params: 1e9,
                tokens: 20e9,
                batch_tokens: 2f64.powi(20),
                cross_dc: net,
                outer_bits: crate::netsim::walltime::BITS_PER_PARAM,
                outer_bits_down: crate::netsim::walltime::BITS_PER_PARAM,
                overlap_tau: tau,
                churn,
            })
            .comm_s
        };
        for (frac, slow) in [(0.1f64, 4.0f64), (0.25, 4.0), (0.25, 16.0)] {
            let churn = Some(ChurnModel {
                dropout_rate: 0.0,
                straggler_frac: frac,
                straggler_slowdown: slow,
            });
            for tau in [0usize, 8] {
                let inner_only = mk(usize::MAX, 0.0, None);
                let clean = mk(30, tau as f64, None) - inner_only;
                let churned = mk(30, tau as f64, churn) - inner_only;
                writeln!(
                    s,
                    "| {} | {frac} x {slow} | {tau} | {clean:.3e} | {churned:.3e} |",
                    net.name,
                )
                .unwrap();
            }
        }
    }
    s
}
