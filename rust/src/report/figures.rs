//! Figure-series generators: each emits the rows/series the paper's
//! figure plots (markdown + CSV blocks, ready for any plotting tool).

use std::fmt::Write as _;

use crate::netsim::utilization::{SimAlgo, SimModel, ARCHETYPES as LLM_ARCHS};
use crate::netsim::walltime::{walltime, WalltimeAlgo, WalltimeInput, BITS_PER_PARAM};
use crate::netsim::ARCHETYPES;
use crate::scaling::PowerLaw;
use crate::sweep::SweepStore;

use super::paperdata as paper;
use super::tables::{best_run, fit_our_loss_laws, measured_ladder, ALGOS, SWEEP_LADDER};

// ---------------------------------------------------------------------------
// Figure 2 — loss vs N, and % difference vs Data-Parallel
// ---------------------------------------------------------------------------
pub fn fig2(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(s, "# Figure 2 — DiLoCo does better with scale\n").unwrap();
    writeln!(s, "## Ours: percentage difference vs DP (negative = DiLoCo wins)\n").unwrap();
    writeln!(s, "model,N,algo,eval_loss,pct_vs_dp").unwrap();
    for (model, n, losses) in measured_ladder(store) {
        if let Some(dp) = losses[0] {
            for (i, l) in losses.iter().enumerate() {
                if let Some(l) = l {
                    writeln!(
                        s,
                        "{model},{n:.0},{},{l:.4},{:+.3}",
                        ALGOS[i],
                        (l - dp) / dp * 100.0
                    )
                    .unwrap();
                }
            }
        }
    }
    writeln!(s, "\n## Paper series (same columns)\n").unwrap();
    writeln!(s, "model,N,algo,eval_loss,pct_vs_dp").unwrap();
    for (row, (&n, name)) in paper::TABLE4
        .iter()
        .zip(paper::PAPER_N.iter().zip(paper::PAPER_N_NAMES))
    {
        for (i, l) in row.iter().enumerate() {
            writeln!(
                s,
                "{name},{n:.0},{},{l:.4},{:+.3}",
                paper::ALGO_LABELS[i],
                (l - row[0]) / row[0] * 100.0
            )
            .unwrap();
        }
    }
    s
}

// ---------------------------------------------------------------------------
// Figures 3-5 (and appendix 14-19) — batch-size robustness
// ---------------------------------------------------------------------------
pub fn fig_batch(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "# Figures 3-5 / 14-19 — evaluation loss and zero-shot accuracy vs \
         global batch size\n"
    )
    .unwrap();
    writeln!(s, "model,algo,batch_tokens,best_eval_loss,cloze_long,cloze_short,cloze_hard").unwrap();
    for model in SWEEP_LADDER {
        for algo in ALGOS {
            let mut by_batch: std::collections::BTreeMap<usize, &crate::coordinator::RunMetrics> =
                Default::default();
            for r in store.by_model_algo(model, algo) {
                if (r.overtrain - 1.0).abs() > 1e-9 || r.sync_every > 30 {
                    continue;
                }
                let e = by_batch.entry(r.global_batch_tokens).or_insert(r);
                if r.final_eval_loss < e.final_eval_loss {
                    *e = r;
                }
            }
            for (b, r) in by_batch {
                let ds = |name: &str| {
                    r.downstream
                        .iter()
                        .find(|(k, _)| k == name)
                        .map(|(_, v)| format!("{v:.3}"))
                        .unwrap_or_default()
                };
                writeln!(
                    s,
                    "{model},{algo},{b},{:.4},{},{},{}",
                    r.final_eval_loss,
                    ds("cloze-long"),
                    ds("cloze-short"),
                    ds("cloze-hard")
                )
                .unwrap();
            }
        }
    }
    writeln!(
        s,
        "\nShape check (paper Findings 2-3): DP degrades fastest as batch \
         grows; DiLoCo flat or improving; optimal batch grows with M."
    )
    .unwrap();
    s
}

// ---------------------------------------------------------------------------
// Figures 7-8 — optimal outer LR vs N, M, H
// ---------------------------------------------------------------------------
pub fn fig7_8(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(s, "# Figures 7-8 — optimal outer learning rate\n").unwrap();
    writeln!(s, "## Optimal eta per (model, M) — paper: constant in N, grows with M\n").unwrap();
    writeln!(s, "model,N,M,best_eta,best_loss").unwrap();
    for model in SWEEP_LADDER {
        for (algo, m) in [("diloco-m1", 1), ("diloco-m2", 2), ("diloco-m4", 4), ("diloco-m8", 8)] {
            if let Some(r) = best_run(store, model, algo) {
                writeln!(
                    s,
                    "{model},{},{m},{},{:.4}",
                    r.param_count, r.outer_lr, r.final_eval_loss
                )
                .unwrap();
            }
        }
    }
    writeln!(s, "\n## Optimal eta per (M, H) — paper: eta grows with H\n").unwrap();
    writeln!(s, "M,H,best_eta,best_loss").unwrap();
    for (algo, m) in [("diloco-m1", 1), ("diloco-m2", 2), ("diloco-m4", 4)] {
        let mut hs: Vec<usize> = store
            .by_model_algo("m0", algo)
            .iter()
            .map(|r| r.sync_every)
            .collect();
        hs.sort_unstable();
        hs.dedup();
        for h in hs {
            if let Some(r) = store.best(|r| {
                r.model == "m0" && r.algo == algo && r.sync_every == h
                    && (r.overtrain - 1.0).abs() < 1e-9
            }) {
                writeln!(s, "{m},{h},{},{:.4}", r.outer_lr, r.final_eval_loss).unwrap();
            }
        }
    }
    s
}

// ---------------------------------------------------------------------------
// Figure 9 — eval loss vs synchronization cadence H
// ---------------------------------------------------------------------------
pub fn fig9(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(s, "# Figure 9 — infrequent synchronization\n").unwrap();
    writeln!(s, "M,H,best_eval_loss").unwrap();
    for (algo, m) in [("diloco-m1", 1), ("diloco-m2", 2), ("diloco-m4", 4)] {
        for h in [1usize, 5, 10, 30, 100, 300] {
            if let Some(r) = store.best(|r| {
                r.model == "m0" && r.algo == algo && r.sync_every == h
                    && (r.overtrain - 1.0).abs() < 1e-9
            }) {
                writeln!(s, "{m},{h},{:.4}", r.final_eval_loss).unwrap();
            }
        }
    }
    writeln!(
        s,
        "\nShape check (paper 5.1): H=1 worst; loss rises slowly with H; \
         gentler for M=1."
    )
    .unwrap();
    s
}

// ---------------------------------------------------------------------------
// Figure 6 / 12 — idealized wall-clock time (Appendix A model)
// ---------------------------------------------------------------------------
pub fn fig6_12(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "# Figures 6 & 12 — idealized wall-clock time (Appendix A model)\n"
    )
    .unwrap();
    writeln!(s, "network,model,N,algo,batch_tokens,eval_loss,compute_s,comm_s,total_s").unwrap();
    for net in ARCHETYPES {
        for (model, n, _) in measured_ladder(store) {
            for algo in ALGOS {
                for r in store.by_model_algo(&model, algo) {
                    if (r.overtrain - 1.0).abs() > 1e-9 || r.sync_every > 30 {
                        continue;
                    }
                    let walgo = match r.replicas {
                        1 if r.algo == "dp" => WalltimeAlgo::DataParallel,
                        m => WalltimeAlgo::DiLoCo {
                            replicas: m,
                            sync_every: r.sync_every.max(1),
                        },
                    };
                    let w = walltime(&WalltimeInput {
                        algo: walgo,
                        params: n,
                        tokens: r.tokens as f64,
                        batch_tokens: r.global_batch_tokens as f64,
                        cross_dc: net,
                        // uncompressed runs modelled at bf16 (paper
                        // section 3 — this figure reproduces Appendix
                        // A); compressed runs at their width, per leg.
                        // The comm report (tables::table_comm) instead
                        // models every run at its actual wire widths.
                        outer_bits: if r.outer_bits >= 32 {
                            BITS_PER_PARAM
                        } else {
                            r.outer_bits as f64
                        },
                        outer_bits_down: if r.outer_bits_down >= 32 {
                            BITS_PER_PARAM
                        } else {
                            r.outer_bits_down as f64
                        },
                        overlap_tau: r.overlap_tau as f64,
                        churn: None,
                    });
                    writeln!(
                        s,
                        "{},{model},{n:.0},{algo},{},{:.4},{:.3e},{:.3e},{:.3e}",
                        net.name,
                        r.global_batch_tokens,
                        r.final_eval_loss,
                        w.compute_s,
                        w.comm_s,
                        w.total_s()
                    )
                    .unwrap();
                }
            }
        }
    }
    // Paper-scale illustration (the actual Fig 6 axes): paper ladder sizes.
    writeln!(s, "\n## Paper-scale series (35M-10B, Chinchilla budgets)\n").unwrap();
    writeln!(s, "network,N,algo,batch_tokens,total_hours").unwrap();
    for net in ARCHETYPES {
        for &n in &paper::PAPER_N {
            let tokens = 20.0 * n;
            for pow in [18u32, 20, 22] {
                let b = 2f64.powi(pow as i32);
                for (label, algo) in [
                    ("dp", WalltimeAlgo::DataParallel),
                    (
                        "diloco-m2",
                        WalltimeAlgo::DiLoCo {
                            replicas: 2,
                            sync_every: 30,
                        },
                    ),
                    (
                        "diloco-m4",
                        WalltimeAlgo::DiLoCo {
                            replicas: 4,
                            sync_every: 30,
                        },
                    ),
                ] {
                    let w = walltime(&WalltimeInput {
                        algo,
                        params: n,
                        tokens,
                        batch_tokens: b,
                        cross_dc: net,
                        outer_bits: BITS_PER_PARAM,
                        outer_bits_down: BITS_PER_PARAM,
                        overlap_tau: 0.0,
                        churn: None,
                    });
                    writeln!(
                        s,
                        "{},{n:.0},{label},{b:.0},{:.3}",
                        net.name,
                        w.total_s() / 3600.0
                    )
                    .unwrap();
                }
            }
        }
    }
    s
}

// ---------------------------------------------------------------------------
// Figure 10 — compute utilization vs bandwidth curves
// ---------------------------------------------------------------------------
pub fn fig10() -> String {
    let mut s = String::new();
    writeln!(s, "# Figure 10 — compute utilization vs bandwidth\n").unwrap();
    writeln!(s, "architecture,algo,bandwidth_gbps,compute_utilization").unwrap();
    let m = SimModel::default();
    for arch in &LLM_ARCHS {
        let mut algos = vec![("dp".to_string(), SimAlgo::DataParallel)];
        for h in [1usize, 10, 50, 100, 300] {
            algos.push((format!("diloco-h{h}"), SimAlgo::DiLoCo { sync_every: h }));
        }
        for (label, algo) in algos {
            for w in crate::netsim::utilization::bandwidth_grid_gbps() {
                writeln!(
                    s,
                    "{},{label},{w:.1},{:.4}",
                    arch.name,
                    m.utilization(arch, algo, w)
                )
                .unwrap();
            }
        }
    }
    s
}

// ---------------------------------------------------------------------------
// Figure 11 — overtraining
// ---------------------------------------------------------------------------
pub fn fig11(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(s, "# Figure 11 — DiLoCo scales reliably with overtraining\n").unwrap();
    writeln!(s, "model,algo,overtrain,flops,eval_loss").unwrap();
    for r in store.records() {
        if (r.overtrain - 1.0).abs() < 1e-9 && r.seed != 1817 {
            continue; // overtraining family only (distinct seed marks it)
        }
        let flops = 6.0 * r.param_count as f64 * r.tokens as f64;
        writeln!(
            s,
            "{},{},{},{flops:.3e},{:.4}",
            r.model, r.algo, r.overtrain, r.final_eval_loss
        )
        .unwrap();
    }
    writeln!(
        s,
        "\nShape check (paper 5.2): per algorithm, loss vs FLOPs curves for \
         different overtrain multipliers are near-parallel lines in log-log; \
         DiLoCo M=1 below DP at all budgets."
    )
    .unwrap();
    s
}

// ---------------------------------------------------------------------------
// Figure 13 — scaling-law extrapolation
// ---------------------------------------------------------------------------
pub fn fig13(store: &SweepStore) -> String {
    let mut s = String::new();
    writeln!(s, "# Figure 13 — scaling laws extrapolate\n").unwrap();
    writeln!(s, "## Fitted independent laws (ours)\n").unwrap();
    writeln!(s, "algo,A,alpha,predicted_loss_at_m3,predicted_loss_at_m4").unwrap();
    // m3/m4 param counts from any record, else from configs
    let n3 = store
        .records()
        .find(|r| r.model == "m3")
        .map(|r| r.param_count as f64)
        .unwrap_or(328_608.0);
    let n4 = 935_648.0;
    for (algo, fit) in fit_our_loss_laws(store) {
        if let Some(f) = fit {
            writeln!(
                s,
                "{algo},{:.4},{:.5},{:.4},{:.4}",
                f.a,
                f.alpha,
                f.predict(n3),
                f.predict(n4)
            )
            .unwrap();
        }
    }
    writeln!(s, "\n## Measured extrapolation points (if run)\n").unwrap();
    writeln!(s, "model,algo,eval_loss").unwrap();
    for model in ["m3", "m4"] {
        for algo in ALGOS {
            if let Some(r) = store.best(|r| r.model == model && r.algo == algo) {
                writeln!(s, "{model},{algo},{:.4}", r.final_eval_loss).unwrap();
            }
        }
    }
    writeln!(s, "\n## Paper: fits on 35M-2.4B predict 4B/10B losses within a few %\n").unwrap();
    for (algo, fit) in super::tables::fit_paper_loss_laws() {
        let p4 = fit.predict(4e9);
        let p10 = fit.predict(10e9);
        let (m4, m10) = match algo.as_str() {
            "dp" => (Some(2.224), Some(2.090)),
            "diloco-m1" => (Some(2.219), Some(2.086)),
            "diloco-m2" => (Some(2.220), Some(2.086)),
            "diloco-m4" => (Some(2.230), Some(2.096)),
            _ => (None, None),
        };
        writeln!(
            s,
            "{algo}: predict(4B)={p4:.3} (measured {}), predict(10B)={p10:.3} (measured {})",
            m4.map_or("—".into(), |v: f64| format!("{v:.3}")),
            m10.map_or("—".into(), |v: f64| format!("{v:.3}")),
        )
        .unwrap();
    }
    s
}

/// Fitted-law summary reused by examples and EXPERIMENTS.md.
pub fn law_summary(store: &SweepStore) -> Vec<(String, Option<PowerLaw>)> {
    fit_our_loss_laws(store)
}
