//! Training-loop policies: LR schedule (warmup + cosine) and the
//! paper's weight-decay rule lambda = 1/T. Plus the [`toy`] engine —
//! the deterministic host-math inner step shared by the CLI's `--toy`
//! mode, the loopback twin test, and the CI multi-process smoke.

pub mod schedule;
pub mod toy;
