//! Training-loop policies: LR schedule (warmup + cosine) and the
//! paper's weight-decay rule lambda = 1/T.

pub mod schedule;
