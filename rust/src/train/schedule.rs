//! Learning-rate schedule and weight-decay policy (paper section 3):
//! linear warmup then cosine decay to `final_frac` of peak, and
//! AdamW weight decay lambda = 1/T (Wang & Aitchison 2024), where T is
//! the run's total step count (which depends on batch size and token
//! budget — hence computed here at run setup, not baked into HLO).

#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub peak: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub final_frac: f64,
}

impl LrSchedule {
    /// Paper setup: 1000 warmup steps, cosine to 5% of peak. Mini-scale
    /// runs are much shorter than the paper's, so warmup is
    /// min(cap, frac*T) (DESIGN.md §3 substitution table).
    pub fn new(peak: f64, total_steps: usize, warmup_frac: f64,
               warmup_cap: usize, final_frac: f64) -> LrSchedule {
        let warmup = ((total_steps as f64 * warmup_frac) as usize)
            .min(warmup_cap)
            .max(1);
        LrSchedule {
            peak,
            warmup_steps: warmup,
            total_steps: total_steps.max(1),
            final_frac,
        }
    }

    /// LR for 1-based step `t` in [1, total_steps].
    pub fn lr(&self, t: usize) -> f64 {
        let t = t.max(1);
        if t <= self.warmup_steps {
            return self.peak * t as f64 / self.warmup_steps as f64;
        }
        if t >= self.total_steps {
            return self.peak * self.final_frac;
        }
        let progress = (t - self.warmup_steps) as f64
            / (self.total_steps - self.warmup_steps) as f64;
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
        self.peak * (self.final_frac + (1.0 - self.final_frac) * cos)
    }
}

/// lambda = 1/T (decoupled weight decay, per the paper).
pub fn weight_decay(total_steps: usize) -> f64 {
    1.0 / total_steps.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> LrSchedule {
        LrSchedule::new(1e-2, 1000, 0.1, 1000, 0.05)
    }

    #[test]
    fn warmup_is_linear() {
        let s = sched();
        assert_eq!(s.warmup_steps, 100);
        assert!((s.lr(50) - 0.5 * s.peak).abs() < 1e-12);
        assert!((s.lr(100) - s.peak).abs() < 1e-12);
    }

    #[test]
    fn decays_to_final_frac() {
        let s = sched();
        assert!((s.lr(1000) - 0.05 * s.peak).abs() < 1e-9);
        assert!(s.lr(1_000_000) == 0.05 * s.peak);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = sched();
        let mut prev = s.lr(s.warmup_steps);
        for t in s.warmup_steps + 1..=s.total_steps {
            let cur = s.lr(t);
            assert!(cur <= prev + 1e-15, "t={t}");
            prev = cur;
        }
    }

    #[test]
    fn warmup_cap_applies() {
        let s = LrSchedule::new(1e-2, 100_000, 0.1, 1000, 0.05);
        assert_eq!(s.warmup_steps, 1000);
    }

    #[test]
    fn wd_is_inverse_t() {
        assert_eq!(weight_decay(200), 0.005);
        assert_eq!(weight_decay(0), 1.0);
    }
}
