//! The toy engine: a deterministic host-math stand-in for the PJRT
//! inner step, promoted from the test suite into the crate so the CLI
//! (`--toy`), the loopback twin test, and the CI multi-process smoke
//! all drive the *same* engine.
//!
//! The update mixes the replica's private token shard with the step
//! index, entirely in host f32 math; the loss is a pure function of
//! the post-step state. No PJRT, no artifacts — it runs in any
//! environment, which is exactly what a CI job spawning three OS
//! processes needs. Determinism is total: replica init is pure in the
//! run seed, shards are pure in `(seed, replica id)`, and the step is
//! pure in `(replica id, state, t)` — so two processes that agree on
//! the handshake config cannot disagree on a single bit.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{InnerEngine, ReplicaState};
use crate::data::synthetic::{CorpusSpec, TokenStream};
use crate::runtime::{FlatLayout, HostTensor};

/// The toy model's fixed parameter layout: five small leaves (17
/// scalars total) — enough shape variety to exercise fragment ranges
/// and literal rebuilds while staying trivially cheap.
pub fn toy_layout() -> Arc<FlatLayout> {
    Arc::new(FlatLayout::new(vec![
        vec![3, 2],
        vec![4],
        vec![2, 2],
        vec![5],
        vec![1],
    ]))
}

/// The shared init literals, pure in `(layout, seed)` — every replica
/// (on every process) starts from this view, like Algorithm 1 line 2.
pub fn toy_init(layout: &FlatLayout, seed: u64) -> Result<Vec<Arc<xla::Literal>>> {
    (0..layout.n_leaves())
        .map(|l| {
            let v: Vec<f32> = (0..layout.len(l))
                .map(|i| {
                    let h = (l as u64)
                        .wrapping_mul(37)
                        .wrapping_add(i as u64 * 11)
                        .wrapping_add(seed.wrapping_mul(7) + 5);
                    (h % 23) as f32 * 0.1 - 1.0
                })
                .collect();
            Ok(Arc::new(
                HostTensor::from_vec(layout.shape(l), v).to_literal()?,
            ))
        })
        .collect()
}

/// Build replica states `first..last` (half-open) of an `m`-replica
/// universe. A remote worker calls this with just its owned range;
/// shard streams are per-replica pure, so partial construction is
/// bit-identical to slicing the full set.
pub fn toy_replicas(
    layout: &FlatLayout,
    range: std::ops::Range<usize>,
    seed: u64,
) -> Result<Vec<ReplicaState>> {
    let init = toy_init(layout, seed)?;
    Ok(range
        .map(|r| ReplicaState {
            state: init.clone(),
            shard: TokenStream::new(CorpusSpec::default(), seed, r as u64),
        })
        .collect())
}

/// Build replica states for an explicit id set (remote workers own
/// arbitrary claims, not necessarily a contiguous range).
pub fn toy_replicas_for(
    layout: &FlatLayout,
    rids: &[usize],
    seed: u64,
) -> Result<Vec<ReplicaState>> {
    let init = toy_init(layout, seed)?;
    Ok(rids
        .iter()
        .map(|&r| ReplicaState {
            state: init.clone(),
            shard: TokenStream::new(CorpusSpec::default(), seed, r as u64),
        })
        .collect())
}

/// The deterministic host-math inner engine (see module docs).
pub struct ToyEngine {
    n_leaves: usize,
}

impl ToyEngine {
    pub fn new(layout: &FlatLayout) -> ToyEngine {
        ToyEngine {
            n_leaves: layout.n_leaves(),
        }
    }
}

impl InnerEngine for ToyEngine {
    fn inner_step(&self, rep: usize, replica: &mut ReplicaState, t: usize) -> Result<f64> {
        let toks = replica.shard.next_batch(2, 8);
        let mut loss = 0.0f64;
        for leaf in 0..self.n_leaves {
            let lit = &replica.state[leaf];
            let dims = lit.array_shape()?.dims().to_vec();
            let mut v = lit.to_vec::<f32>()?;
            for (i, x) in v.iter_mut().enumerate() {
                *x = 0.5 * *x
                    + 1e-3 * toks[(i + t) % toks.len()] as f32
                    + 1e-2 * (t as f32 + rep as f32 * 0.25).sin();
            }
            loss += v.iter().map(|&f| f as f64).sum::<f64>() / v.len() as f64;
            replica.state[leaf] = Arc::new(xla::Literal::vec1(&v).reshape(&dims)?);
        }
        Ok(loss / self.n_leaves as f64)
    }

    /// Deterministic digest of the parameter literals — a weighted sum
    /// so leaf order matters (any mixed-up rebuild changes the curve).
    fn eval(&self, params: &[Arc<xla::Literal>]) -> Result<f64> {
        let mut acc = 0.0f64;
        for (i, p) in params.iter().enumerate() {
            for x in p.to_vec::<f32>()? {
                acc += x as f64 * (i + 1) as f64;
            }
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_pure_in_seed() {
        let l = toy_layout();
        let a = toy_init(&l, 9).unwrap();
        let b = toy_init(&l, 9).unwrap();
        let c = toy_init(&l, 10).unwrap();
        for leaf in 0..l.n_leaves() {
            assert_eq!(
                a[leaf].to_vec::<f32>().unwrap(),
                b[leaf].to_vec::<f32>().unwrap()
            );
        }
        assert_ne!(
            a[0].to_vec::<f32>().unwrap(),
            c[0].to_vec::<f32>().unwrap()
        );
    }

    #[test]
    fn partial_replica_sets_match_the_full_universe() {
        let l = toy_layout();
        let full = toy_replicas(&l, 0..4, 7).unwrap();
        let tail = toy_replicas_for(&l, &[2, 3], 7).unwrap();
        let engine = ToyEngine::new(&l);
        let mut a = full.into_iter().nth(2).unwrap();
        let mut b = tail.into_iter().next().unwrap();
        for t in 1..=3 {
            let la = engine.inner_step(2, &mut a, t).unwrap();
            let lb = engine.inner_step(2, &mut b, t).unwrap();
            assert_eq!(la.to_bits(), lb.to_bits());
        }
    }

    #[test]
    fn steps_are_deterministic() {
        let l = toy_layout();
        let engine = ToyEngine::new(&l);
        let mut a = toy_replicas(&l, 0..1, 3).unwrap().remove(0);
        let mut b = toy_replicas(&l, 0..1, 3).unwrap().remove(0);
        for t in 1..=5 {
            let la = engine.inner_step(0, &mut a, t).unwrap();
            let lb = engine.inner_step(0, &mut b, t).unwrap();
            assert_eq!(la.to_bits(), lb.to_bits());
        }
        let ea = engine.eval(&a.state).unwrap();
        let eb = engine.eval(&b.state).unwrap();
        assert_eq!(ea.to_bits(), eb.to_bits());
    }
}
