//! Command-line interface (clap is unavailable offline): a small
//! subcommand + `--flag value` parser and the `diloco` entrypoints.

pub mod args;
pub mod remote;

use anyhow::{bail, Context, Result};

use crate::config::RepoConfig;
use crate::coordinator::{run, run_checkpoint, run_resume, Algo, RunConfig};
use crate::runtime::{ModelRuntime, Runtime};
use crate::sweep::{execute_grid, grid_by_name, grid_names, run_id, SweepStore};

use args::Args;

pub const USAGE: &str = "\
diloco — Scaling Laws for DiLoCo (reproduction)

USAGE:
  diloco train   [--model m0] [--algo dp|diloco-mK] [--h 30] [--batch 16]
                 [--lr 6e-3] [--eta 0.8] [--budget TOKENS] [--overtrain X]
                 [--seed N] [--eval-every K] [--downstream] [--fragments P]
                 [--workers W]   # replica-parallel inner loop; 1 = sequential
                 [--sync-threads N]  # coordinator reduce/outer-step threads (0 = match --workers); bit-identical at any N
                 [--overlap-tau T]  # delayed application: merge a fragment's broadcast T steps after its send (0 = barrier; requires T < H/P)
                 [--outer-bits 32|16|8|4]       # up-wire width: outer gradients (32 = exact fp32)
                 [--outer-bits-down 32|16|8|4]  # down-wire width: global broadcast (32 = literal handoff)
                 [--churn SPEC]  # deterministic fault plan, e.g. \"crash@2:r1,join@3:r4\" or \"rate=0.1\"
                 [--verbose]  # per-sync stage latency lines on stderr (enc/wire/reduce/step/bcast)
  diloco checkpoint --after-sync K [--out runs/ckpt.json] [train flags...]
                                    # run until outer sync K completes, snapshot, stop
  diloco resume  --from runs/ckpt.json   # finish the run; bit-identical to uninterrupted
  diloco coordinate --toy --expect M [--listen 127.0.0.1:7700] [--steps T]
                    [train flags...]  # multi-process coordinator: waits for M
                                      # workers, drives the run over their sockets.
                                      # --expect 0 = in-process oracle, same final line
  diloco worker  --connect HOST:PORT --replicas SPEC   # e.g. 0..2 or 1,3
                 [--verify-config [train flags...]]  # default: adopt coordinator config
  diloco predict --n PARAMS [--m REPLICAS] [--store runs/sweep.jsonl]
  diloco sweep   --grid NAME [--store runs/sweep.jsonl] [--max-runs N]
  diloco grids                      # list available sweep grids
  diloco report  [--exp all|table4|...] [--store runs/sweep.jsonl]
                 [--out reports/]
  diloco simulate utilization|walltime [--out reports/]
  diloco bench-diff OLD.json NEW.json [--max-regress-pct P]
                                    # per-case deltas between BENCH_*.json
                 [--tight-cases SUB,SUB] [--tight-pct P]  # stricter cap for cases whose name contains any SUB

Artifacts must exist (make artifacts) for train/sweep.";

pub fn dispatch(argv: &[String]) -> Result<()> {
    let (cmd, args) = Args::parse(argv)?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "checkpoint" => cmd_checkpoint(&args),
        "resume" => cmd_resume(&args),
        "sweep" => cmd_sweep(&args),
        "grids" => {
            for g in grid_names() {
                let n = if g == "all" {
                    grid_by_name(g)?.len()
                } else {
                    grid_by_name(g).map(|v| v.len()).unwrap_or(0)
                };
                println!("{g:<12} {n} runs");
            }
            Ok(())
        }
        "coordinate" => remote::cmd_coordinate(&args),
        "worker" => remote::cmd_worker(&args),
        "report" => crate::report::cmd_report(&args),
        "simulate" => crate::report::cmd_simulate(&args),
        "predict" => cmd_predict(&args),
        "bench-diff" => cmd_bench_diff(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn run_config_from_args(args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig {
        model: args.get_or("model", "m0"),
        ..Default::default()
    };
    if let Some(a) = args.get("algo") {
        cfg.algo = Algo::parse(&a)?;
    }
    if let Some(h) = args.get("h") {
        cfg.sync_every = h.parse().context("--h")?;
    }
    if let Some(b) = args.get("batch") {
        cfg.global_batch_seqs = b.parse().context("--batch")?;
    }
    if let Some(lr) = args.get("lr") {
        cfg.inner_lr = lr.parse().context("--lr")?;
    }
    if let Some(eta) = args.get("eta") {
        cfg.outer_lr = eta.parse().context("--eta")?;
    }
    if let Some(b) = args.get("budget") {
        cfg.token_budget = Some(b.parse().context("--budget")?);
    }
    if let Some(ot) = args.get("overtrain") {
        cfg.overtrain = ot.parse().context("--overtrain")?;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s.parse().context("--seed")?;
    }
    if let Some(k) = args.get("eval-every") {
        cfg.eval_every = Some(k.parse().context("--eval-every")?);
    }
    if let Some(p) = args.get("fragments") {
        cfg.streaming_fragments = p.parse().context("--fragments")?;
    }
    if let Some(t) = args.get("overlap-tau") {
        cfg.overlap_tau = t.parse().context("--overlap-tau")?;
    }
    if let Some(w) = args.get("workers") {
        cfg.workers = w.parse().context("--workers")?;
    }
    if let Some(n) = args.get("sync-threads") {
        cfg.sync_threads = n.parse().context("--sync-threads")?;
    }
    if let Some(ob) = args.get("outer-bits") {
        cfg.outer_bits = crate::comm::OuterBits::parse(&ob).context("--outer-bits")?;
    }
    if let Some(obd) = args.get("outer-bits-down") {
        cfg.outer_bits_down =
            crate::comm::OuterBits::parse(&obd).context("--outer-bits-down")?;
    }
    if let Some(c) = args.get("churn") {
        cfg.churn = c;
    }
    cfg.downstream = args.flag("downstream");
    cfg.verbose = args.flag("verbose");
    Ok(cfg)
}

/// Diff two machine-readable bench reports (`BENCH_*.json`) and print
/// per-case deltas; with `--max-regress-pct P` exit non-zero when any
/// case slowed down by more than P percent (CI regression gate).
/// `--tight-cases SUB,SUB --tight-pct Q` applies the stricter cap Q to
/// cases whose name contains any comma-separated substring — the hot
/// codec/reduce kernels hold a tighter line than end-to-end drives.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    use crate::util::bench::{diff_reports, print_diff};
    use crate::util::json::Json;
    if args.positional.len() != 2 {
        bail!(
            "usage: diloco bench-diff OLD.json NEW.json [--max-regress-pct P] \
             [--tight-cases SUB,SUB --tight-pct P]"
        );
    }
    let old = Json::parse_file(std::path::Path::new(&args.positional[0]))?;
    let new = Json::parse_file(std::path::Path::new(&args.positional[1]))?;
    let deltas = diff_reports(&old, &new)?;
    print_diff(&deltas);
    if let Some(p) = args.get("max-regress-pct") {
        let cap: f64 = p.parse().context("--max-regress-pct")?;
        let worst = deltas
            .iter()
            .filter_map(|d| d.delta_pct())
            .fold(0.0f64, f64::max);
        if worst > cap {
            bail!("bench regression {worst:.1}% exceeds --max-regress-pct {cap}%");
        }
    }
    if let Some(subs) = args.get("tight-cases") {
        let cap: f64 = args
            .get("tight-pct")
            .ok_or_else(|| anyhow::anyhow!("--tight-cases requires --tight-pct"))?
            .parse()
            .context("--tight-pct")?;
        let subs: Vec<&str> = subs.split(',').filter(|s| !s.is_empty()).collect();
        for d in &deltas {
            let Some(pct) = d.delta_pct() else { continue };
            if pct > cap && subs.iter().any(|s| d.name.contains(s)) {
                bail!(
                    "bench regression {pct:.1}% on tight case {:?} exceeds \
                     --tight-pct {cap}%",
                    d.name
                );
            }
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let repo = RepoConfig::load_default()?;
    let cfg = run_config_from_args(args)?;
    let rt = Runtime::cpu()?;
    let mr = ModelRuntime::load(rt, &repo.model_dir(&cfg.model))?;
    let metrics = run(&mr, &repo.optimizer, &cfg)?;
    println!("{}", metrics.to_json().to_string_pretty());
    Ok(())
}

/// Run the configured job until outer sync K has merged, then snapshot
/// replicas, outer state, wire accounting, and the event journal to a
/// JSON checkpoint and stop. `diloco resume --from FILE` finishes the
/// run bit-identically to the uninterrupted trajectory.
fn cmd_checkpoint(args: &Args) -> Result<()> {
    let repo = RepoConfig::load_default()?;
    let cfg = run_config_from_args(args)?;
    let after: u64 = args
        .get("after-sync")
        .context("--after-sync K required")?
        .parse()
        .context("--after-sync")?;
    let out = std::path::PathBuf::from(args.get_or("out", "runs/ckpt.json"));
    let out = if out.is_absolute() { out } else { repo.root.join(out) };
    let rt = Runtime::cpu()?;
    let mr = ModelRuntime::load(rt, &repo.model_dir(&cfg.model))?;
    let step = run_checkpoint(&mr, &repo.optimizer, &cfg, after, &out)?;
    println!(
        "checkpointed at step {step} (outer sync {after} merged) -> {}",
        out.display()
    );
    Ok(())
}

fn cmd_resume(args: &Args) -> Result<()> {
    use crate::util::json::Json;
    let repo = RepoConfig::load_default()?;
    let from = args.get("from").context("--from CKPT.json required")?;
    let path = std::path::PathBuf::from(&from);
    let path = if path.is_absolute() { path } else { repo.root.join(path) };
    // Peek the embedded config for the model name; run_resume re-reads
    // and validates the full checkpoint.
    let model = Json::parse_file(&path)?
        .get("config")
        .and_then(|c| c.get("model"))
        .and_then(|m| m.as_str())
        .map(str::to_string)
        .context("checkpoint carries no config.model (not written by `diloco checkpoint`?)")?;
    let rt = Runtime::cpu()?;
    let mr = ModelRuntime::load(rt, &repo.model_dir(&model))?;
    let metrics = run_resume(&mr, &repo.optimizer, &path)?;
    println!("{}", metrics.to_json().to_string_pretty());
    Ok(())
}

/// The paper's practical payoff (section 6.4): predict loss and optimal
/// hyperparameters for a model size you have NOT trained, from the
/// scaling laws fit to the sweep store — exactly how the paper set the
/// 4B/10B hyperparameters without tuning.
fn cmd_predict(args: &Args) -> Result<()> {
    use crate::report::tables::{fit_our_loss_laws, fit_paper_loss_laws};
    let repo = RepoConfig::load_default()?;
    let n: f64 = args
        .get("n")
        .context("--n PARAMS required")?
        .parse()
        .context("--n")?;
    let m: f64 = args.get_or("m", "1").parse().context("--m")?;
    let store = SweepStore::open(&repo.root.join(args.get_or("store", "runs/sweep.jsonl")))?;

    println!("== predictions for N={n:.3e}, M={m} ==\n");
    println!("from OUR mini-ladder fits (runs/sweep.jsonl, {} runs):", store.len());
    let algo = if m <= 1.0 { "diloco-m1".to_string() } else { format!("diloco-m{}", m as usize) };
    for (a, fit) in fit_our_loss_laws(&store) {
        if a == "dp" || a == algo {
            match fit {
                Some(f) => println!("  {a:<10} predicted eval loss {:.4}  (L ~ {:.3} * N^{:.4})", f.predict(n), f.a, f.alpha),
                None => println!("  {a:<10} (not enough ladder data yet)"),
            }
        }
    }
    // joint fit over DiLoCo observations
    let obs = crate::report::tables::our_joint_obs(&store);
    if obs.len() >= 4 {
        let ns: Vec<f64> = obs.iter().map(|o| o.n).collect();
        let ms: Vec<f64> = obs.iter().map(|o| o.m).collect();
        let ys: Vec<f64> = obs.iter().map(|o| o.loss).collect();
        if let Ok(j) = crate::scaling::JointFit::fit(&ns, &ms, &ys) {
            println!("  joint      predicted eval loss {:.4}  (L ~ {:.3} * N^{:.4} * M^{:.4})",
                j.predict(n, m.max(1.0)), j.a, j.alpha, j.beta);
        }
    }
    println!("\nfrom the PAPER's fitted laws (Tables 7-10, C4 scale):");
    for (a, fit) in fit_paper_loss_laws() {
        if a == "dp" || a == algo {
            println!("  {a:<10} predicted eval loss {:.4}", fit.predict(n));
        }
    }
    for (label, pa, palpha) in crate::report::paperdata::TABLE8 {
        if label == algo || label == "dp" {
            println!("  {label:<10} optimal inner LR ~ {:.3e}", pa * n.powf(palpha));
        }
    }
    for (label, pa, palpha) in crate::report::paperdata::TABLE9 {
        if label == algo || label == "dp" {
            println!("  {label:<10} optimal global batch ~ {:.3e} tokens", pa * n.powf(palpha));
        }
    }
    let (_, a, al, be) = crate::report::paperdata::TABLE10[1];
    println!("  joint      optimal inner LR ~ {:.3e} (A*N^a*M^b)", a * n.powf(al) * m.max(1.0).powf(be));
    let (_, a, al, be) = crate::report::paperdata::TABLE10[2];
    println!("  joint      optimal global batch ~ {:.3e} tokens", a * n.powf(al) * m.max(1.0).powf(be));
    println!("\n(outer LR: constant in N — use the best eta for this M; paper Fig 7)");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let repo = RepoConfig::load_default()?;
    let grid_name = args
        .get("grid")
        .context("--grid required (see `diloco grids`)")?;
    let grid = grid_by_name(&grid_name)?;
    let store_path = repo
        .root
        .join(args.get_or("store", "runs/sweep.jsonl"));
    let mut store = SweepStore::open(&store_path)?;
    let max_runs = args
        .get("max-runs")
        .map(|v| v.parse::<usize>())
        .transpose()
        .context("--max-runs")?;
    if args.flag("dry-run") {
        for cfg in &grid {
            let done = if store.contains(&run_id(cfg)) { "done" } else { "todo" };
            println!("{done}  {}", run_id(cfg));
        }
        return Ok(());
    }
    let n = execute_grid(&repo, &mut store, &grid, max_runs)?;
    println!("completed {n} runs; store now has {}", store.len());
    Ok(())
}
