//! Tiny argument parser: `<command> [--key value|--flag]...`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Split argv into (subcommand, parsed flags). argv excludes argv[0].
    pub fn parse(argv: &[String]) -> Result<(String, Args)> {
        if argv.is_empty() {
            return Ok(("help".into(), Args::default()));
        }
        let cmd = argv[0].clone();
        let mut args = Args::default();
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(key) = tok.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.values.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    args.values.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(key.to_string());
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                bail!("short flags not supported: {tok}");
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok((cmd, args))
    }

    pub fn get(&self, key: &str) -> Option<String> {
        self.values.get(key).cloned()
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.values.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_kv() {
        let (cmd, a) = Args::parse(&sv(&["train", "--model", "m2", "--lr=3e-3"])).unwrap();
        assert_eq!(cmd, "train");
        assert_eq!(a.get("model").unwrap(), "m2");
        assert_eq!(a.get("lr").unwrap(), "3e-3");
    }

    #[test]
    fn bare_flags_and_positionals() {
        let (_, a) =
            Args::parse(&sv(&["simulate", "utilization", "--downstream", "--out", "x"])).unwrap();
        assert_eq!(a.positional, vec!["utilization"]);
        assert!(a.flag("downstream"));
        assert_eq!(a.get_or("out", "y"), "x");
        assert!(!a.flag("missing"));
    }

    #[test]
    fn empty_is_help() {
        let (cmd, _) = Args::parse(&[]).unwrap();
        assert_eq!(cmd, "help");
    }

    #[test]
    fn rejects_short_flags() {
        assert!(Args::parse(&sv(&["x", "-q"])).is_err());
    }

    #[test]
    fn negative_number_as_value() {
        // "--delta -3" would be ambiguous; "--delta=-3" works.
        let (_, a) = Args::parse(&sv(&["x", "--delta=-3"])).unwrap();
        assert_eq!(a.get("delta").unwrap(), "-3");
    }
}
