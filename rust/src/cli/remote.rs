//! Multi-process verbs: `diloco coordinate` and `diloco worker`.
//!
//! The coordinator binds a TCP listener, waits for `--expect` workers
//! to hand-shake (each claiming a disjoint replica set that must tile
//! the universe), then runs the exact same `coordinate()` schedule the
//! in-process driver uses — over [`TcpLane`]s instead of channels. A
//! worker connects (with bounded-backoff retries), adopts the
//! coordinator's config from the `Welcome` frame, rebuilds engine,
//! replicas, and comm link locally, and loops in
//! [`worker_session`] until `Finish` or the socket closes.
//!
//! Remote runs are `--toy` only today: the PJRT engine needs per-host
//! compiled artifacts and a model manifest, which the handshake does
//! not ship (the `ENGINE_PJRT` tag in the frame header reserves the
//! slot). The toy engine is fully deterministic in the handshake
//! config, which is the property the loopback twin test and the CI
//! smoke pin: a coordinator plus N worker processes must be
//! bit-identical to the single-process in-proc run.
//!
//! `--expect 0` short-circuits the sockets entirely and runs the
//! in-process oracle on the same config, printing the same `final:`
//! line — CI launches both and diffs the two lines.

use std::net::TcpListener;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::comm::{CommLink, ReplicaComm, WorkerComm};
use crate::coordinator::{
    drive_ctl, drive_reactor, parse_replica_set, worker_session, Algo, DriveCtl, DrivePlan,
    EventKind, FaultPlan, Membership, OuterSync, OwnedReplica, RunConfig,
};
use crate::runtime::{FlatLayout, HostTensor};
use crate::train::toy::{toy_init, toy_layout, toy_replicas, toy_replicas_for, ToyEngine};
use crate::transport::frame::fnv1a64;
use crate::transport::tcp::{
    accept_workers, connect_with_backoff, worker_handshake, LaneReactor, SessionInfo,
    TcpWorkerLink, CONNECT_ATTEMPTS, ENGINE_TOY,
};
use crate::util::json::Json;

use super::args::Args;
use super::run_config_from_args;

/// Outer Nesterov momentum for toy remote runs. Coordinator-side state
/// only (workers never see it), pinned so the oracle and the TCP run
/// can't drift through a default change.
const TOY_OUTER_MOMENTUM: f64 = 0.9;

/// The config envelope shipped in the `Welcome` frame and fingerprinted
/// by the handshake: the full [`RunConfig`] JSON plus the fields a
/// worker cannot derive from it (step count, engine tag). Key order is
/// fixed here so a `--verify-config` worker rebuilding the envelope
/// from its own flags lands on the same fingerprint bytes.
pub fn toy_envelope(cfg: &RunConfig, steps: usize) -> String {
    Json::obj(vec![
        ("engine", Json::str("toy")),
        ("steps", Json::int(steps as u64)),
        ("run", cfg.to_json()),
    ])
    .to_string()
}

/// Everything the toy coordinator derives from the run config before
/// any socket opens — mirrors `prepare()`'s schedule math so remote
/// runs honor fragments, overlap, and churn exactly like `train`.
struct ToySchedule {
    universe: usize,
    frag_interval: usize,
    fragments: usize,
    plan_events: Vec<crate::coordinator::FaultEvent>,
    live: Vec<bool>,
}

fn toy_schedule(cfg: &RunConfig, steps: usize) -> Result<ToySchedule> {
    let m = match cfg.algo {
        Algo::DiLoCo { replicas } => replicas,
        Algo::DataParallel => {
            bail!("remote runs need --algo diloco-mK (Data-Parallel has no outer sync to ship)")
        }
    };
    if m == 0 {
        bail!("--algo diloco-m0: at least one replica required");
    }
    if steps == 0 {
        bail!("--steps 0: nothing to run");
    }
    let h = cfg.sync_every.max(1);
    let fragments = cfg.streaming_fragments.max(1);
    if fragments > 1 && h % fragments != 0 {
        bail!("streaming fragments P={fragments} must divide H={h}");
    }
    let frag_interval = if fragments > 1 { h / fragments } else { h };
    if cfg.overlap_tau > 0 && cfg.overlap_tau >= frag_interval {
        bail!(
            "--overlap-tau {} needs tau < H/P = {frag_interval}",
            cfg.overlap_tau
        );
    }
    let n_sends = ((steps - 1) / frag_interval + 1) as u64;
    let fault_plan = FaultPlan::parse(&cfg.churn, cfg.seed)?;
    let universe = fault_plan.universe(m);
    let plan_events = fault_plan.resolve(m, n_sends);
    let live = Membership::initial(universe, m).flags().to_vec();
    Ok(ToySchedule {
        universe,
        frag_interval,
        fragments,
        plan_events,
        live,
    })
}

/// Build the coordinator-side outer engine over the toy layout with the
/// run's codecs attached — shared by the oracle and the TCP path.
fn toy_outer_sync(layout: &Arc<FlatLayout>, cfg: &RunConfig, fragments: usize) -> Result<OuterSync> {
    use crate::comm::codec_for;
    let init_lits = toy_init(layout, cfg.seed)?;
    let host: Vec<HostTensor> = init_lits
        .iter()
        .map(|l| HostTensor::from_literal(l))
        .collect::<Result<_>>()?;
    Ok(OuterSync::new(
        Arc::clone(layout),
        &host,
        init_lits,
        cfg.outer_lr,
        TOY_OUTER_MOMENTUM,
        fragments,
    )?
    .with_sync_threads(cfg.sync_threads.max(1))
    .with_codec(codec_for(cfg.outer_bits), cfg.seed)
    .with_down_codec(codec_for(cfg.outer_bits_down))
    .with_verbose(cfg.verbose))
}

/// The one line CI diffs between the `--expect 0` oracle and the real
/// multi-process run. Everything in it must be transport-invariant:
/// losses, sync count, and wire accounting — never socket facts.
fn print_final(cfg: &RunConfig, steps: usize, train: f64, eval: f64, syncs: usize, sync: &OuterSync) {
    let w = sync.wire_stats();
    println!(
        "final: algo={} steps={steps} train_loss={train:.12e} eval_loss={eval:.12e} \
         syncs={syncs} wire_up={} wire_down={} framed={}",
        cfg.algo.label(),
        w.total_up(),
        w.total_down(),
        w.total_framed(),
    );
}

fn print_journal(ctl: &DriveCtl) {
    for ev in ctl.journal.events() {
        match ev.kind {
            EventKind::Crash | EventKind::Join | EventKind::Leave | EventKind::Straggle => {
                let r = ev.replica.map(|r| format!("r{r}")).unwrap_or_default();
                println!(
                    "journal: {} {r} at step {} sync {} ({})",
                    ev.kind.label(),
                    ev.step,
                    ev.sync,
                    ev.detail
                );
            }
            _ => {}
        }
    }
}

/// `diloco coordinate --toy --expect M [--listen ADDR] [--steps T]
/// [train flags...]` — bind, hand-shake M workers, drive the run over
/// their lanes. `--expect 0` runs the in-process oracle instead.
pub fn cmd_coordinate(args: &Args) -> Result<()> {
    if !args.flag("toy") {
        bail!(
            "diloco coordinate currently requires --toy: the PJRT engine needs per-host \
             artifacts the handshake does not ship (the frame header reserves an engine \
             tag for when it does)"
        );
    }
    let cfg = run_config_from_args(args)?;
    let steps: usize = args.get_or("steps", "24").parse().context("--steps")?;
    let expect: usize = args.get_or("expect", "1").parse().context("--expect")?;
    let sched = toy_schedule(&cfg, steps)?;

    let layout = toy_layout();
    let engine = ToyEngine::new(&layout);
    let mut sync = toy_outer_sync(&layout, &cfg, sched.fragments)?;
    let mut ctl = DriveCtl::fresh(sched.universe);
    ctl.events = sched.plan_events;
    ctl.live = sched.live;
    let mut plan = DrivePlan {
        total_steps: steps,
        sync_interval: sched.frag_interval,
        fragments: sched.fragments,
        n_params: layout.n_leaves(),
        eval_every: cfg.eval_every,
        log_every: cfg.log_every.max(1),
        workers: 1,
        overlap_tau: cfg.overlap_tau,
    };

    let outcome = if expect == 0 {
        // In-process oracle on the identical schedule: same final line,
        // no sockets. CI runs this next to the real thing and diffs.
        let mut replicas = toy_replicas(&layout, 0..sched.universe, cfg.seed)?;
        drive_ctl(&engine, &mut replicas, Some(&mut sync), &plan, &mut ctl)?
    } else {
        let envelope = toy_envelope(&cfg, steps);
        let info = SessionInfo {
            fingerprint: fnv1a64(envelope.as_bytes()),
            up_bits: cfg.outer_bits.bits() as u8,
            down_bits: cfg.outer_bits_down.bits() as u8,
            engine: ENGINE_TOY,
            live: ctl.live.clone(),
            config_json: envelope,
        };
        let listen = args.get_or("listen", "127.0.0.1:7700");
        let listener = TcpListener::bind(&listen)
            .with_context(|| format!("coordinate: binding {listen}"))?;
        println!("coordinate: listening on {}", listener.local_addr()?);
        let lanes = accept_workers(&listener, expect, &info)?;
        for (i, (_, rids)) in lanes.iter().enumerate() {
            println!("coordinate: worker {i} owns replicas {rids:?}");
        }
        plan.workers = lanes.len();
        // One poll loop over every lane — not one reader thread each.
        let mut reactor = LaneReactor::new(lanes)?;
        let outcome = drive_reactor(&engine, &mut reactor, Some(&mut sync), &plan, &mut ctl)?;
        // Socket facts (heartbeats) print on their own line, never in
        // the transport-invariant `final:` line CI diffs.
        println!(
            "transport: control_bytes={}",
            sync.wire_stats().control_bytes()
        );
        outcome
    };

    print_journal(&ctl);
    let eval = engine.eval(sync.global_literals()?)?;
    let train = outcome.step_losses.last().copied().unwrap_or(f64::NAN);
    print_final(&cfg, steps, train, eval, outcome.outer_syncs, &sync);
    Ok(())
}

/// `diloco worker --connect HOST:PORT --replicas SPEC [--verify-config
/// [train flags...]]` — connect with bounded backoff, adopt the
/// coordinator's config (or verify it against local flags), rebuild
/// the toy engine + replicas + comm link, and serve segments until
/// `Finish` or the socket closes.
pub fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.get("connect").context("--connect HOST:PORT required")?;
    let spec = args
        .get("replicas")
        .context("--replicas SPEC required (e.g. 0..2 or 1,3)")?;
    let claims = parse_replica_set(&spec)?;

    // Adopt by default: fingerprint 0 and zero widths tell the
    // coordinator "send me the truth". `--verify-config` instead
    // rebuilds the envelope from this process's own flags, so any
    // config drift between launch scripts dies in the handshake.
    let (fp, up, down) = if args.flag("verify-config") {
        let cfg = run_config_from_args(args)?;
        let steps: usize = args.get_or("steps", "24").parse().context("--steps")?;
        let envelope = toy_envelope(&cfg, steps);
        (
            fnv1a64(envelope.as_bytes()),
            cfg.outer_bits.bits() as u8,
            cfg.outer_bits_down.bits() as u8,
        )
    } else {
        (0, 0, 0)
    };

    let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS)?;
    let info = worker_handshake(&mut stream, &claims, fp, up, down)?;
    if info.engine != ENGINE_TOY {
        bail!(
            "coordinator runs engine tag {} but this build only serves toy remote runs",
            info.engine
        );
    }
    let envelope = Json::parse(&info.config_json)
        .map_err(|e| anyhow::anyhow!("worker: bad config envelope in Welcome: {e}"))?;
    let cfg = RunConfig::from_json(
        envelope
            .get("run")
            .context("worker: Welcome envelope has no \"run\" config")?,
    )?;

    let layout = toy_layout();
    let engine = ToyEngine::new(&layout);
    let n_params = layout.n_leaves();
    let reps = toy_replicas_for(&layout, &claims, cfg.seed)?;
    let mut owned: Vec<OwnedReplica> = claims
        .iter()
        .zip(reps)
        .map(|(&rid, rep)| OwnedReplica {
            rid,
            live: info.live.get(rid).copied().unwrap_or(false),
            rep,
            rc: ReplicaComm::default(),
        })
        .collect();

    // Rebuild the comm plane exactly like the in-process driver: size
    // the shared arenas from any owned replica's init state (Algorithm
    // 1 line 2 — all replicas enter equal to the global).
    let mut wc = WorkerComm::default();
    let link = CommLink::for_run(
        &layout,
        cfg.outer_bits,
        cfg.outer_bits_down,
        cfg.streaming_fragments.max(1),
        cfg.seed,
    );
    let link = if link.is_active() {
        let first = owned.first().context("worker: empty replica claim")?;
        link.init_snapshot(&mut wc, &first.rep.state)?;
        for o in &mut owned {
            link.init_replica(&mut o.rc);
        }
        Some(link)
    } else {
        None
    };

    println!("worker: serving replicas {claims:?} for {addr}");
    let mut wl = TcpWorkerLink::new(stream, &info)?;
    let (_owned, arena_bytes, finish) = worker_session(&engine, n_params, link, wc, owned, &mut wl);
    finish?;
    println!("worker: done (replicas {claims:?}, comm arena {arena_bytes} B)");
    Ok(())
}
