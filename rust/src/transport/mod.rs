//! Pluggable transport behind the worker↔coordinator comm plane.
//!
//! The coordinator drives training through pairs of abstract endpoints:
//! a [`Lane`] (coordinator side — one per worker) and a [`WorkerLink`]
//! (worker side). Everything that crosses them is a [`msg`] type —
//! segment commands, worker reports, membership churn — so the drive
//! loop in `coordinator::pool` is transport-agnostic: the schedule,
//! the reduction order, and therefore every loss and parameter bit are
//! decided above this layer.
//!
//! Two implementations:
//!
//! - [`inproc`] — `std::sync::mpsc` channels moving Rust values, the
//!   default and the bit-identity oracle. Zero-copy (`Arc` handoffs),
//!   zero serialization: exactly the pre-transport behavior.
//! - [`tcp`] — length-prefixed [`frame`]s over TCP sockets, so one run
//!   spans OS processes or machines (`diloco coordinate` /
//!   `diloco worker`). A versioned handshake rejects mismatched peers
//!   fail-loud; worker heartbeats plus per-lane patience clocks turn
//!   a dead peer into a journaled `Crash` instead of a hang. The
//!   coordinator drives every lane from one nonblocking poll loop
//!   ([`tcp::LaneReactor`]) rather than a reader thread per worker,
//!   and both legs run zero-copy in steady state: payloads serialize
//!   straight into recycled framed wire buffers and parse as slices
//!   of the frame they arrived in. The loopback twin test
//!   (`tests/transport_loopback.rs`) pins TCP runs bit-identical to
//!   in-proc runs.
//!
//! Error semantics are part of the contract:
//!
//! - `Lane::send` / `Lane::recv` **outer** errors mean the lane itself
//!   died (peer hung up, timed out, spoke garbage). The drive loop
//!   maps that to crash-membership semantics (remote mode) or fails
//!   the run (in-proc mode, where a vanished thread is a bug).
//! - `Lane::recv`'s **inner** `Result` is the worker's own verdict: a
//!   worker-reported engine error fails the run on every transport —
//!   a broken engine is never churn.

pub mod frame;
pub mod inproc;
pub mod msg;
pub mod tcp;

use anyhow::Result;

use msg::{Cmd, WorkerReport};

/// Coordinator-side endpoint of one worker connection.
pub trait Lane: Send {
    /// Ship one command. Takes the command by value so transports can
    /// move its buffers (`Spares` recycling) or serialize without a
    /// second copy. An error means the lane is dead.
    fn send(&mut self, cmd: Cmd) -> Result<()>;

    /// Block for the worker's next report (honoring any transport
    /// read-timeout). Outer `Err` = the lane died; inner `Err` = the
    /// worker reported an engine failure.
    fn recv(&mut self) -> Result<Result<WorkerReport>>;

    /// Non-blocking poll for a report: `Ok(Some(..))` = one is ready,
    /// `Ok(None)` = nothing yet, `Err` = the lane died. Lanes that
    /// can't poll keep the default and their callers fall back to
    /// blocking [`Lane::recv`] in slot order.
    fn try_recv(&mut self) -> Result<Option<Result<WorkerReport>>> {
        Ok(None)
    }

    /// Whether [`Lane::try_recv`] actually polls (readiness-driven
    /// collection is only worth the spin when it can observe arrivals).
    fn can_poll(&self) -> bool {
        false
    }
}

/// Worker-side endpoint of the coordinator connection.
pub trait WorkerLink {
    /// Block for the next command. `None` means the coordinator is
    /// gone (clean channel close, socket EOF, or an unrecoverable
    /// transport error) — the worker session ends quietly; the
    /// coordinator side is where failures are judged.
    fn recv_cmd(&mut self) -> Option<Cmd>;

    /// Ship a segment report (or the worker's own error). An error
    /// means the coordinator is gone.
    fn send_report(&mut self, report: Result<WorkerReport>) -> Result<()>;

    /// Whether this link ships streamed up-leg contributions
    /// ([`msg::MsgKind::ContribChunk`] frames ahead of the report).
    /// Links that don't stream keep the default and the session sends
    /// one-shot `SyncPayload::Encoded` reports instead.
    fn stream_contrib(&self) -> bool {
        false
    }

    /// Ship one encoded chunk of replica `rid`'s contribution to sync
    /// `sync_index` over `frag`, starting at wire-byte `offset` of the
    /// replica's payload. Chunks for one replica must be flushed in
    /// contiguous payload order; the report that follows (tagged
    /// `SyncPayload::Streamed`) closes the stream.
    fn send_contrib_chunk(
        &mut self,
        _rid: usize,
        _sync_index: u64,
        _frag: Option<usize>,
        _offset: usize,
        _chunk: &[u8],
    ) -> Result<()> {
        anyhow::bail!("this transport does not stream contributions")
    }
}
