//! TCP transport: length-prefixed [`frame`]s over sockets, so one run
//! spans OS processes (or machines).
//!
//! # Handshake
//!
//! Workers connect (with bounded backoff — racing the coordinator's
//! bind is expected, not an error) and send a `Hello` frame claiming a
//! replica set; the header carries the worker's run-config fingerprint
//! and codec widths when the operator passed train flags (0 =
//! "unspecified, adopt the coordinator's"). The coordinator validates
//! — protocol version (enforced by frame decoding itself), nonzero
//! fingerprint/width agreement, claim sanity (in-universe, disjoint,
//! and jointly covering every replica) — and answers `Welcome` (engine
//! kind + initial liveness + the authoritative run-config JSON) or
//! `Reject` (reason string), failing the run loudly on any mismatch:
//! a quietly divergent peer would poison every reduce it touches.
//!
//! # Liveness
//!
//! Each worker runs a heartbeat thread writing `Heartbeat` frames on a
//! fixed cadence (writes share a mutex with report frames, held across
//! the whole `write_all`, so frames never interleave). The coordinator
//! reads with a timeout a few heartbeats long: a dead or wedged worker
//! surfaces as a lane error within seconds, which the drive loop turns
//! into a journaled `Crash` with survivors continuing — never a hang.
//! Workers read commands without a timeout: a dead coordinator closes
//! the socket, which ends the session cleanly.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::frame::{read_frame, write_frame, FrameHeader, MsgKind};
use super::msg::{self, Cmd, WorkerReport};
use super::{Lane, WorkerLink};

/// Worker heartbeat cadence.
pub const HEARTBEAT_PERIOD: Duration = Duration::from_millis(500);
/// Coordinator read patience: this many heartbeats missed = dead peer.
pub const HEARTBEAT_PATIENCE: u32 = 6;
/// Handshake read timeout (a connecting peer that never says Hello).
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// Default connect attempts for [`connect_with_backoff`].
pub const CONNECT_ATTEMPTS: usize = 10;
/// First retry delay; doubles per attempt, capped at [`BACKOFF_CAP`].
pub const BACKOFF_START: Duration = Duration::from_millis(100);
pub const BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Engine kinds shipped in the Welcome payload.
pub const ENGINE_PJRT: u8 = 0;
pub const ENGINE_TOY: u8 = 1;

/// Connect to `addr`, retrying with bounded exponential backoff: a
/// worker launched alongside the coordinator routinely races its
/// `--listen` bind, so refused connections retry (100ms, 200ms, ...,
/// capped at 2s) up to `attempts` times before giving up with an
/// error naming the address and the attempt count.
pub fn connect_with_backoff(addr: &str, attempts: usize) -> Result<TcpStream> {
    let attempts = attempts.max(1);
    let mut delay = BACKOFF_START;
    let mut last_err: Option<std::io::Error> = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            std::thread::sleep(delay);
            delay = (delay * 2).min(BACKOFF_CAP);
        }
        match TcpStream::connect(addr) {
            Ok(stream) => {
                stream.set_nodelay(true).ok();
                return Ok(stream);
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(anyhow!(
        "could not connect to {addr} after {attempts} attempts: {}",
        last_err.expect("attempts >= 1 guarantees an error")
    ))
}

/// What both sides agree on after the handshake.
#[derive(Debug, Clone)]
pub struct SessionInfo {
    /// fnv1a64 of the canonical run-config JSON.
    pub fingerprint: u64,
    /// Up/down codec widths in bits (stamped on every data frame).
    pub up_bits: u8,
    pub down_bits: u8,
    /// Engine kind ([`ENGINE_PJRT`] / [`ENGINE_TOY`]).
    pub engine: u8,
    /// Initial liveness per universe slot (joiner slots dark).
    pub live: Vec<bool>,
    /// The coordinator's run config JSON — the source of truth every
    /// worker rebuilds its engine, replicas, and comm link from.
    pub config_json: String,
}

fn data_header(kind: MsgKind, info_fp: u64, up: u8, down: u8) -> FrameHeader {
    FrameHeader {
        kind,
        up_bits: up,
        down_bits: down,
        fingerprint: info_fp,
        sync_index: 0,
        frag: None,
    }
}

// ---- coordinator side -------------------------------------------------

/// Coordinator-side endpoint of one worker connection.
pub struct TcpLane {
    stream: TcpStream,
    header: FrameHeader,
    peer: String,
}

impl Lane for TcpLane {
    fn send(&mut self, cmd: Cmd) -> Result<()> {
        if matches!(cmd, Cmd::Spares(_)) {
            return Ok(()); // buffer recycling never crosses a socket
        }
        let mut payload = Vec::new();
        let kind = msg::cmd_payload(&cmd, &mut payload)?;
        let mut h = self.header.clone();
        h.kind = kind;
        // stamp the schedule position for wire-level observability
        if let Cmd::Run {
            payload: super::msg::PayloadSpec::Encoded(spec),
            ..
        } = &cmd
        {
            h.sync_index = spec.sync_index;
            h.frag = spec.frag.map(|f| f as u32);
        }
        write_frame(&mut self.stream, &h, &payload)
            .with_context(|| format!("tcp lane to {}", self.peer))
    }

    fn recv(&mut self) -> Result<Result<WorkerReport>> {
        loop {
            let (h, payload) = read_frame(&mut self.stream).with_context(|| {
                format!(
                    "tcp lane to {}: no frame within the read timeout \
                     ({HEARTBEAT_PATIENCE} heartbeats)",
                    self.peer
                )
            })?;
            match h.kind {
                MsgKind::Heartbeat => continue,
                MsgKind::Report => return Ok(Ok(msg::report_from_payload(&payload)?)),
                MsgKind::Error => {
                    return Ok(Err(anyhow!(
                        "worker at {}: {}",
                        self.peer,
                        String::from_utf8_lossy(&payload)
                    )))
                }
                other => bail!(
                    "tcp lane to {}: unexpected {other:?} frame while awaiting a report",
                    self.peer
                ),
            }
        }
    }
}

fn reject(stream: &mut TcpStream, reason: &str) {
    let _ = write_frame(
        stream,
        &FrameHeader::bare(MsgKind::Reject),
        reason.as_bytes(),
    );
}

/// Accept and handshake exactly `expect` workers off `listener`,
/// validating every claim; returns one lane per worker paired with the
/// replica ids it owns. Any mismatch rejects the peer AND fails the
/// coordinator — a run with a divergent or missing worker must never
/// limp onward silently.
pub fn accept_workers(
    listener: &TcpListener,
    expect: usize,
    info: &SessionInfo,
) -> Result<Vec<(TcpLane, Vec<usize>)>> {
    let universe = info.live.len();
    let mut claimed: Vec<bool> = vec![false; universe];
    let mut lanes: Vec<(TcpLane, Vec<usize>)> = Vec::with_capacity(expect);
    while lanes.len() < expect {
        let (mut stream, peer_addr) = listener.accept().context("transport: accept")?;
        let peer = peer_addr.to_string();
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
            .context("transport: set handshake timeout")?;
        let (h, payload) = read_frame(&mut stream)
            .with_context(|| format!("transport: handshake with {peer}"))?;
        if h.kind != MsgKind::Hello {
            let why = format!("expected Hello, got {:?}", h.kind);
            reject(&mut stream, &why);
            bail!("transport: handshake with {peer}: {why}");
        }
        if h.fingerprint != 0 && h.fingerprint != info.fingerprint {
            let why = format!(
                "run-config fingerprint mismatch: worker has {:#018x}, \
                 coordinator has {:#018x} (flags or build differ)",
                h.fingerprint, info.fingerprint
            );
            reject(&mut stream, &why);
            bail!("transport: handshake with {peer}: {why}");
        }
        if (h.up_bits != 0 && h.up_bits != info.up_bits)
            || (h.down_bits != 0 && h.down_bits != info.down_bits)
        {
            let why = format!(
                "codec width mismatch: worker claims {}/{} bits, run uses {}/{}",
                h.up_bits, h.down_bits, info.up_bits, info.down_bits
            );
            reject(&mut stream, &why);
            bail!("transport: handshake with {peer}: {why}");
        }
        let claims = msg::hello_from_payload(&payload)
            .with_context(|| format!("transport: handshake with {peer}"))?;
        if claims.is_empty() {
            reject(&mut stream, "claimed no replicas");
            bail!("transport: handshake with {peer}: worker claimed no replicas");
        }
        for &r in &claims {
            if r >= universe {
                let why = format!("replica {r} is outside the universe of {universe}");
                reject(&mut stream, &why);
                bail!("transport: handshake with {peer}: {why}");
            }
            if claimed[r] {
                let why = format!("replica {r} is already claimed by another worker");
                reject(&mut stream, &why);
                bail!("transport: handshake with {peer}: {why}");
            }
            claimed[r] = true;
        }
        let mut welcome = Vec::new();
        msg::welcome_payload(info.engine, &info.live, &info.config_json, &mut welcome)?;
        let mut wh = data_header(MsgKind::Welcome, info.fingerprint, info.up_bits, info.down_bits);
        wh.kind = MsgKind::Welcome;
        write_frame(&mut stream, &wh, &welcome)
            .with_context(|| format!("transport: welcoming {peer}"))?;
        stream
            .set_read_timeout(Some(HEARTBEAT_PERIOD * HEARTBEAT_PATIENCE))
            .context("transport: set lane timeout")?;
        lanes.push((
            TcpLane {
                stream,
                header: data_header(MsgKind::Run, info.fingerprint, info.up_bits, info.down_bits),
                peer,
            },
            claims,
        ));
    }
    if let Some(r) = claimed.iter().position(|&c| !c) {
        bail!(
            "transport: all {expect} workers connected but replica {r} is unclaimed \
             (claims must cover the whole universe of {universe})"
        );
    }
    Ok(lanes)
}

// ---- worker side ------------------------------------------------------

/// Worker-side endpoint of the coordinator connection. Owns the
/// heartbeat thread; dropping the link stops it within one period.
pub struct TcpWorkerLink {
    reader: TcpStream,
    writer: Arc<Mutex<TcpStream>>,
    header: FrameHeader,
    stop: Arc<AtomicBool>,
}

/// Connect-side handshake: claim `claims`, offer `fingerprint` and
/// codec widths (0 = unspecified), and adopt the coordinator's
/// session. Fail-loud on `Reject` — the reason travels in the frame.
pub fn worker_handshake(
    stream: &mut TcpStream,
    claims: &[usize],
    fingerprint: u64,
    up_bits: u8,
    down_bits: u8,
) -> Result<SessionInfo> {
    let mut hello = Vec::new();
    msg::hello_payload(claims, &mut hello)?;
    let h = FrameHeader {
        kind: MsgKind::Hello,
        up_bits,
        down_bits,
        fingerprint,
        sync_index: 0,
        frag: None,
    };
    write_frame(stream, &h, &hello).context("transport: sending Hello")?;
    stream
        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .context("transport: set handshake timeout")?;
    let (wh, payload) = read_frame(stream).context("transport: awaiting Welcome")?;
    match wh.kind {
        MsgKind::Welcome => {
            let (engine, live, config_json) = msg::welcome_from_payload(&payload)?;
            Ok(SessionInfo {
                fingerprint: wh.fingerprint,
                up_bits: wh.up_bits,
                down_bits: wh.down_bits,
                engine,
                live,
                config_json,
            })
        }
        MsgKind::Reject => bail!(
            "transport: coordinator rejected this worker: {}",
            String::from_utf8_lossy(&payload)
        ),
        other => bail!("transport: expected Welcome or Reject, got {other:?}"),
    }
}

impl TcpWorkerLink {
    /// Wrap a handshaken stream and start the heartbeat thread.
    pub fn new(stream: TcpStream, info: &SessionInfo) -> Result<TcpWorkerLink> {
        // commands can be arbitrarily far apart (the coordinator
        // reduces between segments) — block without a timeout; a dead
        // coordinator closes the socket, which ends the read
        stream
            .set_read_timeout(None)
            .context("transport: clear worker read timeout")?;
        let writer = Arc::new(Mutex::new(
            stream.try_clone().context("transport: clone stream for writes")?,
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let hb_writer = Arc::clone(&writer);
        let hb_stop = Arc::clone(&stop);
        let hb_header = data_header(
            MsgKind::Heartbeat,
            info.fingerprint,
            info.up_bits,
            info.down_bits,
        );
        // detached on purpose: it holds only the shared writer and
        // exits within one period of `stop` (or on the first failed
        // write once the socket closes)
        std::thread::spawn(move || {
            while !hb_stop.load(Ordering::Relaxed) {
                std::thread::sleep(HEARTBEAT_PERIOD);
                if hb_stop.load(Ordering::Relaxed) {
                    break;
                }
                let mut w = match hb_writer.lock() {
                    Ok(w) => w,
                    Err(_) => break,
                };
                let mut hh = hb_header.clone();
                hh.kind = MsgKind::Heartbeat;
                if write_frame(&mut *w, &hh, &[]).is_err() {
                    break;
                }
                let _ = w.flush();
            }
        });
        Ok(TcpWorkerLink {
            reader: stream,
            writer,
            header: data_header(
                MsgKind::Report,
                info.fingerprint,
                info.up_bits,
                info.down_bits,
            ),
            stop,
        })
    }
}

impl Drop for TcpWorkerLink {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl WorkerLink for TcpWorkerLink {
    fn recv_cmd(&mut self) -> Option<Cmd> {
        // any failure — EOF, reset, garbage — ends the session; the
        // coordinator side is where failures are judged and journaled
        let (h, payload) = read_frame(&mut self.reader).ok()?;
        msg::cmd_from_frame(h.kind, &payload).ok()
    }

    fn send_report(&mut self, report: Result<WorkerReport>) -> Result<()> {
        let mut payload = Vec::new();
        let kind = match &report {
            Ok(rep) => {
                msg::report_payload(rep, &mut payload)?;
                MsgKind::Report
            }
            Err(e) => {
                payload.extend_from_slice(format!("{e:#}").as_bytes());
                MsgKind::Error
            }
        };
        let mut h = self.header.clone();
        h.kind = kind;
        let mut w = self
            .writer
            .lock()
            .map_err(|_| anyhow!("transport: writer mutex poisoned"))?;
        write_frame(&mut *w, &h, &payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::msg::{Broadcast, PayloadSpec, SegmentChurn, SyncPayload};

    fn session(universe: usize) -> SessionInfo {
        SessionInfo {
            fingerprint: 0xDEAD_BEEF,
            up_bits: 32,
            down_bits: 32,
            engine: ENGINE_TOY,
            live: vec![true; universe],
            config_json: "{\"seed\":17}".to_string(),
        }
    }

    #[test]
    fn connect_backoff_names_address_and_attempts() {
        // a port nothing listens on: bind, learn the port, drop
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = connect_with_backoff(&addr, 3).expect_err("nothing listens there");
        let msg = format!("{err:#}");
        assert!(msg.contains(&addr), "{msg}");
        assert!(msg.contains("3 attempts"), "{msg}");
    }

    #[test]
    fn loopback_handshake_and_one_segment() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let info = session(2);
        let worker_info = info.clone();
        let worker = std::thread::spawn(move || {
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            let got = worker_handshake(&mut stream, &[0, 1], 0, 0, 0).unwrap();
            assert_eq!(got.fingerprint, worker_info.fingerprint);
            assert_eq!(got.engine, ENGINE_TOY);
            assert_eq!(got.live, vec![true, true]);
            assert_eq!(got.config_json, worker_info.config_json);
            let mut link = TcpWorkerLink::new(stream, &got).unwrap();
            let Some(Cmd::Run { from, to, .. }) = link.recv_cmd() else {
                panic!("expected Run");
            };
            assert_eq!((from, to), (0, 3));
            link.send_report(Ok(WorkerReport {
                reps: vec![
                    (0, vec![1.5, 2.5, 3.5], SyncPayload::Skipped),
                    (1, vec![4.5, 5.5, 6.5], SyncPayload::Encoded(vec![7, 7])),
                ],
            }))
            .unwrap();
            assert!(link.recv_cmd().is_none(), "coordinator closed: clean end");
        });
        let mut lanes = accept_workers(&listener, 1, &info).unwrap();
        assert_eq!(lanes.len(), 1);
        assert_eq!(lanes[0].1, vec![0, 1]);
        let lane = &mut lanes[0].0;
        lane.send(Cmd::Spares(vec![vec![1u8; 8]])).unwrap(); // dropped, not sent
        lane.send(Cmd::Run {
            from: 0,
            to: 3,
            broadcast: Broadcast::empty(),
            payload: PayloadSpec::None,
            churn: SegmentChurn::default(),
        })
        .unwrap();
        let report = lane.recv().unwrap().unwrap();
        assert_eq!(report.reps[0].1, vec![1.5, 2.5, 3.5]);
        assert!(matches!(report.reps[1].2, SyncPayload::Encoded(ref b) if b == &vec![7, 7]));
        drop(lanes);
        worker.join().unwrap();
    }

    #[test]
    fn fingerprint_mismatch_rejects_fail_loud() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = std::thread::spawn(move || {
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            worker_handshake(&mut stream, &[0], 0x1234, 0, 0)
                .expect_err("mismatched fingerprint must be rejected")
        });
        let err = accept_workers(&listener, 1, &session(1))
            .expect_err("coordinator fails loud too");
        let msg = format!("{err:#}");
        assert!(msg.contains("fingerprint mismatch"), "{msg}");
        assert!(msg.contains("0x0000000000001234"), "{msg}");
        let werr = format!("{:#}", worker.join().unwrap());
        assert!(werr.contains("rejected"), "{werr}");
        assert!(werr.contains("fingerprint"), "{werr}");
    }

    #[test]
    fn overlapping_claims_reject() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let a1 = addr.clone();
        let w1 = std::thread::spawn(move || {
            let mut s = connect_with_backoff(&a1, CONNECT_ATTEMPTS).unwrap();
            worker_handshake(&mut s, &[0, 1], 0, 0, 0).map(|_| s)
        });
        let w2 = std::thread::spawn(move || {
            // second worker waits so the claim order is deterministic
            std::thread::sleep(Duration::from_millis(200));
            let mut s = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            worker_handshake(&mut s, &[1], 0, 0, 0).map(|_| s)
        });
        let err = accept_workers(&listener, 2, &session(2)).expect_err("claim overlap");
        assert!(format!("{err:#}").contains("already claimed"), "{err:#}");
        assert!(w1.join().unwrap().is_ok(), "first claimer was welcomed");
        assert!(w2.join().unwrap().is_err(), "second claimer was rejected");
    }

    #[test]
    fn dead_worker_times_out_as_lane_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = std::thread::spawn(move || {
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            let info = worker_handshake(&mut stream, &[0], 0, 0, 0).unwrap();
            let link = TcpWorkerLink::new(stream, &info).unwrap();
            // die without reporting: drop the link (and socket)
            drop(link);
        });
        let mut lanes = accept_workers(&listener, 1, &session(1)).unwrap();
        worker.join().unwrap();
        let err = lanes[0].0.recv().expect_err("closed socket = dead lane");
        assert!(!format!("{err:#}").is_empty());
    }
}
