//! TCP transport: length-prefixed [`frame`]s over sockets, so one run
//! spans OS processes (or machines).
//!
//! # Handshake
//!
//! Workers connect (with bounded backoff — racing the coordinator's
//! bind is expected, not an error) and send a `Hello` frame claiming a
//! replica set; the header carries the worker's run-config fingerprint
//! and codec widths when the operator passed train flags (0 =
//! "unspecified, adopt the coordinator's"). The coordinator validates
//! — protocol version (enforced by frame decoding itself), nonzero
//! fingerprint/width agreement, claim sanity (in-universe, disjoint,
//! and jointly covering every replica) — and answers `Welcome` (engine
//! kind + initial liveness + the authoritative run-config JSON) or
//! `Reject` (reason string), failing the run loudly on any mismatch:
//! a quietly divergent peer would poison every reduce it touches.
//!
//! # The lane reactor
//!
//! After the handshake the coordinator folds every worker socket into
//! one [`LaneReactor`]: a nonblocking poll(2) loop over all lanes. One
//! thread serves however many workers — `--expect 64` costs 64 file
//! descriptors, not 64 parked reader threads. Commands serialize once
//! ([`msg::cmd_wire`]) and fan out to every lane; reports drain as
//! lanes produce them, each parsed zero-copy out of a pooled frame
//! buffer; heartbeats are consumed inside the loop (counted into a
//! control-bytes bucket, never the framed totals) while per-lane
//! patience clocks turn a silent peer into a journaled `Crash`.
//! Reactor writes never block the loop either: when a socket's send
//! buffer fills mid-broadcast, the reactor drains incoming frames from
//! every lane and resumes — a worker pushing a large report can never
//! deadlock against a coordinator pushing a large broadcast.
//!
//! Lossy broadcasts additionally *stream*: the encoded payload goes
//! out as its own `Bcast` frame whose chunks hit the lanes as each
//! encode shard finishes (overlapping encode with socket time), and
//! the `Run` that references it carries only a [`Broadcast::Pending`]
//! marker the worker resolves against its stashed frame. On-wire
//! bytes are pinned identical to the one-shot frame.
//!
//! # Liveness
//!
//! Each worker runs a heartbeat thread writing a precomputed 36-byte
//! `Heartbeat` frame on a fixed cadence (writes share a mutex with
//! report frames, held across the whole write, so frames never
//! interleave). A worker silent for [`HEARTBEAT_PATIENCE`] periods is
//! dead to the reactor; survivors continue — never a hang. Workers
//! read commands without a timeout: a dead coordinator closes the
//! socket, which ends the session cleanly.

use std::collections::VecDeque;
use std::io::{self, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::frame::{
    header_bytes, parse_header, read_frame, read_frame_into, reclaim_wires, write_all_vectored,
    write_frame, BufPool, FrameHeader, MsgKind, WireBuf, WireSlice, HEADER_LEN,
};
use super::msg::{self, Broadcast, Cmd, PayloadSpec, SyncPayload, WorkerReport};
use super::{Lane, WorkerLink};

/// Worker heartbeat cadence.
pub const HEARTBEAT_PERIOD: Duration = Duration::from_millis(500);
/// Coordinator read patience: this many heartbeats missed = dead peer.
pub const HEARTBEAT_PATIENCE: u32 = 6;
/// Handshake read timeout (a connecting peer that never says Hello).
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
/// Default connect attempts for [`connect_with_backoff`].
pub const CONNECT_ATTEMPTS: usize = 10;
/// First retry delay; doubles per attempt, capped at [`BACKOFF_CAP`].
pub const BACKOFF_START: Duration = Duration::from_millis(100);
pub const BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Engine kinds shipped in the Welcome payload.
pub const ENGINE_PJRT: u8 = 0;
pub const ENGINE_TOY: u8 = 1;

/// How long a lane may go silent before the reactor declares it dead.
fn patience() -> Duration {
    HEARTBEAT_PERIOD * HEARTBEAT_PATIENCE
}

/// Connect to `addr`, retrying with bounded exponential backoff: a
/// worker launched alongside the coordinator routinely races its
/// `--listen` bind, so refused connections retry (100ms, 200ms, ...,
/// capped at 2s) up to `attempts` times before giving up with an
/// error naming the address and the attempt count.
pub fn connect_with_backoff(addr: &str, attempts: usize) -> Result<TcpStream> {
    let attempts = attempts.max(1);
    let mut delay = BACKOFF_START;
    let mut last_err: Option<std::io::Error> = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            std::thread::sleep(delay);
            delay = (delay * 2).min(BACKOFF_CAP);
        }
        match TcpStream::connect(addr) {
            Ok(stream) => {
                if let Err(e) = stream.set_nodelay(true) {
                    // degraded latency, not a broken lane — run on
                    log::warn!("transport: set_nodelay for {addr}: {e}");
                }
                return Ok(stream);
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(anyhow!(
        "could not connect to {addr} after {attempts} attempts: {}",
        last_err.expect("attempts >= 1 guarantees an error")
    ))
}

/// What both sides agree on after the handshake.
#[derive(Debug, Clone)]
pub struct SessionInfo {
    /// fnv1a64 of the canonical run-config JSON.
    pub fingerprint: u64,
    /// Up/down codec widths in bits (stamped on every data frame).
    pub up_bits: u8,
    pub down_bits: u8,
    /// Engine kind ([`ENGINE_PJRT`] / [`ENGINE_TOY`]).
    pub engine: u8,
    /// Initial liveness per universe slot (joiner slots dark).
    pub live: Vec<bool>,
    /// The coordinator's run config JSON — the source of truth every
    /// worker rebuilds its engine, replicas, and comm link from.
    pub config_json: String,
}

fn data_header(kind: MsgKind, info_fp: u64, up: u8, down: u8) -> FrameHeader {
    FrameHeader {
        kind,
        up_bits: up,
        down_bits: down,
        fingerprint: info_fp,
        sync_index: 0,
        frag: None,
    }
}

// ---- readiness waiting ------------------------------------------------

/// One fd's poll request/result (mirrors `struct pollfd`).
// `fd`/`events` are read by the kernel through the poll(2) pointer,
// never by Rust code, which only inspects `revents`.
#[allow(dead_code)]
#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;

#[cfg(target_os = "linux")]
mod sys {
    //! Direct poll(2) FFI — the build vendors no libc, and the reactor
    //! needs exactly one syscall from it.
    use super::PollFd;
    use std::io;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: std::os::raw::c_ulong, timeout: i32) -> i32;
    }

    /// Wait for readiness on `fds` (revents filled in place) for up to
    /// `timeout_ms`. Returns the ready count (0 = timed out).
    pub fn wait(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as _, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod sys {
    //! Portability stub: no poll(2), so every fd is reported ready
    //! after a ~1ms nap and the nonblocking reads/writes themselves
    //! govern progress. Correct, just busier than a real readiness
    //! wait — acceptable for the platforms this fallback serves.
    use super::PollFd;
    use std::io;

    pub fn wait(fds: &mut [PollFd], _timeout_ms: i32) -> io::Result<usize> {
        std::thread::sleep(std::time::Duration::from_millis(1));
        for f in fds.iter_mut() {
            f.revents = f.events;
        }
        Ok(fds.len())
    }
}

#[cfg(unix)]
fn raw_fd(s: &TcpStream) -> i32 {
    use std::os::fd::AsRawFd;
    s.as_raw_fd()
}

#[cfg(not(unix))]
fn raw_fd(_s: &TcpStream) -> i32 {
    0
}

// ---- coordinator side -------------------------------------------------

/// Coordinator-side endpoint of one worker connection — the simple
/// blocking form the generic [`Lane`]-driven path and the handshake
/// produce. Production multi-worker runs fold these into a
/// [`LaneReactor`] instead of reading each on its own thread.
pub struct TcpLane {
    stream: TcpStream,
    header: FrameHeader,
    peer: String,
    scratch: Vec<u8>,
}

impl Lane for TcpLane {
    fn send(&mut self, cmd: Cmd) -> Result<()> {
        if matches!(cmd, Cmd::Spares(_)) {
            return Ok(()); // buffer recycling never crosses a socket
        }
        let TcpLane {
            stream,
            header,
            peer,
            scratch,
        } = self;
        let (kind, cuts) = msg::cmd_wire(&cmd, scratch)?;
        let mut h = header.clone();
        h.kind = kind;
        // stamp the schedule position for wire-level observability
        if let Cmd::Run {
            payload: PayloadSpec::Encoded(spec),
            ..
        } = &cmd
        {
            h.sync_index = spec.sync_index;
            h.frag = spec.frag.map(|f| f as u32);
        }
        cuts.write(stream, &h, scratch)
            .map(|_| ())
            .with_context(|| format!("tcp lane to {peer}"))
    }

    fn recv(&mut self) -> Result<Result<WorkerReport>> {
        loop {
            let (h, payload) = read_frame(&mut self.stream).with_context(|| {
                format!(
                    "tcp lane to {}: no frame within the read timeout \
                     ({HEARTBEAT_PATIENCE} heartbeats)",
                    self.peer
                )
            })?;
            match h.kind {
                MsgKind::Heartbeat => continue,
                MsgKind::Report => return Ok(Ok(msg::report_from_payload(&payload)?)),
                MsgKind::Error => {
                    return Ok(Err(anyhow!(
                        "worker at {}: {}",
                        self.peer,
                        String::from_utf8_lossy(&payload)
                    )))
                }
                other => bail!(
                    "tcp lane to {}: unexpected {other:?} frame while awaiting a report",
                    self.peer
                ),
            }
        }
    }
}

/// One worker socket inside the reactor: its identity, liveness, an
/// incremental read state (header, then payload straight into a pooled
/// buffer), and an inbox of complete frames awaiting consumption.
struct ReactorLane {
    stream: TcpStream,
    peer: String,
    rids: Vec<usize>,
    alive: bool,
    last_heard: Instant,
    hdr: [u8; HEADER_LEN],
    hdr_have: usize,
    /// Parsed header + payload buffer + bytes filled so far.
    body: Option<(FrameHeader, WireBuf, usize)>,
    inbox: VecDeque<(FrameHeader, WireBuf)>,
}

/// Mark a lane dead exactly once: log it, surface its replicas as
/// newly lost. Idempotent — read errors discovered while draining can
/// race a write failure on the same lane.
fn kill(lane: &mut ReactorLane, lost: &mut Vec<usize>, why: &str) {
    if !lane.alive {
        return;
    }
    lane.alive = false;
    log::warn!("transport: lane to {} died: {why}", lane.peer);
    lost.extend(lane.rids.iter().copied());
}

/// The reactor's poll-loop state, split from [`LaneReactor`] so
/// serialization scratch can be borrowed while lanes are driven.
struct ReactorCore {
    lanes: Vec<ReactorLane>,
    pool: BufPool,
    control_bytes: u64,
    lost: Vec<usize>,
}

impl ReactorCore {
    /// Drain whatever lane `idx`'s socket holds right now: complete
    /// frames land in its inbox (heartbeats consumed on the spot and
    /// counted as control bytes), a partial frame persists in the read
    /// state for the next readiness. A read error kills the lane.
    fn pump_read(&mut self, idx: usize) {
        let ReactorCore {
            lanes,
            pool,
            control_bytes,
            lost,
        } = self;
        let lane = &mut lanes[idx];
        if !lane.alive {
            return;
        }
        if let Err(e) = pump_read_inner(lane, pool, control_bytes) {
            kill(lane, lost, &format!("{e:#}"));
        }
    }

    /// Block until a lane is readable (or `write_idx`'s socket is
    /// writable), drain the readable ones, and enforce the heartbeat
    /// patience clocks — a lane silent past its deadline dies here.
    fn wait_io(&mut self, write_idx: Option<usize>) -> Result<()> {
        let now = Instant::now();
        let mut timeout = patience();
        for lane in self.lanes.iter().filter(|l| l.alive) {
            let left = patience().saturating_sub(now.duration_since(lane.last_heard));
            timeout = timeout.min(left);
        }
        let mut fds: Vec<PollFd> = Vec::new();
        let mut map: Vec<usize> = Vec::new();
        for (i, lane) in self.lanes.iter().enumerate() {
            if !lane.alive {
                continue;
            }
            let mut events = POLLIN;
            if write_idx == Some(i) {
                events |= POLLOUT;
            }
            fds.push(PollFd {
                fd: raw_fd(&lane.stream),
                events,
                revents: 0,
            });
            map.push(i);
        }
        if fds.is_empty() {
            return Ok(()); // everyone is dead; callers notice
        }
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        sys::wait(&mut fds, ms.max(1)).context("transport: poll")?;
        for (k, f) in fds.iter().enumerate() {
            // anything but a pure write-readiness (data, EOF, error,
            // hangup) is the read pump's to judge
            if f.revents & !POLLOUT != 0 {
                let idx = map[k];
                self.pump_read(idx);
            }
        }
        // pump first, *then* judge patience: heartbeats queued in the
        // socket during a long reduce refresh last_heard before the check
        let ReactorCore { lanes, lost, .. } = self;
        let now = Instant::now();
        for lane in lanes.iter_mut() {
            if lane.alive && now.duration_since(lane.last_heard) > patience() {
                kill(
                    lane,
                    lost,
                    &format!("silent for {HEARTBEAT_PATIENCE} heartbeat periods"),
                );
            }
        }
        Ok(())
    }

    /// Write every byte of `parts` to lane `idx` without ever blocking
    /// the reactor: when the socket's send buffer fills, incoming
    /// frames are drained from *all* lanes and the write resumes — a
    /// worker mid-report can never deadlock a coordinator
    /// mid-broadcast. `Err` means the target lane is dead (the caller
    /// kills it); deaths among the drained lanes are absorbed.
    fn write_parts(&mut self, idx: usize, parts: &[&[u8]]) -> Result<()> {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let mut written = 0usize;
        while written < total {
            if !self.lanes[idx].alive {
                bail!("lane died while a write was in flight");
            }
            let mut skip = written;
            let mut bufs: Vec<IoSlice> = Vec::with_capacity(parts.len());
            for p in parts {
                if skip >= p.len() {
                    skip -= p.len();
                    continue;
                }
                bufs.push(IoSlice::new(&p[skip..]));
                skip = 0;
            }
            match self.lanes[idx].stream.write_vectored(&bufs) {
                Ok(0) => bail!("socket accepted zero bytes"),
                Ok(n) => written += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    self.wait_io(Some(idx))?;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("lane write"),
            }
        }
        Ok(())
    }

    /// Ship one pre-serialized frame (or frame piece) to every live
    /// lane. A lane whose write fails dies — crash-membership
    /// semantics, not a run failure.
    fn fan_out(&mut self, parts: &[&[u8]]) {
        for i in 0..self.lanes.len() {
            if !self.lanes[i].alive {
                continue;
            }
            if let Err(e) = self.write_parts(i, parts) {
                let ReactorCore { lanes, lost, .. } = self;
                kill(&mut lanes[i], lost, &format!("{e:#}"));
            }
        }
    }
}

/// The lane-local half of the read pump (free function so the core's
/// pool and counters can be borrowed alongside the lane).
fn pump_read_inner(lane: &mut ReactorLane, pool: &mut BufPool, control: &mut u64) -> Result<()> {
    loop {
        if lane.body.is_none() {
            while lane.hdr_have < HEADER_LEN {
                match lane.stream.read(&mut lane.hdr[lane.hdr_have..]) {
                    Ok(0) => {
                        if lane.hdr_have == 0 {
                            bail!("peer closed the connection");
                        }
                        bail!("peer closed mid-frame");
                    }
                    Ok(n) => lane.hdr_have += n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(()),
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e).context("lane read"),
                }
            }
            let (h, payload_len) = parse_header(&lane.hdr)?;
            let mut buf = pool.take();
            buf.resize_payload(payload_len);
            lane.hdr_have = 0;
            lane.body = Some((h, buf, 0));
        }
        {
            let (_, buf, filled) = lane.body.as_mut().expect("installed above");
            let need = buf.payload_len();
            while *filled < need {
                match lane.stream.read(&mut buf.payload_mut()[*filled..]) {
                    Ok(0) => bail!("peer closed mid-frame"),
                    Ok(n) => *filled += n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(()),
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(e).context("lane read"),
                }
            }
        }
        let (h, buf, _) = lane.body.take().expect("completed above");
        lane.last_heard = Instant::now();
        if h.kind == MsgKind::Heartbeat {
            // liveness traffic: consumed here, never surfaced; counted
            // into the control bucket (socket fact, not sync traffic)
            *control += (HEADER_LEN + buf.payload_len()) as u64;
            pool.put(buf);
        } else {
            lane.inbox.push_back((h, buf));
        }
    }
}

/// The multiplexed coordinator endpoint: every worker socket inside
/// one nonblocking poll loop. See the module docs for the design; see
/// `coordinator::pool::drive_reactor` for the drive loop that runs on
/// top of it.
pub struct LaneReactor {
    core: ReactorCore,
    /// Data-frame template (fingerprint + codec widths).
    header: FrameHeader,
    /// Command meta scratch, recycled across sends.
    scratch: Vec<u8>,
    /// Undelivered remainder of a streamed broadcast's declared
    /// payload — chunks must account for exactly this many bytes.
    bcast_left: u64,
}

impl LaneReactor {
    /// Fold handshaken lanes (from [`accept_workers`]) into one
    /// reactor, switching their sockets to nonblocking mode.
    pub fn new(lanes: Vec<(TcpLane, Vec<usize>)>) -> Result<LaneReactor> {
        let cap = lanes.len() * 2 + 4;
        let mut header: Option<FrameHeader> = None;
        let mut rl = Vec::with_capacity(lanes.len());
        for (lane, rids) in lanes {
            lane.stream
                .set_nonblocking(true)
                .with_context(|| format!("transport: nonblocking mode for {}", lane.peer))?;
            header.get_or_insert(lane.header.clone());
            rl.push(ReactorLane {
                stream: lane.stream,
                peer: lane.peer,
                rids,
                alive: true,
                last_heard: Instant::now(),
                hdr: [0u8; HEADER_LEN],
                hdr_have: 0,
                body: None,
                inbox: VecDeque::new(),
            });
        }
        Ok(LaneReactor {
            core: ReactorCore {
                lanes: rl,
                pool: BufPool::with_cap(cap),
                control_bytes: 0,
                lost: Vec::new(),
            },
            header: header.unwrap_or_else(|| FrameHeader::bare(MsgKind::Run)),
            scratch: Vec::new(),
            bcast_left: 0,
        })
    }

    /// Replica ownership per lane, in lane order (fixed at handshake;
    /// includes dead lanes — they still cover their universe slots).
    pub fn lane_rids(&self) -> Vec<Vec<usize>> {
        self.core.lanes.iter().map(|l| l.rids.clone()).collect()
    }

    /// Serialize `cmd` once and fan it out to every live lane. Lane
    /// write failures are lane deaths, not errors; `Err` means the
    /// command itself cannot travel (`Spares`).
    pub fn send_cmd(&mut self, cmd: &Cmd) -> Result<()> {
        let (kind, cuts) = msg::cmd_wire(cmd, &mut self.scratch)?;
        let mut h = self.header.clone();
        h.kind = kind;
        if let Cmd::Run {
            payload: PayloadSpec::Encoded(spec),
            ..
        } = cmd
        {
            h.sync_index = spec.sync_index;
            h.frag = spec.frag.map(|f| f as u32);
        }
        let hdr = header_bytes(&h, cuts.payload_len(&self.scratch))?;
        let body = cuts.parts(&self.scratch);
        let mut parts: Vec<&[u8]> = Vec::with_capacity(body.len() + 1);
        parts.push(&hdr);
        parts.extend(body);
        self.core.fan_out(&parts);
        Ok(())
    }

    /// Block until every live lane has produced its segment report (or
    /// died trying). Heartbeats are consumed along the way; a worker's
    /// `Error` frame fails the run (a broken engine is never churn); a
    /// garbled or unexpected frame kills its lane. Reports parse
    /// zero-copy out of their single frame buffer — payloadless frames
    /// recycle immediately, payload-bearing ones return through
    /// [`LaneReactor::recycle`] after the reduce.
    pub fn collect_reports(&mut self) -> Result<Vec<WorkerReport>> {
        self.collect_inner(None)
    }

    /// [`LaneReactor::collect_reports`] with an up-leg chunk sink:
    /// `ContribChunk` frames for sync `sync_index` over `frag` hand
    /// `(rid, offset, bytes)` to `sink` the moment they arrive — lanes
    /// are serviced by readiness, so a stalled lane never delays
    /// another lane's chunks (no head-of-line blocking). A chunk for a
    /// replica its lane doesn't own, or for the wrong schedule slot,
    /// fails the run loudly — that's a protocol violation, not churn.
    /// Chunk frame buffers stay zero-copy: the sink's `WireSlice`
    /// views them, and the slices spent by the reduce return through
    /// [`LaneReactor::recycle`].
    pub fn collect_reports_streamed(
        &mut self,
        sync_index: u64,
        frag: Option<usize>,
        sink: &mut dyn FnMut(usize, usize, WireSlice) -> Result<()>,
    ) -> Result<Vec<WorkerReport>> {
        self.collect_inner(Some((sync_index, frag, sink)))
    }

    fn collect_inner(
        &mut self,
        mut chunk_sink: Option<(
            u64,
            Option<usize>,
            &mut dyn FnMut(usize, usize, WireSlice) -> Result<()>,
        )>,
    ) -> Result<Vec<WorkerReport>> {
        let core = &mut self.core;
        let n = core.lanes.len();
        let mut reported = vec![false; n];
        let mut out = Vec::new();
        loop {
            for i in 0..n {
                // frames received before a death are still valid —
                // drain inboxes regardless of the alive flag
                while !reported[i] {
                    let Some((h, buf)) = core.lanes[i].inbox.pop_front() else {
                        break;
                    };
                    match h.kind {
                        MsgKind::Report => {
                            let frame = Arc::new(buf);
                            match msg::report_from_wire(&frame) {
                                Ok(rep) => {
                                    out.push(rep);
                                    reported[i] = true;
                                }
                                Err(e) => {
                                    let ReactorCore { lanes, lost, .. } = core;
                                    kill(&mut lanes[i], lost, &format!("garbled report: {e:#}"));
                                }
                            }
                            // a report whose payloads are all literal/
                            // skipped leaves the frame unshared —
                            // recycle it on the spot
                            if let Ok(b) = Arc::try_unwrap(frame) {
                                core.pool.put(b);
                            }
                        }
                        MsgKind::ContribChunk => {
                            let Some((want_sync, want_frag, sink)) = chunk_sink.as_mut() else {
                                let ReactorCore { lanes, lost, .. } = core;
                                kill(
                                    &mut lanes[i],
                                    lost,
                                    "streamed a ContribChunk into a one-shot collect",
                                );
                                continue;
                            };
                            if h.sync_index != *want_sync
                                || h.frag != want_frag.map(|f| f as u32)
                            {
                                bail!(
                                    "transport: lane {} streamed a chunk for sync {} frag \
                                     {:?} while collecting sync {} frag {:?}",
                                    core.lanes[i].peer,
                                    h.sync_index,
                                    h.frag,
                                    want_sync,
                                    want_frag
                                );
                            }
                            let frame = Arc::new(buf);
                            match msg::contrib_chunk_from_wire(&frame) {
                                Ok((rid, offset, slice)) => {
                                    if !core.lanes[i].rids.contains(&rid) {
                                        bail!(
                                            "transport: lane {} (replicas {:?}) streamed a \
                                             chunk claiming replica {rid}",
                                            core.lanes[i].peer,
                                            core.lanes[i].rids
                                        );
                                    }
                                    sink(rid, offset, slice)?;
                                }
                                Err(e) => {
                                    let ReactorCore { lanes, lost, .. } = core;
                                    kill(&mut lanes[i], lost, &format!("garbled chunk: {e:#}"));
                                }
                            }
                            // a rejected/garbled chunk leaves the frame
                            // unshared — recycle it on the spot
                            if let Ok(b) = Arc::try_unwrap(frame) {
                                core.pool.put(b);
                            }
                        }
                        MsgKind::Error => {
                            return Err(anyhow!(
                                "worker at {}: {}",
                                core.lanes[i].peer,
                                String::from_utf8_lossy(buf.payload())
                            ));
                        }
                        other => {
                            let ReactorCore { lanes, lost, .. } = core;
                            kill(
                                &mut lanes[i],
                                lost,
                                &format!("unexpected {other:?} frame while awaiting a report"),
                            );
                        }
                    }
                }
            }
            let done = (0..n)
                .all(|i| reported[i] || (!core.lanes[i].alive && core.lanes[i].inbox.is_empty()));
            if done {
                return Ok(out);
            }
            core.wait_io(None)?;
        }
    }

    /// Every replica owned by a lane that has died so far (cumulative
    /// — a dead lane's replicas stay dark for the rest of the run).
    pub fn dead_rids(&self) -> Vec<usize> {
        self.core
            .lanes
            .iter()
            .filter(|l| !l.alive)
            .flat_map(|l| l.rids.iter().copied())
            .collect()
    }

    /// Replicas newly lost since the last call — the drive loop turns
    /// these into journaled `Crash` membership.
    pub fn take_lost(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.core.lost)
    }

    /// Return spent frame buffers (reclaimed after a reduce) to the
    /// receive pool.
    pub fn recycle(&mut self, bufs: Vec<WireBuf>) {
        for b in bufs {
            self.core.pool.put(b);
        }
    }

    /// Open a streamed broadcast: stamp one `Bcast` header declaring
    /// the full payload length onto every live lane. Chunks follow via
    /// [`LaneReactor::bcast_chunk`] and must total exactly
    /// `payload_len` — the header is the frame boundary, so an
    /// undershoot would desync every lane.
    pub fn bcast_begin(
        &mut self,
        frag: Option<usize>,
        sync_index: u64,
        payload_len: u64,
    ) -> Result<()> {
        if self.bcast_left != 0 {
            bail!(
                "transport: streamed broadcast opened with {} bytes of the previous \
                 one undelivered",
                self.bcast_left
            );
        }
        let mut h = self.header.clone();
        h.kind = MsgKind::Bcast;
        h.sync_index = sync_index;
        h.frag = frag.map(|f| f as u32);
        let hdr = header_bytes(&h, payload_len as usize)?;
        self.bcast_left = payload_len;
        self.core.fan_out(&[&hdr]);
        Ok(())
    }

    /// Ship one encode shard of the open streamed broadcast to every
    /// live lane (overlapping the encoder with the sockets).
    pub fn bcast_chunk(&mut self, chunk: &[u8]) -> Result<()> {
        let n = chunk.len() as u64;
        if n > self.bcast_left {
            bail!(
                "transport: broadcast chunk of {n} bytes overruns the declared payload \
                 ({} bytes remain)",
                self.bcast_left
            );
        }
        self.bcast_left -= n;
        self.core.fan_out(&[chunk]);
        Ok(())
    }

    /// Ship the final broadcast as `Finish` to every surviving lane.
    /// Errors are swallowed — a lane dead at shutdown already crashed
    /// out, and the workers' own adopt verdicts travel via exit codes.
    pub fn send_finish(&mut self, broadcast: &Broadcast) {
        let cmd = Cmd::Finish {
            broadcast: broadcast.clone(),
        };
        if let Err(e) = self.send_cmd(&cmd) {
            log::warn!("transport: final broadcast not shipped: {e:#}");
        }
    }

    /// Drain the control-plane byte count (heartbeat frames consumed
    /// so far) — folded into `WireStats`' control bucket, never the
    /// framed totals, so `final:` lines stay transport-invariant.
    pub fn take_control_bytes(&mut self) -> u64 {
        std::mem::take(&mut self.core.control_bytes)
    }
}

fn reject(stream: &mut TcpStream, reason: &str) {
    let _ = write_frame(
        stream,
        &FrameHeader::bare(MsgKind::Reject),
        reason.as_bytes(),
    );
}

/// Accept and handshake exactly `expect` workers off `listener`,
/// validating every claim; returns one lane per worker paired with the
/// replica ids it owns. Any mismatch rejects the peer AND fails the
/// coordinator — a run with a divergent or missing worker must never
/// limp onward silently. Fold the result into a [`LaneReactor`] to
/// drive them all from one thread.
pub fn accept_workers(
    listener: &TcpListener,
    expect: usize,
    info: &SessionInfo,
) -> Result<Vec<(TcpLane, Vec<usize>)>> {
    let universe = info.live.len();
    let mut claimed: Vec<bool> = vec![false; universe];
    let mut lanes: Vec<(TcpLane, Vec<usize>)> = Vec::with_capacity(expect);
    while lanes.len() < expect {
        let (mut stream, peer_addr) = listener.accept().context("transport: accept")?;
        let peer = peer_addr.to_string();
        if let Err(e) = stream.set_nodelay(true) {
            log::warn!("transport: set_nodelay for {peer}: {e}");
        }
        stream
            .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
            .context("transport: set handshake timeout")?;
        let (h, payload) = read_frame(&mut stream)
            .with_context(|| format!("transport: handshake with {peer}"))?;
        if h.kind != MsgKind::Hello {
            let why = format!("expected Hello, got {:?}", h.kind);
            reject(&mut stream, &why);
            bail!("transport: handshake with {peer}: {why}");
        }
        if h.fingerprint != 0 && h.fingerprint != info.fingerprint {
            let why = format!(
                "run-config fingerprint mismatch: worker has {:#018x}, \
                 coordinator has {:#018x} (flags or build differ)",
                h.fingerprint, info.fingerprint
            );
            reject(&mut stream, &why);
            bail!("transport: handshake with {peer}: {why}");
        }
        if (h.up_bits != 0 && h.up_bits != info.up_bits)
            || (h.down_bits != 0 && h.down_bits != info.down_bits)
        {
            let why = format!(
                "codec width mismatch: worker claims {}/{} bits, run uses {}/{}",
                h.up_bits, h.down_bits, info.up_bits, info.down_bits
            );
            reject(&mut stream, &why);
            bail!("transport: handshake with {peer}: {why}");
        }
        let claims = msg::hello_from_payload(&payload)
            .with_context(|| format!("transport: handshake with {peer}"))?;
        if claims.is_empty() {
            reject(&mut stream, "claimed no replicas");
            bail!("transport: handshake with {peer}: worker claimed no replicas");
        }
        for &r in &claims {
            if r >= universe {
                let why = format!("replica {r} is outside the universe of {universe}");
                reject(&mut stream, &why);
                bail!("transport: handshake with {peer}: {why}");
            }
            if claimed[r] {
                let why = format!("replica {r} is already claimed by another worker");
                reject(&mut stream, &why);
                bail!("transport: handshake with {peer}: {why}");
            }
            claimed[r] = true;
        }
        let mut welcome = Vec::new();
        msg::welcome_payload(info.engine, &info.live, &info.config_json, &mut welcome)?;
        let wh = data_header(
            MsgKind::Welcome,
            info.fingerprint,
            info.up_bits,
            info.down_bits,
        );
        write_frame(&mut stream, &wh, &welcome)
            .with_context(|| format!("transport: welcoming {peer}"))?;
        stream
            .set_read_timeout(Some(HEARTBEAT_PERIOD * HEARTBEAT_PATIENCE))
            .context("transport: set lane timeout")?;
        lanes.push((
            TcpLane {
                stream,
                header: data_header(
                    MsgKind::Run,
                    info.fingerprint,
                    info.up_bits,
                    info.down_bits,
                ),
                peer,
                scratch: Vec::new(),
            },
            claims,
        ));
    }
    if let Some(r) = claimed.iter().position(|&c| !c) {
        bail!(
            "transport: all {expect} workers connected but replica {r} is unclaimed \
             (claims must cover the whole universe of {universe})"
        );
    }
    Ok(lanes)
}

// ---- worker side ------------------------------------------------------

/// Worker-side endpoint of the coordinator connection. Owns the
/// heartbeat thread; dropping the link stops it within one period.
///
/// Receive-side buffers recycle through a local pool (a fully consumed
/// command's frame buffer returns on the next `recv_cmd`), and the
/// wire buffers behind a shipped report come back as a locally
/// synthesized [`Cmd::Spares`] — the socket twin of the coordinator's
/// buffer recycling, without ever shipping empty buffers.
pub struct TcpWorkerLink {
    reader: TcpStream,
    writer: Arc<Mutex<TcpStream>>,
    header: FrameHeader,
    stop: Arc<AtomicBool>,
    pool: BufPool,
    /// Frame buffers still viewed by an outstanding command's payload
    /// slices; swept back into the pool once unshared.
    inflight: Vec<Arc<WireBuf>>,
    /// A received `Bcast` frame awaiting the `Pending` command that
    /// references it.
    stash: Option<(FrameHeader, WireBuf)>,
    /// Encode buffers reclaimed from the last report, returned to the
    /// session as a synthesized `Cmd::Spares`.
    spares: Vec<WireBuf>,
    /// Report meta scratch, recycled across sends.
    scratch: Vec<u8>,
}

/// Connect-side handshake: claim `claims`, offer `fingerprint` and
/// codec widths (0 = unspecified), and adopt the coordinator's
/// session. Fail-loud on `Reject` — the reason travels in the frame.
pub fn worker_handshake(
    stream: &mut TcpStream,
    claims: &[usize],
    fingerprint: u64,
    up_bits: u8,
    down_bits: u8,
) -> Result<SessionInfo> {
    let mut hello = Vec::new();
    msg::hello_payload(claims, &mut hello)?;
    let h = FrameHeader {
        kind: MsgKind::Hello,
        up_bits,
        down_bits,
        fingerprint,
        sync_index: 0,
        frag: None,
    };
    write_frame(stream, &h, &hello).context("transport: sending Hello")?;
    stream
        .set_read_timeout(Some(HANDSHAKE_TIMEOUT))
        .context("transport: set handshake timeout")?;
    let (wh, payload) = read_frame(stream).context("transport: awaiting Welcome")?;
    match wh.kind {
        MsgKind::Welcome => {
            let (engine, live, config_json) = msg::welcome_from_payload(&payload)?;
            Ok(SessionInfo {
                fingerprint: wh.fingerprint,
                up_bits: wh.up_bits,
                down_bits: wh.down_bits,
                engine,
                live,
                config_json,
            })
        }
        MsgKind::Reject => bail!(
            "transport: coordinator rejected this worker: {}",
            String::from_utf8_lossy(&payload)
        ),
        other => bail!("transport: expected Welcome or Reject, got {other:?}"),
    }
}

impl TcpWorkerLink {
    /// Wrap a handshaken stream and start the heartbeat thread.
    pub fn new(stream: TcpStream, info: &SessionInfo) -> Result<TcpWorkerLink> {
        // commands can be arbitrarily far apart (the coordinator
        // reduces between segments) — block without a timeout; a dead
        // coordinator closes the socket, which ends the read
        stream
            .set_read_timeout(None)
            .context("transport: clear worker read timeout")?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown peer>".to_string());
        let writer = Arc::new(Mutex::new(
            stream
                .try_clone()
                .context("transport: clone stream for writes")?,
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let hb_writer = Arc::clone(&writer);
        let hb_stop = Arc::clone(&stop);
        // the heartbeat frame never varies — build its 36 bytes once
        // instead of cloning and re-stamping a header every period
        let hb_frame = header_bytes(
            &data_header(
                MsgKind::Heartbeat,
                info.fingerprint,
                info.up_bits,
                info.down_bits,
            ),
            0,
        )?;
        // detached on purpose: it holds only the shared writer and
        // exits within one period of `stop` (or on the first failed
        // write once the socket closes)
        std::thread::spawn(move || {
            let mut flush_logged = false;
            while !hb_stop.load(Ordering::Relaxed) {
                std::thread::sleep(HEARTBEAT_PERIOD);
                if hb_stop.load(Ordering::Relaxed) {
                    break;
                }
                let mut w = match hb_writer.lock() {
                    Ok(w) => w,
                    Err(_) => break,
                };
                if w.write_all(&hb_frame).is_err() {
                    break;
                }
                if let Err(e) = w.flush() {
                    // a flush hiccup is not yet a dead socket — beat
                    // on, but say so once instead of dropping it silently
                    if !flush_logged {
                        log::warn!("transport: heartbeat flush to {peer}: {e}");
                        flush_logged = true;
                    }
                }
            }
        });
        Ok(TcpWorkerLink {
            reader: stream,
            writer,
            header: data_header(
                MsgKind::Report,
                info.fingerprint,
                info.up_bits,
                info.down_bits,
            ),
            stop,
            pool: BufPool::with_cap(8),
            inflight: Vec::new(),
            stash: None,
            spares: Vec::new(),
            scratch: Vec::new(),
        })
    }

    /// Swap a `Pending` broadcast marker for the stashed `Bcast` frame
    /// it references. `None` = protocol violation (no stash, or the
    /// stash is for a different fragment) — the session ends; the
    /// coordinator side judges the silence.
    fn take_stashed(&mut self, frag: Option<usize>) -> Option<Broadcast> {
        let Some((bh, buf)) = self.stash.take() else {
            log::warn!("transport: pending broadcast but no Bcast frame was stashed");
            return None;
        };
        let want = frag.map(|f| f as u32);
        if bh.frag != want {
            log::warn!(
                "transport: pending broadcast resolves fragment {want:?} but the stash \
                 holds {:?}",
                bh.frag
            );
            return None;
        }
        let frame = Arc::new(buf);
        let bytes = WireSlice::whole(Arc::clone(&frame));
        self.inflight.push(frame);
        Some(Broadcast::Encoded { frag, bytes })
    }

    /// Resolve any `Pending` broadcast in `cmd` against the stash;
    /// pass everything else through untouched.
    fn resolve(&mut self, cmd: Cmd) -> Option<Cmd> {
        Some(match cmd {
            Cmd::Run {
                from,
                to,
                broadcast: Broadcast::Pending { frag },
                payload,
                churn,
            } => Cmd::Run {
                from,
                to,
                broadcast: self.take_stashed(frag)?,
                payload,
                churn,
            },
            Cmd::Finish {
                broadcast: Broadcast::Pending { frag },
            } => Cmd::Finish {
                broadcast: self.take_stashed(frag)?,
            },
            other => other,
        })
    }
}

impl Drop for TcpWorkerLink {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl WorkerLink for TcpWorkerLink {
    fn recv_cmd(&mut self) -> Option<Cmd> {
        // encode buffers reclaimed from the last report go back to the
        // worker's comm pool as a synthesized command — before any
        // socket read, so the session absorbs them between segments
        if !self.spares.is_empty() {
            return Some(Cmd::Spares(std::mem::take(&mut self.spares)));
        }
        // frame buffers from fully consumed commands return to the pool
        let mut still_shared = Vec::new();
        for arc in self.inflight.drain(..) {
            match Arc::try_unwrap(arc) {
                Ok(buf) => self.pool.put(buf),
                Err(arc) => still_shared.push(arc),
            }
        }
        self.inflight = still_shared;
        loop {
            // any failure — EOF, reset, garbage — ends the session; the
            // coordinator side is where failures are judged and journaled
            let mut buf = self.pool.take();
            let h = read_frame_into(&mut self.reader, &mut buf).ok()?;
            match h.kind {
                MsgKind::Bcast => {
                    // a streamed broadcast ahead of the command that
                    // references it: stash until that command arrives
                    if self.stash.replace((h, buf)).is_some() {
                        log::warn!("transport: Bcast frame replaced an unresolved stash");
                    }
                }
                MsgKind::Run | MsgKind::Finish => {
                    let frame = Arc::new(buf);
                    let cmd = msg::cmd_from_wire(h.kind, &frame).ok()?;
                    self.inflight.push(frame);
                    return self.resolve(cmd);
                }
                other => {
                    log::warn!("transport: unexpected {other:?} frame while awaiting a command");
                    return None;
                }
            }
        }
    }

    fn send_report(&mut self, report: Result<WorkerReport>) -> Result<()> {
        let rep = match report {
            Ok(rep) => rep,
            Err(e) => {
                let mut h = self.header.clone();
                h.kind = MsgKind::Error;
                let mut w = self
                    .writer
                    .lock()
                    .map_err(|_| anyhow!("transport: writer mutex poisoned"))?;
                return write_frame(&mut *w, &h, format!("{e:#}").as_bytes());
            }
        };
        {
            let cuts = msg::report_wire(&rep, &mut self.scratch)?;
            let mut w = self
                .writer
                .lock()
                .map_err(|_| anyhow!("transport: writer mutex poisoned"))?;
            cuts.write(&mut *w, &self.header, &self.scratch)?;
        }
        // the encoded payloads just shipped are spent: reclaim their
        // wire buffers locally and hand them back to the session as
        // Spares on the next recv
        let slices: Vec<WireSlice> = rep
            .reps
            .into_iter()
            .filter_map(|(_, _, p)| match p {
                SyncPayload::Encoded(ws) => Some(ws),
                _ => None,
            })
            .collect();
        self.spares.extend(reclaim_wires(slices));
        Ok(())
    }

    fn stream_contrib(&self) -> bool {
        true
    }

    /// One vectored write under the writer mutex: frame header + the
    /// 8-byte chunk meta + the borrowed chunk bytes, so the encoder's
    /// wire view ships without ever being copied into a frame buffer.
    /// Holding the mutex across the whole write keeps chunk, report,
    /// and heartbeat frames from interleaving — lanes stay FIFO, which
    /// is what lets the closing report prove every chunk arrived.
    fn send_contrib_chunk(
        &mut self,
        rid: usize,
        sync_index: u64,
        frag: Option<usize>,
        offset: usize,
        chunk: &[u8],
    ) -> Result<()> {
        let mut h = self.header.clone();
        h.kind = MsgKind::ContribChunk;
        h.sync_index = sync_index;
        h.frag = frag.map(|f| f as u32);
        let meta = msg::contrib_chunk_meta(rid, offset)?;
        let hdr = header_bytes(&h, msg::CONTRIB_META_LEN + chunk.len())?;
        let mut w = self
            .writer
            .lock()
            .map_err(|_| anyhow!("transport: writer mutex poisoned"))?;
        write_all_vectored(&mut *w, &[&hdr[..], &meta[..], chunk])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::write_all_vectored;
    use crate::transport::msg::SegmentChurn;

    fn session(universe: usize) -> SessionInfo {
        SessionInfo {
            fingerprint: 0xDEAD_BEEF,
            up_bits: 32,
            down_bits: 32,
            engine: ENGINE_TOY,
            live: vec![true; universe],
            config_json: "{\"seed\":17}".to_string(),
        }
    }

    fn run_cmd(from: usize, to: usize) -> Cmd {
        Cmd::Run {
            from,
            to,
            broadcast: Broadcast::empty(),
            payload: PayloadSpec::None,
            churn: SegmentChurn::default(),
        }
    }

    #[test]
    fn connect_backoff_names_address_and_attempts() {
        // a port nothing listens on: bind, learn the port, drop
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = connect_with_backoff(&addr, 3).expect_err("nothing listens there");
        let msg = format!("{err:#}");
        assert!(msg.contains(&addr), "{msg}");
        assert!(msg.contains("3 attempts"), "{msg}");
    }

    #[test]
    fn loopback_handshake_and_one_segment() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let info = session(2);
        let worker_info = info.clone();
        let worker = std::thread::spawn(move || {
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            let got = worker_handshake(&mut stream, &[0, 1], 0, 0, 0).unwrap();
            assert_eq!(got.fingerprint, worker_info.fingerprint);
            assert_eq!(got.engine, ENGINE_TOY);
            assert_eq!(got.live, vec![true, true]);
            assert_eq!(got.config_json, worker_info.config_json);
            let mut link = TcpWorkerLink::new(stream, &got).unwrap();
            let Some(Cmd::Run { from, to, .. }) = link.recv_cmd() else {
                panic!("expected Run");
            };
            assert_eq!((from, to), (0, 3));
            link.send_report(Ok(WorkerReport {
                reps: vec![
                    (0, vec![1.5, 2.5, 3.5], SyncPayload::Skipped),
                    (
                        1,
                        vec![4.5, 5.5, 6.5],
                        SyncPayload::Encoded(WireSlice::copied_from(&[7, 7])),
                    ),
                ],
            }))
            .unwrap();
            // the shipped encode buffer comes straight back as a
            // locally synthesized Spares — no socket read involved
            let Some(Cmd::Spares(bufs)) = link.recv_cmd() else {
                panic!("expected the reclaimed report buffer as Spares");
            };
            assert_eq!(bufs.len(), 1);
            assert!(link.recv_cmd().is_none(), "coordinator closed: clean end");
        });
        let mut lanes = accept_workers(&listener, 1, &info).unwrap();
        assert_eq!(lanes.len(), 1);
        assert_eq!(lanes[0].1, vec![0, 1]);
        let lane = &mut lanes[0].0;
        lane.send(Cmd::Spares(vec![WireBuf::new()])).unwrap(); // dropped, not sent
        lane.send(run_cmd(0, 3)).unwrap();
        let report = lane.recv().unwrap().unwrap();
        assert_eq!(report.reps[0].1, vec![1.5, 2.5, 3.5]);
        assert!(
            matches!(report.reps[1].2, SyncPayload::Encoded(ref b) if b.as_slice() == [7, 7])
        );
        drop(lanes);
        worker.join().unwrap();
    }

    #[test]
    fn fingerprint_mismatch_rejects_fail_loud() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = std::thread::spawn(move || {
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            worker_handshake(&mut stream, &[0], 0x1234, 0, 0)
                .expect_err("mismatched fingerprint must be rejected")
        });
        let err =
            accept_workers(&listener, 1, &session(1)).expect_err("coordinator fails loud too");
        let msg = format!("{err:#}");
        assert!(msg.contains("fingerprint mismatch"), "{msg}");
        assert!(msg.contains("0x0000000000001234"), "{msg}");
        let werr = format!("{:#}", worker.join().unwrap());
        assert!(werr.contains("rejected"), "{werr}");
        assert!(werr.contains("fingerprint"), "{werr}");
    }

    #[test]
    fn overlapping_claims_reject() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let a1 = addr.clone();
        let w1 = std::thread::spawn(move || {
            let mut s = connect_with_backoff(&a1, CONNECT_ATTEMPTS).unwrap();
            worker_handshake(&mut s, &[0, 1], 0, 0, 0).map(|_| s)
        });
        let w2 = std::thread::spawn(move || {
            // second worker waits so the claim order is deterministic
            std::thread::sleep(Duration::from_millis(200));
            let mut s = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            worker_handshake(&mut s, &[1], 0, 0, 0).map(|_| s)
        });
        let err = accept_workers(&listener, 2, &session(2)).expect_err("claim overlap");
        assert!(format!("{err:#}").contains("already claimed"), "{err:#}");
        assert!(w1.join().unwrap().is_ok(), "first claimer was welcomed");
        assert!(w2.join().unwrap().is_err(), "second claimer was rejected");
    }

    #[test]
    fn dead_worker_times_out_as_lane_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = std::thread::spawn(move || {
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            let info = worker_handshake(&mut stream, &[0], 0, 0, 0).unwrap();
            let link = TcpWorkerLink::new(stream, &info).unwrap();
            // die without reporting: drop the link (and socket)
            drop(link);
        });
        let mut lanes = accept_workers(&listener, 1, &session(1)).unwrap();
        worker.join().unwrap();
        let err = lanes[0].0.recv().expect_err("closed socket = dead lane");
        assert!(!format!("{err:#}").is_empty());
    }

    // ---- lane reactor -------------------------------------------------

    #[test]
    fn reactor_runs_a_segment_over_two_lanes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let info = session(2);
        let workers: Vec<_> = (0..2usize)
            .map(|rid| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
                    let got = worker_handshake(&mut stream, &[rid], 0, 0, 0).unwrap();
                    let mut link = TcpWorkerLink::new(stream, &got).unwrap();
                    let Some(Cmd::Run { from, to, .. }) = link.recv_cmd() else {
                        panic!("expected Run");
                    };
                    assert_eq!((from, to), (0, 2));
                    link.send_report(Ok(WorkerReport {
                        reps: vec![(
                            rid,
                            vec![rid as f64 + 0.5],
                            SyncPayload::Encoded(WireSlice::copied_from(&[rid as u8; 3])),
                        )],
                    }))
                    .unwrap();
                    let Some(Cmd::Spares(bufs)) = link.recv_cmd() else {
                        panic!("expected local Spares");
                    };
                    assert_eq!(bufs.len(), 1);
                    let Some(Cmd::Finish { .. }) = link.recv_cmd() else {
                        panic!("expected Finish");
                    };
                })
            })
            .collect();
        let lanes = accept_workers(&listener, 2, &info).unwrap();
        let mut reactor = LaneReactor::new(lanes).unwrap();
        let rids: Vec<usize> = reactor.lane_rids().into_iter().flatten().collect();
        assert_eq!(rids.len(), 2);
        reactor.send_cmd(&run_cmd(0, 2)).unwrap();
        let reports = reactor.collect_reports().unwrap();
        assert_eq!(reports.len(), 2);
        let mut seen: Vec<usize> = reports
            .iter()
            .flat_map(|r| r.reps.iter().map(|(rid, ..)| *rid))
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1]);
        for r in &reports {
            let (rid, losses, p) = &r.reps[0];
            assert_eq!(losses, &vec![*rid as f64 + 0.5]);
            let SyncPayload::Encoded(ws) = p else {
                panic!("expected an encoded payload");
            };
            assert_eq!(ws.as_slice(), &[*rid as u8; 3]);
        }
        assert!(reactor.dead_rids().is_empty());
        assert!(reactor.take_lost().is_empty());
        reactor.send_finish(&Broadcast::empty());
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn streamed_broadcast_resolves_against_the_stash() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let info = session(1);
        let worker = std::thread::spawn(move || {
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            let got = worker_handshake(&mut stream, &[0], 0, 0, 0).unwrap();
            let mut link = TcpWorkerLink::new(stream, &got).unwrap();
            // the Pending marker must come back resolved, carrying the
            // chunks exactly as the coordinator flushed them
            let Some(Cmd::Run {
                broadcast: Broadcast::Encoded { frag, bytes },
                ..
            }) = link.recv_cmd()
            else {
                panic!("expected Run with a resolved broadcast");
            };
            assert_eq!(frag, Some(1));
            assert_eq!(bytes.as_slice(), &[1, 2, 3, 4, 5, 6]);
            drop(bytes);
            link.send_report(Ok(WorkerReport {
                reps: vec![(0, vec![1.0], SyncPayload::Skipped)],
            }))
            .unwrap();
            let Some(Cmd::Finish {
                broadcast: Broadcast::Encoded { frag, bytes },
            }) = link.recv_cmd()
            else {
                panic!("expected Finish with a resolved broadcast");
            };
            assert_eq!(frag, None);
            assert_eq!(bytes.as_slice(), &[9, 9, 9, 9]);
        });
        let lanes = accept_workers(&listener, 1, &info).unwrap();
        let mut reactor = LaneReactor::new(lanes).unwrap();
        reactor.bcast_begin(Some(1), 7, 6).unwrap();
        reactor.bcast_chunk(&[1, 2, 3]).unwrap();
        reactor.bcast_chunk(&[4, 5, 6]).unwrap();
        let err = reactor.bcast_chunk(&[0]).expect_err("overrun must fail");
        assert!(format!("{err:#}").contains("overruns"), "{err:#}");
        reactor
            .send_cmd(&Cmd::Run {
                from: 0,
                to: 1,
                broadcast: Broadcast::Pending { frag: Some(1) },
                payload: PayloadSpec::None,
                churn: SegmentChurn::default(),
            })
            .unwrap();
        assert_eq!(reactor.collect_reports().unwrap().len(), 1);
        reactor.bcast_begin(None, 8, 4).unwrap();
        reactor.bcast_chunk(&[9, 9, 9, 9]).unwrap();
        reactor.send_finish(&Broadcast::Pending { frag: None });
        worker.join().unwrap();
    }

    #[test]
    fn a_vanished_worker_becomes_lost_rids_not_a_hang() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let info = session(2);
        let a1 = addr.clone();
        let steady = std::thread::spawn(move || {
            let mut stream = connect_with_backoff(&a1, CONNECT_ATTEMPTS).unwrap();
            let got = worker_handshake(&mut stream, &[0], 0, 0, 0).unwrap();
            let mut link = TcpWorkerLink::new(stream, &got).unwrap();
            let Some(Cmd::Run { .. }) = link.recv_cmd() else {
                panic!("expected Run");
            };
            link.send_report(Ok(WorkerReport {
                reps: vec![(0, vec![2.0], SyncPayload::Skipped)],
            }))
            .unwrap();
            let Some(Cmd::Finish { .. }) = link.recv_cmd() else {
                panic!("expected Finish");
            };
        });
        let vanisher = std::thread::spawn(move || {
            // claim second, so the claim order is deterministic
            std::thread::sleep(Duration::from_millis(100));
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            let got = worker_handshake(&mut stream, &[1], 0, 0, 0).unwrap();
            let link = TcpWorkerLink::new(stream, &got).unwrap();
            drop(link); // die right after the handshake
        });
        let lanes = accept_workers(&listener, 2, &info).unwrap();
        vanisher.join().unwrap();
        let mut reactor = LaneReactor::new(lanes).unwrap();
        reactor.send_cmd(&run_cmd(0, 1)).unwrap();
        let reports = reactor.collect_reports().unwrap();
        assert_eq!(reports.len(), 1, "only the steady worker reports");
        assert_eq!(reports[0].reps[0].0, 0);
        assert_eq!(reactor.dead_rids(), vec![1]);
        assert_eq!(reactor.take_lost(), vec![1]);
        assert!(reactor.take_lost().is_empty(), "lost drains once");
        reactor.send_finish(&Broadcast::empty());
        steady.join().unwrap();
    }

    #[test]
    fn heartbeats_are_consumed_and_counted_as_control_bytes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let info = session(1);
        let worker = std::thread::spawn(move || {
            // a hand-driven worker (no background heartbeat thread), so
            // the control-byte count below is exact
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            worker_handshake(&mut stream, &[0], 0, 0, 0).unwrap();
            for _ in 0..3 {
                write_frame(&mut stream, &FrameHeader::bare(MsgKind::Heartbeat), &[]).unwrap();
            }
            let report = WorkerReport {
                reps: vec![(0, vec![4.25], SyncPayload::Skipped)],
            };
            let mut scratch = Vec::new();
            let cuts = msg::report_wire(&report, &mut scratch).unwrap();
            let hdr = header_bytes(
                &FrameHeader::bare(MsgKind::Report),
                cuts.payload_len(&scratch),
            )
            .unwrap();
            let mut parts: Vec<&[u8]> = vec![&hdr];
            parts.extend(cuts.parts(&scratch));
            write_all_vectored(&mut stream, &parts).unwrap();
        });
        let lanes = accept_workers(&listener, 1, &info).unwrap();
        let mut reactor = LaneReactor::new(lanes).unwrap();
        let reports = reactor.collect_reports().unwrap();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].reps[0].1, vec![4.25]);
        assert_eq!(
            reactor.take_control_bytes(),
            3 * HEADER_LEN as u64,
            "three heartbeat frames, header-only each"
        );
        assert_eq!(reactor.take_control_bytes(), 0, "control drains once");
        worker.join().unwrap();
    }

    #[test]
    fn streamed_contribs_bypass_a_stalled_lane() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let info = session(2);
        let payload = |rid: usize| vec![rid as u8 + 0xA0; 700];
        let workers: Vec<_> = (0..2usize)
            .map(|rid| {
                let addr = addr.clone();
                let bytes = payload(rid);
                std::thread::spawn(move || {
                    let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
                    let got = worker_handshake(&mut stream, &[rid], 0, 0, 0).unwrap();
                    let mut link = TcpWorkerLink::new(stream, &got).unwrap();
                    let Some(Cmd::Run { .. }) = link.recv_cmd() else {
                        panic!("expected Run");
                    };
                    // lane 0 stalls before its first chunk; lane 1's
                    // chunks must reach the sink regardless
                    if rid == 0 {
                        std::thread::sleep(Duration::from_millis(300));
                    }
                    let cuts = [0, 250, 700];
                    for w in cuts.windows(2) {
                        link.send_contrib_chunk(rid, 4, None, w[0], &bytes[w[0]..w[1]])
                            .unwrap();
                    }
                    link.send_report(Ok(WorkerReport {
                        reps: vec![(rid, vec![rid as f64], SyncPayload::Streamed)],
                    }))
                    .unwrap();
                    let Some(Cmd::Finish { .. }) = link.recv_cmd() else {
                        panic!("expected Finish");
                    };
                })
            })
            .collect();
        let lanes = accept_workers(&listener, 2, &info).unwrap();
        let mut reactor = LaneReactor::new(lanes).unwrap();
        reactor.send_cmd(&run_cmd(0, 2)).unwrap();
        let mut got: Vec<(usize, usize, Vec<u8>)> = Vec::new();
        let reports = reactor
            .collect_reports_streamed(4, None, &mut |rid, off, ws| {
                got.push((rid, off, ws.as_slice().to_vec()));
                Ok(())
            })
            .unwrap();
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(matches!(r.reps[0].2, SyncPayload::Streamed));
        }
        // readiness servicing: every chunk of the prompt lane landed
        // before the stalled lane produced its first one
        let first_stalled = got.iter().position(|(rid, ..)| *rid == 0).unwrap();
        assert_eq!(
            got[..first_stalled].iter().filter(|(rid, ..)| *rid == 1).count(),
            2,
            "lane 1's chunks must not wait behind stalled lane 0: {:?}",
            got.iter().map(|(r, o, b)| (*r, *o, b.len())).collect::<Vec<_>>()
        );
        for rid in 0..2 {
            let mut cat = Vec::new();
            for (_, off, b) in got.iter().filter(|(r, ..)| *r == rid) {
                assert_eq!(*off, cat.len(), "chunks arrive in payload order");
                cat.extend_from_slice(b);
            }
            assert_eq!(cat, payload(rid));
        }
        reactor.send_finish(&Broadcast::empty());
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn a_chunk_claiming_a_foreign_replica_fails_the_run() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let info = session(2);
        let a1 = addr.clone();
        let rogue = std::thread::spawn(move || {
            let mut stream = connect_with_backoff(&a1, CONNECT_ATTEMPTS).unwrap();
            let got = worker_handshake(&mut stream, &[0], 0, 0, 0).unwrap();
            let mut link = TcpWorkerLink::new(stream, &got).unwrap();
            let Some(Cmd::Run { .. }) = link.recv_cmd() else {
                panic!("expected Run");
            };
            // claims replica 1, which the other lane owns
            link.send_contrib_chunk(1, 0, None, 0, &[7; 16]).unwrap();
            assert!(link.recv_cmd().is_none(), "coordinator bailed");
        });
        let bystander = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            let got = worker_handshake(&mut stream, &[1], 0, 0, 0).unwrap();
            let mut link = TcpWorkerLink::new(stream, &got).unwrap();
            let Some(Cmd::Run { .. }) = link.recv_cmd() else {
                panic!("expected Run");
            };
            assert!(link.recv_cmd().is_none(), "coordinator bailed");
        });
        let lanes = accept_workers(&listener, 2, &info).unwrap();
        let mut reactor = LaneReactor::new(lanes).unwrap();
        reactor.send_cmd(&run_cmd(0, 1)).unwrap();
        let err = reactor
            .collect_reports_streamed(0, None, &mut |_, _, _| Ok(()))
            .expect_err("a lane streaming another lane's replica is a protocol violation");
        assert!(format!("{err:#}").contains("claiming replica 1"), "{err:#}");
        drop(reactor);
        rogue.join().unwrap();
        bystander.join().unwrap();
    }

    #[test]
    fn a_worker_error_frame_fails_the_collect() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let info = session(1);
        let worker = std::thread::spawn(move || {
            let mut stream = connect_with_backoff(&addr, CONNECT_ATTEMPTS).unwrap();
            let got = worker_handshake(&mut stream, &[0], 0, 0, 0).unwrap();
            let mut link = TcpWorkerLink::new(stream, &got).unwrap();
            let Some(Cmd::Run { .. }) = link.recv_cmd() else {
                panic!("expected Run");
            };
            link.send_report(Err(anyhow!("engine exploded"))).unwrap();
        });
        let lanes = accept_workers(&listener, 1, &info).unwrap();
        let mut reactor = LaneReactor::new(lanes).unwrap();
        reactor.send_cmd(&run_cmd(0, 1)).unwrap();
        let err = reactor
            .collect_reports()
            .expect_err("a worker-reported engine error fails the run");
        assert!(format!("{err:#}").contains("engine exploded"), "{err:#}");
        worker.join().unwrap();
    }
}
