//! The worker↔coordinator message plane: the types that cross a
//! [`Lane`](crate::transport::Lane), and their byte serialization for
//! transports that leave the process.
//!
//! These types were born inside `coordinator/pool.rs` hard-wired to
//! `std::sync::mpsc`; they live here now so every transport speaks the
//! same vocabulary. In-process lanes move them as Rust values (the
//! zero-copy `Arc` handoff the oracle path depends on); the TCP lane
//! serializes them with the little-endian codecs below. Serialization
//! is **exact**: f32/f64 values travel as raw bit patterns, so a
//! payload decoded on the far side is bit-identical to the value sent
//! — the loopback twin test pins the whole pipeline on this.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::frame::{MsgKind, MAX_PAYLOAD};

/// Literal adopt list: (leaf index, shared literal) pairs every replica
/// applies before its next inner step.
pub type Adopt = Vec<(usize, Arc<xla::Literal>)>;

/// One broadcast as it leaves the coordinator.
#[derive(Clone)]
pub enum Broadcast {
    /// Identity down-wire (and Data-Parallel): deduplicated `Arc`
    /// literal handoff — zero-copy, one upload per leaf run-wide.
    Literals(Adopt),
    /// Lossy down-wire: the fragment's single encoded payload, one
    /// allocation `Arc`-shared by every worker; each decodes it into
    /// its shared snapshot.
    Encoded {
        frag: Option<usize>,
        bytes: Arc<Vec<u8>>,
    },
}

impl Broadcast {
    pub fn empty() -> Broadcast {
        Broadcast::Literals(Vec::new())
    }
}

/// What the coordinator told the workers to produce at segment end.
#[derive(Debug, Clone)]
pub struct EncodeSpec {
    /// Streaming fragment due at the boundary (None = full sync).
    pub frag: Option<usize>,
    /// 0-based outer-sync index (stochastic-rounding seed component).
    pub sync_index: u64,
}

/// What a segment's boundary asks of the workers. Merge-only
/// boundaries (and the drain's main segment) ask for nothing — the
/// coordinator would discard it, so the workers never build it.
#[derive(Debug, Clone)]
pub enum PayloadSpec {
    /// No payload crosses at this boundary.
    None,
    /// Current parameter literal handles (identity up-wire sends, and
    /// every Data-Parallel segment — its boundary eval reads them).
    Params,
    /// Encoded wire contribution for the due fragment (lossy up-wire).
    Encoded(EncodeSpec),
}

/// One replica's contribution at a segment boundary.
pub enum SyncPayload {
    /// Data-Parallel (and identity up-wire sends): current parameter
    /// literal handles.
    Params(Vec<Arc<xla::Literal>>),
    /// DiLoCo lossy up-wire: the encoded contribution for the due
    /// fragment.
    Encoded(Vec<u8>),
    /// The boundary asked for nothing ([`PayloadSpec::None`]) —
    /// consuming this anywhere is a coordinator bug and fails loud.
    Skipped,
}

/// Per-segment result: `losses[r]` / `payloads[r]` for replica r.
pub type SegmentData = (Vec<Vec<f64>>, Vec<SyncPayload>);

/// Membership changes taking effect at a segment's dispatch, in
/// application order: `deaths` freeze their replicas *before* the
/// broadcast is adopted (a crashed/left replica never sees a merge it
/// missed), then live replicas adopt the broadcast, then `joins` come
/// alive initialized from the current broadcast view — either
/// `join_view` (full-leaf literal list the coordinator built from the
/// global; identity wires, where workers keep no snapshot) or the
/// worker's own decoded snapshot (lossy wires — which also hands the
/// joiner the down-wire EF stream state for free, since the snapshot
/// *is* that stream's decode state).
#[derive(Clone, Default)]
pub struct SegmentChurn {
    pub deaths: Vec<usize>,
    pub joins: Vec<usize>,
    pub join_view: Adopt,
}

impl SegmentChurn {
    pub fn is_empty(&self) -> bool {
        self.deaths.is_empty() && self.joins.is_empty()
    }
}

/// A coordinator→worker command.
pub enum Cmd {
    /// Apply membership changes and the broadcast, run steps
    /// (from, to], then build the boundary payload `payload` asks for.
    Run {
        from: usize,
        to: usize,
        broadcast: Broadcast,
        payload: PayloadSpec,
        churn: SegmentChurn,
    },
    /// Spent wire payload buffers from a completed reduce, returned
    /// for this worker's encode pool. No reply — the worker absorbs
    /// them between segments. Never serialized: shipping empty
    /// buffers across a socket to save the far side an allocation
    /// would cost more than it saves, so the TCP lane drops these.
    Spares(Vec<Vec<u8>>),
    /// Apply the final broadcast and exit, returning replica ownership.
    Finish { broadcast: Broadcast },
}

/// A worker's answer to one `Cmd::Run`.
pub struct WorkerReport {
    /// (replica id, per-step losses, boundary sync payload).
    pub reps: Vec<(usize, Vec<f64>, SyncPayload)>,
}

// ---- byte serialization ----------------------------------------------
//
// Everything little-endian; floats as raw bit patterns (exactness is
// load-bearing). Containers are u32-counted — MAX_PAYLOAD bounds any
// single frame long before u32 does.

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(out: &mut Vec<u8>, v: usize) -> Result<()> {
    let v = u32::try_from(v).map_err(|_| anyhow!("msg: count {v} exceeds u32"))?;
    put_u32(out, v);
    Ok(())
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) -> Result<()> {
    put_usize(out, b.len())?;
    out.extend_from_slice(b);
    Ok(())
}

fn put_opt_frag(out: &mut Vec<u8>, frag: Option<usize>) -> Result<()> {
    match frag {
        Some(f) => {
            out.push(1);
            put_usize(out, f)?;
        }
        None => out.push(0),
    }
    Ok(())
}

fn put_literal(out: &mut Vec<u8>, lit: &xla::Literal) -> Result<()> {
    let shape = lit.array_shape()?;
    let dims = shape.dims();
    put_usize(out, dims.len())?;
    for &d in dims {
        put_u64(out, u64::try_from(d).map_err(|_| anyhow!("msg: negative dim {d}"))?);
    }
    let data = lit.to_vec::<f32>()?;
    put_usize(out, data.len())?;
    out.reserve(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    Ok(())
}

fn put_adopt(out: &mut Vec<u8>, list: &Adopt) -> Result<()> {
    put_usize(out, list.len())?;
    for (leaf, lit) in list {
        put_usize(out, *leaf)?;
        put_literal(out, lit)?;
    }
    Ok(())
}

/// Bounds-checked little-endian reader: every truncation is a clean
/// `Err`, never a slice panic.
struct Rd<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Rd<'a> {
        Rd { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .at
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow!(
                    "msg: truncated payload (need {n} bytes at offset {}, have {})",
                    self.at,
                    self.buf.len() - self.at.min(self.buf.len())
                )
            })?;
        let s = &self.buf[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn count(&mut self) -> Result<usize> {
        let n = self.u32()? as usize;
        // a count can never describe more bytes than a frame may hold
        if n > MAX_PAYLOAD {
            bail!("msg: count {n} exceeds any legal payload");
        }
        Ok(n)
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.count()?;
        Ok(self.take(n)?.to_vec())
    }

    fn opt_frag(&mut self) -> Result<Option<usize>> {
        Ok(match self.u8()? {
            0 => None,
            _ => Some(self.count()?),
        })
    }

    fn literal(&mut self) -> Result<Arc<xla::Literal>> {
        let ndims = self.count()?;
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            dims.push(i64::try_from(self.u64()?).map_err(|_| anyhow!("msg: dim exceeds i64"))?);
        }
        let n = self.count()?;
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(f32::from_bits(self.u32()?));
        }
        Ok(Arc::new(xla::Literal::vec1(&data).reshape(&dims)?))
    }

    fn adopt(&mut self) -> Result<Adopt> {
        let n = self.count()?;
        let mut list = Vec::with_capacity(n);
        for _ in 0..n {
            let leaf = self.count()?;
            list.push((leaf, self.literal()?));
        }
        Ok(list)
    }

    fn done(&self) -> Result<()> {
        if self.at != self.buf.len() {
            bail!(
                "msg: {} trailing bytes after a complete message",
                self.buf.len() - self.at
            );
        }
        Ok(())
    }
}

fn put_broadcast(out: &mut Vec<u8>, b: &Broadcast) -> Result<()> {
    match b {
        Broadcast::Literals(list) => {
            out.push(0);
            put_adopt(out, list)
        }
        Broadcast::Encoded { frag, bytes } => {
            out.push(1);
            put_opt_frag(out, *frag)?;
            put_bytes(out, bytes)
        }
    }
}

fn read_broadcast(rd: &mut Rd) -> Result<Broadcast> {
    Ok(match rd.u8()? {
        0 => Broadcast::Literals(rd.adopt()?),
        1 => Broadcast::Encoded {
            frag: rd.opt_frag()?,
            bytes: Arc::new(rd.bytes()?),
        },
        t => bail!("msg: unknown broadcast tag {t}"),
    })
}

fn put_payload_spec(out: &mut Vec<u8>, p: &PayloadSpec) -> Result<()> {
    match p {
        PayloadSpec::None => out.push(0),
        PayloadSpec::Params => out.push(1),
        PayloadSpec::Encoded(spec) => {
            out.push(2);
            put_opt_frag(out, spec.frag)?;
            put_u64(out, spec.sync_index);
        }
    }
    Ok(())
}

fn read_payload_spec(rd: &mut Rd) -> Result<PayloadSpec> {
    Ok(match rd.u8()? {
        0 => PayloadSpec::None,
        1 => PayloadSpec::Params,
        2 => PayloadSpec::Encoded(EncodeSpec {
            frag: rd.opt_frag()?,
            sync_index: rd.u64()?,
        }),
        t => bail!("msg: unknown payload-spec tag {t}"),
    })
}

fn put_churn(out: &mut Vec<u8>, c: &SegmentChurn) -> Result<()> {
    put_usize(out, c.deaths.len())?;
    for &d in &c.deaths {
        put_usize(out, d)?;
    }
    put_usize(out, c.joins.len())?;
    for &j in &c.joins {
        put_usize(out, j)?;
    }
    put_adopt(out, &c.join_view)
}

fn read_churn(rd: &mut Rd) -> Result<SegmentChurn> {
    let n = rd.count()?;
    let mut deaths = Vec::with_capacity(n);
    for _ in 0..n {
        deaths.push(rd.count()?);
    }
    let n = rd.count()?;
    let mut joins = Vec::with_capacity(n);
    for _ in 0..n {
        joins.push(rd.count()?);
    }
    Ok(SegmentChurn {
        deaths,
        joins,
        join_view: rd.adopt()?,
    })
}

fn put_sync_payload(out: &mut Vec<u8>, p: &SyncPayload) -> Result<()> {
    match p {
        SyncPayload::Params(lits) => {
            out.push(0);
            put_usize(out, lits.len())?;
            for lit in lits {
                put_literal(out, lit)?;
            }
        }
        SyncPayload::Encoded(bytes) => {
            out.push(1);
            put_bytes(out, bytes)?;
        }
        SyncPayload::Skipped => out.push(2),
    }
    Ok(())
}

fn read_sync_payload(rd: &mut Rd) -> Result<SyncPayload> {
    Ok(match rd.u8()? {
        0 => {
            let n = rd.count()?;
            let mut lits = Vec::with_capacity(n);
            for _ in 0..n {
                lits.push(rd.literal()?);
            }
            SyncPayload::Params(lits)
        }
        1 => SyncPayload::Encoded(rd.bytes()?),
        2 => SyncPayload::Skipped,
        t => bail!("msg: unknown sync-payload tag {t}"),
    })
}

/// Serialize a command into `out`; returns the frame kind it travels
/// under. `Spares` is deliberately unencodable (see [`Cmd::Spares`]).
pub fn cmd_payload(cmd: &Cmd, out: &mut Vec<u8>) -> Result<MsgKind> {
    match cmd {
        Cmd::Run {
            from,
            to,
            broadcast,
            payload,
            churn,
        } => {
            put_u64(out, *from as u64);
            put_u64(out, *to as u64);
            put_broadcast(out, broadcast)?;
            put_payload_spec(out, payload)?;
            put_churn(out, churn)?;
            Ok(MsgKind::Run)
        }
        Cmd::Finish { broadcast } => {
            put_broadcast(out, broadcast)?;
            Ok(MsgKind::Finish)
        }
        Cmd::Spares(_) => bail!("msg: Spares never crosses a serialized transport"),
    }
}

/// Deserialize a command from a received frame.
pub fn cmd_from_frame(kind: MsgKind, payload: &[u8]) -> Result<Cmd> {
    let mut rd = Rd::new(payload);
    let cmd = match kind {
        MsgKind::Run => {
            let from = rd.u64()? as usize;
            let to = rd.u64()? as usize;
            let broadcast = read_broadcast(&mut rd)?;
            let payload = read_payload_spec(&mut rd)?;
            let churn = read_churn(&mut rd)?;
            Cmd::Run {
                from,
                to,
                broadcast,
                payload,
                churn,
            }
        }
        MsgKind::Finish => Cmd::Finish {
            broadcast: read_broadcast(&mut rd)?,
        },
        other => bail!("msg: frame kind {other:?} is not a command"),
    };
    rd.done()?;
    Ok(cmd)
}

/// Serialize a worker report.
pub fn report_payload(report: &WorkerReport, out: &mut Vec<u8>) -> Result<()> {
    put_usize(out, report.reps.len())?;
    for (rid, losses, payload) in &report.reps {
        put_usize(out, *rid)?;
        put_usize(out, losses.len())?;
        for &l in losses {
            put_u64(out, l.to_bits());
        }
        put_sync_payload(out, payload)?;
    }
    Ok(())
}

/// Deserialize a worker report.
pub fn report_from_payload(payload: &[u8]) -> Result<WorkerReport> {
    let mut rd = Rd::new(payload);
    let n = rd.count()?;
    let mut reps = Vec::with_capacity(n);
    for _ in 0..n {
        let rid = rd.count()?;
        let nl = rd.count()?;
        let mut losses = Vec::with_capacity(nl);
        for _ in 0..nl {
            losses.push(f64::from_bits(rd.u64()?));
        }
        reps.push((rid, losses, read_sync_payload(&mut rd)?));
    }
    rd.done()?;
    Ok(WorkerReport { reps })
}

/// Handshake Hello payload: the replica ids this worker claims.
pub fn hello_payload(claims: &[usize], out: &mut Vec<u8>) -> Result<()> {
    put_usize(out, claims.len())?;
    for &r in claims {
        put_usize(out, r)?;
    }
    Ok(())
}

pub fn hello_from_payload(payload: &[u8]) -> Result<Vec<usize>> {
    let mut rd = Rd::new(payload);
    let n = rd.count()?;
    let mut claims = Vec::with_capacity(n);
    for _ in 0..n {
        claims.push(rd.count()?);
    }
    rd.done()?;
    Ok(claims)
}

/// Handshake Welcome payload: engine kind, initial liveness over the
/// replica universe, and the coordinator's run config JSON (the source
/// of truth the worker rebuilds from).
pub fn welcome_payload(
    engine: u8,
    live: &[bool],
    config_json: &str,
    out: &mut Vec<u8>,
) -> Result<()> {
    out.push(engine);
    put_usize(out, live.len())?;
    out.extend(live.iter().map(|&l| l as u8));
    put_bytes(out, config_json.as_bytes())
}

pub fn welcome_from_payload(payload: &[u8]) -> Result<(u8, Vec<bool>, String)> {
    let mut rd = Rd::new(payload);
    let engine = rd.u8()?;
    let n = rd.count()?;
    let live = rd.take(n)?.iter().map(|&b| b != 0).collect();
    let config = String::from_utf8(rd.bytes()?)
        .map_err(|_| anyhow!("msg: welcome config is not UTF-8"))?;
    rd.done()?;
    Ok((engine, live, config))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lit(shape: &[i64], vals: &[f32]) -> Arc<xla::Literal> {
        Arc::new(xla::Literal::vec1(vals).reshape(shape).unwrap())
    }

    #[test]
    fn run_cmd_roundtrips_bit_exact() {
        let cmd = Cmd::Run {
            from: 3,
            to: 9,
            broadcast: Broadcast::Literals(vec![
                (0, lit(&[2, 2], &[1.5, -0.0, f32::MIN_POSITIVE, 3.25])),
                (2, lit(&[3], &[0.1, 0.2, 0.3])),
            ]),
            payload: PayloadSpec::Encoded(EncodeSpec {
                frag: Some(1),
                sync_index: 42,
            }),
            churn: SegmentChurn {
                deaths: vec![1],
                joins: vec![3],
                join_view: vec![(0, lit(&[1], &[7.0]))],
            },
        };
        let mut buf = Vec::new();
        let kind = cmd_payload(&cmd, &mut buf).unwrap();
        assert_eq!(kind, MsgKind::Run);
        let back = cmd_from_frame(kind, &buf).unwrap();
        let Cmd::Run {
            from,
            to,
            broadcast,
            payload,
            churn,
        } = back
        else {
            panic!("wrong command kind");
        };
        assert_eq!((from, to), (3, 9));
        let Broadcast::Literals(list) = broadcast else {
            panic!("wrong broadcast kind");
        };
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].0, 0);
        // bit-exact, including the negative zero
        let v = list[0].1.to_vec::<f32>().unwrap();
        assert_eq!(
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            [1.5f32, -0.0, f32::MIN_POSITIVE, 3.25]
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<_>>()
        );
        assert_eq!(list[0].1.array_shape().unwrap().dims(), &[2, 2]);
        let PayloadSpec::Encoded(spec) = payload else {
            panic!("wrong payload spec");
        };
        assert_eq!((spec.frag, spec.sync_index), (Some(1), 42));
        assert_eq!((churn.deaths, churn.joins), (vec![1], vec![3]));
        assert_eq!(churn.join_view.len(), 1);
    }

    #[test]
    fn finish_and_encoded_broadcast_roundtrip() {
        let cmd = Cmd::Finish {
            broadcast: Broadcast::Encoded {
                frag: None,
                bytes: Arc::new(vec![1, 2, 3, 255]),
            },
        };
        let mut buf = Vec::new();
        let kind = cmd_payload(&cmd, &mut buf).unwrap();
        assert_eq!(kind, MsgKind::Finish);
        let Cmd::Finish {
            broadcast: Broadcast::Encoded { frag, bytes },
        } = cmd_from_frame(kind, &buf).unwrap()
        else {
            panic!("wrong shape back");
        };
        assert_eq!(frag, None);
        assert_eq!(&bytes[..], &[1, 2, 3, 255]);
    }

    #[test]
    fn spares_never_serialize() {
        assert!(cmd_payload(&Cmd::Spares(vec![vec![0u8; 4]]), &mut Vec::new()).is_err());
    }

    #[test]
    fn report_roundtrips_losses_bit_exact() {
        let report = WorkerReport {
            reps: vec![
                (0, vec![1.0625, -2.5, f64::EPSILON], SyncPayload::Encoded(vec![9, 8, 7])),
                (2, Vec::new(), SyncPayload::Skipped),
                (4, vec![0.0], SyncPayload::Params(vec![lit(&[2], &[1.0, 2.0])])),
            ],
        };
        let mut buf = Vec::new();
        report_payload(&report, &mut buf).unwrap();
        let back = report_from_payload(&buf).unwrap();
        assert_eq!(back.reps.len(), 3);
        assert_eq!(back.reps[0].0, 0);
        assert_eq!(
            back.reps[0].1.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            report.reps[0].1.iter().map(|l| l.to_bits()).collect::<Vec<_>>()
        );
        assert!(matches!(back.reps[1].2, SyncPayload::Skipped));
        let SyncPayload::Params(lits) = &back.reps[2].2 else {
            panic!("wrong payload kind");
        };
        assert_eq!(lits[0].to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn truncated_messages_reject_cleanly() {
        let mut buf = Vec::new();
        report_payload(
            &WorkerReport {
                reps: vec![(1, vec![3.5, 4.5], SyncPayload::Encoded(vec![1, 2, 3]))],
            },
            &mut buf,
        )
        .unwrap();
        for cut in 0..buf.len() {
            assert!(
                report_from_payload(&buf[..cut]).is_err(),
                "cut at {cut} must reject"
            );
        }
        // trailing garbage rejects too
        buf.push(0);
        assert!(report_from_payload(&buf).is_err());
    }

    #[test]
    fn handshake_payloads_roundtrip() {
        let mut buf = Vec::new();
        hello_payload(&[0, 2, 5], &mut buf).unwrap();
        assert_eq!(hello_from_payload(&buf).unwrap(), vec![0, 2, 5]);

        let mut buf = Vec::new();
        welcome_payload(1, &[true, false, true], "{\"seed\":17}", &mut buf).unwrap();
        let (engine, live, cfg) = welcome_from_payload(&buf).unwrap();
        assert_eq!(engine, 1);
        assert_eq!(live, vec![true, false, true]);
        assert_eq!(cfg, "{\"seed\":17}");
    }
}
