//! The worker↔coordinator message plane: the types that cross a
//! [`Lane`](crate::transport::Lane), and their byte serialization for
//! transports that leave the process.
//!
//! These types were born inside `coordinator/pool.rs` hard-wired to
//! `std::sync::mpsc`; they live here now so every transport speaks the
//! same vocabulary. In-process lanes move them as Rust values (the
//! zero-copy `Arc` handoff the oracle path depends on); the TCP lane
//! serializes them with the little-endian codecs below. Serialization
//! is **exact**: f32/f64 values travel as raw bit patterns, so a
//! payload decoded on the far side is bit-identical to the value sent
//! — the loopback twin test pins the whole pipeline on this.
//!
//! # The zero-copy codec
//!
//! Serialization never assembles a message into a fresh `Vec`. An
//! [`Emit`] writes the small *meta* bytes (tags, counts, ids, losses,
//! literal bits) into a caller-recycled scratch buffer and records a
//! *cut* wherever a payload-sized blob (an encoded [`WireSlice`])
//! belongs; [`WireCuts::write`] then ships header, meta segments, and
//! borrowed blobs with one vectored write — the multi-megabyte sync
//! payloads go from encoder arena to socket without ever being copied
//! into a message buffer. Literal elements are read by borrow
//! (`Literal::as_slice`), killing the old `to_vec::<f32>` staging
//! allocation. The receive side parses straight out of one pooled
//! frame buffer: every encoded payload comes back as a [`WireSlice`]
//! sub-range of that buffer, so a 4-replica report is one read and
//! zero per-replica copies.
//!
//! The retired copying serializer is kept verbatim in the in-test
//! [`retired`] module as the byte oracle: the wire format is
//! unchanged, and the property tests pin the two byte-identical
//! across the codec's corner cases.

use std::io::Write;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::frame::{
    header_bytes, write_all_vectored, FrameHeader, MsgKind, WireBuf, WireSlice, HEADER_LEN,
    MAX_PAYLOAD,
};

/// Literal adopt list: (leaf index, shared literal) pairs every replica
/// applies before its next inner step.
pub type Adopt = Vec<(usize, Arc<xla::Literal>)>;

/// One broadcast as it leaves the coordinator.
#[derive(Clone)]
pub enum Broadcast {
    /// Identity down-wire (and Data-Parallel): deduplicated `Arc`
    /// literal handoff — zero-copy, one upload per leaf run-wide.
    Literals(Adopt),
    /// Lossy down-wire: the fragment's single encoded payload, one
    /// buffer `Arc`-shared by every worker; each decodes it into its
    /// shared snapshot.
    Encoded {
        frag: Option<usize>,
        bytes: WireSlice,
    },
    /// Lossy down-wire, streamed ahead of this command: the payload
    /// already went out as its own `Bcast` frame (flushed shard by
    /// shard, overlapping encode with the socket) and the worker
    /// stashed it; this marker tells it which fragment to resolve.
    /// Never crosses the in-process lane — streaming is a socket
    /// optimization, and the oracle path must stay byte-for-byte the
    /// pre-streaming pipeline.
    Pending { frag: Option<usize> },
}

impl Broadcast {
    pub fn empty() -> Broadcast {
        Broadcast::Literals(Vec::new())
    }
}

/// What the coordinator told the workers to produce at segment end.
#[derive(Debug, Clone)]
pub struct EncodeSpec {
    /// Streaming fragment due at the boundary (None = full sync).
    pub frag: Option<usize>,
    /// 0-based outer-sync index (stochastic-rounding seed component).
    pub sync_index: u64,
    /// Coordinator opt-in for the streamed up-leg: when set, links
    /// that can stream ship the contribution as `ContribChunk` frames
    /// ahead of a `SyncPayload::Streamed` report; the collector feeds
    /// them into an arrival-pipelined reduce. Never set unless the
    /// collector accepts chunks — a chunk at a one-shot collector is
    /// a protocol error.
    pub stream: bool,
}

/// What a segment's boundary asks of the workers. Merge-only
/// boundaries (and the drain's main segment) ask for nothing — the
/// coordinator would discard it, so the workers never build it.
#[derive(Debug, Clone)]
pub enum PayloadSpec {
    /// No payload crosses at this boundary.
    None,
    /// Current parameter literal handles (identity up-wire sends, and
    /// every Data-Parallel segment — its boundary eval reads them).
    Params,
    /// Encoded wire contribution for the due fragment (lossy up-wire).
    Encoded(EncodeSpec),
}

/// One replica's contribution at a segment boundary.
pub enum SyncPayload {
    /// Data-Parallel (and identity up-wire sends): current parameter
    /// literal handles.
    Params(Vec<Arc<xla::Literal>>),
    /// DiLoCo lossy up-wire: the encoded contribution for the due
    /// fragment, as a view of a recycled wire buffer (on the receive
    /// side, of the report's single frame buffer — many replicas, one
    /// buffer, zero copies).
    Encoded(WireSlice),
    /// The boundary asked for nothing ([`PayloadSpec::None`]) —
    /// consuming this anywhere is a coordinator bug and fails loud.
    Skipped,
    /// DiLoCo lossy up-wire, streamed ahead of this report: the
    /// contribution already went out as `ContribChunk` frames (flushed
    /// shard by shard, overlapping encode with the socket) and the
    /// coordinator's arrival tracker has the bytes; this marker just
    /// closes the stream. Lanes are FIFO, so a report carrying this
    /// tag proves every chunk before it has arrived. Never crosses the
    /// in-process lane — streaming is a socket optimization, and the
    /// oracle path must stay byte-for-byte the pre-streaming pipeline.
    Streamed,
}

/// Per-segment result: `losses[r]` / `payloads[r]` for replica r.
pub type SegmentData = (Vec<Vec<f64>>, Vec<SyncPayload>);

/// Membership changes taking effect at a segment's dispatch, in
/// application order: `deaths` freeze their replicas *before* the
/// broadcast is adopted (a crashed/left replica never sees a merge it
/// missed), then live replicas adopt the broadcast, then `joins` come
/// alive initialized from the current broadcast view — either
/// `join_view` (full-leaf literal list the coordinator built from the
/// global; identity wires, where workers keep no snapshot) or the
/// worker's own decoded snapshot (lossy wires — which also hands the
/// joiner the down-wire EF stream state for free, since the snapshot
/// *is* that stream's decode state).
#[derive(Clone, Default)]
pub struct SegmentChurn {
    pub deaths: Vec<usize>,
    pub joins: Vec<usize>,
    pub join_view: Adopt,
}

impl SegmentChurn {
    pub fn is_empty(&self) -> bool {
        self.deaths.is_empty() && self.joins.is_empty()
    }
}

/// A coordinator→worker command.
pub enum Cmd {
    /// Apply membership changes and the broadcast, run steps
    /// (from, to], then build the boundary payload `payload` asks for.
    Run {
        from: usize,
        to: usize,
        broadcast: Broadcast,
        payload: PayloadSpec,
        churn: SegmentChurn,
    },
    /// Spent wire buffers from a completed reduce, returned for this
    /// worker's encode pool. No reply — the worker absorbs them
    /// between segments. Never serialized: shipping empty buffers
    /// across a socket to save the far side an allocation would cost
    /// more than it saves, so socket transports recycle locally
    /// instead of sending these.
    Spares(Vec<WireBuf>),
    /// Apply the final broadcast and exit, returning replica ownership.
    Finish { broadcast: Broadcast },
}

/// A worker's answer to one `Cmd::Run`.
pub struct WorkerReport {
    /// (replica id, per-step losses, boundary sync payload).
    pub reps: Vec<(usize, Vec<f64>, SyncPayload)>,
}

// ---- byte serialization ----------------------------------------------
//
// Everything little-endian; floats as raw bit patterns (exactness is
// load-bearing). Containers are u32-counted — MAX_PAYLOAD bounds any
// single frame long before u32 does.

/// The zero-copy emitter: meta bytes append to a recycled scratch,
/// payload blobs are recorded as cuts (scratch offset + borrowed
/// slice) to be interleaved at write time.
struct Emit<'m, 's> {
    meta: &'s mut Vec<u8>,
    cuts: Vec<(usize, &'m [u8])>,
}

impl<'m> Emit<'m, '_> {
    fn u8(&mut self, v: u8) {
        self.meta.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.meta.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.meta.extend_from_slice(&v.to_le_bytes());
    }

    fn count(&mut self, v: usize) -> Result<()> {
        let v = u32::try_from(v).map_err(|_| anyhow!("msg: count {v} exceeds u32"))?;
        self.u32(v);
        Ok(())
    }

    /// Length-prefixed bytes, copied into the meta scratch — for small
    /// fields only; payload-sized data must use [`Emit::blob`].
    fn bytes(&mut self, b: &[u8]) -> Result<()> {
        self.count(b.len())?;
        self.meta.extend_from_slice(b);
        Ok(())
    }

    /// Length-prefixed bytes, **borrowed**: the prefix goes into the
    /// meta scratch, the blob itself is stitched in at write time —
    /// zero copies between the encoder's buffer and the socket.
    fn blob(&mut self, b: &'m [u8]) -> Result<()> {
        self.count(b.len())?;
        self.cuts.push((self.meta.len(), b));
        Ok(())
    }

    fn opt_frag(&mut self, frag: Option<usize>) -> Result<()> {
        match frag {
            Some(f) => {
                self.u8(1);
                self.count(f)?;
            }
            None => self.u8(0),
        }
        Ok(())
    }

    /// Literal bits straight off the borrowed element buffer — no
    /// `to_vec` staging allocation.
    fn literal(&mut self, lit: &xla::Literal) -> Result<()> {
        let shape = lit.array_shape()?;
        let dims = shape.dims();
        self.count(dims.len())?;
        for &d in dims {
            self.u64(u64::try_from(d).map_err(|_| anyhow!("msg: negative dim {d}"))?);
        }
        let data: &[f32] = lit.as_slice()?;
        self.count(data.len())?;
        self.meta.reserve(data.len() * 4);
        for v in data {
            self.meta.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        Ok(())
    }

    fn adopt(&mut self, list: &Adopt) -> Result<()> {
        self.count(list.len())?;
        for (leaf, lit) in list {
            self.count(*leaf)?;
            self.literal(lit)?;
        }
        Ok(())
    }

    fn broadcast(&mut self, b: &'m Broadcast) -> Result<()> {
        match b {
            Broadcast::Literals(list) => {
                self.u8(0);
                self.adopt(list)
            }
            Broadcast::Encoded { frag, bytes } => {
                self.u8(1);
                self.opt_frag(*frag)?;
                self.blob(bytes.as_slice())
            }
            Broadcast::Pending { frag } => {
                self.u8(2);
                self.opt_frag(*frag)
            }
        }
    }

    fn payload_spec(&mut self, p: &PayloadSpec) -> Result<()> {
        match p {
            PayloadSpec::None => self.u8(0),
            PayloadSpec::Params => self.u8(1),
            PayloadSpec::Encoded(spec) => {
                self.u8(2);
                self.opt_frag(spec.frag)?;
                self.u64(spec.sync_index);
                self.u8(spec.stream as u8);
            }
        }
        Ok(())
    }

    fn churn(&mut self, c: &SegmentChurn) -> Result<()> {
        self.count(c.deaths.len())?;
        for &d in &c.deaths {
            self.count(d)?;
        }
        self.count(c.joins.len())?;
        for &j in &c.joins {
            self.count(j)?;
        }
        self.adopt(&c.join_view)
    }

    fn sync_payload(&mut self, p: &'m SyncPayload) -> Result<()> {
        match p {
            SyncPayload::Params(lits) => {
                self.u8(0);
                self.count(lits.len())?;
                for lit in lits {
                    self.literal(lit)?;
                }
                Ok(())
            }
            SyncPayload::Encoded(bytes) => {
                self.u8(1);
                self.blob(bytes.as_slice())
            }
            SyncPayload::Skipped => {
                self.u8(2);
                Ok(())
            }
            SyncPayload::Streamed => {
                self.u8(3);
                Ok(())
            }
        }
    }
}

/// A serialized message body: the blob cut list over a meta scratch
/// the caller recycles. Ship it with [`WireCuts::write`] — one
/// vectored write of header + meta segments + borrowed blobs.
pub struct WireCuts<'m> {
    cuts: Vec<(usize, &'m [u8])>,
    blob_len: usize,
}

impl WireCuts<'_> {
    /// Payload length this body frames to (meta + blobs).
    pub fn payload_len(&self, meta: &[u8]) -> usize {
        meta.len() + self.blob_len
    }

    /// The payload as its ordered borrowed segments — meta runs
    /// interleaved with blobs, exactly what a vectored write ships
    /// after the header. The lane reactor consumes this form so it can
    /// resume a nonblocking write mid-message.
    pub fn parts<'a>(&'a self, meta: &'a [u8]) -> Vec<&'a [u8]> {
        let mut parts: Vec<&[u8]> = Vec::with_capacity(self.cuts.len() * 2 + 1);
        let mut prev = 0usize;
        for (off, blob) in &self.cuts {
            parts.push(&meta[prev..*off]);
            parts.push(blob);
            prev = *off;
        }
        parts.push(&meta[prev..]);
        parts
    }

    /// Write the complete frame — header stamped from `h` with this
    /// body's payload length — as one vectored write. Returns the
    /// framed byte count (header included).
    pub fn write(&self, w: &mut impl Write, h: &FrameHeader, meta: &[u8]) -> Result<u64> {
        let payload_len = self.payload_len(meta);
        let hdr = header_bytes(h, payload_len)?;
        let mut parts: Vec<&[u8]> = Vec::with_capacity(self.cuts.len() * 2 + 2);
        parts.push(&hdr);
        parts.extend(self.parts(meta));
        write_all_vectored(w, &parts)?;
        Ok((HEADER_LEN + payload_len) as u64)
    }

    /// The assembled payload as one contiguous vector — test/oracle
    /// use only (the hot path never materializes this).
    pub fn to_bytes(&self, meta: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.payload_len(meta));
        for part in self.parts(meta) {
            out.extend_from_slice(part);
        }
        out
    }
}

/// Serialize a command: meta into the recycled `scratch` (cleared
/// here), blobs borrowed from `cmd`. Returns the frame kind it travels
/// under and the cut list. `Spares` is deliberately unencodable (see
/// [`Cmd::Spares`]).
pub fn cmd_wire<'m>(cmd: &'m Cmd, scratch: &mut Vec<u8>) -> Result<(MsgKind, WireCuts<'m>)> {
    scratch.clear();
    let mut e = Emit {
        meta: scratch,
        cuts: Vec::new(),
    };
    let kind = match cmd {
        Cmd::Run {
            from,
            to,
            broadcast,
            payload,
            churn,
        } => {
            e.u64(*from as u64);
            e.u64(*to as u64);
            e.broadcast(broadcast)?;
            e.payload_spec(payload)?;
            e.churn(churn)?;
            MsgKind::Run
        }
        Cmd::Finish { broadcast } => {
            e.broadcast(broadcast)?;
            MsgKind::Finish
        }
        Cmd::Spares(_) => bail!("msg: Spares never crosses a serialized transport"),
    };
    Ok((
        kind,
        WireCuts {
            blob_len: e.cuts.iter().map(|(_, b)| b.len()).sum(),
            cuts: e.cuts,
        },
    ))
}

/// Serialize a worker report (meta into recycled `scratch`, encoded
/// sync payloads as borrowed blobs).
pub fn report_wire<'m>(report: &'m WorkerReport, scratch: &mut Vec<u8>) -> Result<WireCuts<'m>> {
    scratch.clear();
    let mut e = Emit {
        meta: scratch,
        cuts: Vec::new(),
    };
    e.count(report.reps.len())?;
    for (rid, losses, payload) in &report.reps {
        e.count(*rid)?;
        e.count(losses.len())?;
        for &l in losses {
            e.u64(l.to_bits());
        }
        e.sync_payload(payload)?;
    }
    Ok(WireCuts {
        blob_len: e.cuts.iter().map(|(_, b)| b.len()).sum(),
        cuts: e.cuts,
    })
}

/// Bounds-checked little-endian reader: every truncation is a clean
/// `Err`, never a slice panic.
struct Rd<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Rd<'a> {
        Rd { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .at
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow!(
                    "msg: truncated payload (need {n} bytes at offset {}, have {})",
                    self.at,
                    self.buf.len() - self.at.min(self.buf.len())
                )
            })?;
        let s = &self.buf[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn count(&mut self) -> Result<usize> {
        let n = self.u32()? as usize;
        // a count can never describe more bytes than a frame may hold
        if n > MAX_PAYLOAD {
            bail!("msg: count {n} exceeds any legal payload");
        }
        Ok(n)
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.count()?;
        Ok(self.take(n)?.to_vec())
    }

    /// Length-prefixed bytes as a zero-copy view of the frame buffer
    /// this reader walks. `src` must be the very buffer the reader was
    /// built over (`Rd::new(src.payload())`), so reader offsets are
    /// payload offsets.
    fn blob(&mut self, src: &Arc<WireBuf>) -> Result<WireSlice> {
        let n = self.count()?;
        let start = self.at;
        self.take(n)?;
        Ok(WireSlice::part(Arc::clone(src), start..start + n))
    }

    fn opt_frag(&mut self) -> Result<Option<usize>> {
        Ok(match self.u8()? {
            0 => None,
            _ => Some(self.count()?),
        })
    }

    fn literal(&mut self) -> Result<Arc<xla::Literal>> {
        let ndims = self.count()?;
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            dims.push(i64::try_from(self.u64()?).map_err(|_| anyhow!("msg: dim exceeds i64"))?);
        }
        let n = self.count()?;
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(f32::from_bits(self.u32()?));
        }
        Ok(Arc::new(xla::Literal::vec1(&data).reshape(&dims)?))
    }

    fn adopt(&mut self) -> Result<Adopt> {
        let n = self.count()?;
        let mut list = Vec::with_capacity(n);
        for _ in 0..n {
            let leaf = self.count()?;
            list.push((leaf, self.literal()?));
        }
        Ok(list)
    }

    fn done(&self) -> Result<()> {
        if self.at != self.buf.len() {
            bail!(
                "msg: {} trailing bytes after a complete message",
                self.buf.len() - self.at
            );
        }
        Ok(())
    }
}

fn read_broadcast(rd: &mut Rd, src: &Arc<WireBuf>) -> Result<Broadcast> {
    Ok(match rd.u8()? {
        0 => Broadcast::Literals(rd.adopt()?),
        1 => Broadcast::Encoded {
            frag: rd.opt_frag()?,
            bytes: rd.blob(src)?,
        },
        2 => Broadcast::Pending {
            frag: rd.opt_frag()?,
        },
        t => bail!("msg: unknown broadcast tag {t}"),
    })
}

fn read_payload_spec(rd: &mut Rd) -> Result<PayloadSpec> {
    Ok(match rd.u8()? {
        0 => PayloadSpec::None,
        1 => PayloadSpec::Params,
        2 => PayloadSpec::Encoded(EncodeSpec {
            frag: rd.opt_frag()?,
            sync_index: rd.u64()?,
            stream: match rd.u8()? {
                0 => false,
                1 => true,
                t => bail!("msg: bad stream flag {t}"),
            },
        }),
        t => bail!("msg: unknown payload-spec tag {t}"),
    })
}

fn read_churn(rd: &mut Rd) -> Result<SegmentChurn> {
    let n = rd.count()?;
    let mut deaths = Vec::with_capacity(n);
    for _ in 0..n {
        deaths.push(rd.count()?);
    }
    let n = rd.count()?;
    let mut joins = Vec::with_capacity(n);
    for _ in 0..n {
        joins.push(rd.count()?);
    }
    Ok(SegmentChurn {
        deaths,
        joins,
        join_view: rd.adopt()?,
    })
}

fn read_sync_payload(rd: &mut Rd, src: &Arc<WireBuf>) -> Result<SyncPayload> {
    Ok(match rd.u8()? {
        0 => {
            let n = rd.count()?;
            let mut lits = Vec::with_capacity(n);
            for _ in 0..n {
                lits.push(rd.literal()?);
            }
            SyncPayload::Params(lits)
        }
        1 => SyncPayload::Encoded(rd.blob(src)?),
        2 => SyncPayload::Skipped,
        3 => SyncPayload::Streamed,
        t => bail!("msg: unknown sync-payload tag {t}"),
    })
}

/// Deserialize a command straight out of a received frame buffer.
/// Encoded broadcast bytes come back as a zero-copy view of `buf`.
pub fn cmd_from_wire(kind: MsgKind, buf: &Arc<WireBuf>) -> Result<Cmd> {
    let mut rd = Rd::new(buf.payload());
    let cmd = match kind {
        MsgKind::Run => {
            let from = rd.u64()? as usize;
            let to = rd.u64()? as usize;
            let broadcast = read_broadcast(&mut rd, buf)?;
            let payload = read_payload_spec(&mut rd)?;
            let churn = read_churn(&mut rd)?;
            Cmd::Run {
                from,
                to,
                broadcast,
                payload,
                churn,
            }
        }
        MsgKind::Finish => Cmd::Finish {
            broadcast: read_broadcast(&mut rd, buf)?,
        },
        other => bail!("msg: frame kind {other:?} is not a command"),
    };
    rd.done()?;
    Ok(cmd)
}

/// Deserialize a worker report straight out of a received frame
/// buffer: every replica's encoded payload is a sub-range view of the
/// one buffer — one socket read, zero per-replica copies.
pub fn report_from_wire(buf: &Arc<WireBuf>) -> Result<WorkerReport> {
    let mut rd = Rd::new(buf.payload());
    let n = rd.count()?;
    let mut reps = Vec::with_capacity(n);
    for _ in 0..n {
        let rid = rd.count()?;
        let nl = rd.count()?;
        let mut losses = Vec::with_capacity(nl);
        for _ in 0..nl {
            losses.push(f64::from_bits(rd.u64()?));
        }
        reps.push((rid, losses, read_sync_payload(&mut rd, buf)?));
    }
    rd.done()?;
    Ok(WorkerReport { reps })
}

/// Byte length of the meta prefix a `ContribChunk` payload carries
/// ahead of the chunk bytes: `u32` replica id + `u32` wire-byte offset.
pub const CONTRIB_META_LEN: usize = 8;

/// Build the `ContribChunk` meta prefix. The chunk's wire range is
/// `offset..offset + chunk_len`; both ride little-endian as `u32` (a
/// contribution is bounded by `MAX_PAYLOAD` long before `u32`).
pub fn contrib_chunk_meta(rid: usize, offset: usize) -> Result<[u8; CONTRIB_META_LEN]> {
    let rid = u32::try_from(rid).map_err(|_| anyhow!("msg: replica id {rid} exceeds u32"))?;
    let off = u32::try_from(offset).map_err(|_| anyhow!("msg: chunk offset {offset} exceeds u32"))?;
    let mut meta = [0u8; CONTRIB_META_LEN];
    meta[..4].copy_from_slice(&rid.to_le_bytes());
    meta[4..].copy_from_slice(&off.to_le_bytes());
    Ok(meta)
}

/// Parse a `ContribChunk` frame buffer: `(replica id, wire-byte
/// offset, chunk bytes)` — the chunk comes back as a zero-copy view of
/// the frame buffer, ready to park in the arrival tracker unmoved.
pub fn contrib_chunk_from_wire(buf: &Arc<WireBuf>) -> Result<(usize, usize, WireSlice)> {
    let payload = buf.payload();
    if payload.len() < CONTRIB_META_LEN {
        bail!(
            "msg: contrib chunk payload of {} bytes is shorter than its meta prefix",
            payload.len()
        );
    }
    let rid = u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize;
    let off = u32::from_le_bytes([payload[4], payload[5], payload[6], payload[7]]) as usize;
    let chunk = WireSlice::part(Arc::clone(buf), CONTRIB_META_LEN..payload.len());
    Ok((rid, off, chunk))
}

/// Compat/test parser over a bare byte slice (copies it into a fresh
/// frame buffer first — the hot path uses [`cmd_from_wire`]).
pub fn cmd_from_frame(kind: MsgKind, payload: &[u8]) -> Result<Cmd> {
    cmd_from_wire(kind, &Arc::new(WireBuf::from_payload(payload)))
}

/// Compat/test parser over a bare byte slice (see [`cmd_from_frame`]).
pub fn report_from_payload(payload: &[u8]) -> Result<WorkerReport> {
    report_from_wire(&Arc::new(WireBuf::from_payload(payload)))
}

/// Handshake Hello payload: the replica ids this worker claims.
/// (Handshakes run once per connection — plain copying serialization.)
pub fn hello_payload(claims: &[usize], out: &mut Vec<u8>) -> Result<()> {
    let mut e = Emit {
        meta: out,
        cuts: Vec::new(),
    };
    e.count(claims.len())?;
    for &r in claims {
        e.count(r)?;
    }
    Ok(())
}

pub fn hello_from_payload(payload: &[u8]) -> Result<Vec<usize>> {
    let mut rd = Rd::new(payload);
    let n = rd.count()?;
    let mut claims = Vec::with_capacity(n);
    for _ in 0..n {
        claims.push(rd.count()?);
    }
    rd.done()?;
    Ok(claims)
}

/// Handshake Welcome payload: engine kind, initial liveness over the
/// replica universe, and the coordinator's run config JSON (the source
/// of truth the worker rebuilds from).
pub fn welcome_payload(
    engine: u8,
    live: &[bool],
    config_json: &str,
    out: &mut Vec<u8>,
) -> Result<()> {
    let mut e = Emit {
        meta: out,
        cuts: Vec::new(),
    };
    e.u8(engine);
    e.count(live.len())?;
    for &l in live {
        e.u8(l as u8);
    }
    e.bytes(config_json.as_bytes())
}

pub fn welcome_from_payload(payload: &[u8]) -> Result<(u8, Vec<bool>, String)> {
    let mut rd = Rd::new(payload);
    let engine = rd.u8()?;
    let n = rd.count()?;
    let live = rd.take(n)?.iter().map(|&b| b != 0).collect();
    let config = String::from_utf8(rd.bytes()?)
        .map_err(|_| anyhow!("msg: welcome config is not UTF-8"))?;
    rd.done()?;
    Ok((engine, live, config))
}

/// The retired copying serializer, kept verbatim as the wire-format
/// oracle: it assembles each message into one contiguous `Vec` with
/// per-literal `to_vec` staging — exactly what shipped before the
/// zero-copy codec. The property tests pin the zero-copy output
/// byte-identical to this, so any accidental format drift fails loud.
#[cfg(test)]
pub(crate) mod retired {
    use super::*;

    fn put_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    fn put_usize(out: &mut Vec<u8>, v: usize) -> Result<()> {
        let v = u32::try_from(v).map_err(|_| anyhow!("msg: count {v} exceeds u32"))?;
        put_u32(out, v);
        Ok(())
    }

    fn put_bytes(out: &mut Vec<u8>, b: &[u8]) -> Result<()> {
        put_usize(out, b.len())?;
        out.extend_from_slice(b);
        Ok(())
    }

    fn put_opt_frag(out: &mut Vec<u8>, frag: Option<usize>) -> Result<()> {
        match frag {
            Some(f) => {
                out.push(1);
                put_usize(out, f)?;
            }
            None => out.push(0),
        }
        Ok(())
    }

    fn put_literal(out: &mut Vec<u8>, lit: &xla::Literal) -> Result<()> {
        let shape = lit.array_shape()?;
        let dims = shape.dims();
        put_usize(out, dims.len())?;
        for &d in dims {
            put_u64(
                out,
                u64::try_from(d).map_err(|_| anyhow!("msg: negative dim {d}"))?,
            );
        }
        let data = lit.to_vec::<f32>()?;
        put_usize(out, data.len())?;
        out.reserve(data.len() * 4);
        for v in data {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        Ok(())
    }

    fn put_adopt(out: &mut Vec<u8>, list: &Adopt) -> Result<()> {
        put_usize(out, list.len())?;
        for (leaf, lit) in list {
            put_usize(out, *leaf)?;
            put_literal(out, lit)?;
        }
        Ok(())
    }

    fn put_broadcast(out: &mut Vec<u8>, b: &Broadcast) -> Result<()> {
        match b {
            Broadcast::Literals(list) => {
                out.push(0);
                put_adopt(out, list)
            }
            Broadcast::Encoded { frag, bytes } => {
                out.push(1);
                put_opt_frag(out, *frag)?;
                put_bytes(out, bytes.as_slice())
            }
            Broadcast::Pending { frag } => {
                out.push(2);
                put_opt_frag(out, *frag)
            }
        }
    }

    fn put_payload_spec(out: &mut Vec<u8>, p: &PayloadSpec) -> Result<()> {
        match p {
            PayloadSpec::None => out.push(0),
            PayloadSpec::Params => out.push(1),
            PayloadSpec::Encoded(spec) => {
                out.push(2);
                put_opt_frag(out, spec.frag)?;
                put_u64(out, spec.sync_index);
                out.push(spec.stream as u8);
            }
        }
        Ok(())
    }

    fn put_churn(out: &mut Vec<u8>, c: &SegmentChurn) -> Result<()> {
        put_usize(out, c.deaths.len())?;
        for &d in &c.deaths {
            put_usize(out, d)?;
        }
        put_usize(out, c.joins.len())?;
        for &j in &c.joins {
            put_usize(out, j)?;
        }
        put_adopt(out, &c.join_view)
    }

    fn put_sync_payload(out: &mut Vec<u8>, p: &SyncPayload) -> Result<()> {
        match p {
            SyncPayload::Params(lits) => {
                out.push(0);
                put_usize(out, lits.len())?;
                for lit in lits {
                    put_literal(out, lit)?;
                }
            }
            SyncPayload::Encoded(bytes) => {
                out.push(1);
                put_bytes(out, bytes.as_slice())?;
            }
            SyncPayload::Skipped => out.push(2),
            SyncPayload::Streamed => out.push(3),
        }
        Ok(())
    }

    /// Serialize a command into `out`; returns the frame kind it
    /// travels under.
    pub fn cmd_payload(cmd: &Cmd, out: &mut Vec<u8>) -> Result<MsgKind> {
        match cmd {
            Cmd::Run {
                from,
                to,
                broadcast,
                payload,
                churn,
            } => {
                put_u64(out, *from as u64);
                put_u64(out, *to as u64);
                put_broadcast(out, broadcast)?;
                put_payload_spec(out, payload)?;
                put_churn(out, churn)?;
                Ok(MsgKind::Run)
            }
            Cmd::Finish { broadcast } => {
                put_broadcast(out, broadcast)?;
                Ok(MsgKind::Finish)
            }
            Cmd::Spares(_) => bail!("msg: Spares never crosses a serialized transport"),
        }
    }

    /// Serialize a worker report.
    pub fn report_payload(report: &WorkerReport, out: &mut Vec<u8>) -> Result<()> {
        put_usize(out, report.reps.len())?;
        for (rid, losses, payload) in &report.reps {
            put_usize(out, *rid)?;
            put_usize(out, losses.len())?;
            for &l in losses {
                put_u64(out, l.to_bits());
            }
            put_sync_payload(out, payload)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lit(shape: &[i64], vals: &[f32]) -> Arc<xla::Literal> {
        Arc::new(xla::Literal::vec1(vals).reshape(shape).unwrap())
    }

    fn cmd_bytes(cmd: &Cmd) -> (MsgKind, Vec<u8>) {
        let mut scratch = Vec::new();
        let (kind, cuts) = cmd_wire(cmd, &mut scratch).unwrap();
        (kind, cuts.to_bytes(&scratch))
    }

    fn report_bytes(report: &WorkerReport) -> Vec<u8> {
        let mut scratch = Vec::new();
        let cuts = report_wire(report, &mut scratch).unwrap();
        cuts.to_bytes(&scratch)
    }

    #[test]
    fn run_cmd_roundtrips_bit_exact() {
        let cmd = Cmd::Run {
            from: 3,
            to: 9,
            broadcast: Broadcast::Literals(vec![
                (0, lit(&[2, 2], &[1.5, -0.0, f32::MIN_POSITIVE, 3.25])),
                (2, lit(&[3], &[0.1, 0.2, 0.3])),
            ]),
            payload: PayloadSpec::Encoded(EncodeSpec {
                frag: Some(1),
                sync_index: 42,
                stream: true,
            }),
            churn: SegmentChurn {
                deaths: vec![1],
                joins: vec![3],
                join_view: vec![(0, lit(&[1], &[7.0]))],
            },
        };
        let (kind, buf) = cmd_bytes(&cmd);
        assert_eq!(kind, MsgKind::Run);
        let back = cmd_from_frame(kind, &buf).unwrap();
        let Cmd::Run {
            from,
            to,
            broadcast,
            payload,
            churn,
        } = back
        else {
            panic!("wrong command kind");
        };
        assert_eq!((from, to), (3, 9));
        let Broadcast::Literals(list) = broadcast else {
            panic!("wrong broadcast kind");
        };
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].0, 0);
        // bit-exact, including the negative zero
        let v = list[0].1.to_vec::<f32>().unwrap();
        assert_eq!(
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            [1.5f32, -0.0, f32::MIN_POSITIVE, 3.25]
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<_>>()
        );
        assert_eq!(list[0].1.array_shape().unwrap().dims(), &[2, 2]);
        let PayloadSpec::Encoded(spec) = payload else {
            panic!("wrong payload spec");
        };
        assert_eq!((spec.frag, spec.sync_index), (Some(1), 42));
        assert!(spec.stream, "stream opt-in survives the wire");
        assert_eq!((churn.deaths, churn.joins), (vec![1], vec![3]));
        assert_eq!(churn.join_view.len(), 1);
    }

    #[test]
    fn finish_and_encoded_broadcast_roundtrip() {
        let cmd = Cmd::Finish {
            broadcast: Broadcast::Encoded {
                frag: None,
                bytes: WireSlice::copied_from(&[1, 2, 3, 255]),
            },
        };
        let (kind, buf) = cmd_bytes(&cmd);
        assert_eq!(kind, MsgKind::Finish);
        let Cmd::Finish {
            broadcast: Broadcast::Encoded { frag, bytes },
        } = cmd_from_frame(kind, &buf).unwrap()
        else {
            panic!("wrong shape back");
        };
        assert_eq!(frag, None);
        assert_eq!(bytes.as_slice(), &[1, 2, 3, 255]);
    }

    #[test]
    fn pending_broadcast_roundtrips() {
        let cmd = Cmd::Run {
            from: 0,
            to: 4,
            broadcast: Broadcast::Pending { frag: Some(7) },
            payload: PayloadSpec::None,
            churn: SegmentChurn::default(),
        };
        let (kind, buf) = cmd_bytes(&cmd);
        let Cmd::Run {
            broadcast: Broadcast::Pending { frag },
            ..
        } = cmd_from_frame(kind, &buf).unwrap()
        else {
            panic!("wrong shape back");
        };
        assert_eq!(frag, Some(7));
    }

    #[test]
    fn spares_never_serialize() {
        let cmd = Cmd::Spares(vec![WireBuf::new()]);
        assert!(cmd_wire(&cmd, &mut Vec::new()).is_err());
        assert!(retired::cmd_payload(&cmd, &mut Vec::new()).is_err());
    }

    #[test]
    fn report_roundtrips_losses_bit_exact() {
        let report = WorkerReport {
            reps: vec![
                (
                    0,
                    vec![1.0625, -2.5, f64::EPSILON],
                    SyncPayload::Encoded(WireSlice::copied_from(&[9, 8, 7])),
                ),
                (2, Vec::new(), SyncPayload::Skipped),
                (4, vec![0.0], SyncPayload::Params(vec![lit(&[2], &[1.0, 2.0])])),
                (6, vec![-1.5], SyncPayload::Streamed),
            ],
        };
        let buf = report_bytes(&report);
        let back = report_from_payload(&buf).unwrap();
        assert_eq!(back.reps.len(), 4);
        assert_eq!(back.reps[0].0, 0);
        assert_eq!(
            back.reps[0].1.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            report.reps[0].1.iter().map(|l| l.to_bits()).collect::<Vec<_>>()
        );
        let SyncPayload::Encoded(bytes) = &back.reps[0].2 else {
            panic!("wrong payload kind");
        };
        assert_eq!(bytes.as_slice(), &[9, 8, 7]);
        assert!(matches!(back.reps[1].2, SyncPayload::Skipped));
        let SyncPayload::Params(lits) = &back.reps[2].2 else {
            panic!("wrong payload kind");
        };
        assert_eq!(lits[0].to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert!(matches!(back.reps[3].2, SyncPayload::Streamed));
    }

    #[test]
    fn contrib_chunk_meta_roundtrips_as_frame_view() {
        let chunk: Vec<u8> = (0..37u8).collect();
        let mut payload = contrib_chunk_meta(3, 0x0102_0304).unwrap().to_vec();
        payload.extend_from_slice(&chunk);
        let frame = Arc::new(WireBuf::from_payload(&payload));
        let (rid, off, ws) = contrib_chunk_from_wire(&frame).unwrap();
        assert_eq!((rid, off), (3, 0x0102_0304));
        assert_eq!(ws.as_slice(), &chunk[..]);
        // zero-copy: the chunk must view the frame buffer itself
        assert!(Arc::ptr_eq(ws.buf(), &frame));
        // meta prefix shorter than 8 bytes fails loud
        let short = Arc::new(WireBuf::from_payload(&[1, 2, 3]));
        assert!(contrib_chunk_from_wire(&short).is_err());
    }

    #[test]
    fn report_payloads_share_the_frame_buffer() {
        // the receive-side zero-copy invariant: every replica's
        // encoded payload is a view of the ONE received frame buffer
        let report = WorkerReport {
            reps: vec![
                (0, vec![1.0], SyncPayload::Encoded(WireSlice::copied_from(&[1, 2, 3, 4]))),
                (1, vec![2.0], SyncPayload::Encoded(WireSlice::copied_from(&[5, 6]))),
            ],
        };
        let frame = Arc::new(WireBuf::from_payload(&report_bytes(&report)));
        let back = report_from_wire(&frame).unwrap();
        for (i, (_, _, p)) in back.reps.iter().enumerate() {
            let SyncPayload::Encoded(ws) = p else {
                panic!("wrong payload kind");
            };
            assert!(
                Arc::ptr_eq(ws.buf(), &frame),
                "replica {i} payload must view the frame buffer"
            );
        }
        let SyncPayload::Encoded(a) = &back.reps[0].2 else { unreachable!() };
        let SyncPayload::Encoded(b) = &back.reps[1].2 else { unreachable!() };
        assert_eq!(a.as_slice(), &[1, 2, 3, 4]);
        assert_eq!(b.as_slice(), &[5, 6]);
    }

    #[test]
    fn truncated_messages_reject_cleanly() {
        let buf = report_bytes(&WorkerReport {
            reps: vec![(
                1,
                vec![3.5, 4.5],
                SyncPayload::Encoded(WireSlice::copied_from(&[1, 2, 3])),
            )],
        });
        for cut in 0..buf.len() {
            assert!(
                report_from_payload(&buf[..cut]).is_err(),
                "cut at {cut} must reject"
            );
        }
        // trailing garbage rejects too
        let mut buf = buf;
        buf.push(0);
        assert!(report_from_payload(&buf).is_err());
    }

    #[test]
    fn handshake_payloads_roundtrip() {
        let mut buf = Vec::new();
        hello_payload(&[0, 2, 5], &mut buf).unwrap();
        assert_eq!(hello_from_payload(&buf).unwrap(), vec![0, 2, 5]);

        let mut buf = Vec::new();
        welcome_payload(1, &[true, false, true], "{\"seed\":17}", &mut buf).unwrap();
        let (engine, live, cfg) = welcome_from_payload(&buf).unwrap();
        assert_eq!(engine, 1);
        assert_eq!(live, vec![true, false, true]);
        assert_eq!(cfg, "{\"seed\":17}");
    }

    // ---- zero-copy vs retired-oracle property pins -------------------

    fn assert_cmd_matches_oracle(cmd: &Cmd, label: &str) {
        let (kind, zero_copy) = cmd_bytes(cmd);
        let mut oracle = Vec::new();
        let oracle_kind = retired::cmd_payload(cmd, &mut oracle).unwrap();
        assert_eq!(kind, oracle_kind, "{label}: kind");
        assert_eq!(zero_copy, oracle, "{label}: bytes");
    }

    fn assert_report_matches_oracle(report: &WorkerReport, label: &str) {
        let zero_copy = report_bytes(report);
        let mut oracle = Vec::new();
        retired::report_payload(report, &mut oracle).unwrap();
        assert_eq!(zero_copy, oracle, "{label}: bytes");
    }

    #[test]
    fn zero_copy_cmds_match_the_retired_oracle() {
        // empty-literal corner: zero elements, zero dims, rank-2 empty
        assert_cmd_matches_oracle(
            &Cmd::Run {
                from: 0,
                to: 1,
                broadcast: Broadcast::Literals(vec![
                    (0, lit(&[0], &[])),
                    (1, lit(&[2, 0], &[])),
                    (5, Arc::new(xla::Literal::vec1::<f32>(&[]))),
                ]),
                payload: PayloadSpec::Params,
                churn: SegmentChurn::default(),
            },
            "empty literals",
        );
        // empty-blob corner: a zero-length encoded broadcast
        assert_cmd_matches_oracle(
            &Cmd::Run {
                from: 7,
                to: 13,
                broadcast: Broadcast::Encoded {
                    frag: Some(0),
                    bytes: WireSlice::copied_from(&[]),
                },
                payload: PayloadSpec::Encoded(EncodeSpec {
                    frag: Some(0),
                    sync_index: u64::MAX,
                    stream: false,
                }),
                churn: SegmentChurn::default(),
            },
            "empty encoded broadcast",
        );
        // max-claim churn corner: every replica dying and joining at
        // once, with a multi-leaf join view
        assert_cmd_matches_oracle(
            &Cmd::Run {
                from: 100,
                to: 106,
                broadcast: Broadcast::Encoded {
                    frag: None,
                    bytes: WireSlice::copied_from(&(0..=255u8).collect::<Vec<_>>()),
                },
                payload: PayloadSpec::None,
                churn: SegmentChurn {
                    deaths: (0..64).collect(),
                    joins: (0..64).collect(),
                    join_view: (0..8)
                        .map(|l| (l, lit(&[3], &[l as f32, -0.0, f32::NAN])))
                        .collect(),
                },
            },
            "max churn",
        );
        // pending-broadcast corner (new tag, both frag arms)
        for frag in [None, Some(3)] {
            assert_cmd_matches_oracle(
                &Cmd::Run {
                    from: 1,
                    to: 2,
                    broadcast: Broadcast::Pending { frag },
                    payload: PayloadSpec::None,
                    churn: SegmentChurn::default(),
                },
                "pending broadcast",
            );
        }
        assert_cmd_matches_oracle(
            &Cmd::Finish {
                broadcast: Broadcast::Encoded {
                    frag: Some(1),
                    bytes: WireSlice::copied_from(&[42; 1000]),
                },
            },
            "finish",
        );
    }

    #[test]
    fn zero_copy_reports_match_the_retired_oracle() {
        // multi-fragment report corner: several replicas, mixed
        // payload kinds, bit-pattern-hostile losses
        assert_report_matches_oracle(
            &WorkerReport {
                reps: vec![
                    (
                        0,
                        vec![f64::NAN, -0.0, f64::INFINITY, f64::MIN_POSITIVE],
                        SyncPayload::Encoded(WireSlice::copied_from(&[0; 513])),
                    ),
                    (
                        3,
                        vec![1.0; 64],
                        SyncPayload::Encoded(WireSlice::copied_from(&[0xFF; 7])),
                    ),
                    (5, Vec::new(), SyncPayload::Skipped),
                    (7, vec![0.25], SyncPayload::Streamed),
                    (
                        9,
                        vec![2.5],
                        SyncPayload::Params(vec![lit(&[2, 2], &[1.0, 2.0, 3.0, 4.0]), lit(&[0], &[])]),
                    ),
                ],
            },
            "mixed report",
        );
        // empty report corner
        assert_report_matches_oracle(&WorkerReport { reps: Vec::new() }, "empty report");
        // sub-range blobs: payloads that are views into a shared buffer
        // (exactly what the reduce hands back) serialize identically
        let shared = Arc::new(WireBuf::from_payload(&(0..100u8).collect::<Vec<_>>()));
        assert_report_matches_oracle(
            &WorkerReport {
                reps: vec![
                    (0, vec![1.0], SyncPayload::Encoded(WireSlice::part(Arc::clone(&shared), 0..50))),
                    (1, vec![2.0], SyncPayload::Encoded(WireSlice::part(shared, 50..100))),
                ],
            },
            "shared-buffer report",
        );
    }
}
