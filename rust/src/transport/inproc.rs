//! In-process transport: `std::sync::mpsc` channels moving Rust
//! values — the default lane and the bit-identity oracle.
//!
//! This is exactly the worker pool's original message plane, wrapped
//! behind the [`Lane`]/[`WorkerLink`] traits: commands and reports
//! move by value (broadcast `Arc`s are cloned, buffers are moved), so
//! nothing is serialized and the zero-copy literal handoff survives.
//! `Spares` recycling works here and only here — across a socket the
//! buffers would cost more to ship than to reallocate.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

use anyhow::{anyhow, Result};

use super::msg::{Cmd, WorkerReport};
use super::{Lane, WorkerLink};

/// Coordinator end: command sender + report receiver.
pub struct InProcLane {
    tx: Sender<Cmd>,
    rx: Receiver<Result<WorkerReport>>,
}

/// Worker end: command receiver + report sender.
pub struct InProcWorkerLink {
    rx: Receiver<Cmd>,
    tx: Sender<Result<WorkerReport>>,
}

/// One connected lane/link pair.
pub fn pair() -> (InProcLane, InProcWorkerLink) {
    let (cmd_tx, cmd_rx) = channel::<Cmd>();
    let (res_tx, res_rx) = channel::<Result<WorkerReport>>();
    (
        InProcLane {
            tx: cmd_tx,
            rx: res_rx,
        },
        InProcWorkerLink {
            rx: cmd_rx,
            tx: res_tx,
        },
    )
}

impl Lane for InProcLane {
    fn send(&mut self, cmd: Cmd) -> Result<()> {
        self.tx
            .send(cmd)
            .map_err(|_| anyhow!("in-proc lane: worker hung up"))
    }

    fn recv(&mut self) -> Result<Result<WorkerReport>> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("in-proc lane: worker died without reporting"))
    }

    fn try_recv(&mut self) -> Result<Option<Result<WorkerReport>>> {
        match self.rx.try_recv() {
            Ok(rep) => Ok(Some(rep)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                Err(anyhow!("in-proc lane: worker died without reporting"))
            }
        }
    }

    fn can_poll(&self) -> bool {
        true
    }
}

impl WorkerLink for InProcWorkerLink {
    fn recv_cmd(&mut self) -> Option<Cmd> {
        self.rx.recv().ok()
    }

    fn send_report(&mut self, report: Result<WorkerReport>) -> Result<()> {
        self.tx
            .send(report)
            .map_err(|_| anyhow!("in-proc link: coordinator hung up"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::msg::{Broadcast, PayloadSpec, SegmentChurn, SyncPayload};

    #[test]
    fn pair_moves_commands_and_reports() {
        let (mut lane, mut link) = pair();
        lane.send(Cmd::Run {
            from: 0,
            to: 2,
            broadcast: Broadcast::empty(),
            payload: PayloadSpec::None,
            churn: SegmentChurn::default(),
        })
        .unwrap();
        let Some(Cmd::Run { from, to, .. }) = link.recv_cmd() else {
            panic!("expected the Run command");
        };
        assert_eq!((from, to), (0, 2));
        link.send_report(Ok(WorkerReport {
            reps: vec![(0, vec![1.0, 2.0], SyncPayload::Skipped)],
        }))
        .unwrap();
        let report = lane.recv().unwrap().unwrap();
        assert_eq!(report.reps[0].1, vec![1.0, 2.0]);
    }

    #[test]
    fn try_recv_polls_without_blocking() {
        let (mut lane, mut link) = pair();
        assert!(lane.can_poll());
        assert!(lane.try_recv().unwrap().is_none(), "nothing sent yet");
        link.send_report(Ok(WorkerReport {
            reps: vec![(1, vec![3.0], SyncPayload::Skipped)],
        }))
        .unwrap();
        let rep = lane.try_recv().unwrap().expect("report is ready").unwrap();
        assert_eq!(rep.reps[0].0, 1);
        drop(link);
        assert!(lane.try_recv().is_err(), "hangup surfaces as a lane error");
    }

    #[test]
    fn closed_ends_surface_as_lane_errors() {
        let (mut lane, link) = pair();
        drop(link);
        assert!(lane.send(Cmd::Finish { broadcast: Broadcast::empty() }).is_err());
        assert!(lane.recv().is_err());

        let (lane, mut link) = pair();
        drop(lane);
        assert!(link.recv_cmd().is_none());
        assert!(link.send_report(Ok(WorkerReport { reps: Vec::new() })).is_err());
    }
}
